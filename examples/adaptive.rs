//! Adaptive control plane demo: the same straggler-heavy barrier-free
//! run with the knobs fixed vs. closed-loop, plus the live decision log.
//!
//!     cargo run --release --example adaptive
//!
//! Requires `make artifacts` first (or set VAFL_MOCK=1 to use the
//! pure-Rust mock model). The adaptive run starts from the *same* knobs
//! as the fixed one (buffer of 2, alpha 0.9, top-k budget 0.25) and lets
//! the telemetry-driven controllers retune them online: the staleness
//! controller moves `buffer_k`/`alpha(tau)` toward its staleness target,
//! and the compression controller moves `k_fraction` with the observed
//! error-feedback residual pressure.

use vafl::config::{
    AsyncEngineConfig, Backend, CompressionConfig, CompressionMode, ControlConfig, EngineMode,
};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    cfg.rounds = 40;
    cfg.target_acc = 0.5;
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    cfg.compression = CompressionConfig {
        mode: CompressionMode::TopK,
        k_fraction: 0.25,
        error_feedback: true,
        ..Default::default()
    };
    if std::env::var("VAFL_MOCK").is_ok() {
        cfg.backend = Backend::Mock;
    }

    let fixed = experiments::run(&cfg)?;

    let mut acfg = cfg.clone();
    acfg.control = ControlConfig { enabled: true, interval: 2, window: 8, ..Default::default() };
    let adaptive = experiments::run(&acfg)?;

    println!("\ndecision log ({} decisions):", adaptive.metrics.control_records.len());
    for d in &adaptive.metrics.control_records {
        match d.client {
            Some(c) => println!(
                "  flush {:>3} [vt {:>7.1}s] {:<11} migrate c{c}: shard {:.0} -> {:.0}  (skew {:.2})",
                d.round, d.vtime, d.controller, d.old, d.new, d.signal
            ),
            None => println!(
                "  flush {:>3} [vt {:>7.1}s] {:<11} {:<10} {:.4} -> {:.4}  (signal {:.4})",
                d.round, d.vtime, d.controller, d.knob, d.old, d.new, d.signal
            ),
        }
    }

    let line = |label: &str, out: &vafl::Outcome| {
        println!(
            "  {label:<10} best_acc={:.4}  uploads={:>4}  bytes_up={:>9.1}kB  bytes->{:.0}%={}  vtime->{:.0}%={}",
            out.best_accuracy,
            out.total_uploads,
            out.metrics.total_bytes_up() as f64 / 1e3,
            cfg.target_acc * 100.0,
            out.metrics
                .bytes_up_to_target()
                .map_or_else(|| "never".into(), |b| format!("{:.1}kB", b as f64 / 1e3)),
            cfg.target_acc * 100.0,
            out.metrics
                .vtime_to_target()
                .map_or_else(|| "never".into(), |v| format!("{v:.1}s")),
        );
    };
    println!("\nfixed knobs vs adaptive control (same seed, fleet, link):");
    line("fixed", &fixed);
    line("adaptive", &adaptive);
    Ok(())
}
