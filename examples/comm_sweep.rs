//! Client-count scaling study (the paper's §V-C claim: "the better VAFL
//! performs as the number of clients increases"): run VAFL vs AFL across
//! fleet sizes and report communication compression and accuracy.
//!
//! Run: `cargo run --release --example comm_sweep [-- rounds]`
//! Uses the mock backend by default for speed; set VAFL_PJRT=1 for the real
//! artifacts.

use vafl::config::{Algorithm, Backend};
use vafl::data::PartitionScheme;
use vafl::experiments;
use vafl::metrics::ccr;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let rounds: usize = std::env::args()
        .nth(1)
        .map_or(25, |s| s.parse().expect("rounds"));
    let pjrt = std::env::var("VAFL_PJRT").is_ok();

    println!("clients  afl_comms  vafl_comms  CCR      vafl_best_acc");
    println!("------------------------------------------------------");
    for &n in &[3usize, 5, 7, 11, 15] {
        let mut base = experiments::preset('b')?;
        base.num_clients = n;
        base.samples_per_client = 7000 / n.max(1);
        base.partition = PartitionScheme::PaperSkew;
        base.rounds = rounds;
        base.name = format!("n{n}");
        if !pjrt {
            base.backend = Backend::Mock;
            base.target_acc = 0.80; // the mock linear model tops out lower
        }
        let afl = experiments::run(&vafl::config::ExperimentConfig {
            algorithm: Algorithm::Afl,
            ..base.clone()
        })?;
        let va = experiments::run(&vafl::config::ExperimentConfig {
            algorithm: Algorithm::Vafl,
            ..base.clone()
        })?;
        let c0 = afl.comm_times_to_target.unwrap_or(afl.total_uploads);
        let c1 = va.comm_times_to_target.unwrap_or(va.total_uploads);
        println!(
            "{n:>7}  {c0:>9}  {c1:>10}  {:<8.4} {:.4}",
            ccr(c0, c1),
            va.best_accuracy
        );
    }
    Ok(())
}
