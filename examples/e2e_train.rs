//! End-to-end driver (EXPERIMENTS.md §E2E): the paper's hardest setting —
//! experiment d (7 heterogeneous clients, Non-IID shards) — trained for a
//! few hundred communication rounds through the full stack:
//!
//!   SynthDigits -> Non-IID partitioner -> simulated RPi/laptop fleet ->
//!   PJRT train/eval artifacts (JAX+Pallas AOT) -> VAFL coordinator ->
//!   metrics (loss/acc curves, comm counts, CCR vs AFL baseline).
//!
//! Run: `cargo run --release --example e2e_train [-- rounds [algo]]`
//! (defaults: 120 rounds, vafl). Writes curves to results/e2e/.

use vafl::config::Algorithm;
use vafl::experiments;
use vafl::metrics::csv::{write_client_acc_csv, write_rounds_csv};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let rounds: usize = args.first().map_or(120, |s| s.parse().expect("rounds"));
    let algo = args
        .get(1)
        .map(|s| Algorithm::from_name(s))
        .transpose()?
        .unwrap_or(Algorithm::Vafl);

    let mut cfg = experiments::preset('d')?;
    cfg.rounds = rounds;
    cfg.algorithm = algo;

    println!(
        "e2e: experiment d — {} clients, Non-IID, {} rounds, algorithm {}",
        cfg.num_clients,
        cfg.rounds,
        cfg.algorithm.name()
    );
    let t0 = std::time::Instant::now();
    let out = experiments::run(&cfg)?;
    let wall = t0.elapsed();

    println!("\nloss/accuracy curve (every 5th round):");
    println!("round  train_loss  test_loss  test_acc  uploads(cum)");
    for r in out.metrics.records.iter().filter(|r| r.round % 5 == 0 || r.round == 1) {
        println!(
            "{:>5}  {:>10.4}  {:>9.4}  {:>8.4}  {:>3} ({:>4})",
            r.round, r.train_loss, r.global_loss, r.global_acc, r.uploads, r.cum_uploads
        );
    }
    println!(
        "\nbest acc {:.4} | final acc {:.4} | uploads {} | comm->94% {:?}",
        out.best_accuracy, out.final_accuracy, out.total_uploads, out.comm_times_to_target
    );
    println!(
        "virtual time {:.1}s | straggler idle {:.1}s | wall {:.1}s",
        out.total_vtime,
        out.metrics.total_idle(),
        wall.as_secs_f64()
    );

    std::fs::create_dir_all("results/e2e")?;
    let base = format!("results/e2e/d_{}", cfg.algorithm.name());
    write_rounds_csv(&out.metrics, format!("{base}_rounds.csv"))?;
    write_client_acc_csv(&out.metrics, format!("{base}_clients.csv"))?;
    std::fs::write(format!("{base}.json"), out.metrics.to_json().to_string_pretty())?;
    println!("wrote {base}_rounds.csv / _clients.csv / .json");
    Ok(())
}
