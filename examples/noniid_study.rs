//! Non-IID severity study (the paper's §V-C claim: VAFL improves as "the
//! imbalance in the distribution of the dataset intensifies"): sweep the
//! Dirichlet concentration alpha from near-IID (alpha=10) to extreme label
//! skew (alpha=0.1) and compare VAFL's compression and accuracy against
//! AFL at each level.
//!
//! Run: `cargo run --release --example noniid_study [-- rounds]`
//! Mock backend by default; VAFL_PJRT=1 for the real artifacts.

use vafl::config::{Algorithm, Backend};
use vafl::data::stats::DistributionTable;
use vafl::data::synth::SynthConfig;
use vafl::data::{partition, PartitionScheme};
use vafl::experiments;
use vafl::metrics::ccr;
use vafl::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let rounds: usize = std::env::args()
        .nth(1)
        .map_or(25, |s| s.parse().expect("rounds"));
    let pjrt = std::env::var("VAFL_PJRT").is_ok();

    println!("alpha    skewness  afl_comms  vafl_comms  CCR      vafl_best_acc");
    println!("----------------------------------------------------------------");
    for &alpha in &[10.0, 1.0, 0.5, 0.2, 0.1] {
        let mut base = experiments::preset('b')?;
        base.partition = PartitionScheme::Dirichlet { alpha };
        base.rounds = rounds;
        base.name = format!("alpha{alpha}");
        if !pjrt {
            base.backend = Backend::Mock;
            base.target_acc = 0.75;
        }
        // Measure the skew the partitioner actually produced.
        let synth = SynthConfig { pixel_noise: base.pixel_noise, ..Default::default() };
        let (shards, _) = partition(
            base.partition,
            base.num_clients,
            base.samples_per_client,
            base.test_samples,
            &synth,
            &Rng::new(base.seed),
        );
        let skew = DistributionTable::from_shards(&shards).skewness();

        let afl = experiments::run(&vafl::config::ExperimentConfig {
            algorithm: Algorithm::Afl,
            ..base.clone()
        })?;
        let va = experiments::run(&vafl::config::ExperimentConfig {
            algorithm: Algorithm::Vafl,
            ..base.clone()
        })?;
        let c0 = afl.comm_times_to_target.unwrap_or(afl.total_uploads);
        let c1 = va.comm_times_to_target.unwrap_or(va.total_uploads);
        println!(
            "{alpha:<8} {skew:<9.3} {c0:>9}  {c1:>10}  {:<8.4} {:.4}",
            ccr(c0, c1),
            va.best_accuracy
        );
    }
    Ok(())
}
