//! Quickstart: run the paper's experiment `a` (3 IID clients) with VAFL
//! for a handful of rounds and print the accuracy curve and communication
//! counts.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` first (or set VAFL_MOCK=1 to use the pure-Rust
//! mock model).

use vafl::config::Backend;
use vafl::experiments;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    let mut cfg = experiments::preset('a')?;
    cfg.rounds = 15;
    if std::env::var("VAFL_MOCK").is_ok() {
        cfg.backend = Backend::Mock;
    }

    let out = experiments::run(&cfg)?;
    println!("\nround  acc     uploads(cum)");
    for r in &out.metrics.records {
        if r.global_acc.is_finite() {
            println!("{:>5}  {:.4}  {:>3} ({:>3})", r.round, r.global_acc, r.uploads, r.cum_uploads);
        }
    }
    println!(
        "\nbest acc {:.4} | total uploads {} | virtual time {:.1}s",
        out.best_accuracy, out.total_uploads, out.total_vtime
    );
    Ok(())
}
