//! Threaded fleet demo: every simulated edge client runs its local round
//! on its own OS thread against a shared PJRT executor service (the
//! paper's deployment shape — concurrent devices, one compute substrate,
//! serialized at the accelerator). Results are bit-identical to the
//! sequential engine: all randomness is per-client streams.
//!
//! Run: `cargo run --release --example threaded_fleet [-- rounds]`
//! (VAFL_MOCK=1 for the artifact-free mock model.)

use std::time::Instant;

use vafl::config::Backend;
use vafl::experiments;
use vafl::runtime::{ExecutorService, MockExecutor, PjrtRuntime};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    let rounds: usize = std::env::args()
        .nth(1)
        .map_or(8, |s| s.parse().expect("rounds"));
    let mock = std::env::var("VAFL_MOCK").is_ok();

    let mut cfg = experiments::preset('b')?;
    cfg.rounds = rounds;
    if mock {
        cfg.backend = Backend::Mock;
    }

    // Threaded run: 7 client threads sharing one executor service.
    let (mut server, _exec) = experiments::build(&cfg)?;
    let svc = if mock {
        ExecutorService::spawn(|| Ok(MockExecutor::standard()))?
    } else {
        ExecutorService::spawn(|| PjrtRuntime::load("artifacts"))?
    };
    let t0 = Instant::now();
    println!("round  acc     uploads  vtime     wall");
    for _ in 0..cfg.rounds {
        let r = server.run_round_threaded(&svc)?;
        println!(
            "{:>5}  {:.4}  {:>2}/7     {:>7.1}s  {:>6.1}s",
            r.round,
            r.global_acc,
            r.uploads,
            r.vtime,
            t0.elapsed().as_secs_f64()
        );
    }
    let threaded_metrics = server.metrics.clone();
    svc.shutdown();

    // Cross-check against the sequential engine (same seed -> bitwise
    // identical records).
    let (mut seq, mut exec) = experiments::build(&cfg)?;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    seq.run(exec.as_mut())?;
    let identical = threaded_metrics
        .records
        .iter()
        .zip(&seq.metrics.records)
        .all(|(a, b)| {
            a.global_acc.to_bits() == b.global_acc.to_bits() && a.selected == b.selected
        });
    println!(
        "\nthreaded == sequential (bitwise): {}",
        if identical { "YES" } else { "NO (bug!)" }
    );
    anyhow::ensure!(identical, "threaded/sequential divergence");
    Ok(())
}
