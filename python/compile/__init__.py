"""Build-time-only Python: L1 Pallas kernels + L2 JAX model + AOT lowering.

Nothing in this package is imported at runtime; ``make artifacts`` runs
``python -m compile.aot`` once and the Rust coordinator consumes the HLO
text artifacts through PJRT.
"""
