"""AOT lowering: JAX (L2, Pallas L1 inside) -> HLO text artifacts for Rust.

Run once at build time (``make artifacts``); Python never appears on the
training/request path. Emits into ``artifacts/``:

    train_step.hlo.txt   (params f32[P], x f32[B,784], y i32[B], lr f32[])
                         -> (new_params f32[P], loss f32[], grad f32[P])
    eval_step.hlo.txt    (params f32[P], x f32[EB,784], y i32[EB])
                         -> (correct f32[], loss_sum f32[])
    value.hlo.txt        (g_prev f32[P], g_new f32[P], acc f32[], n f32[])
                         -> V f32[]          (paper Eq. 1 on the HLO path)
    init_params.f32      raw little-endian f32[P] initial parameters
    params_spec.json     layout + shapes + cost model + artifact manifest

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``;
the Rust side unwraps the tuple.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lower(fn: Callable, specs: Sequence[jax.ShapeDtypeStruct]) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_artifacts(out_dir: str, seed: int = 0, pallas_mode: str = "head") -> dict:
    """Lower every entry point and write the artifact bundle to ``out_dir``."""
    os.makedirs(out_dir, exist_ok=True)
    p = model.PARAM_COUNT
    b, eb, d = model.BATCH_SIZE, model.EVAL_BATCH, model.INPUT_DIM

    artifacts = {}

    def emit(name: str, fn: Callable, specs) -> None:
        text = _lower(fn, specs)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        artifacts[name] = {"file": f"{name}.hlo.txt", "chars": len(text)}
        print(f"  {name}: {len(text)} chars -> {path}")

    emit(
        "train_step",
        lambda params, x, y, lr: model.train_step(
            params, x, y, lr, pallas_mode=pallas_mode
        ),
        (f32(p), f32(b, d), i32(b), f32()),
    )
    emit(
        "eval_step",
        lambda params, x, y: model.eval_step(params, x, y, pallas_mode=pallas_mode),
        (f32(p), f32(eb, d), i32(eb)),
    )
    emit("value", model.value_fn, (f32(p), f32(p), f32(), f32()))

    # Initial parameters (raw little-endian f32), identical for every client
    # at round 0 — the server broadcast of theta_0 in Algorithm 1.
    import numpy as np

    init = np.asarray(model.init_params(seed), dtype="<f4")
    init.tofile(os.path.join(out_dir, "init_params.f32"))

    spec = {
        "format_version": 1,
        "model": "resnet_lite",
        "param_count": p,
        "channels": model.CHANNELS,
        "input_dim": d,
        "image_dim": model.IMAGE_DIM,
        "num_classes": model.NUM_CLASSES,
        "batch_size": b,
        "eval_batch": eb,
        "seed": seed,
        "pallas_mode": pallas_mode,
        "train_step_flops": model.train_step_flops(),
        "eval_step_flops": model.eval_step_flops(),
        "layers": model.param_spec(),
        "artifacts": artifacts,
        "init_params_file": "init_params.f32",
    }
    with open(os.path.join(out_dir, "params_spec.json"), "w") as f:
        json.dump(spec, f, indent=2)
    print(f"  params_spec.json: P={p} params, batch={b}, eval_batch={eb}")
    return spec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0, help="init seed (theta_0)")
    ap.add_argument(
        "--pallas-mode",
        choices=model.PALLAS_MODES,
        default="head",
        help="kernel backend for the lowered artifacts (see model docstring)",
    )
    args = ap.parse_args()
    build_artifacts(args.out, seed=args.seed, pallas_mode=args.pallas_mode)


if __name__ == "__main__":
    main()
