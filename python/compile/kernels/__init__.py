"""L1: Pallas kernels for the VAFL client training hot spot.

``matmul`` — fused tiled matmul+bias+activation (differentiable, Pallas
fwd and bwd); ``conv`` — conv2d as im2col + the matmul kernel; ``ref`` —
pure-jnp oracles used by the pytest/hypothesis correctness suite.
"""

from . import conv, matmul, ref  # noqa: F401
