"""Conv2D lowered to im2col + the Pallas matmul kernel.

The paper's client model is a small ResNet; on TPU the standard mapping of
a 3x3 convolution is patch extraction (im2col) followed by an MXU matmul.
Patch extraction is pure data movement (linear, so JAX differentiates it
exactly); the matmul is the differentiable Pallas :func:`~.matmul.dense`
kernel, so conv fwd+bwd both run through Pallas.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from . import matmul as mk


def _extract_patches(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """NHWC -> [B, H, W, C*kh*kw] SAME-padded patches.

    ``conv_general_dilated_patches`` returns the feature dim ordered as
    ``C * kh * kw`` (channel-major); the weight layout below matches it.
    """
    b, h, w, c = x.shape
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return patches.reshape(b, h, w, c * kh * kw)


def conv2d_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    act: str = "none",
) -> jax.Array:
    """SAME 3x3 (or kh x kw) convolution, stride 1, fused bias+activation.

    Args:
      x: ``f32[B, H, W, Cin]``.
      w: ``f32[kh, kw, Cin, Cout]`` (HWIO).
      b: ``f32[Cout]``.
      act: "none" | "relu".

    Returns:
      ``f32[B, H, W, Cout]``.
    """
    bsz, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    if cin != cin2:
        raise ValueError(f"channel mismatch: x {x.shape} vs w {w.shape}")
    patches = _extract_patches(x, kh, kw)  # [B,H,W, Cin*kh*kw], channel-major
    cols = patches.reshape(bsz * h * wd, cin * kh * kw)
    # Reorder HWIO weights to the patches' channel-major (I, kh, kw) layout.
    wm = jnp.transpose(w, (2, 0, 1, 3)).reshape(cin * kh * kw, cout)
    y = mk.dense(cols, wm, b, act)
    return y.reshape(bsz, h, wd, cout)


def avg_pool_2x2(x: jax.Array) -> jax.Array:
    """2x2 average pooling, stride 2 (NHWC)."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))


def global_avg_pool(x: jax.Array) -> jax.Array:
    """NHWC -> [B, C] global average pooling."""
    return x.mean(axis=(1, 2))
