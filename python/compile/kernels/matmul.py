"""L1 Pallas kernels: fused tiled matmul (+ bias + activation).

This is the compute hot spot of the VAFL client training step: every conv
layer is lowered to im2col + this matmul (the canonical TPU mapping, see
DESIGN.md "Hardware adaptation"), and the classifier head calls it directly.

The kernel is written TPU-style -- the grid tiles (M, N) into MXU-shaped
blocks held in VMEM, with the full K dimension resident per block (K is
small for this model: <= 9*C). ``interpret=True`` is mandatory in this
image: the CPU PJRT plugin cannot execute Mosaic custom-calls, and the
interpret path lowers the kernel to plain HLO so that the AOT artifact runs
anywhere.

Because ``pallas_call`` has no automatic differentiation rule, the public
entry point :func:`dense` carries a ``jax.custom_vjp`` whose backward pass
is expressed with the *same* Pallas kernel (dX = dY @ W^T, dW = X^T @ dY),
so the whole fwd+bwd training step lowers through Pallas.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-shaped tile. M is padded up to a multiple of this; N and K
# stay un-tiled (both are <= 160 for this model) so each grid step performs
# one (BM, K) x (K, N) systolic pass with the accumulator in VMEM.
BLOCK_M = 128

# Activations the fused kernel understands.
ACTIVATIONS = ("none", "relu")


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    """One grid step: o = act(x @ w + b) for a (BM, K) x (K, N) tile."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...]
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One grid step of a plain (no bias / activation) matmul tile."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_rows(x: jax.Array, multiple: int) -> jax.Array:
    m = x.shape[0]
    pad = (-m) % multiple
    if pad == 0:
        return x
    return jnp.pad(x, ((0, pad), (0, 0)))


def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    act: str = "none",
    *,
    block_m: int = BLOCK_M,
) -> jax.Array:
    """Fused ``act(x @ w + b)`` as a tiled Pallas kernel.

    Args:
      x: ``f32[M, K]`` activations (M is padded internally to ``block_m``).
      w: ``f32[K, N]`` weights.
      b: ``f32[N]`` bias.
      act: one of :data:`ACTIVATIONS`.
      block_m: row-tile size (MXU-shaped 128 by default).

    Returns:
      ``f32[M, N]``.
    """
    if act not in ACTIVATIONS:
        raise ValueError(f"unknown activation {act!r}; expected {ACTIVATIONS}")
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x is {x.shape}, w is {w.shape}")
    if b.shape != (n,):
        raise ValueError(f"bias shape {b.shape} != ({n},)")

    bm = min(block_m, m) if m % block_m else block_m
    xp = _pad_rows(x, bm)
    mp = xp.shape[0]
    grid = (mp // bm,)
    b2 = b.reshape(1, n)

    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=True,
    )(xp, w, b2)
    return out[:m]


def matmul(x: jax.Array, w: jax.Array, *, block_m: int = BLOCK_M) -> jax.Array:
    """Plain ``x @ w`` as a tiled Pallas kernel (used by the VJP)."""
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: x is {x.shape}, w is {w.shape}")
    bm = min(block_m, m) if m % block_m else block_m
    xp = _pad_rows(x, bm)
    mp = xp.shape[0]
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), x.dtype),
        interpret=True,
    )(xp, w)
    return out[:m]


# --------------------------------------------------------------------------
# Differentiable fused dense layer: y = act(x @ w + b), Pallas fwd AND bwd.
# --------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"):
    """Differentiable fused dense layer backed by the Pallas matmul kernel."""
    return matmul_bias_act(x, w, b, act)


def _dense_fwd(x, w, b, act):
    pre = matmul_bias_act(x, w, b, "none")
    y = jnp.maximum(pre, 0.0) if act == "relu" else pre
    return y, (x, w, pre)


def _dense_bwd(act, res, dy):
    x, w, pre = res
    if act == "relu":
        dy = jnp.where(pre > 0, dy, 0.0).astype(dy.dtype)
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
