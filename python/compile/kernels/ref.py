"""Pure-jnp oracles for the L1 Pallas kernels.

Every kernel in this package has an exact reference here; pytest (plus
hypothesis shape/dtype sweeps) asserts allclose between the Pallas output
and these. This is the core correctness signal for the compiled artifacts:
if kernel == ref and model-built-on-kernel == model-built-on-ref, the HLO
the Rust runtime executes is trusted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def matmul_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """Reference for kernels.matmul.matmul_bias_act / dense."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    return y.astype(x.dtype)


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Reference for kernels.matmul.matmul."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def conv2d_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """Reference for kernels.conv.conv2d_bias_act: direct XLA convolution."""
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    return y
