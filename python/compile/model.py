"""L2: the VAFL client model — a ResNet-style CNN over a flat parameter
vector, with the fused fwd+bwd+SGD training step and evaluation step that
are AOT-lowered to HLO artifacts for the Rust runtime.

The paper (Fig. 2) trains a small ResNet on 28x28 MNIST images. This module
defines ResNet-lite:

    input [B, 784] -> reshape [B, 28, 28, 1]
    stem:  conv3x3 1->C, relu
    rb1:   (conv3x3 C->C, relu, conv3x3 C->C) + skip, relu
    pool:  avg 2x2                                   -> 14x14
    rb2:   (conv3x3 C->C, relu, conv3x3 C->C) + skip, relu
    pool:  avg 2x2                                   -> 7x7
    head:  flatten -> dense 7*7*C -> 10 logits (Pallas kernel)

The compute layers route through one of three backends (``pallas_mode``):

* ``"full"`` — every conv and the head run through the L1 Pallas kernels.
  This is the faithful TPU mapping, but under ``interpret=True`` on the CPU
  PJRT plugin the interpreter machinery costs ~40x (measured: 1.6 s/step vs
  40 ms; see EXPERIMENTS.md §Perf), so it is used for correctness tests and
  the kernel-path benchmark artifact, not the experiment hot loop.
* ``"head"`` (default for artifacts) — convs use the XLA-native reference
  ops; the classifier head runs through the Pallas ``dense`` kernel, so the
  production HLO still contains the Pallas-lowered kernel on its hot path
  at CPU-tractable cost (measured 46.6 ms/step).
* ``"none"`` — pure-jnp reference everywhere (the pytest oracle).

All three are numerically interchangeable (pytest asserts allclose on
losses and gradients).

Every exported function takes/returns parameters as a single flat ``f32[P]``
vector. The layout (name/shape/offset per tensor) is PARAM_SPEC; ``aot.py``
serializes it to ``artifacts/params_spec.json`` so the Rust side can size
payloads and (for diagnostics) address individual tensors.
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv as ck
from .kernels import matmul as mk
from .kernels import ref as ref

# ---------------------------------------------------------------------------
# Architecture constants (paper Table II: B=32, eta=0.1; Fig. 2: small ResNet)
# ---------------------------------------------------------------------------

IMAGE_DIM = 28
INPUT_DIM = IMAGE_DIM * IMAGE_DIM  # flattened grayscale image
NUM_CLASSES = 10
CHANNELS = 16  # ResNet-lite width
BATCH_SIZE = 32  # training batch (paper Table II)
EVAL_BATCH = 128  # evaluation chunk size
GRAD_CLIP_NORM = 5.0  # global-norm gradient clip (stabilizes the long
# unsynced local runs VAFL's gating produces; see DESIGN.md §6)


def _layer_defs(c: int = CHANNELS) -> List[Tuple[str, Tuple[int, ...]]]:
    """(name, shape) for every parameter tensor, in flat-vector order."""
    return [
        ("stem/w", (3, 3, 1, c)),
        ("stem/b", (c,)),
        ("rb1/w1", (3, 3, c, c)),
        ("rb1/b1", (c,)),
        ("rb1/w2", (3, 3, c, c)),
        ("rb1/b2", (c,)),
        ("rb2/w1", (3, 3, c, c)),
        ("rb2/b1", (c,)),
        ("rb2/w2", (3, 3, c, c)),
        ("rb2/b2", (c,)),
        ("head/w", (7 * 7 * c, NUM_CLASSES)),
        ("head/b", (NUM_CLASSES,)),
    ]


LAYERS = _layer_defs()


def param_spec() -> List[Dict]:
    """Flat-vector layout: name, shape, offset, size for each tensor."""
    spec, off = [], 0
    for name, shape in LAYERS:
        size = int(math.prod(shape))
        spec.append({"name": name, "shape": list(shape), "offset": off, "size": size})
        off += size
    return spec


PARAM_COUNT = sum(int(math.prod(s)) for _, s in LAYERS)


def unflatten(params: jax.Array) -> Dict[str, jax.Array]:
    """Split the flat ``f32[P]`` vector into named, shaped tensors."""
    out = {}
    off = 0
    for name, shape in LAYERS:
        size = int(math.prod(shape))
        out[name] = params[off : off + size].reshape(shape)
        off += size
    return out


def init_params(seed: int = 0) -> jax.Array:
    """He-normal weights / zero biases, flattened. Deterministic in seed."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in LAYERS:
        key, sub = jax.random.split(key)
        if len(shape) == 1:  # biases
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif len(shape) == 4:  # conv HWIO: fan_in = kh*kw*Cin
            fan_in = shape[0] * shape[1] * shape[2]
            std = math.sqrt(2.0 / fan_in)
            chunks.append((jax.random.normal(sub, shape) * std).ravel())
        else:  # dense
            fan_in = shape[0]
            std = math.sqrt(2.0 / fan_in)
            chunks.append((jax.random.normal(sub, shape) * std).ravel())
    return jnp.concatenate(chunks).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Forward pass (Pallas or reference backend)
# ---------------------------------------------------------------------------


PALLAS_MODES = ("full", "head", "none")


def _conv(x, w, b, act, mode):
    if mode == "full":
        return ck.conv2d_bias_act(x, w, b, act)
    return ref.conv2d_bias_act_ref(x, w, b, act)


def _dense(x, w, b, act, mode):
    if mode in ("full", "head"):
        return mk.dense(x, w, b, act)
    return ref.matmul_bias_act_ref(x, w, b, act)


def apply_fn(
    params: jax.Array, x: jax.Array, *, pallas_mode: str = "head"
) -> jax.Array:
    """Logits for a batch of flattened images.

    Args:
      params: flat ``f32[P]`` parameter vector.
      x: ``f32[B, 784]`` images in [0, 1].
      pallas_mode: kernel backend — "full" | "head" | "none" (see module
        docstring).

    Returns:
      ``f32[B, 10]`` logits.
    """
    if pallas_mode not in PALLAS_MODES:
        raise ValueError(f"pallas_mode {pallas_mode!r} not in {PALLAS_MODES}")
    p = unflatten(params)
    b = x.shape[0]
    h = x.reshape(b, IMAGE_DIM, IMAGE_DIM, 1)
    h = _conv(h, p["stem/w"], p["stem/b"], "relu", pallas_mode)
    # Residual block 1 (28x28).
    r = _conv(h, p["rb1/w1"], p["rb1/b1"], "relu", pallas_mode)
    r = _conv(r, p["rb1/w2"], p["rb1/b2"], "none", pallas_mode)
    h = jax.nn.relu(h + r)
    h = ck.avg_pool_2x2(h)
    # Residual block 2 (14x14).
    r = _conv(h, p["rb2/w1"], p["rb2/b1"], "relu", pallas_mode)
    r = _conv(r, p["rb2/w2"], p["rb2/b2"], "none", pallas_mode)
    h = jax.nn.relu(h + r)
    h = ck.avg_pool_2x2(h)  # -> 7x7
    h = h.reshape(b, -1)
    return _dense(h, p["head/w"], p["head/b"], "none", pallas_mode)


def loss_fn(
    params: jax.Array, x: jax.Array, y: jax.Array, *, pallas_mode: str = "head"
) -> jax.Array:
    """Mean softmax cross-entropy. ``y`` is ``i32[B]`` class labels."""
    logits = apply_fn(params, x, pallas_mode=pallas_mode)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=logp.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


# ---------------------------------------------------------------------------
# Exported steps (AOT entry points; see aot.py)
# ---------------------------------------------------------------------------


def train_step(
    params: jax.Array,
    x: jax.Array,
    y: jax.Array,
    lr: jax.Array,
    *,
    pallas_mode: str = "head",
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One SGD step: fused forward + backward + update.

    Returns ``(new_params f32[P], loss f32[], grad f32[P])``. The gradient
    (after global-norm clipping at GRAD_CLIP_NORM) is returned so the client
    can form the VAFL communication value ``||grad_prev - grad||^2`` (Eq. 1)
    across successive local passes.
    """
    loss, grad = jax.value_and_grad(
        lambda p: loss_fn(p, x, y, pallas_mode=pallas_mode)
    )(params)
    # Global-norm clip: a client whose upload is gated out can run hundreds
    # of consecutive local steps without a sync; unclipped SGD at eta=0.1
    # diverges on skewed shards (observed in experiment c). The returned
    # gradient is the clipped one, so new_params == params - lr*grad holds
    # exactly and Eq. 1 sees the same vector the update used.
    norm = jnp.sqrt(jnp.sum(grad * grad))
    scale = jnp.minimum(1.0, GRAD_CLIP_NORM / jnp.maximum(norm, 1e-12))
    grad = grad * scale
    return params - lr * grad, loss, grad


def eval_step(
    params: jax.Array, x: jax.Array, y: jax.Array, *, pallas_mode: str = "head"
) -> Tuple[jax.Array, jax.Array]:
    """Evaluation over one chunk: ``(correct_count f32[], loss_sum f32[])``.

    The Rust side streams the test set through fixed-size chunks (padding the
    tail with label -1, which never counts as correct) and accumulates.
    """
    logits = apply_fn(params, x, pallas_mode=pallas_mode)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct = jnp.sum((pred == y).astype(jnp.float32))
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = (y >= 0).astype(logp.dtype)
    onehot = jax.nn.one_hot(jnp.maximum(y, 0), NUM_CLASSES, dtype=logp.dtype)
    loss_sum = -jnp.sum(valid * jnp.sum(onehot * logp, axis=-1))
    return correct, loss_sum


def value_fn(
    g_prev: jax.Array, g_new: jax.Array, acc: jax.Array, n: jax.Array
) -> jax.Array:
    """VAFL communication value, paper Eq. 1:

        V = ||g_prev - g_new||^2 * (1 + N/10^3)^Acc
    """
    d = g_prev - g_new
    sq = jnp.sum(d * d)
    return sq * jnp.power(1.0 + n / 1000.0, acc)


# ---------------------------------------------------------------------------
# Analytic cost model (feeds the Rust device simulator via params_spec.json)
# ---------------------------------------------------------------------------


def train_step_flops(batch: int = BATCH_SIZE, c: int = CHANNELS) -> int:
    """Approximate FLOPs of one fwd+bwd+update train step.

    Conv at HxW with Cin->Cout: 2*H*W*9*Cin*Cout per image forward;
    backward ~2x forward (dX + dW matmuls). Used only by the device-latency
    model — the real compute is the HLO itself.
    """
    hw28, hw14 = 28 * 28, 14 * 14
    fwd = 0
    fwd += 2 * hw28 * 9 * 1 * c  # stem
    fwd += 2 * 2 * hw28 * 9 * c * c  # rb1
    fwd += 2 * 2 * hw14 * 9 * c * c  # rb2
    fwd += 2 * (7 * 7 * c) * NUM_CLASSES  # head
    per_image = 3 * fwd  # fwd + ~2x bwd
    return batch * per_image + 2 * PARAM_COUNT  # + SGD update


def eval_step_flops(batch: int = EVAL_BATCH, c: int = CHANNELS) -> int:
    """Approximate FLOPs of one forward-only eval chunk."""
    return train_step_flops(batch, c) // 3
