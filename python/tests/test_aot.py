"""AOT bundle: artifacts exist, parse, and the spec is self-consistent."""

import json
import os

import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def bundle(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    spec = aot.build_artifacts(str(out), seed=0, pallas_mode="head")
    return str(out), spec


def test_all_artifacts_written(bundle):
    out, spec = bundle
    for name in ("train_step", "eval_step", "value"):
        path = os.path.join(out, f"{name}.hlo.txt")
        assert os.path.exists(path), name
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        assert spec["artifacts"][name]["chars"] == len(text)


def test_init_params_file_matches_model(bundle):
    out, spec = bundle
    raw = np.fromfile(os.path.join(out, "init_params.f32"), dtype="<f4")
    assert raw.shape == (spec["param_count"],)
    np.testing.assert_array_equal(raw, np.asarray(model.init_params(0)))


def test_spec_consistency(bundle):
    _, spec = bundle
    assert spec["param_count"] == model.PARAM_COUNT
    assert spec["batch_size"] == model.BATCH_SIZE
    assert spec["eval_batch"] == model.EVAL_BATCH
    assert spec["layers"][-1]["offset"] + spec["layers"][-1]["size"] == spec[
        "param_count"
    ]
    assert spec["train_step_flops"] > 0


def test_spec_json_round_trips(bundle):
    out, spec = bundle
    loaded = json.load(open(os.path.join(out, "params_spec.json")))
    assert loaded == spec


def test_hlo_entry_signatures(bundle):
    """The lowered entry computations must carry the shapes Rust expects."""
    out, spec = bundle
    p, b, d = spec["param_count"], spec["batch_size"], spec["input_dim"]
    train = open(os.path.join(out, "train_step.hlo.txt")).read()
    assert f"f32[{p}]" in train
    assert f"f32[{b},{d}]" in train
    assert f"s32[{b}]" in train
    ev = open(os.path.join(out, "eval_step.hlo.txt")).read()
    assert f"f32[{spec['eval_batch']},{d}]" in ev


def test_none_mode_variant_builds(tmp_path):
    spec = aot.build_artifacts(str(tmp_path), seed=0, pallas_mode="none")
    assert spec["pallas_mode"] == "none"
    assert os.path.exists(tmp_path / "train_step.hlo.txt")
