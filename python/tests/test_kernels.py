"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Fixed-shape cases cover the exact shapes the model emits; hypothesis sweeps
random (M, K, N) shapes — including non-multiples of the 128 row tile and
degenerate M=1 — and both activations. Gradients of the custom-VJP dense
layer are checked against JAX autodiff of the reference.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv as ck
from compile.kernels import matmul as mk
from compile.kernels import ref


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ["none", "relu"])
@pytest.mark.parametrize(
    "m,k,n",
    [
        (32, 16, 10),  # head shape
        (128, 9, 16),  # stem tile
        (8192, 144, 16),  # rb conv im2col tile (full run is 32*28*28 rows)
        (1, 7, 3),  # degenerate single row
        (130, 5, 4),  # M % 128 != 0 -> padding path
        (256, 144, 16),  # exact multiple
    ],
)
def test_matmul_bias_act_matches_ref(m, k, n, act):
    x, w, b = _rand(0, m, k), _rand(1, k, n), _rand(2, n)
    got = mk.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(64, 32, 8), (129, 3, 5)])
def test_plain_matmul_matches_ref(m, k, n):
    x, w = _rand(3, m, k), _rand(4, k, n)
    np.testing.assert_allclose(
        mk.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        mk.matmul_bias_act(_rand(0, 4, 3), _rand(1, 5, 2), _rand(2, 2))
    with pytest.raises(ValueError):
        mk.matmul_bias_act(_rand(0, 4, 3), _rand(1, 3, 2), _rand(2, 7))
    with pytest.raises(ValueError):
        mk.matmul_bias_act(_rand(0, 4, 3), _rand(1, 3, 2), _rand(2, 2), "sigmoid")
    with pytest.raises(ValueError):
        mk.matmul(_rand(0, 4, 3), _rand(1, 5, 2))


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 64),
    n=st.integers(1, 48),
    act=st.sampled_from(["none", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_hypothesis(m, k, n, act, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw, kb = jax.random.split(key, 3)
    x = jax.random.normal(kx, (m, k), dtype=jnp.float32)
    w = jax.random.normal(kw, (k, n), dtype=jnp.float32)
    b = jax.random.normal(kb, (n,), dtype=jnp.float32)
    got = mk.matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 128),
    k=st.integers(1, 32),
    n=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_bias_act_bf16_hypothesis(m, k, n, seed):
    """dtype sweep: the kernel must also hold together in bfloat16."""
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), dtype=jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(kw, (k, n), dtype=jnp.float32).astype(jnp.bfloat16)
    b = jnp.zeros((n,), jnp.bfloat16)
    got = mk.matmul_bias_act(x, w, b, "none").astype(jnp.float32)
    want = ref.matmul_bias_act_ref(x, w, b, "none").astype(jnp.float32)
    # bf16 accumulate-in-f32: tolerances scaled to bf16 epsilon.
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# dense (custom VJP)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ["none", "relu"])
def test_dense_vjp_matches_ref_grads(act):
    x, w, b = _rand(5, 40, 12), _rand(6, 12, 7), _rand(7, 7)

    def f_pallas(x, w, b):
        return jnp.sum(mk.dense(x, w, b, act) ** 2)

    def f_ref(x, w, b):
        return jnp.sum(ref.matmul_bias_act_ref(x, w, b, act) ** 2)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_dense_vjp_relu_masks_at_zero():
    """Gradient through relu must be zero exactly where pre-activation <= 0."""
    x = jnp.array([[1.0, -1.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros(2, jnp.float32)
    g = jax.grad(lambda x: jnp.sum(mk.dense(x, w, b, "relu")))(x)
    np.testing.assert_allclose(g, [[1.0, 0.0]])


# ---------------------------------------------------------------------------
# conv2d + pooling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("act", ["none", "relu"])
@pytest.mark.parametrize(
    "b,h,w,cin,cout",
    [
        (2, 8, 8, 3, 5),
        (32, 28, 28, 1, 16),  # stem shape
        (4, 14, 14, 16, 16),  # rb2 shape
        (1, 4, 4, 1, 1),
    ],
)
def test_conv2d_matches_ref(b, h, w, cin, cout, act):
    x = _rand(8, b, h, w, cin)
    wt = _rand(9, 3, 3, cin, cout) * 0.2
    bias = _rand(10, cout) * 0.1
    got = ck.conv2d_bias_act(x, wt, bias, act)
    want = ref.conv2d_bias_act_ref(x, wt, bias, act)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(ValueError):
        ck.conv2d_bias_act(_rand(0, 1, 4, 4, 3), _rand(1, 3, 3, 2, 5), _rand(2, 5))


def test_conv2d_grad_matches_ref():
    x = _rand(11, 2, 6, 6, 3)
    wt = _rand(12, 3, 3, 3, 4) * 0.3
    bias = _rand(13, 4) * 0.1

    gp = jax.grad(lambda w: jnp.sum(ck.conv2d_bias_act(x, w, bias, "relu") ** 2))(wt)
    gr = jax.grad(lambda w: jnp.sum(ref.conv2d_bias_act_ref(x, w, bias, "relu") ** 2))(
        wt
    )
    np.testing.assert_allclose(gp, gr, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 6, 8, 14]),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_hypothesis(b, hw, cin, cout, seed):
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (b, hw, hw, cin), dtype=jnp.float32)
    wt = jax.random.normal(kw, (3, 3, cin, cout), dtype=jnp.float32) * 0.2
    bias = jnp.zeros((cout,), jnp.float32)
    got = ck.conv2d_bias_act(x, wt, bias, "none")
    want = ref.conv2d_bias_act_ref(x, wt, bias, "none")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_avg_pool_2x2():
    x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
    got = ck.avg_pool_2x2(x)
    want = jnp.array([[[[2.5], [4.5]], [[10.5], [12.5]]]], jnp.float32)
    np.testing.assert_allclose(got, want)


def test_global_avg_pool():
    x = jnp.ones((3, 5, 5, 7), jnp.float32) * 2.0
    got = ck.global_avg_pool(x)
    assert got.shape == (3, 7)
    np.testing.assert_allclose(got, 2.0)
