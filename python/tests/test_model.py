"""L2 correctness: model over flat params, train/eval/value entry points."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


@pytest.fixture(scope="module")
def batch():
    key = jax.random.PRNGKey(42)
    kx, ky = jax.random.split(key)
    x = jax.random.uniform(kx, (model.BATCH_SIZE, model.INPUT_DIM))
    y = jax.random.randint(ky, (model.BATCH_SIZE,), 0, model.NUM_CLASSES)
    return x, y


def test_param_spec_layout_contiguous():
    spec = model.param_spec()
    off = 0
    for entry in spec:
        assert entry["offset"] == off
        assert entry["size"] == int(np.prod(entry["shape"]))
        off += entry["size"]
    assert off == model.PARAM_COUNT


def test_unflatten_roundtrip(params):
    tensors = model.unflatten(params)
    flat = jnp.concatenate([tensors[n].ravel() for n, _ in model.LAYERS])
    np.testing.assert_array_equal(flat, params)


def test_init_deterministic_and_seed_sensitive():
    a, b = model.init_params(7), model.init_params(7)
    np.testing.assert_array_equal(a, b)
    c = model.init_params(8)
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_init_biases_zero(params):
    tensors = model.unflatten(params)
    for name, _ in model.LAYERS:
        if tensors[name].ndim == 1:
            np.testing.assert_array_equal(tensors[name], 0.0)


def test_apply_shapes(params, batch):
    x, _ = batch
    logits = model.apply_fn(params, x)
    assert logits.shape == (model.BATCH_SIZE, model.NUM_CLASSES)
    assert logits.dtype == jnp.float32


def test_all_backends_agree(params, batch):
    x, y = batch
    lp = model.loss_fn(params, x, y, pallas_mode="full")
    lh = model.loss_fn(params, x, y, pallas_mode="head")
    lr = model.loss_fn(params, x, y, pallas_mode="none")
    np.testing.assert_allclose(lh, lr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(lp, lr, rtol=1e-5, atol=1e-6)


def test_all_backend_gradients_agree(params, batch):
    x, y = batch
    gp = jax.grad(lambda p: model.loss_fn(p, x, y, pallas_mode="full"))(params)
    gh = jax.grad(lambda p: model.loss_fn(p, x, y, pallas_mode="head"))(params)
    gr = jax.grad(lambda p: model.loss_fn(p, x, y, pallas_mode="none"))(params)
    np.testing.assert_allclose(gh, gr, rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-5)


def test_train_step_shapes_and_descent(params, batch):
    x, y = batch
    lr = jnp.float32(0.1)
    p, losses = params, []
    step = jax.jit(model.train_step)
    for _ in range(8):
        p, loss, grad = step(p, x, y, lr)
        losses.append(float(loss))
    assert p.shape == (model.PARAM_COUNT,)
    assert grad.shape == (model.PARAM_COUNT,)
    # Repeated steps on one batch must overfit it: loss strictly improves
    # from start to finish.
    assert losses[-1] < losses[0] * 0.9, losses


def test_train_step_is_sgd_update(params, batch):
    """new_params must equal params - lr * grad exactly."""
    x, y = batch
    lr = jnp.float32(0.05)
    new_p, _, grad = model.train_step(params, x, y, lr)
    np.testing.assert_allclose(new_p, params - lr * grad, rtol=1e-6, atol=1e-7)


def test_eval_step_counts(params):
    """eval_step must count argmax matches and ignore padded labels (-1)."""
    key = jax.random.PRNGKey(3)
    x = jax.random.uniform(key, (model.EVAL_BATCH, model.INPUT_DIM))
    logits = model.apply_fn(params, x)
    pred = np.asarray(jnp.argmax(logits, axis=-1), dtype=np.int32)

    y = pred.copy()  # all correct
    correct, loss_sum = model.eval_step(params, x, jnp.asarray(y))
    assert float(correct) == model.EVAL_BATCH

    y_half = pred.copy()
    y_half[::2] = (y_half[::2] + 1) % model.NUM_CLASSES  # half wrong
    correct, _ = model.eval_step(params, x, jnp.asarray(y_half))
    assert float(correct) == model.EVAL_BATCH // 2

    y_pad = pred.copy()
    y_pad[100:] = -1  # padded tail: not correct, not in loss
    correct, loss_pad = model.eval_step(params, x, jnp.asarray(y_pad))
    assert float(correct) == 100
    y_100 = pred[:100]
    x_100_logits = logits[:100]
    logp = jax.nn.log_softmax(x_100_logits, axis=-1)
    want = -float(
        jnp.sum(logp[jnp.arange(100), jnp.asarray(y_100)])
    )
    np.testing.assert_allclose(float(loss_pad), want, rtol=1e-5)


def test_value_fn_formula():
    """Eq. 1: V = ||g_prev - g_new||^2 * (1 + N/1e3)^Acc."""
    g0 = jnp.array([1.0, 2.0, 3.0])
    g1 = jnp.array([0.0, 0.0, 0.0])
    v = model.value_fn(g0, g1, jnp.float32(0.9), jnp.float32(7.0))
    want = 14.0 * (1 + 7 / 1000.0) ** 0.9
    np.testing.assert_allclose(float(v), want, rtol=1e-6)


def test_value_fn_zero_when_stale():
    """An 'old' model (no gradient change) has zero communication value."""
    g = jnp.ones(5)
    v = model.value_fn(g, g, jnp.float32(0.99), jnp.float32(100.0))
    assert float(v) == 0.0


def test_value_fn_monotone_in_acc_and_n():
    g0, g1 = jnp.ones(4), jnp.zeros(4)
    v_lo = model.value_fn(g0, g1, jnp.float32(0.1), jnp.float32(7.0))
    v_hi = model.value_fn(g0, g1, jnp.float32(0.9), jnp.float32(7.0))
    assert float(v_hi) > float(v_lo)
    v_n3 = model.value_fn(g0, g1, jnp.float32(0.9), jnp.float32(3.0))
    assert float(v_hi) > float(v_n3)


def test_train_step_flops_positive():
    assert model.train_step_flops() > 1e6
    assert model.eval_step_flops() > 0


def test_apply_rejects_unknown_mode(params, batch):
    x, _ = batch
    with pytest.raises(ValueError):
        model.apply_fn(params, x, pallas_mode="gpu")
