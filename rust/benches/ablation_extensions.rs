//! Bench: ablations over the framework extensions (paper future-work
//! directions implemented as first-class features):
//!
//! 1. payload precision (f32 / f16 / int8) — wire bytes vs accuracy;
//! 2. client dropout — robustness of each algorithm to a flaky fleet;
//! 3. staleness-decayed aggregation (FedAsync-style) under VAFL gating.
//!
//!     cargo bench --bench ablation_extensions
//!
//! Env: VAFL_BENCH_ROUNDS (default 20), VAFL_BENCH_MOCK=1.

mod common;

use vafl::config::Algorithm;
use vafl::coordinator::registry::DropoutModel;
use vafl::experiments;
use vafl::model::quant::Precision;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();

    common::section("1. payload precision (experiment b, VAFL)");
    println!("precision  bytes_up_total  best_acc  comm->target");
    for precision in [Precision::F32, Precision::F16, Precision::Int8] {
        let mut cfg = experiments::preset('b')?;
        common::apply_env(&mut cfg, 20);
        cfg.algorithm = Algorithm::Vafl;
        cfg.upload_precision = precision;
        let out = experiments::run(&cfg)?;
        let bytes: u64 = out.metrics.records.iter().map(|r| r.bytes_up).sum();
        println!(
            "{:<10} {:<15} {:<9.4} {:?}",
            precision.name(),
            bytes,
            out.best_accuracy,
            out.comm_times_to_target
        );
    }

    common::section("2. dropout robustness (experiment b, 20% drop prob)");
    println!("algorithm  best_acc  comm->target  total_uploads");
    for algo in Algorithm::ALL {
        let mut cfg = experiments::preset('b')?;
        common::apply_env(&mut cfg, 20);
        cfg.algorithm = algo;
        cfg.dropout = DropoutModel::flaky(0.2);
        let out = experiments::run(&cfg)?;
        println!(
            "{:<10} {:<9.4} {:<13?} {}",
            algo.name(),
            out.best_accuracy,
            out.comm_times_to_target,
            out.total_uploads
        );
    }

    common::section("3. staleness-decayed aggregation (experiment d, VAFL)");
    println!("decay  best_acc  comm->target");
    for decay in [None, Some(0.9), Some(0.5)] {
        let mut cfg = experiments::preset('d')?;
        common::apply_env(&mut cfg, 20);
        cfg.algorithm = Algorithm::Vafl;
        cfg.staleness_decay = decay;
        let out = experiments::run(&cfg)?;
        println!(
            "{:<6} {:<9.4} {:?}",
            decay.map_or("none".to_string(), |d| format!("{d}")),
            out.best_accuracy,
            out.comm_times_to_target
        );
    }
    Ok(())
}
