//! Bench: ablation of the VAFL value function (Eq. 1) — the design choice
//! DESIGN.md §6 calls out: does the `(1 + N/10^3)^Acc` amplification term
//! actually help, or is the raw gradient-change norm enough?
//!
//!     cargo bench --bench ablation_value_fn
//!
//! Env: VAFL_BENCH_ROUNDS (default 30), VAFL_BENCH_MOCK=1.

mod common;

use vafl::config::{Algorithm, ValueFnConfig};
use vafl::experiments;
use vafl::metrics::ccr;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    common::section("Ablation — VAFL value function (Eq. 1)");
    println!("variant                       exp  comm->target  CCR      best_acc");
    println!("------------------------------------------------------------------");
    for which in ['b', 'd'] {
        // Baseline for CCR.
        let mut afl = experiments::preset(which)?;
        common::apply_env(&mut afl, 30);
        afl.algorithm = Algorithm::Afl;
        let afl_out = experiments::run(&afl)?;
        let c0 = afl_out
            .comm_times_to_target
            .unwrap_or(afl_out.total_uploads);
        for (label, value_fn) in [
            ("vafl (full Eq. 1)", ValueFnConfig { use_acc_term: true }),
            ("vafl (grad-diff only)", ValueFnConfig { use_acc_term: false }),
        ] {
            let mut cfg = experiments::preset(which)?;
            common::apply_env(&mut cfg, 30);
            cfg.algorithm = Algorithm::Vafl;
            cfg.value_fn = value_fn;
            let out = experiments::run(&cfg)?;
            let c1 = out.comm_times_to_target.unwrap_or(out.total_uploads);
            println!(
                "{label:<29} {which}    {:<13} {:<8.4} {:.4}",
                c1,
                ccr(c0, c1),
                out.best_accuracy
            );
        }
    }
    println!(
        "\n(the acc term matters more as N grows — paper §III-A: it \"further\n\
         differentiate[s]\" client values for larger fleets)"
    );
    Ok(())
}
