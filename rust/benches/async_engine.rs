//! Bench: barriered vs. barrier-free wall-clock-to-accuracy under a
//! straggler-heavy link (`LinkProfile::straggler_wan`), a sweep over
//! buffer sizes and staleness-mixing rules, the threaded (speculative
//! execution) engine's events/sec scaling, and the aggregation-shard
//! sweep.
//!
//!     cargo bench --bench async_engine [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1.
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) additionally writes every row to
//! `BENCH_async_engine.json` (events/sec, wall ms, vtime-to-target,
//! speculation hit/replay counts per thread/shard configuration) so the
//! engine perf trajectory is tracked across PRs, the same way
//! `perf_hotpath` emits `BENCH_hotpath.json`.
//!
//! The headline numbers: (1) the speedup in *virtual* seconds to the
//! target accuracy — the barriered engine pays the slowest client +
//! slowest transfer every round, the barrier-free engine aggregates
//! whatever arrives; (2) the speedup in *wall* events/sec from running
//! client local rounds speculatively on pool workers — the committed
//! record stream is bitwise identical, only the wall clock moves.

mod common;

use vafl::config::{AsyncEngineConfig, ExperimentConfig};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};
use vafl::metrics::RunMetrics;
use vafl::util::json::{obj, Value};

/// Collects every bench row for the optional JSON artifact.
#[derive(Default)]
struct Recorder {
    rows: Vec<Value>,
}

impl Recorder {
    fn push(&mut self, fields: Vec<(&'static str, Value)>) {
        self.rows.push(obj(fields));
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        let doc = obj(vec![
            ("bench", Value::Str("async_engine".into())),
            ("rows", Value::Arr(self.rows.clone())),
        ]);
        std::fs::write(path, doc.to_string_pretty())
    }
}

fn opt_f64(v: Option<f64>) -> Value {
    v.map(Value::Num).unwrap_or(Value::Null)
}

/// Run the barrier-free engine (threaded per `cfg.engine_opts`); build
/// and pool construction are excluded from the timing
/// (`experiments::run_barrier_free_timed`). Best wall-clock of `reps`
/// runs — the committed metrics are deterministic, so any rep's serve.
fn timed_run(cfg: &ExperimentConfig, reps: usize) -> anyhow::Result<(RunMetrics, f64)> {
    let mut best = f64::INFINITY;
    let mut metrics = None;
    for _ in 0..reps.max(1) {
        let (m, wall) = experiments::run_barrier_free_timed(cfg)?;
        best = best.min(wall);
        metrics = Some(m);
    }
    Ok((metrics.expect("at least one rep"), best))
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    let mut rec = Recorder::default();
    let want_json = std::env::args().any(|a| a == "--json")
        || std::env::var("VAFL_BENCH_JSON").is_ok();

    common::section("Barrier-free engine — straggler scenario (experiment b fleet)");
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    common::apply_env(&mut cfg, 40);
    cfg.target_acc = cfg.target_acc.min(0.5);
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Constant { alpha: 0.9 },
    };
    let cmp = straggler::compare_engines(&cfg)?;
    println!("{}", straggler::render(&cmp));
    match cmp.speedup() {
        Some(s) if s > 1.0 => println!(
            "=> barrier-free reaches {:.0}% accuracy {s:.2}x sooner in virtual wall-clock",
            cfg.target_acc * 100.0
        ),
        Some(s) => println!(
            "=> no speedup on this configuration ({s:.2}x) — straggler pressure too low?"
        ),
        None => println!("=> one engine never reached the target; raise VAFL_BENCH_ROUNDS"),
    }
    let (tb, ta) = cmp.vtimes_to_target();
    rec.push(vec![
        ("section", Value::Str("engine_race".into())),
        ("name", Value::Str("barriered".into())),
        ("vtime_to_target_s", opt_f64(tb)),
        ("uploads", Value::Num(cmp.barriered.total_uploads as f64)),
    ]);
    rec.push(vec![
        ("section", Value::Str("engine_race".into())),
        ("name", Value::Str("barrier_free".into())),
        ("vtime_to_target_s", opt_f64(ta)),
        ("uploads", Value::Num(cmp.barrier_free.total_uploads as f64)),
    ]);

    common::section("Threaded speculative engine — events/sec scaling (straggler_wan)");
    // Inner kernels pinned serial (threads = 1) so the sweep isolates the
    // engine-level overlap; the committed record stream is identical for
    // every row (asserted in tests/engine_async.rs), only wall moves.
    let mut tcfg = cfg.clone();
    tcfg.engine = vafl::config::EngineMode::BarrierFree;
    tcfg.threads = 1;
    println!(
        "{:<26} {:>9} {:>12} {:>9} {:>11} {:>9}",
        "configuration", "wall_ms", "events/sec", "speedup", "spec_hit", "replays"
    );
    let (serial_metrics, serial_wall) = timed_run(&tcfg, 2)?;
    let serial_eps = serial_metrics.engine_events as f64 / serial_wall.max(1e-9);
    println!(
        "{:<26} {:>9.1} {:>12.0} {:>9} {:>11} {:>9}",
        "serial",
        serial_wall * 1e3,
        serial_eps,
        "1.00x",
        "-",
        "-"
    );
    rec.push(vec![
        ("section", Value::Str("thread_sweep".into())),
        ("name", Value::Str("serial".into())),
        ("workers", Value::Num(0.0)),
        ("wall_ms", Value::Num(serial_wall * 1e3)),
        ("events", Value::Num(serial_metrics.engine_events as f64)),
        ("events_per_sec", Value::Num(serial_eps)),
        ("vtime_to_target_s", opt_f64(serial_metrics.vtime_to_target())),
    ]);
    for workers in [1usize, 2, 4] {
        let mut c = tcfg.clone();
        c.engine_opts.threaded = true;
        c.engine_opts.workers = workers;
        let (m, wall) = timed_run(&c, 2)?;
        let eps = m.engine_events as f64 / wall.max(1e-9);
        let (hit, replay) = m.speculation_totals();
        println!(
            "{:<26} {:>9.1} {:>12.0} {:>8.2}x {:>11} {:>9}",
            format!("threaded workers={workers}"),
            wall * 1e3,
            eps,
            eps / serial_eps.max(1e-9),
            hit,
            replay
        );
        assert_eq!(
            m.engine_events, serial_metrics.engine_events,
            "threaded engine committed different work"
        );
        rec.push(vec![
            ("section", Value::Str("thread_sweep".into())),
            ("name", Value::Str(format!("threaded_w{workers}"))),
            ("workers", Value::Num(workers as f64)),
            ("wall_ms", Value::Num(wall * 1e3)),
            ("events", Value::Num(m.engine_events as f64)),
            ("events_per_sec", Value::Num(eps)),
            ("speedup_vs_serial", Value::Num(eps / serial_eps.max(1e-9))),
            ("spec_committed", Value::Num(hit as f64)),
            ("spec_replayed", Value::Num(replay as f64)),
            (
                "spec_replay_rate",
                Value::Num(if hit + replay > 0 {
                    replay as f64 / (hit + replay) as f64
                } else {
                    0.0
                }),
            ),
            ("vtime_to_target_s", opt_f64(m.vtime_to_target())),
        ]);
    }

    common::section("Aggregation-shard sweep (S=1 bitwise == unsharded)");
    println!(
        "{:<26} {:>14} {:>9} {:>10} {:>16}",
        "configuration", "vtime-to-tgt", "uploads", "best_acc", "flushes/shard"
    );
    for shards in [1usize, 2, 4] {
        let mut c = tcfg.clone();
        c.engine_opts.shards = shards.min(c.num_clients);
        c.engine_opts.reconcile_every = 4;
        let (m, wall) = timed_run(&c, 1)?;
        let per_shard = m.per_shard_flushes();
        let flushes: Vec<String> =
            per_shard.iter().map(|(s, n)| format!("{s}:{n}")).collect();
        println!(
            "{:<26} {:>14} {:>9} {:>10.4} {:>16}",
            format!("shards={shards} reconcile=4"),
            m.vtime_to_target()
                .map_or_else(|| "never".to_string(), |v| format!("{v:.1}s")),
            m.total_uploads(),
            m.best_accuracy(),
            flushes.join(" "),
        );
        rec.push(vec![
            ("section", Value::Str("shard_sweep".into())),
            ("name", Value::Str(format!("shards_{shards}"))),
            ("shards", Value::Num(shards as f64)),
            ("wall_ms", Value::Num(wall * 1e3)),
            ("vtime_to_target_s", opt_f64(m.vtime_to_target())),
            ("uploads", Value::Num(m.total_uploads() as f64)),
            ("best_acc", Value::Num(m.best_accuracy())),
        ]);
    }

    common::section("Buffer size / mixing-rule sweep (vtime to target, uploads)");
    println!("{:<34} {:>14} {:>9} {:>10}", "configuration", "vtime-to-tgt", "uploads", "best_acc");
    for (label, k, mixing) in [
        ("k=1  constant(0.6)", 1, MixingRule::Constant { alpha: 0.6 }),
        ("k=1  poly(0.8, 0.5)", 1, MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 }),
        ("k=2  constant(0.9)", 2, MixingRule::Constant { alpha: 0.9 }),
        ("k=2  hinge(0.9, 4, 0.5)", 2, MixingRule::Hinge { alpha: 0.9, grace: 4, slope: 0.5 }),
        ("k=4  constant(1.0)", 4, MixingRule::Constant { alpha: 1.0 }),
    ] {
        let mut c = cfg.clone();
        c.engine = vafl::config::EngineMode::BarrierFree;
        c.async_engine = AsyncEngineConfig { buffer_k: k, mixing };
        let out = experiments::run(&c)?;
        println!(
            "{label:<34} {:>14} {:>9} {:>10.4}",
            out.metrics
                .vtime_to_target()
                .map_or_else(|| "never".to_string(), |v| format!("{v:.1}s")),
            out.total_uploads,
            out.best_accuracy,
        );
    }

    common::section("Staleness distribution (k=2, constant 0.9)");
    println!("{}", straggler::staleness_histogram(&cmp.barrier_free.metrics));

    if want_json {
        rec.write_json("BENCH_async_engine.json")?;
        println!("wrote BENCH_async_engine.json ({} rows)", rec.rows.len());
    }
    Ok(())
}
