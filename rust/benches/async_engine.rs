//! Bench: barriered vs. barrier-free wall-clock-to-accuracy under a
//! straggler-heavy link (`LinkProfile::straggler_wan`), plus a sweep over
//! buffer sizes and staleness-mixing rules.
//!
//!     cargo bench --bench async_engine
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1.
//!
//! The headline number is the speedup in virtual seconds to the target
//! accuracy: the barriered engine pays the slowest client + slowest
//! transfer every round, the barrier-free engine aggregates whatever
//! arrives.

mod common;

use vafl::config::AsyncEngineConfig;
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();

    common::section("Barrier-free engine — straggler scenario (experiment b fleet)");
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    common::apply_env(&mut cfg, 40);
    cfg.target_acc = cfg.target_acc.min(0.5);
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Constant { alpha: 0.9 },
    };
    let cmp = straggler::compare_engines(&cfg)?;
    println!("{}", straggler::render(&cmp));
    match cmp.speedup() {
        Some(s) if s > 1.0 => println!(
            "=> barrier-free reaches {:.0}% accuracy {s:.2}x sooner in virtual wall-clock",
            cfg.target_acc * 100.0
        ),
        Some(s) => println!(
            "=> no speedup on this configuration ({s:.2}x) — straggler pressure too low?"
        ),
        None => println!("=> one engine never reached the target; raise VAFL_BENCH_ROUNDS"),
    }

    common::section("Buffer size / mixing-rule sweep (vtime to target, uploads)");
    println!("{:<34} {:>14} {:>9} {:>10}", "configuration", "vtime-to-tgt", "uploads", "best_acc");
    for (label, k, mixing) in [
        ("k=1  constant(0.6)", 1, MixingRule::Constant { alpha: 0.6 }),
        ("k=1  poly(0.8, 0.5)", 1, MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 }),
        ("k=2  constant(0.9)", 2, MixingRule::Constant { alpha: 0.9 }),
        ("k=2  hinge(0.9, 4, 0.5)", 2, MixingRule::Hinge { alpha: 0.9, grace: 4, slope: 0.5 }),
        ("k=4  constant(1.0)", 4, MixingRule::Constant { alpha: 1.0 }),
    ] {
        let mut c = cfg.clone();
        c.engine = vafl::config::EngineMode::BarrierFree;
        c.async_engine = AsyncEngineConfig { buffer_k: k, mixing };
        let out = experiments::run(&c)?;
        println!(
            "{label:<34} {:>14} {:>9} {:>10.4}",
            out.metrics
                .vtime_to_target()
                .map_or_else(|| "never".to_string(), |v| format!("{v:.1}s")),
            out.total_uploads,
            out.best_accuracy,
        );
    }

    common::section("Staleness distribution (k=2, constant 0.9)");
    println!("{}", straggler::staleness_histogram(&cmp.barrier_free.metrics));
    Ok(())
}
