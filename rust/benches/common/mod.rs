#![allow(dead_code)]

//! Shared bench plumbing: every `rust/benches/*` binary is `harness = false`
//! (the offline crate set has no criterion) and uses `vafl::util::timer`
//! for stats. Benches accept two env knobs:
//!
//! * `VAFL_BENCH_ROUNDS` — communication rounds per run (default varies).
//! * `VAFL_BENCH_MOCK=1` — force the mock backend (CI without artifacts).

use vafl::config::{Backend, ExperimentConfig};

/// Apply the standard env knobs to a config.
pub fn apply_env(cfg: &mut ExperimentConfig, default_rounds: usize) {
    cfg.rounds = std::env::var("VAFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_rounds);
    if std::env::var("VAFL_BENCH_MOCK").is_ok() || !std::path::Path::new("artifacts/params_spec.json").exists() {
        cfg.backend = Backend::Mock;
        // The mock linear model tops out below the CNN; keep the target
        // reachable so comm-to-target is meaningful.
        cfg.target_acc = cfg.target_acc.min(0.75);
    }
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
}

/// Mark a bench section.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
