//! Bench: static vs. adaptive control on the straggler_wan profile.
//!
//!     cargo bench --bench control [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (default 60), VAFL_BENCH_MOCK=1.
//!
//! Two sweeps, both on experiment b's 7-client fleet under the
//! straggler-heavy WAN with the barrier-free engine:
//!
//! 1. **Compression**: every fixed `k_fraction` in the grid vs. the
//!    adaptive compression controller (starting mid-grid). Reported per
//!    row: rounds-to-target, bytes-to-target, total uplink bytes, byte
//!    CCR vs. the dense baseline (Eq. 4 over bytes), best accuracy, and
//!    the decision count. The acceptance bar: adaptive bytes-to-target
//!    no worse than the best *fixed* fraction in the sweep.
//! 2. **Staleness**: fixed `buffer_k` grid vs. the adaptive staleness
//!    controller retuning `buffer_k`/`alpha(tau)` online.
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) writes every row to
//! `BENCH_control.json`, the same trajectory convention as
//! `BENCH_async_engine.json`.

mod common;

use vafl::config::{
    AsyncEngineConfig, CompressionConfig, CompressionMode, ControlConfig, EngineMode,
    ExperimentConfig,
};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};
use vafl::metrics::{ccr_bytes, RunMetrics};
use vafl::util::json::{obj, Value};

#[derive(Default)]
struct Recorder {
    rows: Vec<Value>,
}

impl Recorder {
    fn push(&mut self, fields: Vec<(&'static str, Value)>) {
        self.rows.push(obj(fields));
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        let doc = obj(vec![
            ("bench", Value::Str("control".into())),
            ("rows", Value::Arr(self.rows.clone())),
        ]);
        std::fs::write(path, doc.to_string_pretty())
    }
}

fn opt_usize(v: Option<usize>) -> Value {
    v.map(Value::from).unwrap_or(Value::Null)
}

fn opt_u64(v: Option<u64>) -> Value {
    v.map(|b| Value::from(b as usize)).unwrap_or(Value::Null)
}

fn fmt_opt_usize(v: Option<usize>) -> String {
    v.map_or_else(|| "never".into(), |x| x.to_string())
}

fn fmt_opt_u64(v: Option<u64>) -> String {
    v.map_or_else(|| "never".into(), |x| format!("{:.1}kB", x as f64 / 1e3))
}

fn base_cfg() -> anyhow::Result<ExperimentConfig> {
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    common::apply_env(&mut cfg, 60);
    cfg.target_acc = cfg.target_acc.min(0.5);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 2, mixing: MixingRule::Constant { alpha: 0.9 } };
    Ok(cfg)
}

fn summarize(m: &RunMetrics) -> (Option<usize>, Option<u64>, u64, f64) {
    (m.rounds_to_target(), m.bytes_up_to_target(), m.total_bytes_up(), m.best_accuracy())
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let mut rec = Recorder::default();
    let want_json =
        std::env::args().any(|a| a == "--json") || std::env::var("VAFL_BENCH_JSON").is_ok();

    // Dense baseline: the byte-CCR denominator for every topk row.
    common::section("Dense baseline (straggler_wan, barrier-free, buffer 2)");
    let dense = experiments::run(&base_cfg()?)?;
    let dense_bytes = dense.metrics.total_bytes_up();
    println!(
        "dense: rounds_to_tgt={}  bytes_to_tgt={}  total_up={:.1}kB  best_acc={:.4}",
        fmt_opt_usize(dense.metrics.rounds_to_target()),
        fmt_opt_u64(dense.metrics.bytes_up_to_target()),
        dense_bytes as f64 / 1e3,
        dense.best_accuracy,
    );
    rec.push(vec![
        ("section", Value::Str("compression_sweep".into())),
        ("name", Value::Str("dense".into())),
        ("rounds_to_target", opt_usize(dense.metrics.rounds_to_target())),
        ("bytes_up_to_target", opt_u64(dense.metrics.bytes_up_to_target())),
        ("total_bytes_up", Value::from(dense_bytes as usize)),
        ("best_acc", Value::from(dense.best_accuracy)),
    ]);

    common::section("Static k_fraction sweep vs adaptive compression controller");
    println!(
        "{:<26} {:>14} {:>14} {:>12} {:>10} {:>10} {:>10}",
        "configuration", "rounds-to-tgt", "bytes-to-tgt", "total_up", "ccr_bytes", "best_acc", "decisions"
    );
    let mut best_fixed_bytes: Option<u64> = None;
    for kf in [0.05f64, 0.1, 0.25, 0.5, 1.0] {
        let mut c = base_cfg()?;
        c.compression =
            CompressionConfig { mode: CompressionMode::TopK, k_fraction: kf, error_feedback: true, ..Default::default() };
        let out = experiments::run(&c)?;
        let (rounds, bytes_tgt, total_up, best) = summarize(&out.metrics);
        if let Some(b) = bytes_tgt {
            best_fixed_bytes = Some(best_fixed_bytes.map_or(b, |x: u64| x.min(b)));
        }
        println!(
            "{:<26} {:>14} {:>14} {:>10.1}kB {:>10.4} {:>10.4} {:>10}",
            format!("fixed kf={kf}"),
            fmt_opt_usize(rounds),
            fmt_opt_u64(bytes_tgt),
            total_up as f64 / 1e3,
            ccr_bytes(dense_bytes, total_up),
            best,
            0,
        );
        rec.push(vec![
            ("section", Value::Str("compression_sweep".into())),
            ("name", Value::Str(format!("fixed_kf_{kf}"))),
            ("k_fraction", Value::from(kf)),
            ("rounds_to_target", opt_usize(rounds)),
            ("bytes_up_to_target", opt_u64(bytes_tgt)),
            ("total_bytes_up", Value::from(total_up as usize)),
            ("ccr_bytes_vs_dense", Value::from(ccr_bytes(dense_bytes, total_up))),
            ("best_acc", Value::from(best)),
            ("decisions", Value::from(0usize)),
        ]);
    }
    // Adaptive: compression controller only, starting mid-grid.
    let mut a = base_cfg()?;
    a.compression =
        CompressionConfig { mode: CompressionMode::TopK, k_fraction: 0.25, error_feedback: true, ..Default::default() };
    a.control = ControlConfig {
        enabled: true,
        staleness: false,
        rebalance: false,
        interval: 2,
        window: 8,
        k_fraction_min: 0.05,
        k_fraction_max: 1.0,
        ..Default::default()
    };
    let out = experiments::run(&a)?;
    let (rounds, adaptive_bytes_tgt, total_up, best) = summarize(&out.metrics);
    let decisions = out.metrics.control_records.len();
    println!(
        "{:<26} {:>14} {:>14} {:>10.1}kB {:>10.4} {:>10.4} {:>10}",
        "adaptive (start kf=0.25)",
        fmt_opt_usize(rounds),
        fmt_opt_u64(adaptive_bytes_tgt),
        total_up as f64 / 1e3,
        ccr_bytes(dense_bytes, total_up),
        best,
        decisions,
    );
    rec.push(vec![
        ("section", Value::Str("compression_sweep".into())),
        ("name", Value::Str("adaptive_compression".into())),
        ("k_fraction", Value::from(0.25)),
        ("rounds_to_target", opt_usize(rounds)),
        ("bytes_up_to_target", opt_u64(adaptive_bytes_tgt)),
        ("total_bytes_up", Value::from(total_up as usize)),
        ("ccr_bytes_vs_dense", Value::from(ccr_bytes(dense_bytes, total_up))),
        ("best_acc", Value::from(best)),
        ("decisions", Value::from(decisions)),
    ]);
    match (adaptive_bytes_tgt, best_fixed_bytes) {
        (Some(a), Some(f)) if a <= f => println!(
            "=> adaptive bytes-to-target {:.1}kB <= best fixed {:.1}kB",
            a as f64 / 1e3,
            f as f64 / 1e3
        ),
        (Some(a), Some(f)) => println!(
            "=> adaptive bytes-to-target {:.1}kB vs best fixed {:.1}kB ({:+.1}%)",
            a as f64 / 1e3,
            f as f64 / 1e3,
            (a as f64 / f as f64 - 1.0) * 100.0
        ),
        _ => println!("=> a configuration never reached the target; raise VAFL_BENCH_ROUNDS"),
    }

    common::section("Static buffer_k sweep vs adaptive staleness controller");
    println!(
        "{:<26} {:>14} {:>14} {:>10} {:>10}",
        "configuration", "rounds-to-tgt", "vtime-to-tgt", "best_acc", "decisions"
    );
    for k in [1usize, 2, 4] {
        let mut c = base_cfg()?;
        c.async_engine.buffer_k = k;
        let out = experiments::run(&c)?;
        println!(
            "{:<26} {:>14} {:>14} {:>10.4} {:>10}",
            format!("fixed buffer_k={k}"),
            fmt_opt_usize(out.metrics.rounds_to_target()),
            out.metrics
                .vtime_to_target()
                .map_or_else(|| "never".to_string(), |v| format!("{v:.1}s")),
            out.best_accuracy,
            0,
        );
        rec.push(vec![
            ("section", Value::Str("staleness_sweep".into())),
            ("name", Value::Str(format!("fixed_buffer_{k}"))),
            ("buffer_k", Value::from(k)),
            ("rounds_to_target", opt_usize(out.metrics.rounds_to_target())),
            (
                "vtime_to_target_s",
                out.metrics.vtime_to_target().map(Value::from).unwrap_or(Value::Null),
            ),
            ("best_acc", Value::from(out.best_accuracy)),
            ("decisions", Value::from(0usize)),
        ]);
    }
    let mut s = base_cfg()?;
    s.control = ControlConfig {
        enabled: true,
        compression: false,
        rebalance: false,
        interval: 2,
        window: 8,
        staleness_target: 1.0,
        staleness_deadband: 0.5,
        buffer_k_min: 1,
        buffer_k_max: 4,
        ..Default::default()
    };
    let out = experiments::run(&s)?;
    let decisions = out.metrics.control_records.len();
    println!(
        "{:<26} {:>14} {:>14} {:>10.4} {:>10}",
        "adaptive (start k=2)",
        fmt_opt_usize(out.metrics.rounds_to_target()),
        out.metrics
            .vtime_to_target()
            .map_or_else(|| "never".to_string(), |v| format!("{v:.1}s")),
        out.best_accuracy,
        decisions,
    );
    rec.push(vec![
        ("section", Value::Str("staleness_sweep".into())),
        ("name", Value::Str("adaptive_staleness".into())),
        ("buffer_k", Value::from(2usize)),
        ("rounds_to_target", opt_usize(out.metrics.rounds_to_target())),
        (
            "vtime_to_target_s",
            out.metrics.vtime_to_target().map(Value::from).unwrap_or(Value::Null),
        ),
        ("best_acc", Value::from(out.best_accuracy)),
        ("decisions", Value::from(decisions)),
    ]);

    if want_json {
        rec.write_json("BENCH_control.json")?;
        println!("wrote BENCH_control.json ({} rows)", rec.rows.len());
    }
    Ok(())
}
