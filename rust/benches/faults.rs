//! Bench: fault injection and crash recovery.
//!
//!     cargo bench --bench faults [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1.
//!
//! Two sections:
//!
//! 1. A fault-intensity grid on experiment b's 7-client fleet under the
//!    straggler-heavy WAN with the barrier-free engine: clean / light /
//!    moderate / heavy plans. Per row: best/final accuracy,
//!    rounds-to-target, final virtual time, total uplink bytes (the
//!    retransmit + duplicate wire tax), and the six fault counters —
//!    showing what the recovery machinery costs and that training still
//!    converges through it.
//!
//! 2. Checkpoint overhead: the same moderate-fault run at
//!    `checkpoint_every` in {0, 4, 1}, reporting wall time per run and
//!    the serialized checkpoint size, plus a kill/restore smoke check
//!    (resumed final accuracy bitwise equal to the uninterrupted run).
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) writes every row to
//! `BENCH_faults.json`.

mod common;

use vafl::config::{AsyncEngineConfig, EngineMode, ExperimentConfig, FaultConfig};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};
use vafl::metrics::FaultCounters;
use vafl::util::json::{obj, Value};

fn base_cfg() -> anyhow::Result<ExperimentConfig> {
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    common::apply_env(&mut cfg, 40);
    cfg.target_acc = cfg.target_acc.min(0.5);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    Ok(cfg)
}

fn plan(name: &str) -> FaultConfig {
    match name {
        "clean" => FaultConfig::default(),
        "light" => FaultConfig {
            enabled: true,
            loss_prob: 0.05,
            corrupt_prob: 0.01,
            dup_prob: 0.02,
            down_loss_prob: 0.02,
            reorder_prob: 0.1,
            reorder_window: 0.25,
            ..Default::default()
        },
        "moderate" => FaultConfig {
            enabled: true,
            loss_prob: 0.15,
            corrupt_prob: 0.05,
            dup_prob: 0.10,
            down_loss_prob: 0.10,
            down_corrupt_prob: 0.05,
            reorder_prob: 0.2,
            reorder_window: 0.5,
            max_retransmits: 3,
            crash_prob: 0.01,
            crash_downtime: 2.0,
            ..Default::default()
        },
        "heavy" => FaultConfig {
            enabled: true,
            loss_prob: 0.30,
            corrupt_prob: 0.10,
            dup_prob: 0.15,
            down_loss_prob: 0.20,
            down_corrupt_prob: 0.10,
            reorder_prob: 0.4,
            reorder_window: 1.0,
            max_retransmits: 4,
            crash_prob: 0.03,
            crash_downtime: 4.0,
            outage_every: 60.0,
            outage_len: 4.0,
            ..Default::default()
        },
        other => panic!("unknown plan {other}"),
    }
}

fn totals(m: &vafl::metrics::RunMetrics) -> FaultCounters {
    let mut t = FaultCounters::default();
    for r in &m.records {
        t.add(&r.faults);
    }
    t
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let want_json =
        std::env::args().any(|a| a == "--json") || std::env::var("VAFL_BENCH_JSON").is_ok();
    let mut rows: Vec<Value> = Vec::new();

    common::section("Fault-intensity grid (straggler_wan, barrier-free, buffer 2)");
    println!(
        "{:<10} {:>9} {:>10} {:>12} {:>12} {:>7} {:>6} {:>7} {:>5} {:>7} {:>6}",
        "plan", "best_acc", "final_acc", "vtime_final", "bytes_up", "retx", "lost", "corrupt",
        "dup", "resync", "recov"
    );
    for name in ["clean", "light", "moderate", "heavy"] {
        let mut cfg = base_cfg()?;
        cfg.faults = plan(name);
        let out = experiments::run(&cfg)?;
        let t = totals(&out.metrics);
        let vtime = out.metrics.records.last().map_or(0.0, |r| r.vtime);
        let bytes_up = out.metrics.total_bytes_up();
        println!(
            "{:<10} {:>9.4} {:>10.4} {:>12.1} {:>12} {:>7} {:>6} {:>7} {:>5} {:>7} {:>6}",
            name,
            out.best_accuracy,
            out.final_accuracy,
            vtime,
            bytes_up,
            t.retransmits,
            t.frames_lost,
            t.frames_corrupt,
            t.dup_suppressed,
            t.resyncs,
            t.recoveries,
        );
        rows.push(obj(vec![
            ("section", Value::Str("fault_grid".into())),
            ("plan", Value::Str(name.into())),
            ("best_acc", Value::from(out.best_accuracy)),
            ("final_acc", Value::from(out.final_accuracy)),
            (
                "rounds_to_target",
                out.metrics.rounds_to_target().map(Value::from).unwrap_or(Value::Null),
            ),
            ("vtime_final", Value::from(vtime)),
            ("bytes_up_total", Value::from(bytes_up as usize)),
            ("retransmits", Value::from(t.retransmits as usize)),
            ("frames_lost", Value::from(t.frames_lost as usize)),
            ("frames_corrupt", Value::from(t.frames_corrupt as usize)),
            ("dup_suppressed", Value::from(t.dup_suppressed as usize)),
            ("resyncs", Value::from(t.resyncs as usize)),
            ("recoveries", Value::from(t.recoveries as usize)),
            ("link_capped", Value::from(out.metrics.link_capped as usize)),
        ]));
    }

    common::section("Checkpoint overhead (moderate faults)");
    println!("{:<18} {:>10} {:>12}", "checkpoint_every", "wall_ms", "ckpt_bytes");
    let mut ckpt_bytes_at_1 = 0usize;
    for every in [0usize, 4, 1] {
        let mut cfg = base_cfg()?;
        cfg.faults = FaultConfig { checkpoint_every: every, ..plan("moderate") };
        let t0 = std::time::Instant::now();
        let (mut server, mut exec) = experiments::build(&cfg)?;
        server.run_event_driven(exec.as_mut())?;
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let ckpt = server.checkpoint_bytes().map_or(0, |b| b.len());
        if every == 1 {
            ckpt_bytes_at_1 = ckpt;
        }
        println!("{every:<18} {wall_ms:>10.1} {ckpt:>12}");
        rows.push(obj(vec![
            ("section", Value::Str("checkpoint_overhead".into())),
            ("checkpoint_every", Value::from(every)),
            ("wall_ms", Value::from(wall_ms)),
            ("ckpt_bytes", Value::from(ckpt)),
        ]));
    }

    // Kill/restore smoke check: resume from the mid-run checkpoint and
    // demand the committed stream converges to the identical final state.
    let mut cfg = base_cfg()?;
    cfg.faults = FaultConfig { checkpoint_every: 1, ..plan("moderate") };
    let (mut full, mut ef) = experiments::build(&cfg)?;
    full.run_event_driven(ef.as_mut())?;
    let stop = (cfg.rounds / 2).max(1);
    let (mut killed, mut ek) = experiments::build(&cfg)?;
    killed.stop_after(stop);
    killed.run_event_driven(ek.as_mut())?;
    let blob = killed.checkpoint_bytes().expect("checkpoint after stop_after").to_vec();
    let (mut resumed, mut er) = experiments::build(&cfg)?;
    resumed.restore_checkpoint(&blob);
    resumed.run_event_driven(er.as_mut())?;
    let (a, b) = (
        full.metrics.records.last().expect("full run empty"),
        resumed.metrics.records.last().expect("resumed run empty"),
    );
    let identical = a.vtime.to_bits() == b.vtime.to_bits()
        && a.global_acc.to_bits() == b.global_acc.to_bits()
        && full.metrics.records.len() == resumed.metrics.records.len();
    println!(
        "kill@{stop}/restore: {} (final vtime {:.1}, acc {:.4}, ckpt {} B)",
        if identical { "bitwise-identical resume OK" } else { "MISMATCH" },
        a.vtime,
        a.global_acc,
        ckpt_bytes_at_1,
    );
    assert!(identical, "kill/restore diverged from the uninterrupted run");
    rows.push(obj(vec![
        ("section", Value::Str("kill_restore".into())),
        ("stop_after", Value::from(stop)),
        ("identical", Value::from(identical)),
        ("ckpt_bytes", Value::from(blob.len())),
    ]));

    if want_json {
        let doc = obj(vec![("bench", Value::Str("faults".into())), ("rows", Value::Arr(rows))]);
        std::fs::write("BENCH_faults.json", doc.to_string_pretty())?;
        println!("wrote BENCH_faults.json");
    }
    Ok(())
}
