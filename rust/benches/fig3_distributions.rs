//! Bench: regenerate **Fig. 3** — per-client label/sample distributions of
//! the four experiment datasets, plus partitioner throughput.
//!
//!     cargo bench --bench fig3_distributions

mod common;

use vafl::data::stats::DistributionTable;
use vafl::data::synth::SynthConfig;
use vafl::data::{partition, PartitionScheme};
use vafl::experiments::{self, figures};
use vafl::util::rng::Rng;
use vafl::util::timer::bench;

fn main() -> anyhow::Result<()> {
    common::section("Fig. 3 — Dataset distribution of clients");
    let mut tables = Vec::new();
    for which in ['a', 'b', 'c', 'd'] {
        let cfg = experiments::preset(which)?;
        let synth = SynthConfig { pixel_noise: cfg.pixel_noise, ..Default::default() };
        let (shards, _) = partition(
            cfg.partition,
            cfg.num_clients,
            cfg.samples_per_client,
            cfg.test_samples,
            &synth,
            &Rng::new(cfg.seed),
        );
        tables.push((cfg.name, DistributionTable::from_shards(&shards)));
    }
    println!("{}", figures::fig3(&tables));

    common::section("partitioner + generator throughput");
    let synth = SynthConfig::default();
    for (label, scheme) in [
        ("iid", PartitionScheme::Iid),
        ("paper_skew", PartitionScheme::PaperSkew),
        ("dirichlet(0.5)", PartitionScheme::Dirichlet { alpha: 0.5 }),
    ] {
        let stats = bench(1, 5, || {
            partition(scheme, 7, 500, 100, &synth, &Rng::new(1))
        });
        println!("{}", stats.format_line(&format!("partition 7x500 {label}")));
    }
    Ok(())
}
