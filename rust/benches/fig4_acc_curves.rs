//! Bench: regenerate **Fig. 4** — global-model accuracy curves for AFL /
//! EAFLM / VAFL in each experiment a–d.
//!
//!     cargo bench --bench fig4_acc_curves
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1. Curves are also
//! written to results/bench/fig4_*.csv.

mod common;

use vafl::experiments::{self, figures};
use vafl::metrics::csv::write_rounds_csv;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    for which in ['a', 'b', 'c', 'd'] {
        let mut cfg = experiments::preset(which)?;
        common::apply_env(&mut cfg, 40);
        common::section(&format!("Fig. 4({which}) — experiment {which}"));
        let outs = experiments::run_all_algorithms(&cfg)?;
        let runs: Vec<_> = outs.into_iter().map(|o| o.metrics).collect();
        println!("{}", figures::fig4(&cfg.name, &runs));
        std::fs::create_dir_all("results/bench")?;
        for m in &runs {
            write_rounds_csv(m, format!("results/bench/fig4_{}_{}.csv", m.experiment, m.algorithm))?;
        }
        // Convergence-speed summary: rounds to 80% of best accuracy.
        for m in &runs {
            let best = m.best_accuracy();
            let fast = m
                .acc_curve()
                .iter()
                .find(|(_, a)| *a >= 0.8 * best)
                .map(|(r, _)| *r);
            println!(
                "{:<6} best={:.4} rounds_to_80%_of_best={:?}",
                m.algorithm, best, fast
            );
        }
    }
    Ok(())
}
