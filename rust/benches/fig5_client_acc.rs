//! Bench: regenerate **Fig. 5** — per-client accuracy curves under VAFL
//! for each experiment a–d.
//!
//!     cargo bench --bench fig5_client_acc
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1. Curves are also
//! written to results/bench/fig5_*.csv.

mod common;

use vafl::config::Algorithm;
use vafl::experiments::{self, figures};
use vafl::metrics::csv::write_client_acc_csv;

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    for which in ['a', 'b', 'c', 'd'] {
        let mut cfg = experiments::preset(which)?;
        cfg.algorithm = Algorithm::Vafl;
        common::apply_env(&mut cfg, 40);
        common::section(&format!("Fig. 5({which}) — per-client Acc under VAFL"));
        let out = experiments::run(&cfg)?;
        println!("{}", figures::fig5(&cfg.name, &out.metrics));
        std::fs::create_dir_all("results/bench")?;
        write_client_acc_csv(&out.metrics, format!("results/bench/fig5_{which}.csv"))?;
        // Per-client spread at the end of training (Non-IID experiments
        // show a visibly wider spread — the paper's qualitative claim).
        let curves = out.metrics.client_acc_curves();
        let finals: Vec<f64> = curves
            .iter()
            .filter_map(|c| c.last().map(|&(_, a)| a))
            .collect();
        let s = vafl::util::timer::summarize(&finals);
        println!(
            "final client acc: mean={:.4} sd={:.4} min={:.4} max={:.4}",
            s.mean, s.sd, s.min, s.max
        );
    }
    Ok(())
}
