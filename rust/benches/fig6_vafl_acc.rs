//! Bench: regenerate **Fig. 6** — VAFL global accuracy across the four
//! experiments on one chart.
//!
//!     cargo bench --bench fig6_vafl_acc
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1.

mod common;

use vafl::config::Algorithm;
use vafl::experiments::{self, figures};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    common::section("Fig. 6 — VAFL Acc across experiments a-d");
    let mut runs = Vec::new();
    for which in ['a', 'b', 'c', 'd'] {
        let mut cfg = experiments::preset(which)?;
        cfg.algorithm = Algorithm::Vafl;
        common::apply_env(&mut cfg, 40);
        let out = experiments::run(&cfg)?;
        println!(
            "experiment {which}: best acc {:.4}, comm->target {:?}, uploads {}",
            out.best_accuracy, out.comm_times_to_target, out.total_uploads
        );
        runs.push(out.metrics);
    }
    println!("\n{}", figures::fig6(&runs));
    Ok(())
}
