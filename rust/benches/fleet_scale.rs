//! Bench: fleet-scale memory + flush cost for the virtualized client
//! fleet — a clients × active-set × edge-fanout grid up to 10^6 clients
//! on the barrier-free engine.
//!
//!     cargo bench --bench fleet_scale [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (flushes per run, default 6),
//! VAFL_BENCH_MAX_CLIENTS (cap the sweep, default 1_000_000).
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) writes every row to
//! `BENCH_fleet_scale.json`: peak RSS (VmHWM) and RSS growth per run,
//! wall-clock per flush, the compact bookkeeping footprints (parked
//! records, u8 registry), and the fleet lifecycle counters
//! (hydrations / parks / peak simultaneously-active).
//!
//! The headline claim: resident memory scales with the *concurrency
//! window* (`fleet.active_set`), not fleet size — dense client state for
//! a 10^6-client fleet would be n · dim · 4 B · 2 (params + sync base)
//! ≈ 2.6 GB for the 320-param mock model alone, while the active-set
//! runs keep at most `active_set` clients hydrated and park the rest as
//! ~100 B records. The bench asserts the process high-water mark stays
//! under half the dense floor.

mod common;

use vafl::config::{AsyncEngineConfig, Backend, EngineMode, ExperimentConfig};
use vafl::coordinator::policy::make_policy;
use vafl::coordinator::server::{build_server_with_data, Server};
use vafl::coordinator::MixingRule;
use vafl::data::synth::SynthConfig;
use vafl::data::{LazyPartition, PartitionScheme};
use vafl::fleet::FleetData;
use vafl::runtime::{Executor, MockExecutor};
use vafl::util::json::{obj, Value};
use vafl::util::rng::Rng;

/// Collects every bench row for the optional JSON artifact.
#[derive(Default)]
struct Recorder {
    rows: Vec<Value>,
}

impl Recorder {
    fn push(&mut self, fields: Vec<(&'static str, Value)>) {
        self.rows.push(obj(fields));
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        let doc = obj(vec![
            ("bench", Value::Str("fleet_scale".into())),
            ("rows", Value::Arr(self.rows.clone())),
        ]);
        std::fs::write(path, doc.to_string_pretty())
    }
}

/// `(VmRSS, VmHWM)` in kB from `/proc/self/status`; `(0, 0)` off Linux.
fn rss_kb() -> (u64, u64) {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return (0, 0);
    };
    let field = |key: &str| {
        status
            .lines()
            .find(|l| l.starts_with(key))
            .and_then(|l| l.split_whitespace().nth(1))
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(0)
    };
    (field("VmRSS:"), field("VmHWM:"))
}

fn fleet_cfg(clients: usize, active_set: usize, edge_fanout: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig {
        name: format!("fleet_scale_n{clients}_a{active_set}_e{edge_fanout}"),
        num_clients: clients,
        partition: PartitionScheme::Iid,
        samples_per_client: 64,
        test_samples: 200,
        probe_samples: 32,
        rounds,
        local_passes: 1,
        batches_per_pass: 1,
        lr: 0.5,
        target_acc: 2.0, // never reached; this bench measures cost, not acc
        seed: 11,
        backend: Backend::Mock,
        engine: EngineMode::BarrierFree,
        async_engine: AsyncEngineConfig {
            buffer_k: 32.min(active_set.max(1)),
            mixing: MixingRule::Constant { alpha: 0.9 },
        },
        ..Default::default()
    };
    cfg.engine_opts.edge_fanout = edge_fanout;
    cfg.fleet.active_set = active_set;
    // O(n)-per-flush record columns would dominate the very memory this
    // bench measures.
    cfg.fleet.compact_records = true;
    cfg
}

/// Build the server over a *lazy* partition (no shard pixels resident up
/// front) and run the barrier-free engine to `cfg.rounds` flushes.
fn run_one(cfg: &ExperimentConfig) -> anyhow::Result<(Server, f64, f64)> {
    cfg.validate()?;
    let root_rng = Rng::new(cfg.seed);
    let synth_cfg = SynthConfig::default();
    let build_start = std::time::Instant::now();
    let lazy = LazyPartition::new(
        cfg.partition,
        cfg.num_clients,
        cfg.samples_per_client,
        &synth_cfg,
        &root_rng,
    );
    let test = lazy.test_set(cfg.test_samples);
    let mut exec = MockExecutor::standard();
    let p = exec.param_count();
    let policy = make_policy(cfg.algorithm, cfg.value_fn, cfg.eaflm);
    let payload = cfg.upload_precision.payload_bytes(p);
    let mut server = build_server_with_data(
        cfg,
        FleetData::Lazy(lazy),
        test,
        vec![0.0; p],
        policy,
        exec.batch_size(),
        (2_000_000, 600_000),
        payload,
    );
    let build_s = build_start.elapsed().as_secs_f64();
    let run_start = std::time::Instant::now();
    server.run_event_driven(&mut exec)?;
    let run_s = run_start.elapsed().as_secs_f64();
    Ok((server, build_s, run_s))
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let mut rec = Recorder::default();
    let want_json = std::env::args().any(|a| a == "--json")
        || std::env::var("VAFL_BENCH_JSON").is_ok();
    let rounds = std::env::var("VAFL_BENCH_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(6usize);
    let max_clients = std::env::var("VAFL_BENCH_MAX_CLIENTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1_000_000usize);

    common::section("Fleet scale — clients x active-set x edge-fanout grid");
    println!(
        "{:>9} {:>7} {:>7} {:>9} {:>9} {:>9} {:>10} {:>10} {:>9} {:>9} {:>7}",
        "clients",
        "active",
        "fanout",
        "build_ms",
        "run_ms",
        "flush_ms",
        "rss_kb",
        "hwm_kb",
        "parked_kb",
        "hydr",
        "peak"
    );

    let model_dim = MockExecutor::standard().param_count();
    let mut largest_hwm_kb = 0u64;
    let mut largest_n = 0usize;
    for &clients in &[10_000usize, 100_000, 1_000_000] {
        if clients > max_clients {
            println!("(skipping n={clients}: VAFL_BENCH_MAX_CLIENTS={max_clients})");
            continue;
        }
        for &active_set in &[256usize, 1024] {
            for &edge_fanout in &[1usize, 8] {
                let cfg = fleet_cfg(clients, active_set, edge_fanout, rounds);
                let (rss_before, _) = rss_kb();
                let (server, build_s, run_s) = run_one(&cfg)?;
                let (rss_after, hwm) = rss_kb();
                let fleet = server.fleet();
                let parked_kb = fleet.approx_parked_bytes() / 1024;
                let registry_b = server.registry.approx_bytes();
                let flushes = server.metrics.records.len().max(1);
                let flush_ms = run_s * 1e3 / flushes as f64;
                assert!(
                    fleet.peak_active() <= active_set,
                    "active-set window violated: peak {} > {}",
                    fleet.peak_active(),
                    active_set
                );
                println!(
                    "{:>9} {:>7} {:>7} {:>9.1} {:>9.1} {:>9.2} {:>10} {:>10} {:>9} {:>9} {:>7}",
                    clients,
                    active_set,
                    edge_fanout,
                    build_s * 1e3,
                    run_s * 1e3,
                    flush_ms,
                    rss_after,
                    hwm,
                    parked_kb,
                    fleet.hydrations(),
                    fleet.peak_active()
                );
                if clients >= largest_n {
                    largest_n = clients;
                    largest_hwm_kb = largest_hwm_kb.max(hwm);
                }
                rec.push(vec![
                    ("section", Value::Str("fleet_grid".into())),
                    ("clients", Value::Num(clients as f64)),
                    ("active_set", Value::Num(active_set as f64)),
                    ("edge_fanout", Value::Num(edge_fanout as f64)),
                    ("rounds", Value::Num(flushes as f64)),
                    ("build_ms", Value::Num(build_s * 1e3)),
                    ("run_ms", Value::Num(run_s * 1e3)),
                    ("flush_ms", Value::Num(flush_ms)),
                    ("rss_before_kb", Value::Num(rss_before as f64)),
                    ("rss_after_kb", Value::Num(rss_after as f64)),
                    ("vm_hwm_kb", Value::Num(hwm as f64)),
                    ("parked_bytes", Value::Num(fleet.approx_parked_bytes() as f64)),
                    ("registry_bytes", Value::Num(registry_b as f64)),
                    ("hydrations", Value::Num(fleet.hydrations() as f64)),
                    ("parks", Value::Num(fleet.parks() as f64)),
                    ("peak_active", Value::Num(fleet.peak_active() as f64)),
                    ("engine_events", Value::Num(server.metrics.engine_events as f64)),
                ]);
            }
        }
    }

    // Sublinearity check: dense client state alone for the largest fleet
    // would be n · dim · 4 B · 2 (params + sync base). The whole process
    // must peak well under half of that.
    if largest_n >= 1_000_000 {
        let dense_floor_kb = (largest_n as u64 * model_dim as u64 * 8) / 1024;
        println!(
            "\npeak RSS {largest_hwm_kb} kB vs dense-fleet floor {dense_floor_kb} kB \
             ({largest_n} clients x {model_dim} params)"
        );
        assert!(
            largest_hwm_kb < dense_floor_kb / 2,
            "fleet memory is not sublinear: peak RSS {largest_hwm_kb} kB >= half the \
             dense floor {dense_floor_kb} kB"
        );
        println!("=> resident memory tracks the active-set window, not fleet size");
    }

    if want_json {
        rec.write_json("BENCH_fleet_scale.json")?;
        println!("wrote BENCH_fleet_scale.json ({} rows)", rec.rows.len());
    }
    Ok(())
}
