//! Bench: observability-plane overhead and export cost.
//!
//!     cargo bench --bench obs [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (default 30), VAFL_BENCH_MOCK=1.
//!
//! Two sections:
//!
//! 1. The overhead gate: identical barrier-free runs (serial and
//!    threaded) with the plane disarmed vs armed. Arming must cost at
//!    most 5% wall time at the median (plus a small absolute epsilon so
//!    sub-second runs don't gate on scheduler noise) — the hooks are one
//!    branch when disarmed and a Vec push + ring write when armed.
//! 2. Export cost: span counts, drop counts, and the wall time + output
//!    size of each exporter (Chrome trace JSON, Prometheus text) on an
//!    armed faulty run.
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) writes every row to
//! `BENCH_obs.json`.

mod common;

use vafl::config::{AsyncEngineConfig, EngineMode, ExperimentConfig, FaultConfig};
use vafl::coordinator::MixingRule;
use vafl::experiments;
use vafl::util::json::{obj, Value};
use vafl::util::timer::bench;

/// Median-wall-overhead budget for arming the plane.
const GATE_RELATIVE: f64 = 1.05;
/// Absolute slack so millisecond-scale CI runs don't gate on noise.
const GATE_EPSILON_S: f64 = 0.015;

fn base_cfg() -> anyhow::Result<ExperimentConfig> {
    let mut cfg = experiments::preset('b')?;
    common::apply_env(&mut cfg, 30);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine = AsyncEngineConfig {
        buffer_k: 2,
        mixing: MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 },
    };
    Ok(cfg)
}

fn faulty() -> FaultConfig {
    FaultConfig {
        enabled: true,
        loss_prob: 0.15,
        corrupt_prob: 0.05,
        dup_prob: 0.10,
        down_loss_prob: 0.10,
        reorder_prob: 0.2,
        reorder_window: 0.5,
        max_retransmits: 3,
        ..Default::default()
    }
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let want_json =
        std::env::args().any(|a| a == "--json") || std::env::var("VAFL_BENCH_JSON").is_ok();
    let mut rows: Vec<Value> = Vec::new();

    common::section("Overhead gate: disarmed vs armed (p50 wall per full run)");
    for (label, threaded) in [("barrier_free_serial", false), ("barrier_free_threaded", true)] {
        let mut cfg = base_cfg()?;
        cfg.faults = faulty();
        cfg.engine_opts.threaded = threaded;
        if threaded {
            cfg.engine_opts.workers = 4;
        }
        let mut run_with = |enabled: bool| {
            let mut c = cfg.clone();
            c.obs.enabled = enabled;
            bench(1, 5, || experiments::run(&c).unwrap())
        };
        let off = run_with(false);
        let on = run_with(true);
        println!("{}", off.format_line(&format!("{label} disarmed")));
        println!("{}", on.format_line(&format!("{label} armed")));
        let off_s = off.p50.as_secs_f64();
        let on_s = on.p50.as_secs_f64();
        let budget = off_s * GATE_RELATIVE + GATE_EPSILON_S;
        let overhead_pct = (on_s / off_s - 1.0) * 100.0;
        println!(
            "{label}: armed overhead {overhead_pct:+.2}% (budget 5% + {:.0}ms) — {}",
            GATE_EPSILON_S * 1e3,
            if on_s <= budget { "OK" } else { "FAIL" }
        );
        assert!(
            on_s <= budget,
            "{label}: armed p50 {on_s:.4}s exceeds {budget:.4}s (disarmed {off_s:.4}s)"
        );
        rows.push(obj(vec![
            ("section", Value::Str("overhead_gate".into())),
            ("case", Value::Str(label.into())),
            ("disarmed_p50_s", Value::from(off_s)),
            ("armed_p50_s", Value::from(on_s)),
            ("overhead_pct", Value::from(overhead_pct)),
            ("budget_s", Value::from(budget)),
            ("pass", Value::from(on_s <= budget)),
        ]));
    }

    common::section("Export cost (armed faulty run)");
    let mut cfg = base_cfg()?;
    cfg.faults = FaultConfig { checkpoint_every: 4, ..faulty() };
    cfg.obs.enabled = true;
    let out = experiments::run(&cfg)?;
    let report = out.metrics.obs.as_ref().expect("armed run must report");
    let (trace, trace_dt) = vafl::util::timer::time_once(|| {
        vafl::obs::chrome_trace_json(report).to_string_compact()
    });
    let (prom, prom_dt) = vafl::util::timer::time_once(|| vafl::obs::prometheus_text(report));
    println!(
        "spans={} dropped={} trace_json={}B in {:?} prometheus={}B in {:?}",
        report.spans.len(),
        report.dropped,
        trace.len(),
        trace_dt,
        prom.len(),
        prom_dt,
    );
    rows.push(obj(vec![
        ("section", Value::Str("export_cost".into())),
        ("spans", Value::from(report.spans.len())),
        ("dropped", Value::from(report.dropped as usize)),
        ("trace_json_bytes", Value::from(trace.len())),
        ("trace_json_ms", Value::from(trace_dt.as_secs_f64() * 1e3)),
        ("prometheus_bytes", Value::from(prom.len())),
        ("prometheus_ms", Value::from(prom_dt.as_secs_f64() * 1e3)),
    ]));

    if want_json {
        let doc = obj(vec![("bench", Value::Str("obs".into())), ("rows", Value::Arr(rows))]);
        std::fs::write("BENCH_obs.json", doc.to_string_pretty())?;
        println!("wrote BENCH_obs.json");
    }
    Ok(())
}
