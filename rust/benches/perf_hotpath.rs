//! Bench: L3 hot paths + the PJRT runtime — the numbers behind
//! EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench perf_hotpath
//!
//! Sections:
//!  1. coordinator primitives (aggregation, norms, value amplification)
//!  2. simulation substrate (event queue, netsim, data generation)
//!  3. PJRT runtime steps (skipped with VAFL_BENCH_MOCK=1 / no artifacts)
//!  4. end-to-end mock round (coordinator overhead with compute ~free)

mod common;

use vafl::config::ValueFnConfig;
use vafl::coordinator::aggregate::Aggregator;
use vafl::data::synth::{generate, SynthConfig};
use vafl::fleet::amplify_value;
use vafl::model::{l2_norm_sq, sq_distance};
use vafl::netsim::{LinkProfile, Message};
use vafl::runtime::Executor;
use vafl::sim::EventQueue;
use vafl::util::rng::Rng;
use vafl::util::timer::bench;

fn main() -> anyhow::Result<()> {
    let p = 17290usize; // current artifact parameter count

    common::section("1. coordinator primitives");
    let mut rng = Rng::new(1);
    let models: Vec<Vec<f32>> = (0..7)
        .map(|_| (0..p).map(|_| rng.gauss() as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1000usize; 7];
    let mut out = vec![0.0f32; p];
    let mut agg = Aggregator::new();
    let s = bench(10, 200, || agg.aggregate(&refs, &weights, &mut out));
    println!("{}", s.format_line(&format!("aggregate 7 x {p} params")));

    let s = bench(10, 500, || sq_distance(&models[0], &models[1]));
    println!("{}", s.format_line(&format!("sq_distance {p}")));
    let s = bench(10, 500, || l2_norm_sq(&models[0]));
    println!("{}", s.format_line(&format!("l2_norm_sq {p}")));
    let s = bench(10, 1000, || {
        amplify_value(1.5, 0.93, 7, ValueFnConfig::default())
    });
    println!("{}", s.format_line("amplify_value (Eq. 1 server side)"));

    common::section("2. simulation substrate");
    let s = bench(5, 50, || {
        let mut q = EventQueue::new();
        for i in 0..10_000 {
            q.schedule_at((i % 977) as f64, i);
        }
        while q.pop().is_some() {}
    });
    println!("{}", s.format_line("event queue 10k schedule+pop"));
    let link = LinkProfile::paper_lan();
    let mut nrng = Rng::new(2);
    let msg = Message::ModelUpload { payload_bytes: 4 * p as u64 + 64 };
    let s = bench(10, 1000, || link.transfer_seconds(&msg, &mut nrng));
    println!("{}", s.format_line("netsim transfer_seconds"));
    let synth = SynthConfig::default();
    let mut drng = Rng::new(3);
    let s = bench(2, 10, || generate(100, &synth, &mut drng));
    println!("{}", s.format_line("synthdigits generate 100 images"));

    common::section("3. PJRT runtime steps");
    if std::env::var("VAFL_BENCH_MOCK").is_err()
        && std::path::Path::new("artifacts/params_spec.json").exists()
    {
        let mut rt = vafl::runtime::PjrtRuntime::load("artifacts")?;
        let pc = rt.param_count();
        let (b, eb, d) = (rt.batch_size(), rt.eval_batch(), rt.input_dim());
        let params = rt.spec().load_init_params()?;
        let x = vec![0.5f32; b * d];
        let y: Vec<i32> = (0..b as i32).map(|i| i % 10).collect();
        let s = bench(3, 20, || rt.train_step(&params, &x, &y, 0.1).unwrap());
        println!("{}", s.format_line(&format!("pjrt train_step B={b}")));
        let xe = vec![0.5f32; eb * d];
        let ye: Vec<i32> = (0..eb as i32).map(|i| i % 10).collect();
        let s = bench(2, 10, || rt.eval_step(&params, &xe, &ye).unwrap());
        println!("{}", s.format_line(&format!("pjrt eval_step EB={eb}")));
        let g = vec![0.1f32; pc];
        let s = bench(5, 50, || rt.value(&g, &params, 0.9, 7.0).unwrap());
        println!("{}", s.format_line("pjrt value (Eq. 1 on artifact path)"));
    } else {
        println!("skipped (no artifacts / VAFL_BENCH_MOCK set)");
    }

    common::section("4. end-to-end mock round (coordinator overhead)");
    let mut cfg = vafl::experiments::preset('b')?;
    cfg.backend = vafl::config::Backend::Mock;
    cfg.rounds = 1;
    cfg.samples_per_client = 200;
    cfg.test_samples = 128;
    cfg.probe_samples = 64;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let (mut server, mut exec) = vafl::experiments::build(&cfg)?;
    let s = bench(2, 20, || server.run_round(exec.as_mut()).unwrap());
    println!("{}", s.format_line("full mock round, 7 clients"));
    Ok(())
}
