//! Bench: L3 hot paths + the PJRT runtime — the numbers behind
//! EXPERIMENTS.md §Perf.
//!
//!     cargo bench --bench perf_hotpath [-- --json]
//!
//! Sections:
//!  1. coordinator primitives (aggregation, norms, value amplification)
//!  2. simulation substrate (event queue, netsim, data generation)
//!  3. PJRT runtime steps (skipped with VAFL_BENCH_MOCK=1 / no artifacts)
//!  4. end-to-end mock round (coordinator overhead with compute ~free)
//!  5. fused dequantize-aggregate vs naive round_trip-then-aggregate
//!  6. parallel kernels: 1 vs N workers
//!  7. sparse top-k scatter-aggregation vs dense (O(K·k) vs O(K·n))
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) additionally writes every row to
//! `BENCH_hotpath.json` — and section 7's dense-vs-sparse sweep to
//! `BENCH_sparse.json` — so the perf trajectory is tracked across PRs.

mod common;

use vafl::config::ValueFnConfig;
use vafl::coordinator::aggregate::Aggregator;
use vafl::coordinator::Downlink;
use vafl::data::synth::{generate, generate_t, SynthConfig};
use vafl::fleet::amplify_value;
use vafl::model::quant::{Precision, QuantBuf};
use vafl::model::sparse::SparseDelta;
use vafl::model::{l2_norm_sq, sq_distance, weighted_average_into_t};
use vafl::netsim::{LinkProfile, Message};
use vafl::runtime::Executor;
use vafl::sim::EventQueue;
use vafl::util::json::{obj, Value};
use vafl::util::rng::Rng;
use vafl::util::timer::{bench, BenchStats};

/// Collects every bench row for the optional JSON artifact.
#[derive(Default)]
struct Recorder {
    rows: Vec<(String, BenchStats)>,
}

impl Recorder {
    fn emit(&mut self, name: &str, s: BenchStats) {
        println!("{}", s.format_line(name));
        self.rows.push((name.to_string(), s));
    }

    fn write_json_named(&self, path: &str, bench: &str) -> std::io::Result<()> {
        let rows: Vec<Value> = self
            .rows
            .iter()
            .map(|(name, s)| {
                obj(vec![
                    ("name", Value::Str(name.clone())),
                    ("iters", Value::Num(s.iters as f64)),
                    ("mean_ns", Value::Num(s.mean.as_nanos() as f64)),
                    ("p50_ns", Value::Num(s.p50.as_nanos() as f64)),
                    ("p95_ns", Value::Num(s.p95.as_nanos() as f64)),
                    ("min_ns", Value::Num(s.min.as_nanos() as f64)),
                ])
            })
            .collect();
        let doc = obj(vec![
            ("bench", Value::Str(bench.into())),
            ("rows", Value::Arr(rows)),
        ]);
        std::fs::write(path, doc.to_string_pretty())
    }

    fn write_json(&self, path: &str) -> std::io::Result<()> {
        self.write_json_named(path, "perf_hotpath")
    }
}

fn main() -> anyhow::Result<()> {
    let p = 17290usize; // current artifact parameter count
    let mut rec = Recorder::default();
    let want_json = std::env::args().any(|a| a == "--json")
        || std::env::var("VAFL_BENCH_JSON").is_ok();

    common::section("1. coordinator primitives");
    let mut rng = Rng::new(1);
    let models: Vec<Vec<f32>> = (0..7)
        .map(|_| (0..p).map(|_| rng.gauss() as f32).collect())
        .collect();
    let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
    let weights = vec![1000usize; 7];
    let mut out = vec![0.0f32; p];
    let mut agg = Aggregator::new();
    let s = bench(10, 200, || agg.aggregate(&refs, &weights, &mut out));
    rec.emit(&format!("aggregate 7 x {p} params"), s);

    let s = bench(10, 500, || sq_distance(&models[0], &models[1]));
    rec.emit(&format!("sq_distance {p}"), s);
    let s = bench(10, 500, || l2_norm_sq(&models[0]));
    rec.emit(&format!("l2_norm_sq {p}"), s);
    let s = bench(10, 1000, || {
        amplify_value(1.5, 0.93, 7, ValueFnConfig::default())
    });
    rec.emit("amplify_value (Eq. 1 server side)", s);

    common::section("2. simulation substrate");
    let s = bench(5, 50, || {
        let mut q = EventQueue::new();
        for i in 0..10_000 {
            q.schedule_at((i % 977) as f64, i);
        }
        while q.pop().is_some() {}
    });
    rec.emit("event queue 10k schedule+pop", s);
    let link = LinkProfile::paper_lan();
    let mut nrng = Rng::new(2);
    let msg = Message::ModelUpload { payload_bytes: 4 * p as u64 + 64 };
    let s = bench(10, 1000, || link.transfer_seconds(&msg, &mut nrng));
    rec.emit("netsim transfer_seconds", s);
    let synth = SynthConfig::default();
    let mut drng = Rng::new(3);
    let s = bench(2, 10, || generate(100, &synth, &mut drng));
    rec.emit("synthdigits generate 100 images", s);

    common::section("3. PJRT runtime steps");
    if std::env::var("VAFL_BENCH_MOCK").is_err()
        && std::path::Path::new("artifacts/params_spec.json").exists()
    {
        let mut rt = vafl::runtime::PjrtRuntime::load("artifacts")?;
        let pc = rt.param_count();
        let (b, eb, d) = (rt.batch_size(), rt.eval_batch(), rt.input_dim());
        let params = rt.spec().load_init_params()?;
        let x = vec![0.5f32; b * d];
        let y: Vec<i32> = (0..b as i32).map(|i| i % 10).collect();
        let s = bench(3, 20, || rt.train_step(&params, &x, &y, 0.1).unwrap());
        rec.emit(&format!("pjrt train_step B={b}"), s);
        let xe = vec![0.5f32; eb * d];
        let ye: Vec<i32> = (0..eb as i32).map(|i| i % 10).collect();
        let s = bench(2, 10, || rt.eval_step(&params, &xe, &ye).unwrap());
        rec.emit(&format!("pjrt eval_step EB={eb}"), s);
        let g = vec![0.1f32; pc];
        let s = bench(5, 50, || rt.value(&g, &params, 0.9, 7.0).unwrap());
        rec.emit("pjrt value (Eq. 1 on artifact path)", s);
    } else {
        println!("skipped (no artifacts / VAFL_BENCH_MOCK set)");
    }

    common::section("4. end-to-end mock round (coordinator overhead)");
    let mut cfg = vafl::experiments::preset('b')?;
    cfg.backend = vafl::config::Backend::Mock;
    cfg.rounds = 1;
    cfg.samples_per_client = 200;
    cfg.test_samples = 128;
    cfg.probe_samples = 64;
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let (mut server, mut exec) = vafl::experiments::build(&cfg)?;
    let s = bench(2, 20, || server.run_round(exec.as_mut()).unwrap());
    rec.emit("full mock round, 7 clients", s);

    common::section("5. fused dequantize-aggregate vs naive round_trip");
    // The old server path decoded every upload to a dense staging Vec
    // (`round_trip`) and then aggregated it; the fused path encodes into
    // reusable wire buffers and dequantizes-and-accumulates in one pass.
    // Both timings include the encode/quantize half so they model one full
    // server round over 7 uploads.
    let fweights = vec![1000.0f64; 7];
    let mut bufs = vec![QuantBuf::new(); 7];
    let mut naive_scratch = Vec::new();
    for prec in [Precision::Int8, Precision::F16, Precision::F32] {
        let s_naive = bench(5, 100, || {
            let staged: Vec<Vec<f32>> = models.iter().map(|m| prec.round_trip(m)).collect();
            let views: Vec<&[f32]> = staged.iter().map(|u| u.as_slice()).collect();
            weighted_average_into_t(&views, &fweights, &mut out, &mut naive_scratch, 1);
        });
        let s_fused = bench(5, 100, || {
            for (b, m) in bufs.iter_mut().zip(&models) {
                b.encode(prec, m);
            }
            agg.aggregate_payloads_t(&bufs, &fweights, &mut out, 1);
        });
        let speedup =
            s_naive.mean.as_nanos() as f64 / s_fused.mean.as_nanos().max(1) as f64;
        rec.emit(&format!("naive round_trip+aggregate 7x{p} {}", prec.name()), s_naive);
        rec.emit(&format!("fused encode+aggregate   7x{p} {}", prec.name()), s_fused);
        println!("    -> fused speedup ({}, 1 worker): {speedup:.2}x", prec.name());
    }

    common::section("6. parallel kernels: 1 vs N workers");
    let max_t = vafl::util::par::max_threads().max(1);
    let mut tlist: Vec<usize> = vec![1, 2, 4, max_t];
    tlist.retain(|&t| t <= max_t);
    tlist.sort_unstable();
    tlist.dedup();
    let mut scratch = Vec::new();
    for &t in &tlist {
        let s = bench(5, 100, || {
            weighted_average_into_t(&refs, &fweights, &mut out, &mut scratch, t)
        });
        rec.emit(&format!("weighted_average_into 7x{p} (workers={t})"), s);
    }
    for (b, m) in bufs.iter_mut().zip(&models) {
        b.encode(Precision::Int8, m);
    }
    for &t in &tlist {
        let s = bench(5, 100, || {
            agg.aggregate_payloads_t(&bufs, &fweights, &mut out, t)
        });
        rec.emit(&format!("fused aggregate int8 7x{p} (workers={t})"), s);
    }
    for &t in &tlist {
        // Fresh RNG per invocation so every worker-count row renders the
        // identical dataset (comparable rows in BENCH_hotpath.json).
        let s = bench(1, 5, || generate_t(200, &synth, &mut Rng::new(3), t));
        rec.emit(&format!("synthdigits generate 200 (workers={t})"), s);
    }

    common::section("7. sparse top-k scatter-aggregation: time scales with k, not n");
    // Dense flush cost is O(K·n) no matter how little actually changed;
    // the sparse scatter touches only the K·k transmitted coordinates.
    // Sweep k_fraction at two model sizes: sparse rows should track k
    // (halving k_fraction ≈ halving time) while the dense baseline rows
    // track n. Encode rows are reported separately — selection is O(n)
    // by nature (it must look at every delta once), the claim is about
    // the server-side aggregation.
    let mut sparse_rec = Recorder::default();
    let mut srng = Rng::new(7);
    for &dim in &[p, 4 * p] {
        let k_clients = 7usize;
        let models: Vec<Vec<f32>> = (0..k_clients)
            .map(|_| (0..dim).map(|_| srng.gauss() as f32).collect())
            .collect();
        let bases: Vec<Vec<f32>> = (0..k_clients)
            .map(|_| (0..dim).map(|_| srng.gauss() as f32).collect())
            .collect();
        let fweights = vec![1000.0f64; k_clients];
        let mut out = vec![0.0f32; dim];
        let mut agg = Aggregator::new();
        let mut dense_bufs = vec![QuantBuf::new(); k_clients];
        for (b, m) in dense_bufs.iter_mut().zip(&models) {
            b.encode(Precision::F32, m);
        }
        let s = bench(3, 50, || {
            agg.aggregate_payloads_t(&dense_bufs, &fweights, &mut out, 1)
        });
        sparse_rec.emit(&format!("dense aggregate {k_clients}x{dim}"), s);
        for kf in [0.01f64, 0.1, 0.5, 1.0] {
            let k = ((dim as f64 * kf).ceil() as usize).clamp(1, dim);
            let mut sparse_bufs = vec![SparseDelta::new(); k_clients];
            let s = bench(3, 20, || {
                for ((b, m), base) in sparse_bufs.iter_mut().zip(&models).zip(&bases) {
                    b.encode_topk(Precision::F32, m, base, None, k);
                }
            });
            sparse_rec.emit(&format!("sparse encode    {k_clients}x{dim} k={kf}"), s);
            let s = bench(3, 50, || {
                agg.aggregate_sparse_payloads_t(&sparse_bufs, &fweights, 0.0, &mut out, 1)
            });
            sparse_rec.emit(&format!("sparse aggregate {k_clients}x{dim} k={kf}"), s);
        }
    }
    common::section("8. bidirectional round trip: downlink encode + client apply at down_k=0.25");
    // The broadcast mirror of section 7: per active client the server
    // encodes top-k of (global - acked base) with error feedback, then
    // the client scatters the frame onto its replica. Sweep the same two
    // model sizes at the EXPERIMENTS.md reference budget
    // (down_k_fraction = 0.25) — rows land in BENCH_sparse.json next to
    // the uplink sweep so the round-trip cost is tracked across PRs.
    for &dim in &[p, 4 * p] {
        let k_clients = 7usize;
        let down_k = ((dim as f64 * 0.25).ceil() as usize).clamp(1, dim);
        let global: Vec<f32> = (0..dim).map(|_| srng.gauss() as f32).collect();
        let mut replicas: Vec<Vec<f32>> =
            (0..k_clients).map(|_| (0..dim).map(|_| srng.gauss() as f32).collect()).collect();
        let mut dl = Downlink::new(k_clients, Precision::F32, true);
        for (c, r) in replicas.iter_mut().enumerate() {
            dl.ack_dense(c, r);
        }
        let s = bench(3, 20, || {
            for (c, r) in replicas.iter_mut().enumerate() {
                let delta = dl.encode_for(c, &global, down_k).unwrap();
                delta.scatter_into(r);
            }
        });
        sparse_rec.emit(&format!("downlink rt      {k_clients}x{dim} k=0.25"), s);
    }

    for (name, s) in &sparse_rec.rows {
        rec.rows.push((name.clone(), s.clone()));
    }

    if want_json {
        rec.write_json("BENCH_hotpath.json")?;
        println!("\nwrote BENCH_hotpath.json ({} rows)", rec.rows.len());
        sparse_rec.write_json_named("BENCH_sparse.json", "sparse_topk")?;
        println!("wrote BENCH_sparse.json ({} rows)", sparse_rec.rows.len());
    }
    Ok(())
}
