//! Bench: robust aggregation under model poisoning.
//!
//!     cargo bench --bench robust [-- --json]
//!
//! Env: VAFL_BENCH_ROUNDS (default 60), VAFL_BENCH_MOCK=1.
//!
//! One grid on experiment b's 7-client fleet under the straggler-heavy
//! WAN with the barrier-free engine (buffer_k = 4, so flushes carry five
//! lanes and `trim = 0.25` drops one lane per end): every aggregation
//! mode in {fedavg, trimmed_mean, median} x sign-flip attacker fraction
//! in {0.0, 0.1, 0.2, 0.3}. The robust rows run with trust scoring on.
//! Reported per row: best/final accuracy, rounds-to-target, and the
//! quarantined-upload total.
//!
//! The headline, printed after the grid and embedded in the JSON: at a
//! 20% sign-flip fraction, how much of the clean-vs-poisoned-FedAvg
//! accuracy gap each robust mode recovers. The acceptance bar is >= 0.5.
//!
//! `--json` (or `VAFL_BENCH_JSON=1`) writes every row plus the recovery
//! summary to `BENCH_robust.json`.

mod common;

use vafl::config::{
    AsyncEngineConfig, AttackConfig, AttackMode, EngineMode, ExperimentConfig, RobustConfig,
    RobustMode,
};
use vafl::coordinator::MixingRule;
use vafl::experiments::{self, straggler};
use vafl::util::json::{obj, Value};

fn base_cfg() -> anyhow::Result<ExperimentConfig> {
    let mut cfg = straggler::straggler_config(&experiments::preset('b')?);
    common::apply_env(&mut cfg, 60);
    cfg.target_acc = cfg.target_acc.min(0.5);
    cfg.engine = EngineMode::BarrierFree;
    cfg.async_engine =
        AsyncEngineConfig { buffer_k: 4, mixing: MixingRule::Constant { alpha: 0.9 } };
    Ok(cfg)
}

fn mode_name(mode: RobustMode) -> &'static str {
    match mode {
        RobustMode::None => "fedavg",
        RobustMode::TrimmedMean => "trimmed_mean",
        RobustMode::Median => "median",
    }
}

fn fmt_opt_usize(v: Option<usize>) -> String {
    v.map_or_else(|| "never".into(), |x| x.to_string())
}

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    let want_json =
        std::env::args().any(|a| a == "--json") || std::env::var("VAFL_BENCH_JSON").is_ok();
    let mut rows: Vec<Value> = Vec::new();
    // best accuracy per (mode, fraction) cell, for the recovery summary.
    let mut best = std::collections::BTreeMap::new();

    common::section("Robust aggregation x sign-flip fraction (straggler_wan, buffer 4)");
    println!(
        "{:<16} {:>9} {:>10} {:>10} {:>14} {:>12}",
        "mode", "attack", "best_acc", "final_acc", "rounds-to-tgt", "quarantined"
    );
    for mode in [RobustMode::None, RobustMode::TrimmedMean, RobustMode::Median] {
        for frac in [0.0f64, 0.1, 0.2, 0.3] {
            let mut cfg = base_cfg()?;
            if mode != RobustMode::None {
                cfg.robust = RobustConfig {
                    mode,
                    trim_fraction: 0.25,
                    trust: true,
                    trust_threshold: 0.3,
                    ..Default::default()
                };
            }
            if frac > 0.0 {
                cfg.attack = AttackConfig {
                    mode: AttackMode::SignFlip,
                    fraction: frac,
                    ..Default::default()
                };
            }
            let out = experiments::run(&cfg)?;
            let quarantined: usize = out.metrics.records.iter().map(|r| r.quarantined).sum();
            best.insert((mode_name(mode), (frac * 100.0) as usize), out.best_accuracy);
            println!(
                "{:<16} {:>8.0}% {:>10.4} {:>10.4} {:>14} {:>12}",
                mode_name(mode),
                frac * 100.0,
                out.best_accuracy,
                out.final_accuracy,
                fmt_opt_usize(out.metrics.rounds_to_target()),
                quarantined,
            );
            rows.push(obj(vec![
                ("section", Value::Str("poison_grid".into())),
                ("mode", Value::Str(mode_name(mode).into())),
                ("attack_fraction", Value::from(frac)),
                ("best_acc", Value::from(out.best_accuracy)),
                ("final_acc", Value::from(out.final_accuracy)),
                (
                    "rounds_to_target",
                    out.metrics.rounds_to_target().map(Value::from).unwrap_or(Value::Null),
                ),
                ("quarantined_total", Value::from(quarantined)),
            ]));
        }
    }

    // Recovery headline at the 20% cell: fraction of the clean-FedAvg vs
    // poisoned-FedAvg gap each robust mode wins back.
    common::section("Recovery at 20% sign-flip");
    let clean = best[&("fedavg", 0)];
    let poisoned = best[&("fedavg", 20)];
    let gap = clean - poisoned;
    let mut recovery_rows: Vec<Value> = Vec::new();
    for name in ["trimmed_mean", "median"] {
        let acc = best[&(name, 20)];
        let recovered = if gap.abs() > 1e-9 { (acc - poisoned) / gap } else { 1.0 };
        println!(
            "{name:<16} acc={acc:.4}  (clean fedavg {clean:.4}, poisoned fedavg {poisoned:.4}) \
             => recovered {:.0}% of the gap {}",
            recovered * 100.0,
            if recovered >= 0.5 { "[>= 50% OK]" } else { "[below 50%]" },
        );
        recovery_rows.push(obj(vec![
            ("mode", Value::Str(name.into())),
            ("best_acc", Value::from(acc)),
            ("clean_fedavg_acc", Value::from(clean)),
            ("poisoned_fedavg_acc", Value::from(poisoned)),
            ("gap_recovered", Value::from(recovered)),
        ]));
    }

    if want_json {
        let doc = obj(vec![
            ("bench", Value::Str("robust".into())),
            ("rows", Value::Arr(rows)),
            ("recovery_at_20pct_signflip", Value::Arr(recovery_rows)),
        ]);
        std::fs::write("BENCH_robust.json", doc.to_string_pretty())?;
        println!("wrote BENCH_robust.json");
    }
    Ok(())
}
