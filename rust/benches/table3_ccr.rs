//! Bench: regenerate **Table III** — communication times to the target
//! accuracy and CCR for AFL / EAFLM / VAFL across experiments a–d.
//!
//!     cargo bench --bench table3_ccr
//!
//! Env: VAFL_BENCH_ROUNDS (default 40), VAFL_BENCH_MOCK=1.

mod common;

use vafl::experiments::{self, table3};

fn main() -> anyhow::Result<()> {
    vafl::util::logging::init();
    common::section("Table III — CCR and communication times (paper §V-B)");
    println!(
        "paper reference: a: AFL 39 / EAFLM 25 (.3590) / VAFL 28 (.2821)\n\
         \x20                b: AFL 84 / EAFLM 45 (.4643) / VAFL 43 (.4881)\n\
         \x20                c: AFL 45 / EAFLM 19 (.5778) / VAFL 22 (.5111)\n\
         \x20                d: AFL 77 / EAFLM 35 (.5455) / VAFL 27 (.6494)\n"
    );
    let mut all_rows = Vec::new();
    for which in ['a', 'b', 'c', 'd'] {
        let mut cfg = experiments::preset(which)?;
        common::apply_env(&mut cfg, 40);
        let outs = experiments::run_all_algorithms(&cfg)?;
        let runs: Vec<_> = outs.into_iter().map(|o| o.metrics).collect();
        all_rows.extend(table3::rows_for_experiment(&runs));
    }
    println!("{}", table3::render(&all_rows));
    let (red, mccr) = table3::headline(&all_rows, "vafl");
    println!(
        "headline (paper: 51.02% fewer comms, 48.26% mean CCR):\n\
         measured: VAFL {:.2}% fewer comms than AFL, mean CCR {:.2}%",
        red * 100.0,
        mccr * 100.0
    );
    let (red_e, mccr_e) = table3::headline(&all_rows, "eaflm");
    println!(
        "          EAFLM {:.2}% fewer comms than AFL, mean CCR {:.2}%",
        red_e * 100.0,
        mccr_e * 100.0
    );
    Ok(())
}
