//! Experiment configuration: typed config with validation, TOML-subset
//! file loading, and the paper's presets (experiments a–d).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::registry::DropoutModel;
use crate::coordinator::staleness::MixingRule;
use crate::data::PartitionScheme;
use crate::model::quant::Precision;
use crate::netsim::LinkProfile;
use crate::util::toml;

/// Which server algorithm gates model uploads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Plain asynchronous FedAvg: every client uploads every round.
    Afl,
    /// The paper's contribution: communication-value gating (Eq. 1–2).
    Vafl,
    /// Lu et al.'s gradient gate (paper Eq. 3) as configured in §IV-D.
    Eaflm,
}

impl Algorithm {
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Afl => "afl",
            Algorithm::Vafl => "vafl",
            Algorithm::Eaflm => "eaflm",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "afl" => Ok(Algorithm::Afl),
            "vafl" => Ok(Algorithm::Vafl),
            "eaflm" => Ok(Algorithm::Eaflm),
            other => bail!("unknown algorithm {other:?} (afl|vafl|eaflm)"),
        }
    }

    pub const ALL: [Algorithm; 3] = [Algorithm::Afl, Algorithm::Eaflm, Algorithm::Vafl];
}

/// Which round engine drives the run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// The paper's per-round loop: everyone reports, the server gates,
    /// aggregates when the last upload lands, then broadcasts. One
    /// synchronization barrier per communication round.
    Barriered,
    /// Barrier-free event-driven engine: clients run on independent
    /// virtual clocks, the server aggregates on a small buffer of upload
    /// arrivals with staleness-weighted mixing. `rounds` counts
    /// aggregations (buffer flushes).
    BarrierFree,
}

impl EngineMode {
    pub fn name(&self) -> &'static str {
        match self {
            EngineMode::Barriered => "barriered",
            EngineMode::BarrierFree => "barrier_free",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "barriered" => Ok(EngineMode::Barriered),
            "barrier_free" | "barrier-free" | "async" => Ok(EngineMode::BarrierFree),
            other => bail!("unknown engine {other:?} (barriered|barrier_free)"),
        }
    }
}

/// Knobs of the barrier-free engine (ignored by the barriered one).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AsyncEngineConfig {
    /// Aggregate once this many uploads have arrived (1 = on every
    /// arrival; clamped to the fleet size — at fleet size with
    /// `alpha == 1` the engine degenerates to the barriered algorithm).
    pub buffer_k: usize,
    /// Staleness-weighted mixing rule `alpha(tau)`.
    pub mixing: MixingRule,
}

impl Default for AsyncEngineConfig {
    fn default() -> Self {
        AsyncEngineConfig { buffer_k: 1, mixing: MixingRule::default() }
    }
}

/// Execution strategy of the round engines — how the simulation *runs*,
/// never what it computes: every knob below is bitwise-neutral on the
/// committed `RoundRecord` stream (asserted by the serial==threaded
/// equivalence tests). TOML section `[engine]`; the engine *mode* stays
/// the top-level `engine = "barriered|barrier_free"` key.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineConfig {
    /// Overlap client local rounds on worker threads. Barrier-free:
    /// speculative execution against training-state snapshots with
    /// in-order commit (`Server::run_event_driven_threaded`); barriered:
    /// one thread per active client on a shared executor service
    /// (`Server::run_round_threaded`).
    pub threaded: bool,
    /// Worker threads of the executor pool (0 = auto: the `util::par`
    /// resolution — `threads` config key, then `VAFL_THREADS`, then the
    /// machine's available parallelism).
    pub workers: usize,
    /// Aggregator shards of the barrier-free engine: the fleet is
    /// partitioned round-robin across this many buffers-of-K, each
    /// flushing into its own model replica. 1 = the unsharded engine
    /// (bitwise identical).
    pub shards: usize,
    /// Reconcile the shard model replicas into the true global every this
    /// many flushes (sample-count-weighted average; ignored at
    /// `shards == 1`).
    pub reconcile_every: usize,
    /// Edge aggregators per shard (two-tier aggregation tree, barrier-free
    /// engine only): uploads fold eagerly into per-edge running sums at
    /// arrival, and a buffer flush combines the shard's edge accumulators
    /// instead of re-reading every buffered payload — flush cost
    /// O(edges · dim), not O(K · dim). 1 (the default) = the single-tier
    /// engine, bitwise identical to previous builds.
    pub edge_fanout: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { threaded: false, workers: 0, shards: 1, reconcile_every: 4, edge_fanout: 1 }
    }
}

/// Upload compression mode (extension; see `model::sparse`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMode {
    /// Full dense payloads at `upload_precision` (the paper's system).
    Dense,
    /// Sparse top-k payloads: only the `k = ceil(k_fraction · n)`
    /// coordinates with the largest `local − base (+ residual)` magnitude
    /// cross the wire. At `k_fraction = 1.0` this is bitwise the dense
    /// path.
    TopK,
}

impl CompressionMode {
    pub fn name(&self) -> &'static str {
        match self {
            CompressionMode::Dense => "dense",
            CompressionMode::TopK => "topk",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "dense" => Ok(CompressionMode::Dense),
            "topk" | "top_k" | "top-k" => Ok(CompressionMode::TopK),
            other => bail!("unknown compression mode {other:?} (dense|topk)"),
        }
    }
}

/// Upload compression knobs — TOML section `[compression]`, CLI
/// `--compression` / `--k-fraction` / `--layer-k-fractions` /
/// `--error-feedback` / `--down-mode` / `--down-k-fraction`.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionConfig {
    pub mode: CompressionMode,
    /// Fraction of parameters each sparse upload transmits
    /// (`k = ceil(k_fraction · n)`, clamped to `[1, n]`); must be in
    /// (0, 1]. Ignored in dense mode.
    pub k_fraction: f64,
    /// Per-layer top-k budgets (extension): one fraction per entry of
    /// `ParamSpec::layers`, selecting `ceil(f_l · size_l)` coordinates
    /// *within each layer's parameter range* instead of one global
    /// top-k over the whole vector. Empty (the default) = uniform
    /// `k_fraction` over the flat vector. Must match the model's layer
    /// count when non-empty; each fraction in (0, 1]. With every
    /// fraction at 1.0 the payload is bitwise the dense path. The
    /// adaptive compression controller only drives the flat
    /// `k_fraction`; per-layer budgets are static for the run.
    pub layer_k_fractions: Vec<f64>,
    /// Accumulate unsent delta mass into the per-client error-feedback
    /// residual (a coordinate's debt clears when it is transmitted; the
    /// residual survives model downloads — see `fleet::Client`). Ignored
    /// in dense mode.
    pub error_feedback: bool,
    /// Downlink (broadcast) compression mode. `Dense` (the default) is
    /// the paper's system: every sync ships the full model. `TopK`
    /// mirrors the upload path downstream: the server keeps a last-acked
    /// base model + error-feedback residual per active client and ships
    /// the top-k of `global − base` (see `coordinator::downlink`). A
    /// client with no acked base (first contact, or freshly hydrated
    /// from the parked set) is force-fed a dense frame. Downlink
    /// compression is flat-only — `layer_k_fractions` applies to uploads
    /// only.
    pub down_mode: CompressionMode,
    /// Fraction of parameters each sparse broadcast transmits
    /// (`k = ceil(down_k_fraction · n)`, clamped to `[1, n]`); must be
    /// in (0, 1]. Ignored when `down_mode` is dense. At 1.0 the sparse
    /// frame is byte- and bit-identical to the dense broadcast.
    pub down_k_fraction: f64,
    /// Independent wire precision for broadcasts (both dense frames and
    /// sparse downlink deltas). `None` (the default) reuses
    /// `upload_precision`, which is bitwise the legacy behaviour;
    /// `Some(p)` decouples the two directions (e.g. int8 down, f32 up) —
    /// uplink payloads are untouched.
    pub down_precision: Option<Precision>,
}

impl Default for CompressionConfig {
    fn default() -> Self {
        CompressionConfig {
            mode: CompressionMode::Dense,
            k_fraction: 1.0,
            layer_k_fractions: Vec::new(),
            error_feedback: true,
            down_mode: CompressionMode::Dense,
            down_k_fraction: 1.0,
            down_precision: None,
        }
    }
}

impl CompressionConfig {
    /// Transmitted coordinates per upload for an `n`-parameter model.
    pub fn k_for(&self, n: usize) -> usize {
        ((n as f64 * self.k_fraction).ceil() as usize).clamp(1, n.max(1))
    }

    /// Transmitted coordinates per sparse broadcast for an `n`-parameter
    /// model.
    pub fn down_k_for(&self, n: usize) -> usize {
        ((n as f64 * self.down_k_fraction).ceil() as usize).clamp(1, n.max(1))
    }

    /// Effective broadcast precision: the independent `down_precision`
    /// when set, else the run's `upload_precision` (the legacy coupling).
    pub fn down_precision_or(&self, upload: Precision) -> Precision {
        self.down_precision.unwrap_or(upload)
    }

    /// Per-layer transmitted coordinates for layer sizes `sizes`, or
    /// `None` when no per-layer budgets are configured (flat top-k).
    pub fn layer_ks(&self, sizes: &[usize]) -> Option<Vec<usize>> {
        if self.layer_k_fractions.is_empty() {
            return None;
        }
        assert_eq!(
            self.layer_k_fractions.len(),
            sizes.len(),
            "layer_k_fractions must match the model's layer count"
        );
        Some(
            self.layer_k_fractions
                .iter()
                .zip(sizes)
                .map(|(&f, &s)| ((s as f64 * f).ceil() as usize).clamp(1, s.max(1)))
                .collect(),
        )
    }
}

/// Adaptive control plane knobs — TOML section `[control]`, CLI
/// `--control` / `--control-interval` / `--control-window` (see the
/// `control` module). With `enabled = false` (the default) the plane is
/// fully inert and both engines are bitwise identical to a build without
/// it; with it enabled, pure deterministic controllers retune `buffer_k`
/// / `alpha(tau)` (barrier-free engine), `compression.k_fraction` (top-k
/// mode), and the client-to-shard assignment (sharded runs, reconcile
/// boundaries only) from rolling run telemetry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ControlConfig {
    /// Master switch for the whole plane.
    pub enabled: bool,
    /// Per-controller enables (effective only with `enabled = true`).
    pub staleness: bool,
    pub compression: bool,
    pub rebalance: bool,
    /// Flushes/rounds between knob-controller evaluations.
    pub interval: usize,
    /// Telemetry window length (samples); also the rebalancer's
    /// post-migration cooldown, in flushes.
    pub window: usize,
    /// Staleness controller: drive the window's mean upload staleness
    /// into `target ± deadband` (the deadband is the hysteresis) by
    /// stepping `buffer_k` within `[buffer_k_min, buffer_k_max]` and the
    /// mixing base rate within `[alpha_min, alpha_max]`.
    pub staleness_target: f64,
    pub staleness_deadband: f64,
    pub buffer_k_min: usize,
    pub buffer_k_max: usize,
    pub alpha_min: f64,
    pub alpha_max: f64,
    /// Multiplicative step of the staleness controller's mixing-rate
    /// moves: too stale and `buffer_k` already at its floor → alpha is
    /// multiplied by `alpha_step`; too fresh with `buffer_k` at its
    /// ceiling → divided by it. Must be in (0, 1); smaller = more
    /// aggressive. (Was hardcoded at 0.9 before this key existed.)
    pub alpha_step: f64,
    /// Compression controller: step `k_fraction` by `k_step` within
    /// `[k_fraction_min, k_fraction_max]`, up when the window's
    /// error-feedback residual ratio exceeds `residual_hi`, down below
    /// `residual_lo` (the band between them is the hysteresis).
    pub k_fraction_min: f64,
    pub k_fraction_max: f64,
    pub k_step: f64,
    pub residual_hi: f64,
    pub residual_lo: f64,
    /// Rebalancer: migrate one client off the hottest shard when the
    /// windowed hottest/coldest flush-count ratio exceeds this (>= 1).
    pub rebalance_skew: f64,
    /// Trust controller enable (effective only with `enabled = true` and
    /// an armed trust score, i.e. `robust.trust` with `robust.mode !=
    /// none`): drive the window's mean outlier rate into
    /// `trust_target ± trust_deadband` by stepping
    /// `robust.trust_threshold` within
    /// `[trust_threshold_min, trust_threshold_max]`.
    pub trust: bool,
    pub trust_target: f64,
    pub trust_deadband: f64,
    pub trust_threshold_min: f64,
    pub trust_threshold_max: f64,
    /// Additive step of the trust controller's threshold moves, in (0, 1).
    pub trust_step: f64,
    /// Adaptive trim controller enable (effective only with
    /// `enabled = true` and `robust.mode = trimmed_mean`): drive the
    /// window's mean outlier rate into `trim_target ± trim_deadband` by
    /// stepping `robust.trim_fraction` within `[trim_min, trim_max]` —
    /// widening the trim under heavy outlier pressure, relaxing it toward
    /// `trim_min` when the fleet looks clean.
    pub trim: bool,
    pub trim_target: f64,
    pub trim_deadband: f64,
    pub trim_min: f64,
    pub trim_max: f64,
    /// Additive step of the trim controller's moves, in (0, 0.5).
    pub trim_step: f64,
}

impl Default for ControlConfig {
    fn default() -> Self {
        ControlConfig {
            enabled: false,
            staleness: true,
            compression: true,
            rebalance: true,
            interval: 4,
            window: 32,
            staleness_target: 2.0,
            staleness_deadband: 1.0,
            buffer_k_min: 1,
            buffer_k_max: 16,
            alpha_min: 0.1,
            alpha_max: 1.0,
            alpha_step: 0.9,
            k_fraction_min: 0.05,
            k_fraction_max: 1.0,
            k_step: 1.5,
            residual_hi: 0.6,
            residual_lo: 0.2,
            rebalance_skew: 2.0,
            trust: true,
            trust_target: 0.1,
            trust_deadband: 0.05,
            trust_threshold_min: 0.1,
            trust_threshold_max: 0.9,
            trust_step: 0.05,
            trim: true,
            trim_target: 0.15,
            trim_deadband: 0.05,
            trim_min: 0.0,
            trim_max: 0.45,
            trim_step: 0.05,
        }
    }
}

impl ControlConfig {
    /// Validate the bounds/hysteresis parameters (always, not just when
    /// enabled: a bad `[control]` section should fail loudly rather than
    /// lie in wait for the `--control on` run).
    pub fn validate(&self) -> Result<()> {
        if self.interval == 0 {
            bail!("control.interval must be >= 1");
        }
        if self.window == 0 {
            bail!("control.window must be >= 1");
        }
        if !(self.staleness_target.is_finite() && self.staleness_target >= 0.0) {
            bail!("control.staleness_target must be finite and >= 0");
        }
        if !(self.staleness_deadband.is_finite() && self.staleness_deadband >= 0.0) {
            bail!("control.staleness_deadband must be finite and >= 0");
        }
        if self.buffer_k_min == 0 || self.buffer_k_min > self.buffer_k_max {
            bail!(
                "control buffer_k bounds must satisfy 1 <= buffer_k_min <= buffer_k_max, got [{}, {}]",
                self.buffer_k_min,
                self.buffer_k_max
            );
        }
        if !(0.0 < self.alpha_min && self.alpha_min <= self.alpha_max && self.alpha_max <= 1.0) {
            bail!(
                "control alpha bounds must satisfy 0 < alpha_min <= alpha_max <= 1, got [{}, {}]",
                self.alpha_min,
                self.alpha_max
            );
        }
        if !(self.alpha_step.is_finite() && 0.0 < self.alpha_step && self.alpha_step < 1.0) {
            bail!("control.alpha_step must be in (0, 1), got {}", self.alpha_step);
        }
        if !(0.0 < self.k_fraction_min
            && self.k_fraction_min <= self.k_fraction_max
            && self.k_fraction_max <= 1.0)
        {
            bail!(
                "control k_fraction bounds must satisfy 0 < k_fraction_min <= k_fraction_max <= 1, got [{}, {}]",
                self.k_fraction_min,
                self.k_fraction_max
            );
        }
        if !(self.k_step.is_finite() && self.k_step > 1.0) {
            bail!("control.k_step must be finite and > 1, got {}", self.k_step);
        }
        if !(0.0 <= self.residual_lo
            && self.residual_lo < self.residual_hi
            && self.residual_hi <= 1.0)
        {
            bail!(
                "control residual thresholds must satisfy 0 <= residual_lo < residual_hi <= 1, got [{}, {}]",
                self.residual_lo,
                self.residual_hi
            );
        }
        if !(self.rebalance_skew.is_finite() && self.rebalance_skew >= 1.0) {
            bail!("control.rebalance_skew must be finite and >= 1, got {}", self.rebalance_skew);
        }
        if !(self.trust_target.is_finite() && (0.0..=1.0).contains(&self.trust_target)) {
            bail!("control.trust_target must be in [0, 1], got {}", self.trust_target);
        }
        if !(self.trust_deadband.is_finite() && self.trust_deadband >= 0.0) {
            bail!("control.trust_deadband must be finite and >= 0, got {}", self.trust_deadband);
        }
        if !(0.0 < self.trust_threshold_min
            && self.trust_threshold_min <= self.trust_threshold_max
            && self.trust_threshold_max <= 1.0)
        {
            bail!(
                "control trust_threshold bounds must satisfy 0 < min <= max <= 1, got [{}, {}]",
                self.trust_threshold_min,
                self.trust_threshold_max
            );
        }
        if !(self.trust_step.is_finite() && 0.0 < self.trust_step && self.trust_step < 1.0) {
            bail!("control.trust_step must be in (0, 1), got {}", self.trust_step);
        }
        if !(self.trim_target.is_finite() && (0.0..=1.0).contains(&self.trim_target)) {
            bail!("control.trim_target must be in [0, 1], got {}", self.trim_target);
        }
        if !(self.trim_deadband.is_finite() && self.trim_deadband >= 0.0) {
            bail!("control.trim_deadband must be finite and >= 0, got {}", self.trim_deadband);
        }
        if !(self.trim_min.is_finite()
            && self.trim_max.is_finite()
            && 0.0 <= self.trim_min
            && self.trim_min <= self.trim_max
            && self.trim_max < 0.5)
        {
            bail!(
                "control trim bounds must satisfy 0 <= trim_min <= trim_max < 0.5, got [{}, {}]",
                self.trim_min,
                self.trim_max
            );
        }
        if !(self.trim_step.is_finite() && 0.0 < self.trim_step && self.trim_step < 0.5) {
            bail!("control.trim_step must be in (0, 0.5), got {}", self.trim_step);
        }
        Ok(())
    }
}

/// Byzantine-robust aggregation mode — TOML section `[robust]`, CLI
/// `--robust-mode` (see `coordinator::aggregate`). `None` (the default)
/// is the trusting FedAvg merge, bitwise identical to previous builds.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RobustMode {
    /// Trust every upload (plain weighted FedAvg — the paper's system).
    None,
    /// Coordinate-wise trimmed mean: per coordinate, sort the value lanes
    /// (`total_cmp`, lane-index tie-break), drop
    /// `floor(trim_fraction · lanes)` from each end, renormalize the
    /// surviving weights. `trim_fraction = 0` degenerates bitwise to the
    /// plain merge.
    TrimmedMean,
    /// Coordinate-wise weighted (lower) median over the sorted lanes.
    Median,
}

impl RobustMode {
    pub fn name(&self) -> &'static str {
        match self {
            RobustMode::None => "none",
            RobustMode::TrimmedMean => "trimmed_mean",
            RobustMode::Median => "median",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" | "fedavg" => Ok(RobustMode::None),
            "trimmed_mean" | "trimmed-mean" | "trimmed" | "trim" => Ok(RobustMode::TrimmedMean),
            "median" => Ok(RobustMode::Median),
            other => bail!("unknown robust mode {other:?} (none|trimmed_mean|median)"),
        }
    }
}

/// Byzantine-robust aggregation knobs — TOML section `[robust]` (see
/// `coordinator::aggregate` for the merge and `control::telemetry` for
/// the trust book). With `mode = none` (the default) every path is
/// bitwise identical to previous builds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustConfig {
    pub mode: RobustMode,
    /// Per-end trim fraction of the coordinate-wise trimmed mean:
    /// `floor(trim_fraction · lanes)` lanes are dropped from each end of
    /// the sorted lane order (clamped so at least one lane survives).
    /// Must be in [0, 0.5). Ignored by `median`.
    pub trim_fraction: f64,
    /// Arm the per-client trust score: clients whose rolling outlier rate
    /// exceeds `trust_threshold` get their aggregation weight scaled down
    /// (soft quarantine) at flush time. Requires `mode != none` (the
    /// outlier statistic falls out of the robust merge).
    pub trust: bool,
    /// EWMA decay of the per-client outlier-rate score
    /// (`score <- decay·score + (1−decay)·rate`); must be in (0, 1).
    pub trust_decay: f64,
    /// Outlier-rate score above which a client's weight starts shrinking
    /// (`weight ×= max(threshold/score, trust_floor)`); must be in (0, 1].
    /// The `TrustController` can retune this online.
    pub trust_threshold: f64,
    /// Minimum soft-quarantine weight multiplier, in (0, 1]: even a fully
    /// distrusted client keeps this fraction of its weight (no hard
    /// eviction — scores can recover).
    pub trust_floor: f64,
}

impl Default for RobustConfig {
    fn default() -> Self {
        RobustConfig {
            mode: RobustMode::None,
            trim_fraction: 0.25,
            trust: false,
            trust_decay: 0.8,
            trust_threshold: 0.5,
            trust_floor: 0.1,
        }
    }
}

/// Malicious-client attack mode — TOML section `[attack]`, CLI
/// `--attack` (see `fleet::AttackProfile`). Attacks are applied at
/// gradient-encode time, so they flow through sparsification, error
/// feedback, and speculation unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttackMode {
    /// No attackers (the default; bitwise identical to previous builds).
    None,
    /// Data poisoning: the attacker trains on labels remapped `l → 9−l`.
    LabelFlip,
    /// Model poisoning: the attacker reports its update reflected around
    /// its last synced base (`base − (params − base)`).
    SignFlip,
    /// Model poisoning: the attacker inflates its update by
    /// `attack.scale` (`base + scale·(params − base)`).
    Scale,
    /// Targeted poisoning: the attacker spikes a fixed trigger pattern of
    /// `attack.backdoor_coords` coordinates by `attack.backdoor_boost`.
    Backdoor,
}

impl AttackMode {
    pub fn name(&self) -> &'static str {
        match self {
            AttackMode::None => "none",
            AttackMode::LabelFlip => "label_flip",
            AttackMode::SignFlip => "sign_flip",
            AttackMode::Scale => "scale",
            AttackMode::Backdoor => "backdoor",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Ok(AttackMode::None),
            "label_flip" | "label-flip" | "labelflip" => Ok(AttackMode::LabelFlip),
            "sign_flip" | "sign-flip" | "signflip" => Ok(AttackMode::SignFlip),
            "scale" | "scaling" => Ok(AttackMode::Scale),
            "backdoor" => Ok(AttackMode::Backdoor),
            other => bail!(
                "unknown attack mode {other:?} (none|label_flip|sign_flip|scale|backdoor)"
            ),
        }
    }
}

/// Malicious-client simulator knobs — TOML section `[attack]`. The
/// attacker set is a deterministic function of the experiment seed
/// (`root_rng.fork("attack")`), so attacked runs are reproducible and
/// thread-count invariant like everything else.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AttackConfig {
    pub mode: AttackMode,
    /// Fraction of the fleet that is malicious
    /// (`count = round(fraction · num_clients)`); must be in [0, 1].
    pub fraction: f64,
    /// Update inflation gain of the `scale` attack (> 0).
    pub scale: f64,
    /// Trigger-pattern size of the `backdoor` attack (coordinates spiked
    /// per upload, spread evenly over the parameter vector; >= 1).
    pub backdoor_coords: usize,
    /// Spike magnitude added at each trigger coordinate (finite).
    pub backdoor_boost: f64,
}

impl Default for AttackConfig {
    fn default() -> Self {
        AttackConfig {
            mode: AttackMode::None,
            fraction: 0.0,
            scale: 10.0,
            backdoor_coords: 16,
            backdoor_boost: 1.0,
        }
    }
}

/// Virtualized-fleet knobs — TOML section `[fleet]`, CLI `--active-set`
/// / `--residual-budget` / `--compact-records` (see the `fleet` module's
/// "Virtualized fleet" docs).
///
/// With `active_set = 0` (the default) every client is hydrated up front
/// and the engines are bitwise identical to previous builds. With
/// `active_set = a > 0` (barrier-free engine only) at most `a` clients
/// own dense training state at a time; the rest are parked as compact
/// records and rotate in at buffer flushes, so resident memory scales
/// with `a·dim + n·sizeof(ParkedClient)` instead of `n·dim`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetConfig {
    /// Maximum simultaneously hydrated clients (0 = whole fleet; the
    /// legacy, bitwise-identical mode). Clamped to the fleet size.
    pub active_set: usize,
    /// Error-feedback residual coordinates kept per parked client (the
    /// top-|budget| by magnitude; the rest of the residual is dropped at
    /// park time). Irrelevant in dense mode or with error feedback off.
    pub residual_budget: usize,
    /// Drop the O(n) per-round fleet snapshots (`fleet_values`,
    /// `fleet_selected`, `client_accs`) from `RoundRecord`s. Required
    /// reading for the goldens and several tests, so default off; turn
    /// on for large-fleet runs where records dominate memory.
    pub compact_records: bool,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { active_set: 0, residual_budget: 32, compact_records: false }
    }
}

/// Deterministic fault injection — TOML section `[faults]` (see
/// `netsim::FaultPlan` for the draw discipline and `coordinator::server`
/// for the recovery machinery). With `enabled = false` (the default) no
/// fault stream is ever consumed, no integrity header is charged, and both
/// engines are bitwise identical to previous builds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Master switch for the whole fault layer.
    pub enabled: bool,
    /// Per-uplink-frame terminal loss probability (the frame never
    /// arrives; sender times out, backs off, retransmits).
    pub loss_prob: f64,
    /// Per-uplink-frame corruption probability (frame arrives, integrity
    /// checksum fails, receiver discards it; same retransmit path as loss
    /// but counted separately).
    pub corrupt_prob: f64,
    /// Per-uplink-frame duplication probability (a stale copy arrives
    /// after the original; suppressed via the per-client sequence number
    /// but still charged on the wire).
    pub dup_prob: f64,
    /// Per-broadcast-frame terminal loss probability (client NACKs and is
    /// force-fed a dense resync through the `ack_dense` path).
    pub down_loss_prob: f64,
    /// Per-broadcast-frame corruption probability (checksum mismatch at
    /// the client; same NACK + dense resync, counted separately).
    pub down_corrupt_prob: f64,
    /// Probability a delivered uplink frame is held for a reordering
    /// window before arriving.
    pub reorder_prob: f64,
    /// Maximum extra delay of a reordered frame, seconds.
    pub reorder_window: f64,
    /// Retransmits the sender attempts after the original frame before
    /// giving the round up (the client then marks itself stale and
    /// reschedules; 0 = give up immediately).
    pub max_retransmits: u32,
    /// First retransmit backoff, seconds; doubles per attempt.
    pub backoff_base: f64,
    /// Upper bound on any single backoff, seconds.
    pub backoff_cap: f64,
    /// Per-scheduling-point client crash probability (barrier-free engine
    /// only: the client is parked on the spot, losing local state, and
    /// rehydrates as a fresh joiner after `crash_downtime`).
    pub crash_prob: f64,
    /// Seconds a crashed client stays down before rejoining.
    pub crash_downtime: f64,
    /// Server outage cadence, seconds (0 = no outages). Windows open at
    /// `outage_every, 2·outage_every, ...` and last `outage_len` seconds;
    /// every uplink frame landing inside one is lost.
    pub outage_every: f64,
    /// Length of each server outage window, seconds.
    pub outage_len: f64,
    /// Write a full engine-state checkpoint every this many committed
    /// flushes (barrier-free) or rounds (barriered); 0 = no checkpoints.
    /// Kill-at-checkpoint + restore resumes bitwise (see
    /// `Server::checkpoint_bytes` / `Server::restore_checkpoint`).
    pub checkpoint_every: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            enabled: false,
            loss_prob: 0.0,
            corrupt_prob: 0.0,
            dup_prob: 0.0,
            down_loss_prob: 0.0,
            down_corrupt_prob: 0.0,
            reorder_prob: 0.0,
            reorder_window: 0.25,
            max_retransmits: 5,
            backoff_base: 0.05,
            backoff_cap: 2.0,
            crash_prob: 0.0,
            crash_downtime: 5.0,
            outage_every: 0.0,
            outage_len: 0.0,
            checkpoint_every: 0,
        }
    }
}

impl FaultConfig {
    /// Validate bounds (always, like `ControlConfig::validate`: a bad
    /// `[faults]` section fails loudly even when disabled).
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("faults.loss_prob", self.loss_prob),
            ("faults.corrupt_prob", self.corrupt_prob),
            ("faults.dup_prob", self.dup_prob),
            ("faults.down_loss_prob", self.down_loss_prob),
            ("faults.down_corrupt_prob", self.down_corrupt_prob),
            ("faults.reorder_prob", self.reorder_prob),
            ("faults.crash_prob", self.crash_prob),
        ] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                bail!("{name} must be in [0, 1], got {p}");
            }
        }
        if self.loss_prob + self.corrupt_prob + self.dup_prob > 1.0 {
            bail!(
                "faults loss_prob + corrupt_prob + dup_prob must be <= 1 \
                 (they partition one fate draw), got {}",
                self.loss_prob + self.corrupt_prob + self.dup_prob
            );
        }
        if self.down_loss_prob + self.down_corrupt_prob > 1.0 {
            bail!(
                "faults down_loss_prob + down_corrupt_prob must be <= 1, got {}",
                self.down_loss_prob + self.down_corrupt_prob
            );
        }
        if !(self.reorder_window.is_finite() && self.reorder_window >= 0.0) {
            bail!("faults.reorder_window must be finite and >= 0, got {}", self.reorder_window);
        }
        if !(self.backoff_base.is_finite() && self.backoff_base > 0.0) {
            bail!("faults.backoff_base must be finite and > 0, got {}", self.backoff_base);
        }
        if !(self.backoff_cap.is_finite() && self.backoff_cap >= self.backoff_base) {
            bail!(
                "faults.backoff_cap must be finite and >= backoff_base ({}), got {}",
                self.backoff_base,
                self.backoff_cap
            );
        }
        if !(self.crash_downtime.is_finite() && self.crash_downtime > 0.0) {
            bail!("faults.crash_downtime must be finite and > 0, got {}", self.crash_downtime);
        }
        if !(self.outage_every.is_finite() && self.outage_every >= 0.0) {
            bail!("faults.outage_every must be finite and >= 0, got {}", self.outage_every);
        }
        if !(self.outage_len.is_finite() && self.outage_len >= 0.0) {
            bail!("faults.outage_len must be finite and >= 0, got {}", self.outage_len);
        }
        if self.outage_every > 0.0 && self.outage_len >= self.outage_every {
            bail!(
                "faults.outage_len ({}) must be shorter than faults.outage_every ({}); \
                 a window covering the whole period is a dead server",
                self.outage_len,
                self.outage_every
            );
        }
        Ok(())
    }
}

/// Observability plane (see the `obs` module): span tracing with dual
/// virtual/wall timestamps, the unified `MetricRegistry`, and the
/// Perfetto / Prometheus exporters. Disabled by default — a disabled
/// plane records nothing and runs bitwise identical to a build without
/// it (the golden snapshots pin this).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsConfig {
    /// Master switch for span tracing. The `MetricRegistry` itself is
    /// always live (it backs existing CSV/JSON columns); this arms the
    /// span recorder, per-worker rings, and the trace exporters.
    pub enabled: bool,
    /// Capacity (spans) of each per-worker wall-span ring buffer.
    /// Spans pushed into a full ring are counted as dropped, never
    /// blocked on — the hot path stays lock-free and alloc-free.
    pub ring_capacity: usize,
    /// Hard cap on spans retained per run (engine-thread stream plus
    /// drained worker rings); beyond it spans are counted as dropped.
    /// Bounds trace memory on long runs.
    pub max_spans: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { enabled: false, ring_capacity: 1024, max_spans: 1 << 18 }
    }
}

impl ObsConfig {
    /// Validate bounds (always, like `FaultConfig::validate`).
    pub fn validate(&self) -> Result<()> {
        if self.ring_capacity == 0 {
            bail!("obs.ring_capacity must be >= 1");
        }
        if self.max_spans == 0 {
            bail!("obs.max_spans must be >= 1");
        }
        Ok(())
    }
}

/// EAFLM gate constants (paper Eq. 3 and §IV-D: xi_d = 1/D, D = 1,
/// alpha = 0.98; beta·m² folded into one threshold scale).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EaflmParams {
    pub alpha: f64,
    pub beta: f64,
    pub depth: usize,
}

impl Default for EaflmParams {
    fn default() -> Self {
        EaflmParams { alpha: 0.98, beta: 0.05, depth: 1 }
    }
}

/// Ablation switches over the VAFL value function (Eq. 1) — see the
/// `ablation_value_fn` bench.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ValueFnConfig {
    /// Include the `(1 + N/10^3)^Acc` amplification term.
    pub use_acc_term: bool,
}

impl Default for ValueFnConfig {
    fn default() -> Self {
        ValueFnConfig { use_acc_term: true }
    }
}

/// Which executor backs client training.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// PJRT over the AOT artifacts in the given directory.
    Pjrt { artifact_dir: String },
    /// The pure-Rust mock model (tests/CI; no artifacts needed).
    Mock,
}

/// A full experiment description. Everything observable is derived from
/// this struct plus `seed`.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub algorithm: Algorithm,
    pub num_clients: usize,
    pub partition: PartitionScheme,
    /// Average samples per client (paper: 20_000 for 3 clients, 10_000
    /// for 7; scaled down by default for CPU tractability — see
    /// EXPERIMENTS.md §Scaling).
    pub samples_per_client: usize,
    /// Held-out server test set size.
    pub test_samples: usize,
    /// Probe-set size used for the per-client Acc_i in Eq. 1 (a slice of
    /// the test set; the paper evaluates client models on "the test set").
    pub probe_samples: usize,
    /// Total communication rounds R (paper Table II: 200).
    pub rounds: usize,
    /// Local passes per round = r * E (paper: r=5, E=1). Each pass is
    /// `batches_per_pass` SGD batches.
    pub local_passes: usize,
    /// SGD batches per local pass (paper: a full epoch; scaled down —
    /// see EXPERIMENTS.md §Scaling).
    pub batches_per_pass: usize,
    /// Learning rate eta (paper: 0.1).
    pub lr: f32,
    /// Target accuracy for the Table III communication count (0.94).
    pub target_acc: f64,
    /// Master seed; all randomness forks from it.
    pub seed: u64,
    pub link: LinkProfile,
    pub eaflm: EaflmParams,
    pub value_fn: ValueFnConfig,
    pub backend: Backend,
    /// Evaluate the global model every `eval_every` rounds (1 = paper).
    pub eval_every: usize,
    /// Dataset difficulty: pixel-noise sigma of the SynthDigits corpus.
    pub pixel_noise: f32,
    /// Client availability model (paper §I motivation: dropped users).
    pub dropout: DropoutModel,
    /// Wire precision of model uploads/broadcasts (extension; see
    /// model::quant). The paper's system ships f32.
    pub upload_precision: Precision,
    /// Upload compression (extension; see model::sparse): dense payloads
    /// or sparse top-k deltas with error feedback.
    pub compression: CompressionConfig,
    /// FedAsync-style staleness decay for aggregation weights:
    /// w_i = n_i * decay^staleness_i. None = paper's plain n_i/n.
    pub staleness_decay: Option<f64>,
    /// Worker threads for the parallel kernels (aggregation, data
    /// generation, mock eval). 0 = auto: `VAFL_THREADS` env var, else the
    /// machine's available parallelism. See `util::par`.
    pub threads: usize,
    /// Which round engine drives the run (the paper's barriered loop by
    /// default; `barrier_free` enables the event-driven engine).
    pub engine: EngineMode,
    /// Barrier-free engine knobs (buffer size, staleness mixing).
    pub async_engine: AsyncEngineConfig,
    /// Execution strategy (threading, aggregation sharding) — TOML
    /// section `[engine]`, CLI `--engine-threads` / `--shards` /
    /// `--reconcile-every`.
    pub engine_opts: EngineConfig,
    /// Adaptive control plane — TOML section `[control]`, CLI
    /// `--control` (disabled by default; see the `control` module).
    pub control: ControlConfig,
    /// Virtualized fleet (active-set size, parked-record residual
    /// budget, compact records) — TOML section `[fleet]`.
    pub fleet: FleetConfig,
    /// Byzantine-robust aggregation (trimmed mean / median + trust
    /// scores) — TOML section `[robust]`, CLI `--robust-mode`.
    pub robust: RobustConfig,
    /// Malicious-client simulator — TOML section `[attack]`, CLI
    /// `--attack` / `--attack-fraction`.
    pub attack: AttackConfig,
    /// Deterministic fault injection + crash-safe checkpointing — TOML
    /// section `[faults]` (see `netsim::FaultPlan`).
    pub faults: FaultConfig,
    /// Observability plane (span tracing + exporters) — TOML section
    /// `[obs]`, CLI `--trace-out` / `--metrics-out` (see the `obs`
    /// module). Off by default; off runs are bitwise identical.
    pub obs: ObsConfig,
    /// Record the barrier-free engine's committed event stream as a
    /// `(vtime, label)` trace in `RunMetrics::event_trace` so the
    /// `--realtime` driver can replay in-flight uploads, buffer
    /// occupancy, and live controller decisions (set automatically by
    /// the CLI's `--realtime`; costs one label allocation per event).
    pub trace_events: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "custom".into(),
            algorithm: Algorithm::Vafl,
            num_clients: 3,
            partition: PartitionScheme::Iid,
            samples_per_client: 2000,
            test_samples: 384,
            probe_samples: 128,
            rounds: 200,
            local_passes: 5,
            batches_per_pass: 2,
            lr: 0.1,
            target_acc: 0.94,
            seed: 2021,
            link: LinkProfile::paper_lan(),
            eaflm: EaflmParams::default(),
            value_fn: ValueFnConfig::default(),
            backend: Backend::Pjrt { artifact_dir: "artifacts".into() },
            eval_every: 1,
            pixel_noise: 0.14,
            dropout: DropoutModel::none(),
            upload_precision: Precision::F32,
            compression: CompressionConfig::default(),
            staleness_decay: None,
            threads: 0,
            engine: EngineMode::Barriered,
            async_engine: AsyncEngineConfig::default(),
            engine_opts: EngineConfig::default(),
            control: ControlConfig::default(),
            fleet: FleetConfig::default(),
            robust: RobustConfig::default(),
            attack: AttackConfig::default(),
            faults: FaultConfig::default(),
            obs: ObsConfig::default(),
            trace_events: false,
        }
    }
}

/// Integer config key as `usize`, rejecting negatives at parse time with
/// the key name in the error — the `EventQueue::advance_to` strictness
/// policy: the old `v.max(0)` clamp silently rewrote a negative value and
/// let validation fail later with a misleading message (or, for keys like
/// `num_clients`, reinterpreted it as a huge unsigned count).
fn get_nonneg(doc: &toml::Doc, key: &str) -> Result<Option<usize>> {
    match doc.get_i64(key) {
        Some(v) if v < 0 => bail!("{key} must not be negative, got {v}"),
        Some(v) => Ok(Some(v as usize)),
        None => Ok(None),
    }
}

/// Parse a comma-separated list of per-layer fractions (the TOML subset
/// has no arrays, so `[compression] layer_k_fractions` and the CLI's
/// `--layer-k-fractions` both take e.g. `"0.5,0.1"`). Empty string =
/// no per-layer budgets.
pub fn parse_fraction_list(s: &str) -> Result<Vec<f64>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|p| {
            p.trim()
                .parse::<f64>()
                .with_context(|| format!("bad fraction {:?} in list {s:?}", p.trim()))
        })
        .collect()
}

impl ExperimentConfig {
    /// Validate invariants the engine depends on.
    pub fn validate(&self) -> Result<()> {
        if self.num_clients == 0 {
            bail!("num_clients must be > 0");
        }
        if self.rounds == 0 {
            bail!("rounds must be > 0");
        }
        if self.local_passes == 0 || self.batches_per_pass == 0 {
            bail!("local_passes and batches_per_pass must be > 0");
        }
        if !(0.0..=1.0).contains(&self.target_acc) {
            bail!("target_acc must be in [0, 1]");
        }
        if self.samples_per_client == 0 {
            bail!("samples_per_client must be > 0");
        }
        if self.test_samples == 0 || self.probe_samples == 0 {
            bail!("test/probe sets must be non-empty");
        }
        if self.probe_samples > self.test_samples {
            bail!("probe_samples cannot exceed test_samples");
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0");
        }
        if !(self.lr > 0.0) {
            bail!("lr must be positive");
        }
        if !(0.0..1.0).contains(&self.dropout.drop_prob) {
            bail!("dropout.prob must be in [0, 1)");
        }
        if self.dropout.mean_offline_rounds < 1.0 {
            bail!("dropout.mean_offline_rounds must be >= 1");
        }
        if let Some(d) = self.staleness_decay {
            if !(0.0 < d && d <= 1.0) {
                bail!("staleness_decay must be in (0, 1]");
            }
        }
        if self.async_engine.buffer_k == 0 {
            bail!("async_engine.buffer_k must be >= 1");
        }
        self.async_engine.mixing.validate()?;
        if self.engine_opts.shards == 0 {
            bail!("engine.shards must be >= 1");
        }
        if self.engine_opts.shards > self.num_clients {
            bail!(
                "engine.shards ({}) cannot exceed num_clients ({})",
                self.engine_opts.shards,
                self.num_clients
            );
        }
        if self.engine_opts.reconcile_every == 0 {
            bail!("engine.reconcile_every must be >= 1");
        }
        if self.engine_opts.shards > 1 && self.engine == EngineMode::Barriered {
            bail!(
                "engine.shards only applies to the barrier_free engine; \
                 the barriered loop has a single aggregation point per round"
            );
        }
        if self.engine_opts.edge_fanout == 0 {
            bail!("engine.edge_fanout must be >= 1");
        }
        if self.engine_opts.edge_fanout > 1 && self.engine == EngineMode::Barriered {
            bail!(
                "engine.edge_fanout only applies to the barrier_free engine; \
                 the barriered loop aggregates all reports at one point"
            );
        }
        if self.fleet.active_set > 0 && self.engine == EngineMode::Barriered {
            bail!(
                "fleet.active_set only applies to the barrier_free engine; \
                 the barriered loop needs every client hydrated each round"
            );
        }
        if !(self.compression.k_fraction > 0.0 && self.compression.k_fraction <= 1.0) {
            bail!(
                "compression.k_fraction must be in (0, 1], got {}",
                self.compression.k_fraction
            );
        }
        for (l, &f) in self.compression.layer_k_fractions.iter().enumerate() {
            if !(f > 0.0 && f <= 1.0) {
                bail!("compression.layer_k_fractions[{l}] must be in (0, 1], got {f}");
            }
        }
        if !(self.compression.down_k_fraction > 0.0 && self.compression.down_k_fraction <= 1.0) {
            bail!(
                "compression.down_k_fraction must be in (0, 1], got {}",
                self.compression.down_k_fraction
            );
        }
        if !self.compression.layer_k_fractions.is_empty()
            && self.control.enabled
            && self.control.compression
            && self.compression.mode == CompressionMode::TopK
        {
            bail!(
                "compression.layer_k_fractions is a static per-layer budget; \
                 it cannot be combined with the adaptive compression controller \
                 (disable control.compression or use the flat k_fraction)"
            );
        }
        if self.engine == EngineMode::BarrierFree && self.staleness_decay.is_some() {
            bail!(
                "staleness_decay only applies to the barriered engine; \
                 the barrier-free engine weights uploads by the async_engine \
                 mixing rule alpha(tau) instead"
            );
        }
        self.control.validate()?;
        if self.control.enabled
            && self.control.compression
            && self.compression.mode == CompressionMode::TopK
            && !(self.control.k_fraction_min <= self.compression.k_fraction
                && self.compression.k_fraction <= self.control.k_fraction_max)
        {
            bail!(
                "compression.k_fraction ({}) must start inside the control plane's \
                 [k_fraction_min, k_fraction_max] = [{}, {}]",
                self.compression.k_fraction,
                self.control.k_fraction_min,
                self.control.k_fraction_max
            );
        }
        // The downlink knob shares the compression controller's bounds,
        // so the same starting-inside-the-bounds policy applies.
        if self.control.enabled
            && self.control.compression
            && self.compression.down_mode == CompressionMode::TopK
            && !(self.control.k_fraction_min <= self.compression.down_k_fraction
                && self.compression.down_k_fraction <= self.control.k_fraction_max)
        {
            bail!(
                "compression.down_k_fraction ({}) must start inside the control plane's \
                 [k_fraction_min, k_fraction_max] = [{}, {}]",
                self.compression.down_k_fraction,
                self.control.k_fraction_min,
                self.control.k_fraction_max
            );
        }
        // Same policy for the staleness controller's knobs: a starting
        // value outside the bounds would make the first clamped step move
        // the knob AGAINST the signal (e.g. buffer_k 32 with max 16 drops
        // to 16 on a "batch more" decision).
        if self.control.enabled && self.control.staleness && self.engine == EngineMode::BarrierFree
        {
            if !(self.control.buffer_k_min <= self.async_engine.buffer_k
                && self.async_engine.buffer_k <= self.control.buffer_k_max)
            {
                bail!(
                    "async_engine.buffer_k ({}) must start inside the control plane's \
                     [buffer_k_min, buffer_k_max] = [{}, {}]",
                    self.async_engine.buffer_k,
                    self.control.buffer_k_min,
                    self.control.buffer_k_max
                );
            }
            let a0 = self.async_engine.mixing.alpha0();
            if !(self.control.alpha_min <= a0 && a0 <= self.control.alpha_max) {
                bail!(
                    "async_engine mixing alpha ({a0}) must start inside the control \
                     plane's [alpha_min, alpha_max] = [{}, {}]",
                    self.control.alpha_min,
                    self.control.alpha_max
                );
            }
        }
        if !(self.robust.trim_fraction.is_finite()
            && (0.0..0.5).contains(&self.robust.trim_fraction))
        {
            bail!("robust.trim_fraction must be in [0, 0.5), got {}", self.robust.trim_fraction);
        }
        if !(self.robust.trust_decay.is_finite()
            && 0.0 < self.robust.trust_decay
            && self.robust.trust_decay < 1.0)
        {
            bail!("robust.trust_decay must be in (0, 1), got {}", self.robust.trust_decay);
        }
        if !(self.robust.trust_threshold.is_finite()
            && 0.0 < self.robust.trust_threshold
            && self.robust.trust_threshold <= 1.0)
        {
            bail!(
                "robust.trust_threshold must be in (0, 1], got {}",
                self.robust.trust_threshold
            );
        }
        if !(self.robust.trust_floor.is_finite()
            && 0.0 < self.robust.trust_floor
            && self.robust.trust_floor <= 1.0)
        {
            bail!("robust.trust_floor must be in (0, 1], got {}", self.robust.trust_floor);
        }
        if self.robust.trust && self.robust.mode == RobustMode::None {
            bail!(
                "robust.trust requires a robust aggregation mode \
                 (the trust score is the robust merge's outlier statistic); \
                 set robust.mode = trimmed_mean or median"
            );
        }
        if self.robust.mode != RobustMode::None && self.engine_opts.edge_fanout > 1 {
            bail!(
                "robust aggregation cannot be combined with engine.edge_fanout > 1: \
                 edge accumulators fold uploads into running sums at arrival, \
                 destroying the per-payload value lanes the coordinate-wise \
                 trimmed mean / median sorts over"
            );
        }
        if !((0.0..=1.0).contains(&self.attack.fraction) && self.attack.fraction.is_finite()) {
            bail!("attack.fraction must be in [0, 1], got {}", self.attack.fraction);
        }
        if !(self.attack.scale.is_finite() && self.attack.scale > 0.0) {
            bail!("attack.scale must be finite and > 0, got {}", self.attack.scale);
        }
        if self.attack.backdoor_coords == 0 {
            bail!("attack.backdoor_coords must be >= 1");
        }
        if !self.attack.backdoor_boost.is_finite() {
            bail!("attack.backdoor_boost must be finite, got {}", self.attack.backdoor_boost);
        }
        // Same starting-inside-the-bounds policy as the other armed
        // controllers (see the staleness/compression checks above).
        if self.control.enabled
            && self.control.trust
            && self.robust.trust
            && !(self.control.trust_threshold_min <= self.robust.trust_threshold
                && self.robust.trust_threshold <= self.control.trust_threshold_max)
        {
            bail!(
                "robust.trust_threshold ({}) must start inside the control plane's \
                 [trust_threshold_min, trust_threshold_max] = [{}, {}]",
                self.robust.trust_threshold,
                self.control.trust_threshold_min,
                self.control.trust_threshold_max
            );
        }
        // Same starting-inside-the-bounds policy for the adaptive trim
        // controller (which drives robust.trim_fraction online).
        if self.control.enabled
            && self.control.trim
            && self.robust.mode == RobustMode::TrimmedMean
            && !(self.control.trim_min <= self.robust.trim_fraction
                && self.robust.trim_fraction <= self.control.trim_max)
        {
            bail!(
                "robust.trim_fraction ({}) must start inside the control plane's \
                 [trim_min, trim_max] = [{}, {}]",
                self.robust.trim_fraction,
                self.control.trim_min,
                self.control.trim_max
            );
        }
        if self.link.max_attempts == 0 {
            bail!("link.max_attempts must be >= 1");
        }
        self.faults.validate()?;
        if self.faults.enabled
            && self.faults.crash_prob > 0.0
            && self.engine == EngineMode::Barriered
        {
            bail!(
                "faults.crash_prob only applies to the barrier_free engine: \
                 crash = park-on-crash + rehydrate, and the barriered loop \
                 needs every client hydrated each round"
            );
        }
        self.obs.validate()?;
        if let Algorithm::Eaflm = self.algorithm {
            if !(0.0 < self.eaflm.alpha && self.eaflm.alpha < 1.0) {
                bail!("eaflm.alpha must be in (0,1)");
            }
            if self.eaflm.depth == 0 {
                bail!("eaflm.depth must be >= 1");
            }
        }
        Ok(())
    }

    /// Load from a TOML-subset file (see `examples/configs/*.toml`).
    pub fn from_toml_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text; unset keys keep defaults.
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = toml::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_str("algorithm") {
            cfg.algorithm = Algorithm::from_name(v)?;
        }
        if let Some(v) = get_nonneg(&doc, "num_clients")? {
            cfg.num_clients = v;
        }
        if let Some(v) = doc.get_str("partition") {
            cfg.partition = match v {
                "iid" => PartitionScheme::Iid,
                "paper_skew" | "non_iid" => PartitionScheme::PaperSkew,
                "dirichlet" => PartitionScheme::Dirichlet {
                    alpha: doc.get_f64("dirichlet_alpha").unwrap_or(0.5),
                },
                other => bail!("unknown partition {other:?}"),
            };
        }
        if let Some(v) = get_nonneg(&doc, "samples_per_client")? {
            cfg.samples_per_client = v;
        }
        if let Some(v) = get_nonneg(&doc, "test_samples")? {
            cfg.test_samples = v;
        }
        if let Some(v) = get_nonneg(&doc, "probe_samples")? {
            cfg.probe_samples = v;
        }
        if let Some(v) = get_nonneg(&doc, "rounds")? {
            cfg.rounds = v;
        }
        if let Some(v) = get_nonneg(&doc, "local_passes")? {
            cfg.local_passes = v;
        }
        if let Some(v) = get_nonneg(&doc, "batches_per_pass")? {
            cfg.batches_per_pass = v;
        }
        if let Some(v) = doc.get_f64("lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = doc.get_f64("target_acc") {
            cfg.target_acc = v;
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = get_nonneg(&doc, "eval_every")? {
            cfg.eval_every = v;
        }
        if let Some(v) = doc.get_f64("pixel_noise") {
            cfg.pixel_noise = v as f32;
        }
        // [link]
        if let Some(v) = doc.get_f64("link.up_mbps") {
            cfg.link.up_mbps = v;
        }
        if let Some(v) = doc.get_f64("link.down_mbps") {
            cfg.link.down_mbps = v;
        }
        if let Some(v) = doc.get_f64("link.latency_s") {
            cfg.link.latency_s = v;
        }
        if let Some(v) = doc.get_f64("link.jitter_sigma") {
            cfg.link.jitter_sigma = v;
        }
        if let Some(v) = doc.get_f64("link.drop_prob") {
            cfg.link.drop_prob = v;
        }
        if let Some(v) = get_nonneg(&doc, "link.max_attempts")? {
            if v == 0 || v > u32::MAX as usize {
                bail!("link.max_attempts must be in [1, 2^32), got {v}");
            }
            cfg.link.max_attempts = v as u32;
        }
        // [eaflm]
        if let Some(v) = doc.get_f64("eaflm.alpha") {
            cfg.eaflm.alpha = v;
        }
        if let Some(v) = doc.get_f64("eaflm.beta") {
            cfg.eaflm.beta = v;
        }
        if let Some(v) = get_nonneg(&doc, "eaflm.depth")? {
            cfg.eaflm.depth = v;
        }
        // [value_fn]
        if let Some(v) = doc.get_bool("value_fn.use_acc_term") {
            cfg.value_fn.use_acc_term = v;
        }
        // [dropout]
        if let Some(v) = doc.get_f64("dropout.prob") {
            cfg.dropout.drop_prob = v;
        }
        if let Some(v) = doc.get_f64("dropout.mean_offline_rounds") {
            cfg.dropout.mean_offline_rounds = v;
        }
        // extensions
        if let Some(v) = doc.get_str("upload_precision") {
            cfg.upload_precision = Precision::from_name(v)
                .with_context(|| format!("unknown upload_precision {v:?}"))?;
        }
        // [compression]
        if let Some(v) = doc.get_str("compression.mode") {
            cfg.compression.mode = CompressionMode::from_name(v)?;
        }
        if let Some(v) = doc.get_f64("compression.k_fraction") {
            cfg.compression.k_fraction = v;
        }
        if let Some(v) = doc.get_str("compression.layer_k_fractions") {
            cfg.compression.layer_k_fractions = parse_fraction_list(v)?;
        }
        if let Some(v) = doc.get_bool("compression.error_feedback") {
            cfg.compression.error_feedback = v;
        }
        if let Some(v) = doc.get_str("compression.down_mode") {
            cfg.compression.down_mode = CompressionMode::from_name(v)?;
        }
        if let Some(v) = doc.get_f64("compression.down_k_fraction") {
            cfg.compression.down_k_fraction = v;
        }
        if let Some(v) = doc.get_str("compression.down_precision") {
            cfg.compression.down_precision = Some(
                Precision::from_name(v)
                    .with_context(|| format!("unknown compression.down_precision {v:?}"))?,
            );
        }
        if let Some(v) = doc.get_f64("staleness_decay") {
            cfg.staleness_decay = Some(v);
        }
        if let Some(v) = get_nonneg(&doc, "threads")? {
            cfg.threads = v;
        }
        if let Some(v) = doc.get_str("engine") {
            cfg.engine = EngineMode::from_name(v)?;
        }
        // [engine] — execution strategy. `engine.mode` is the
        // spec-valid way to select the engine from inside the section
        // (standard TOML rejects a top-level `engine = "..."` string
        // next to an `[engine]` table; our flat-map parser accepts
        // both forms, and the section key wins when both are present).
        if let Some(v) = doc.get_str("engine.mode") {
            cfg.engine = EngineMode::from_name(v)?;
        }
        if let Some(v) = doc.get_bool("engine.threaded") {
            cfg.engine_opts.threaded = v;
        }
        if let Some(v) = get_nonneg(&doc, "engine.workers")? {
            cfg.engine_opts.workers = v;
        }
        if let Some(v) = get_nonneg(&doc, "engine.shards")? {
            cfg.engine_opts.shards = v;
        }
        if let Some(v) = get_nonneg(&doc, "engine.reconcile_every")? {
            cfg.engine_opts.reconcile_every = v;
        }
        if let Some(v) = get_nonneg(&doc, "engine.edge_fanout")? {
            cfg.engine_opts.edge_fanout = v;
        }
        // [fleet] — virtualized client state (active-set rotation).
        if let Some(v) = get_nonneg(&doc, "fleet.active_set")? {
            cfg.fleet.active_set = v;
        }
        if let Some(v) = get_nonneg(&doc, "fleet.residual_budget")? {
            cfg.fleet.residual_budget = v;
        }
        if let Some(v) = doc.get_bool("fleet.compact_records") {
            cfg.fleet.compact_records = v;
        }
        // [async_engine]
        if let Some(v) = doc.get_i64("async_engine.buffer_k") {
            // Strict parse (see `get_nonneg`): a negative buffer used to
            // clamp to 0 and only fail in validate() with a misleading
            // "must be >= 1" about a value the user never wrote.
            if v < 1 {
                bail!("async_engine.buffer_k must be >= 1, got {v}");
            }
            cfg.async_engine.buffer_k = v as usize;
        }
        {
            let alpha = doc
                .get_f64("async_engine.mixing_alpha")
                .unwrap_or(cfg.async_engine.mixing.alpha0());
            if let Some(rule) = doc.get_str("async_engine.mixing") {
                cfg.async_engine.mixing = match rule {
                    "constant" => MixingRule::Constant { alpha },
                    "polynomial" | "poly" => MixingRule::Polynomial {
                        alpha,
                        exponent: doc.get_f64("async_engine.mixing_exponent").unwrap_or(0.5),
                    },
                    "hinge" => MixingRule::Hinge {
                        alpha,
                        grace: get_nonneg(&doc, "async_engine.mixing_grace")?.unwrap_or(4),
                        slope: doc.get_f64("async_engine.mixing_slope").unwrap_or(1.0),
                    },
                    other => bail!("unknown mixing rule {other:?} (constant|polynomial|hinge)"),
                };
            } else if doc.get_f64("async_engine.mixing_alpha").is_some()
                || doc.get_f64("async_engine.mixing_exponent").is_some()
            {
                // Parameters alone re-parameterize the default rule.
                cfg.async_engine.mixing = MixingRule::Polynomial {
                    alpha,
                    exponent: doc.get_f64("async_engine.mixing_exponent").unwrap_or(0.5),
                };
            }
        }
        // [control] — adaptive control plane.
        if let Some(v) = doc.get_bool("control.enabled") {
            cfg.control.enabled = v;
        }
        if let Some(v) = doc.get_bool("control.staleness") {
            cfg.control.staleness = v;
        }
        if let Some(v) = doc.get_bool("control.compression") {
            cfg.control.compression = v;
        }
        if let Some(v) = doc.get_bool("control.rebalance") {
            cfg.control.rebalance = v;
        }
        if let Some(v) = get_nonneg(&doc, "control.interval")? {
            cfg.control.interval = v;
        }
        if let Some(v) = get_nonneg(&doc, "control.window")? {
            cfg.control.window = v;
        }
        if let Some(v) = doc.get_f64("control.staleness_target") {
            cfg.control.staleness_target = v;
        }
        if let Some(v) = doc.get_f64("control.staleness_deadband") {
            cfg.control.staleness_deadband = v;
        }
        if let Some(v) = get_nonneg(&doc, "control.buffer_k_min")? {
            cfg.control.buffer_k_min = v;
        }
        if let Some(v) = get_nonneg(&doc, "control.buffer_k_max")? {
            cfg.control.buffer_k_max = v;
        }
        if let Some(v) = doc.get_f64("control.alpha_min") {
            cfg.control.alpha_min = v;
        }
        if let Some(v) = doc.get_f64("control.alpha_max") {
            cfg.control.alpha_max = v;
        }
        if let Some(v) = doc.get_f64("control.alpha_step") {
            cfg.control.alpha_step = v;
        }
        if let Some(v) = doc.get_f64("control.k_fraction_min") {
            cfg.control.k_fraction_min = v;
        }
        if let Some(v) = doc.get_f64("control.k_fraction_max") {
            cfg.control.k_fraction_max = v;
        }
        if let Some(v) = doc.get_f64("control.k_step") {
            cfg.control.k_step = v;
        }
        if let Some(v) = doc.get_f64("control.residual_hi") {
            cfg.control.residual_hi = v;
        }
        if let Some(v) = doc.get_f64("control.residual_lo") {
            cfg.control.residual_lo = v;
        }
        if let Some(v) = doc.get_f64("control.rebalance_skew") {
            cfg.control.rebalance_skew = v;
        }
        if let Some(v) = doc.get_bool("control.trust") {
            cfg.control.trust = v;
        }
        if let Some(v) = doc.get_f64("control.trust_target") {
            cfg.control.trust_target = v;
        }
        if let Some(v) = doc.get_f64("control.trust_deadband") {
            cfg.control.trust_deadband = v;
        }
        if let Some(v) = doc.get_f64("control.trust_threshold_min") {
            cfg.control.trust_threshold_min = v;
        }
        if let Some(v) = doc.get_f64("control.trust_threshold_max") {
            cfg.control.trust_threshold_max = v;
        }
        if let Some(v) = doc.get_f64("control.trust_step") {
            cfg.control.trust_step = v;
        }
        if let Some(v) = doc.get_bool("control.trim") {
            cfg.control.trim = v;
        }
        if let Some(v) = doc.get_f64("control.trim_target") {
            cfg.control.trim_target = v;
        }
        if let Some(v) = doc.get_f64("control.trim_deadband") {
            cfg.control.trim_deadband = v;
        }
        if let Some(v) = doc.get_f64("control.trim_min") {
            cfg.control.trim_min = v;
        }
        if let Some(v) = doc.get_f64("control.trim_max") {
            cfg.control.trim_max = v;
        }
        if let Some(v) = doc.get_f64("control.trim_step") {
            cfg.control.trim_step = v;
        }
        // [robust] — Byzantine-robust aggregation.
        if let Some(v) = doc.get_str("robust.mode") {
            cfg.robust.mode = RobustMode::from_name(v)?;
        }
        if let Some(v) = doc.get_f64("robust.trim_fraction") {
            cfg.robust.trim_fraction = v;
        }
        if let Some(v) = doc.get_bool("robust.trust") {
            cfg.robust.trust = v;
        }
        if let Some(v) = doc.get_f64("robust.trust_decay") {
            cfg.robust.trust_decay = v;
        }
        if let Some(v) = doc.get_f64("robust.trust_threshold") {
            cfg.robust.trust_threshold = v;
        }
        if let Some(v) = doc.get_f64("robust.trust_floor") {
            cfg.robust.trust_floor = v;
        }
        // [attack] — malicious-client simulator.
        if let Some(v) = doc.get_str("attack.mode") {
            cfg.attack.mode = AttackMode::from_name(v)?;
        }
        if let Some(v) = doc.get_f64("attack.fraction") {
            cfg.attack.fraction = v;
        }
        if let Some(v) = doc.get_f64("attack.scale") {
            cfg.attack.scale = v;
        }
        if let Some(v) = get_nonneg(&doc, "attack.backdoor_coords")? {
            cfg.attack.backdoor_coords = v;
        }
        if let Some(v) = doc.get_f64("attack.backdoor_boost") {
            cfg.attack.backdoor_boost = v;
        }
        // [faults] — deterministic fault injection + checkpointing.
        if let Some(v) = doc.get_bool("faults.enabled") {
            cfg.faults.enabled = v;
        }
        if let Some(v) = doc.get_f64("faults.loss_prob") {
            cfg.faults.loss_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.corrupt_prob") {
            cfg.faults.corrupt_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.dup_prob") {
            cfg.faults.dup_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.down_loss_prob") {
            cfg.faults.down_loss_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.down_corrupt_prob") {
            cfg.faults.down_corrupt_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.reorder_prob") {
            cfg.faults.reorder_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.reorder_window") {
            cfg.faults.reorder_window = v;
        }
        if let Some(v) = get_nonneg(&doc, "faults.max_retransmits")? {
            if v > u32::MAX as usize {
                bail!("faults.max_retransmits must fit in u32, got {v}");
            }
            cfg.faults.max_retransmits = v as u32;
        }
        if let Some(v) = doc.get_f64("faults.backoff_base") {
            cfg.faults.backoff_base = v;
        }
        if let Some(v) = doc.get_f64("faults.backoff_cap") {
            cfg.faults.backoff_cap = v;
        }
        if let Some(v) = doc.get_f64("faults.crash_prob") {
            cfg.faults.crash_prob = v;
        }
        if let Some(v) = doc.get_f64("faults.crash_downtime") {
            cfg.faults.crash_downtime = v;
        }
        if let Some(v) = doc.get_f64("faults.outage_every") {
            cfg.faults.outage_every = v;
        }
        if let Some(v) = doc.get_f64("faults.outage_len") {
            cfg.faults.outage_len = v;
        }
        if let Some(v) = get_nonneg(&doc, "faults.checkpoint_every")? {
            cfg.faults.checkpoint_every = v;
        }
        // [obs] — observability plane (span tracing + exporters).
        if let Some(v) = doc.get_bool("obs.enabled") {
            cfg.obs.enabled = v;
        }
        if let Some(v) = get_nonneg(&doc, "obs.ring_capacity")? {
            cfg.obs.ring_capacity = v;
        }
        if let Some(v) = get_nonneg(&doc, "obs.max_spans")? {
            cfg.obs.max_spans = v;
        }
        if let Some(v) = doc.get_bool("trace_events") {
            cfg.trace_events = v;
        }
        // [backend]
        match doc.get_str("backend.kind") {
            Some("mock") => cfg.backend = Backend::Mock,
            Some("pjrt") | None => {
                if let Some(dir) = doc.get_str("backend.artifact_dir") {
                    cfg.backend = Backend::Pjrt { artifact_dir: dir.to_string() };
                }
            }
            Some(other) => bail!("unknown backend {other:?}"),
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn algorithm_names_round_trip() {
        for a in Algorithm::ALL {
            assert_eq!(Algorithm::from_name(a.name()).unwrap(), a);
        }
        assert!(Algorithm::from_name("sgd").is_err());
    }

    #[test]
    fn from_toml_overrides() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            name = "exp-d"
            algorithm = "eaflm"
            num_clients = 7
            partition = "non_iid"
            rounds = 50
            lr = 0.05
            [link]
            drop_prob = 0.0
            [eaflm]
            alpha = 0.9
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.name, "exp-d");
        assert_eq!(cfg.algorithm, Algorithm::Eaflm);
        assert_eq!(cfg.num_clients, 7);
        assert_eq!(cfg.partition, PartitionScheme::PaperSkew);
        assert_eq!(cfg.rounds, 50);
        assert_eq!(cfg.link.drop_prob, 0.0);
        assert_eq!(cfg.eaflm.alpha, 0.9);
        assert_eq!(cfg.backend, Backend::Mock);
    }

    #[test]
    fn threads_key_parses() {
        let cfg = ExperimentConfig::from_toml("threads = 4\n[backend]\nkind = \"mock\"").unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(ExperimentConfig::default().threads, 0);
    }

    #[test]
    fn dirichlet_partition_with_alpha() {
        let cfg = ExperimentConfig::from_toml(
            "partition = \"dirichlet\"\ndirichlet_alpha = 0.3\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(cfg.partition, PartitionScheme::Dirichlet { alpha: 0.3 });
    }

    #[test]
    fn engine_and_mixing_keys_parse() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            engine = "barrier_free"
            [async_engine]
            buffer_k = 3
            mixing = "hinge"
            mixing_alpha = 0.5
            mixing_grace = 2
            mixing_slope = 0.25
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.engine, EngineMode::BarrierFree);
        assert_eq!(cfg.async_engine.buffer_k, 3);
        assert_eq!(
            cfg.async_engine.mixing,
            MixingRule::Hinge { alpha: 0.5, grace: 2, slope: 0.25 }
        );
        // Defaults: barriered, buffer of 1, polynomial mixing.
        let d = ExperimentConfig::default();
        assert_eq!(d.engine, EngineMode::Barriered);
        assert_eq!(d.async_engine.buffer_k, 1);
        assert!(ExperimentConfig::from_toml("engine = \"sync\"").is_err());
    }

    #[test]
    fn engine_opts_keys_parse() {
        // Spec-valid form: everything under [engine], including the mode.
        let cfg = ExperimentConfig::from_toml(
            r#"
            num_clients = 7
            [engine]
            mode = "barrier_free"
            threaded = true
            workers = 4
            shards = 2
            reconcile_every = 8
            edge_fanout = 4
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.engine, EngineMode::BarrierFree);
        assert_eq!(
            cfg.engine_opts,
            EngineConfig {
                threaded: true,
                workers: 4,
                shards: 2,
                reconcile_every: 8,
                edge_fanout: 4,
            }
        );
        // Defaults: serial, auto workers, unsharded, single-tier.
        let d = EngineConfig::default();
        assert!(!d.threaded);
        assert_eq!((d.workers, d.shards, d.reconcile_every, d.edge_fanout), (0, 1, 4, 1));
        // The legacy top-level string still works alongside the section
        // in the flat-map parser (not spec-TOML; kept for existing
        // configs), and the section's `mode` wins when both appear.
        let legacy = ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nthreaded = true\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(legacy.engine, EngineMode::BarrierFree);
        assert!(legacy.engine_opts.threaded);
        let both = ExperimentConfig::from_toml(
            "engine = \"barriered\"\n[engine]\nmode = \"barrier_free\"\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(both.engine, EngineMode::BarrierFree);
    }

    #[test]
    fn engine_opts_rejected_when_invalid() {
        // Sharding needs the barrier-free engine...
        assert!(ExperimentConfig::from_toml(
            "num_clients = 4\n[engine]\nshards = 2\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...at least one shard...
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nshards = 0\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...no more shards than clients...
        assert!(ExperimentConfig::from_toml(
            "num_clients = 3\nengine = \"barrier_free\"\n[engine]\nshards = 4\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...and a positive reconcile cadence.
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nreconcile_every = 0\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // Threading alone is engine-agnostic (barriered uses the shared
        // executor service).
        assert!(ExperimentConfig::from_toml(
            "[engine]\nthreaded = true\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        // EAFLM + shards is supported since each shard replica keeps its
        // own gate history (Eq. 3 thresholds see consecutive movement of
        // the same replica).
        assert!(ExperimentConfig::from_toml(
            "algorithm = \"eaflm\"\nnum_clients = 4\n[engine]\nmode = \"barrier_free\"\nshards = 2\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn compression_keys_parse() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [compression]
            mode = "topk"
            k_fraction = 0.25
            error_feedback = false
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.compression,
            CompressionConfig {
                mode: CompressionMode::TopK,
                k_fraction: 0.25,
                layer_k_fractions: Vec::new(),
                error_feedback: false,
                ..Default::default()
            }
        );
        // Defaults: dense both ways, full k, error feedback armed.
        let d = ExperimentConfig::default();
        assert_eq!(d.compression.mode, CompressionMode::Dense);
        assert_eq!(d.compression.k_fraction, 1.0);
        assert!(d.compression.error_feedback);
        assert_eq!(d.compression.down_mode, CompressionMode::Dense);
        assert_eq!(d.compression.down_k_fraction, 1.0);
        // Mode names round-trip; bad names rejected.
        for m in [CompressionMode::Dense, CompressionMode::TopK] {
            assert_eq!(CompressionMode::from_name(m.name()).unwrap(), m);
        }
        assert!(CompressionMode::from_name("gzip").is_err());
        // k_fraction outside (0, 1] is rejected.
        for bad in ["0.0", "-0.5", "1.5"] {
            let toml =
                format!("[compression]\nk_fraction = {bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "{bad}");
        }
    }

    #[test]
    fn downlink_compression_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [compression]
            down_mode = "topk"
            down_k_fraction = 0.25
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.compression.down_mode, CompressionMode::TopK);
        assert_eq!(cfg.compression.down_k_fraction, 0.25);
        // Uplink stays dense: the two directions are independent knobs.
        assert_eq!(cfg.compression.mode, CompressionMode::Dense);
        // down_k = ceil(f * n), clamped to [1, n].
        assert_eq!(cfg.compression.down_k_for(100), 25);
        assert_eq!(cfg.compression.down_k_for(1), 1);
        assert_eq!(CompressionConfig::default().down_k_for(100), 100);
        // down_k_fraction outside (0, 1] is rejected.
        for bad in ["0.0", "-0.5", "1.5"] {
            let toml =
                format!("[compression]\ndown_k_fraction = {bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "{bad}");
        }
        // With the adaptive compression controller armed, the downlink
        // knob must start inside the shared [k_min, k_max] bounds.
        assert!(ExperimentConfig::from_toml(
            "[compression]\ndown_mode = \"topk\"\ndown_k_fraction = 0.01\n\
             [control]\nenabled = true\nk_fraction_min = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[compression]\ndown_mode = \"topk\"\ndown_k_fraction = 0.5\n\
             [control]\nenabled = true\nk_fraction_min = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn down_precision_parses_and_defaults() {
        let cfg = ExperimentConfig::from_toml(
            "upload_precision = \"f32\"\n[compression]\ndown_precision = \"int8\"\n\
             [backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(cfg.compression.down_precision, Some(Precision::Int8));
        assert_eq!(cfg.compression.down_precision_or(cfg.upload_precision), Precision::Int8);
        // Unset: broadcasts reuse the upload precision (legacy coupling).
        let d = ExperimentConfig::default();
        assert_eq!(d.compression.down_precision, None);
        assert_eq!(d.compression.down_precision_or(Precision::F16), Precision::F16);
        // Unknown precision names are rejected with the key in the error.
        let err = ExperimentConfig::from_toml(
            "[compression]\ndown_precision = \"bf16\"\n[backend]\nkind = \"mock\"",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("down_precision"), "{err:#}");
    }

    #[test]
    fn robust_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [robust]
            mode = "trimmed_mean"
            trim_fraction = 0.3
            trust = true
            trust_decay = 0.9
            trust_threshold = 0.4
            trust_floor = 0.2
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.robust,
            RobustConfig {
                mode: RobustMode::TrimmedMean,
                trim_fraction: 0.3,
                trust: true,
                trust_decay: 0.9,
                trust_threshold: 0.4,
                trust_floor: 0.2,
            }
        );
        // Defaults: robust off, trust disarmed — the legacy engines.
        let d = RobustConfig::default();
        assert_eq!(d.mode, RobustMode::None);
        assert!(!d.trust);
        // Mode names round-trip; bad names rejected.
        for m in [RobustMode::None, RobustMode::TrimmedMean, RobustMode::Median] {
            assert_eq!(RobustMode::from_name(m.name()).unwrap(), m);
        }
        assert!(RobustMode::from_name("krum").is_err());
        // Bounds: trim in [0, 0.5), decay in (0, 1), threshold/floor in
        // (0, 1].
        for bad in [
            "trim_fraction = 0.5",
            "trim_fraction = -0.1",
            "trust_decay = 0.0",
            "trust_decay = 1.0",
            "trust_threshold = 0.0",
            "trust_threshold = 1.5",
            "trust_floor = 0.0",
        ] {
            let toml = format!("[robust]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "accepted bad [robust] {bad:?}");
        }
        // Trust weighting without a robust mode has no outlier statistic
        // to score — rejected.
        assert!(ExperimentConfig::from_toml(
            "[robust]\ntrust = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // Edge-fanout folding destroys the per-payload lanes the robust
        // merges sort over — the combination is rejected.
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nedge_fanout = 2\n\
             [robust]\nmode = \"median\"\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nedge_fanout = 2\n\
             [robust]\nmode = \"none\"\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        // An armed trust controller requires the starting threshold
        // inside its bounds.
        assert!(ExperimentConfig::from_toml(
            "[robust]\nmode = \"median\"\ntrust = true\ntrust_threshold = 0.05\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[robust]\nmode = \"median\"\ntrust = true\ntrust_threshold = 0.5\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn attack_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            num_clients = 10
            [attack]
            mode = "sign_flip"
            fraction = 0.2
            scale = 5.0
            backdoor_coords = 8
            backdoor_boost = 0.5
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.attack,
            AttackConfig {
                mode: AttackMode::SignFlip,
                fraction: 0.2,
                scale: 5.0,
                backdoor_coords: 8,
                backdoor_boost: 0.5,
            }
        );
        // Defaults: no attackers.
        let d = AttackConfig::default();
        assert_eq!(d.mode, AttackMode::None);
        assert_eq!(d.fraction, 0.0);
        // Mode names round-trip; bad names rejected.
        for m in [
            AttackMode::None,
            AttackMode::LabelFlip,
            AttackMode::SignFlip,
            AttackMode::Scale,
            AttackMode::Backdoor,
        ] {
            assert_eq!(AttackMode::from_name(m.name()).unwrap(), m);
        }
        assert!(AttackMode::from_name("dos").is_err());
        for bad in [
            "fraction = 1.5",
            "fraction = -0.1",
            "scale = 0.0",
            "scale = -2.0",
            "backdoor_coords = 0",
        ] {
            let toml = format!("[attack]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "accepted bad [attack] {bad:?}");
        }
    }

    #[test]
    fn layer_k_fractions_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [compression]
            mode = "topk"
            layer_k_fractions = "0.5, 0.1"
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(cfg.compression.layer_k_fractions, vec![0.5, 0.1]);
        // Per-layer k: ceil(f * size), clamped to [1, size].
        assert_eq!(cfg.compression.layer_ks(&[320, 10]), Some(vec![160, 1]));
        // Empty = flat top-k.
        assert_eq!(CompressionConfig::default().layer_ks(&[320, 10]), None);
        // Out-of-range fractions and junk are rejected.
        for bad in ["\"0.0,0.5\"", "\"1.5\"", "\"-0.1\"", "\"abc\""] {
            let toml = format!(
                "[compression]\nmode = \"topk\"\nlayer_k_fractions = {bad}\n[backend]\nkind = \"mock\""
            );
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "{bad}");
        }
        // Static per-layer budgets conflict with the adaptive compression
        // controller (which drives only the flat k_fraction).
        assert!(ExperimentConfig::from_toml(
            "[compression]\nmode = \"topk\"\nk_fraction = 0.25\nlayer_k_fractions = \"0.5,0.1\"\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[compression]\nmode = \"topk\"\nk_fraction = 0.25\nlayer_k_fractions = \"0.5,0.1\"\n\
             [control]\nenabled = true\ncompression = false\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        // parse_fraction_list round-trips the empty string.
        assert!(parse_fraction_list("").unwrap().is_empty());
        assert_eq!(parse_fraction_list(" 0.25 ,1.0").unwrap(), vec![0.25, 1.0]);
    }

    #[test]
    fn fleet_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            engine = "barrier_free"
            num_clients = 64
            [fleet]
            active_set = 8
            residual_budget = 16
            compact_records = true
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.fleet,
            FleetConfig { active_set: 8, residual_budget: 16, compact_records: true }
        );
        // Defaults: whole-fleet hydration, budget 32, full records.
        let d = FleetConfig::default();
        assert_eq!((d.active_set, d.residual_budget), (0, 32));
        assert!(!d.compact_records);
        // Active-set rotation needs the barrier-free engine.
        assert!(ExperimentConfig::from_toml(
            "[fleet]\nactive_set = 4\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...but active_set = 0 (hydrate everything) is engine-agnostic.
        assert!(ExperimentConfig::from_toml(
            "[fleet]\nactive_set = 0\ncompact_records = true\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn edge_fanout_requires_barrier_free() {
        assert!(ExperimentConfig::from_toml(
            "[engine]\nedge_fanout = 4\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nedge_fanout = 0\n[backend]\nkind = \"mock\""
        )
        .is_err());
        let cfg = ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nedge_fanout = 4\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(cfg.engine_opts.edge_fanout, 4);
    }

    #[test]
    fn alpha_step_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml(
            "[control]\nalpha_step = 0.5\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(cfg.control.alpha_step, 0.5);
        assert_eq!(ControlConfig::default().alpha_step, 0.9);
        for bad in ["0.0", "1.0", "1.5", "-0.5"] {
            let toml = format!("[control]\nalpha_step = {bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "{bad}");
        }
    }

    #[test]
    fn compression_k_for_rounds_up_and_clamps() {
        let mut c = CompressionConfig { mode: CompressionMode::TopK, ..Default::default() };
        c.k_fraction = 0.1;
        assert_eq!(c.k_for(320), 32);
        assert_eq!(c.k_for(17290), 1729);
        assert_eq!(c.k_for(3), 1);
        c.k_fraction = 1.0;
        assert_eq!(c.k_for(320), 320);
        c.k_fraction = 1e-9;
        assert_eq!(c.k_for(320), 1, "k is never zero");
    }

    #[test]
    fn control_keys_parse() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            engine = "barrier_free"
            [async_engine]
            buffer_k = 4
            [compression]
            mode = "topk"
            k_fraction = 0.25
            [control]
            enabled = true
            rebalance = false
            interval = 2
            window = 16
            staleness_target = 3.0
            staleness_deadband = 0.5
            buffer_k_min = 2
            buffer_k_max = 8
            alpha_min = 0.2
            alpha_max = 0.9
            k_fraction_min = 0.1
            k_fraction_max = 0.8
            k_step = 2.0
            residual_hi = 0.7
            residual_lo = 0.3
            rebalance_skew = 3.0
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        let c = cfg.control;
        assert!(c.enabled && c.staleness && c.compression && !c.rebalance);
        assert_eq!((c.interval, c.window), (2, 16));
        assert_eq!((c.buffer_k_min, c.buffer_k_max), (2, 8));
        assert_eq!((c.alpha_min, c.alpha_max), (0.2, 0.9));
        assert_eq!((c.k_fraction_min, c.k_fraction_max), (0.1, 0.8));
        assert_eq!((c.k_step, c.rebalance_skew), (2.0, 3.0));
        assert_eq!((c.residual_lo, c.residual_hi), (0.3, 0.7));
        assert_eq!((c.staleness_target, c.staleness_deadband), (3.0, 0.5));
        // Default: the plane is off and the default bounds validate.
        let d = ExperimentConfig::default();
        assert!(!d.control.enabled);
        d.control.validate().unwrap();
    }

    #[test]
    fn control_bounds_are_validated() {
        for bad in [
            "interval = 0",
            "window = 0",
            "staleness_target = -1.0",
            "staleness_deadband = -0.1",
            "buffer_k_min = 0",
            "buffer_k_min = 5\nbuffer_k_max = 2",
            "alpha_min = 0.0",
            "alpha_min = 0.9\nalpha_max = 0.5",
            "alpha_max = 1.5",
            "k_fraction_min = 0.0",
            "k_fraction_min = 0.9\nk_fraction_max = 0.5",
            "k_fraction_max = 1.5",
            "k_step = 1.0",
            "k_step = 0.5",
            "residual_lo = 0.8\nresidual_hi = 0.4",
            "residual_hi = 1.5",
            "residual_lo = -0.1",
            "rebalance_skew = 0.5",
            "interval = -3",
            "window = -1",
            "trust_target = 1.5",
            "trust_target = -0.1",
            "trust_deadband = -0.1",
            "trust_threshold_min = 0.0",
            "trust_threshold_min = 0.8\ntrust_threshold_max = 0.4",
            "trust_threshold_max = 1.5",
            "trust_step = 0.0",
            "trust_step = 1.0",
        ] {
            let toml = format!("[control]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(
                ExperimentConfig::from_toml(&toml).is_err(),
                "accepted bad [control] {bad:?}"
            );
        }
        // Bad bounds are rejected even with the plane disabled.
        assert!(ExperimentConfig::from_toml(
            "[control]\nenabled = false\nk_step = 0.5\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // An enabled compression controller requires the starting
        // k_fraction inside the control bounds.
        assert!(ExperimentConfig::from_toml(
            "[compression]\nmode = \"topk\"\nk_fraction = 0.02\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // An armed staleness controller requires the starting buffer_k
        // and mixing alpha inside its bounds — otherwise the first
        // clamped step would move the knob against the signal.
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[async_engine]\nbuffer_k = 32\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[async_engine]\nmixing = \"constant\"\nmixing_alpha = 0.05\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...but the barriered engine (staleness knobs unused) and a
        // disarmed staleness controller stay unconstrained.
        assert!(ExperimentConfig::from_toml(
            "[async_engine]\nbuffer_k = 32\n\
             [control]\nenabled = true\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[async_engine]\nbuffer_k = 32\n\
             [control]\nenabled = true\nstaleness = false\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn negative_integer_keys_are_rejected_at_parse() {
        // The old `v.max(0)` clamp turned a negative into 0 and failed
        // later in validate() with a misleading "must be >= 1" (or, for
        // fleet-size keys, reinterpreted it as a huge unsigned count).
        let err = ExperimentConfig::from_toml(
            "[async_engine]\nbuffer_k = -3\n[backend]\nkind = \"mock\"",
        )
        .unwrap_err();
        assert!(err.to_string().contains("buffer_k must be >= 1, got -3"), "{err}");
        for bad in [
            "num_clients = -1",
            "rounds = -5",
            "threads = -2",
            "samples_per_client = -10",
            "eval_every = -1",
        ] {
            let toml = format!("{bad}\n[backend]\nkind = \"mock\"");
            let err = ExperimentConfig::from_toml(&toml).unwrap_err();
            assert!(err.to_string().contains("must not be negative"), "{bad}: {err}");
        }
        for bad in ["workers = -4", "shards = -2", "reconcile_every = -1"] {
            let toml = format!("[engine]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "{bad}");
        }
        assert!(ExperimentConfig::from_toml(
            "[async_engine]\nmixing = \"hinge\"\nmixing_grace = -2\n[backend]\nkind = \"mock\""
        )
        .is_err());
    }

    #[test]
    fn fault_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            engine = "barrier_free"
            [faults]
            enabled = true
            loss_prob = 0.1
            corrupt_prob = 0.05
            dup_prob = 0.05
            down_loss_prob = 0.08
            down_corrupt_prob = 0.02
            reorder_prob = 0.1
            reorder_window = 0.5
            max_retransmits = 3
            backoff_base = 0.1
            backoff_cap = 1.5
            crash_prob = 0.01
            crash_downtime = 4.0
            outage_every = 60.0
            outage_len = 2.0
            checkpoint_every = 8
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        assert_eq!(
            cfg.faults,
            FaultConfig {
                enabled: true,
                loss_prob: 0.1,
                corrupt_prob: 0.05,
                dup_prob: 0.05,
                down_loss_prob: 0.08,
                down_corrupt_prob: 0.02,
                reorder_prob: 0.1,
                reorder_window: 0.5,
                max_retransmits: 3,
                backoff_base: 0.1,
                backoff_cap: 1.5,
                crash_prob: 0.01,
                crash_downtime: 4.0,
                outage_every: 60.0,
                outage_len: 2.0,
                checkpoint_every: 8,
            }
        );
        // Defaults: fully inert.
        let d = FaultConfig::default();
        assert!(!d.enabled);
        assert_eq!(d.loss_prob, 0.0);
        assert_eq!(d.checkpoint_every, 0);
        d.validate().unwrap();
        // Bad bounds are rejected even when disabled.
        for bad in [
            "loss_prob = 1.5",
            "loss_prob = -0.1",
            "corrupt_prob = 2.0",
            "loss_prob = 0.6\ncorrupt_prob = 0.3\ndup_prob = 0.2",
            "down_loss_prob = 0.7\ndown_corrupt_prob = 0.4",
            "reorder_window = -1.0",
            "backoff_base = 0.0",
            "backoff_base = 0.5\nbackoff_cap = 0.1",
            "crash_downtime = 0.0",
            "outage_every = 10.0\noutage_len = 10.0",
            "outage_len = -1.0",
        ] {
            let toml = format!("[faults]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "accepted bad [faults] {bad:?}");
        }
        // Crashes need the barrier-free park/hydrate machinery.
        assert!(ExperimentConfig::from_toml(
            "[faults]\nenabled = true\ncrash_prob = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // Edge accumulators are serialized into engine checkpoints, so
        // checkpointing composes with edge_fanout > 1 (was rejected).
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\n[engine]\nedge_fanout = 2\n\
             [faults]\ncheckpoint_every = 4\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        // Checkpointing without armed faults is allowed (pure crash-safety).
        assert!(ExperimentConfig::from_toml(
            "[faults]\ncheckpoint_every = 4\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn obs_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            "[obs]\nenabled = true\nring_capacity = 256\nmax_spans = 4096\n\
             [backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert!(cfg.obs.enabled);
        assert_eq!(cfg.obs.ring_capacity, 256);
        assert_eq!(cfg.obs.max_spans, 4096);
        let d = ObsConfig::default();
        assert!(!d.enabled);
        assert_eq!((d.ring_capacity, d.max_spans), (1024, 1 << 18));
        // Bad bounds are rejected even when disabled.
        for bad in ["ring_capacity = 0", "max_spans = 0", "ring_capacity = -1"] {
            let toml = format!("[obs]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "accepted bad [obs] {bad:?}");
        }
    }

    #[test]
    fn link_max_attempts_parses_and_validates() {
        let cfg = ExperimentConfig::from_toml(
            "[link]\nmax_attempts = 3\n[backend]\nkind = \"mock\"",
        )
        .unwrap();
        assert_eq!(cfg.link.max_attempts, 3);
        // Default preserves the historical cap of 5 (bitwise streams).
        assert_eq!(ExperimentConfig::default().link.max_attempts, 5);
        assert!(ExperimentConfig::from_toml(
            "[link]\nmax_attempts = 0\n[backend]\nkind = \"mock\""
        )
        .is_err());
        assert!(ExperimentConfig::from_toml(
            "[link]\nmax_attempts = -2\n[backend]\nkind = \"mock\""
        )
        .is_err());
    }

    #[test]
    fn trim_controller_keys_parse_and_validate() {
        let cfg = ExperimentConfig::from_toml(
            r#"
            [robust]
            mode = "trimmed_mean"
            trim_fraction = 0.2
            [control]
            enabled = true
            trim = true
            trim_target = 0.2
            trim_deadband = 0.1
            trim_min = 0.05
            trim_max = 0.4
            trim_step = 0.1
            [backend]
            kind = "mock"
            "#,
        )
        .unwrap();
        let c = cfg.control;
        assert!(c.trim);
        assert_eq!((c.trim_target, c.trim_deadband), (0.2, 0.1));
        assert_eq!((c.trim_min, c.trim_max, c.trim_step), (0.05, 0.4, 0.1));
        // Defaults validate and arm the controller (subject to robust mode).
        let d = ControlConfig::default();
        assert!(d.trim);
        d.validate().unwrap();
        for bad in [
            "trim_target = 1.5",
            "trim_deadband = -0.1",
            "trim_min = -0.1",
            "trim_min = 0.4\ntrim_max = 0.2",
            "trim_max = 0.5",
            "trim_step = 0.0",
            "trim_step = 0.5",
        ] {
            let toml = format!("[control]\n{bad}\n[backend]\nkind = \"mock\"");
            assert!(ExperimentConfig::from_toml(&toml).is_err(), "accepted bad trim {bad:?}");
        }
        // Armed trim controller: starting trim_fraction must be inside
        // bounds.
        assert!(ExperimentConfig::from_toml(
            "[robust]\nmode = \"trimmed_mean\"\ntrim_fraction = 0.02\n\
             [control]\nenabled = true\ntrim_min = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // ...unless the controller (or the trimmed mode) is disarmed.
        assert!(ExperimentConfig::from_toml(
            "[robust]\nmode = \"trimmed_mean\"\ntrim_fraction = 0.02\n\
             [control]\nenabled = true\ntrim = false\ntrim_min = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_ok());
        assert!(ExperimentConfig::from_toml(
            "[robust]\nmode = \"median\"\ntrim_fraction = 0.02\n\
             [control]\nenabled = true\ntrim_min = 0.1\n[backend]\nkind = \"mock\""
        )
        .is_ok());
    }

    #[test]
    fn rejects_invalid() {
        assert!(ExperimentConfig::from_toml("num_clients = 0").is_err());
        assert!(ExperimentConfig::from_toml("algorithm = \"sgd\"").is_err());
        assert!(ExperimentConfig::from_toml("target_acc = 1.5").is_err());
        assert!(ExperimentConfig::from_toml("partition = \"zipf\"").is_err());
        assert!(ExperimentConfig::from_toml("rounds = 0").is_err());
        assert!(
            ExperimentConfig::from_toml("[async_engine]\nbuffer_k = 0\n[backend]\nkind = \"mock\"")
                .is_err()
        );
        assert!(ExperimentConfig::from_toml(
            "[async_engine]\nmixing = \"constant\"\nmixing_alpha = 2.0\n[backend]\nkind = \"mock\""
        )
        .is_err());
        // staleness_decay is a barriered-engine knob; the barrier-free
        // engine has alpha(tau) — reject the silently-ignored combination.
        assert!(ExperimentConfig::from_toml(
            "engine = \"barrier_free\"\nstaleness_decay = 0.5\n[backend]\nkind = \"mock\""
        )
        .is_err());
        let mut cfg = ExperimentConfig::default();
        cfg.probe_samples = cfg.test_samples + 1;
        assert!(cfg.validate().is_err());
    }
}
