//! Controller policies of the adaptive control plane: pure, deterministic
//! `fn(window statistics) -> decision` functions with explicit bounds and
//! deadband hysteresis, so every decision is unit-testable on synthetic
//! windows and bitwise reproducible across runs and thread counts.
//!
//! * [`StalenessController`] — retunes the barrier-free engine's
//!   `buffer_k` and the `alpha(tau)` base rate from the observed upload
//!   staleness: high staleness means version counters are outrunning
//!   client syncs, so batch more per flush (larger buffer) and trust
//!   stale uploads less (lower alpha); low staleness unwinds both for
//!   lower aggregation latency.
//! * [`CompressionController`] — retunes the sparse top-k `k_fraction`
//!   from the error-feedback residual mass: a large residual ratio means
//!   the budget is starving the model (ship more), a small one with a
//!   non-degrading accuracy proxy means there is headroom to compress
//!   harder.
//! * [`ShardRebalancer`] — proposes migrating one client off the hottest
//!   aggregator shard when the windowed flush-rate skew exceeds a
//!   threshold (the engine applies migrations only at reconcile
//!   boundaries, where every replica was just reset to the global).

/// A proposed change to one engine knob.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KnobChange {
    /// Barrier-free buffer-of-K threshold.
    BufferK { from: usize, to: usize },
    /// Base rate `alpha(0)` of the staleness mixing rule.
    Alpha0 { from: f64, to: f64 },
    /// Sparse top-k budget `compression.k_fraction`.
    KFraction { from: f64, to: f64 },
    /// Sparse downlink budget `compression.down_k_fraction` (the
    /// broadcast mirror of [`KnobChange::KFraction`], driven by the
    /// downlink residual ratio).
    DownKFraction { from: f64, to: f64 },
    /// Soft-quarantine threshold `robust.trust_threshold` of the robust
    /// aggregation path (driven by the windowed outlier rate).
    TrustThreshold { from: f64, to: f64 },
    /// Robust trimming strength `robust.trim_fraction` of the
    /// trimmed-mean aggregator (driven by the same windowed outlier rate
    /// as [`KnobChange::TrustThreshold`], in the opposite direction:
    /// outliers firing means trim *harder*).
    TrimFraction { from: f64, to: f64 },
}

/// One controller decision: the change plus the window statistic that
/// triggered it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KnobDecision {
    pub controller: &'static str,
    pub change: KnobChange,
    pub signal: f64,
}

/// A proposed client migration between aggregator shards (the engine
/// picks the concrete client deterministically from its own state).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Migration {
    pub from_shard: usize,
    pub to_shard: usize,
    /// Observed hottest/coldest windowed flush-count ratio.
    pub signal: f64,
}

/// Staleness controller: drive the window's upload-weighted mean
/// staleness toward `target` by moving `buffer_k` one step and `alpha0`
/// one multiplicative step per evaluation. The `deadband` around the
/// target is the hysteresis: inside it, nothing moves.
#[derive(Debug, Clone, Copy)]
pub struct StalenessController {
    pub target: f64,
    pub deadband: f64,
    pub k_min: usize,
    pub k_max: usize,
    pub alpha_min: f64,
    pub alpha_max: f64,
    /// Multiplicative alpha step in (0, 1): high staleness multiplies
    /// alpha0 by it, low staleness divides.
    pub alpha_step: f64,
}

impl StalenessController {
    /// Pure decision on a window's mean staleness against the current
    /// `(buffer_k, alpha0)`. Returns zero, one, or two knob changes
    /// (both knobs can move in the same evaluation); changes already at
    /// their bound are suppressed.
    pub fn decide(&self, mean_staleness: f64, buffer_k: usize, alpha0: f64) -> Vec<KnobDecision> {
        let mut out = Vec::new();
        if !mean_staleness.is_finite() {
            return out;
        }
        let push_k = |out: &mut Vec<KnobDecision>, to: usize| {
            if to != buffer_k {
                out.push(KnobDecision {
                    controller: "staleness",
                    change: KnobChange::BufferK { from: buffer_k, to },
                    signal: mean_staleness,
                });
            }
        };
        let push_a = |out: &mut Vec<KnobDecision>, to: f64| {
            if to != alpha0 {
                out.push(KnobDecision {
                    controller: "staleness",
                    change: KnobChange::Alpha0 { from: alpha0, to },
                    signal: mean_staleness,
                });
            }
        };
        if mean_staleness > self.target + self.deadband {
            push_k(&mut out, (buffer_k + 1).clamp(self.k_min, self.k_max));
            push_a(&mut out, (alpha0 * self.alpha_step).clamp(self.alpha_min, self.alpha_max));
        } else if mean_staleness < self.target - self.deadband {
            push_k(&mut out, buffer_k.saturating_sub(1).clamp(self.k_min, self.k_max));
            push_a(&mut out, (alpha0 / self.alpha_step).clamp(self.alpha_min, self.alpha_max));
        }
        out
    }
}

/// Compression controller: move `k_fraction` one multiplicative `step`
/// per evaluation, up when the residual ratio exceeds `residual_hi`,
/// down when it falls below `residual_lo` *and* the accuracy proxy is
/// not degrading. The `[residual_lo, residual_hi]` band is the
/// hysteresis.
#[derive(Debug, Clone, Copy)]
pub struct CompressionController {
    pub k_min: f64,
    pub k_max: f64,
    /// Multiplicative step, > 1.
    pub step: f64,
    pub residual_hi: f64,
    pub residual_lo: f64,
}

impl CompressionController {
    /// Pure decision on the window's residual ratio and accuracy trend
    /// (`None` = not enough evidence, which suppresses shrinking only).
    pub fn decide(
        &self,
        residual_ratio: f64,
        acc_improving: Option<bool>,
        k_fraction: f64,
    ) -> Option<KnobDecision> {
        if !residual_ratio.is_finite() {
            return None;
        }
        let to = if residual_ratio > self.residual_hi {
            (k_fraction * self.step).clamp(self.k_min, self.k_max)
        } else if residual_ratio < self.residual_lo && acc_improving == Some(true) {
            (k_fraction / self.step).clamp(self.k_min, self.k_max)
        } else {
            return None;
        };
        if to == k_fraction {
            return None;
        }
        Some(KnobDecision {
            controller: "compression",
            change: KnobChange::KFraction { from: k_fraction, to },
            signal: residual_ratio,
        })
    }
}

/// Trust controller: drive the windowed mean outlier rate toward
/// `target` by moving the soft-quarantine threshold
/// (`robust.trust_threshold`) one additive `step` per evaluation — an
/// outlier rate above the band means the trimmer keeps firing (an attack
/// or a badly mis-set threshold), so *tighten*: lower the threshold and
/// quarantine suspicious clients harder. A rate below the band means the
/// fleet looks clean; relax the threshold so honest-but-noisy stragglers
/// recover full weight. The `deadband` around the target is the
/// hysteresis; NaN (robust off, or no robust flush in the window) never
/// decides.
#[derive(Debug, Clone, Copy)]
pub struct TrustController {
    pub target: f64,
    pub deadband: f64,
    pub t_min: f64,
    pub t_max: f64,
    /// Additive threshold step in (0, 1).
    pub step: f64,
}

impl TrustController {
    /// Pure decision on the window's mean outlier rate against the
    /// current threshold. Changes already at their bound are suppressed.
    pub fn decide(&self, mean_outlier_rate: f64, threshold: f64) -> Option<KnobDecision> {
        if !mean_outlier_rate.is_finite() {
            return None;
        }
        let to = if mean_outlier_rate > self.target + self.deadband {
            (threshold - self.step).clamp(self.t_min, self.t_max)
        } else if mean_outlier_rate < self.target - self.deadband {
            (threshold + self.step).clamp(self.t_min, self.t_max)
        } else {
            return None;
        };
        if to == threshold {
            return None;
        }
        Some(KnobDecision {
            controller: "trust",
            change: KnobChange::TrustThreshold { from: threshold, to },
            signal: mean_outlier_rate,
        })
    }
}

/// Trim controller: drive the windowed mean outlier rate toward `target`
/// by moving the trimmed-mean strength (`robust.trim_fraction`) one
/// additive `step` per evaluation — the *inverse* sense of
/// [`TrustController`]: a rate above the band means coordinate outliers
/// keep surviving into the aggregate, so *widen* the trim and cut more
/// tails; a rate below the band means the fleet looks clean, so relax the
/// trim and keep more honest mass. The `deadband` around the target is
/// the hysteresis; NaN (robust off, or no robust flush in the window)
/// never decides.
#[derive(Debug, Clone, Copy)]
pub struct TrimController {
    pub target: f64,
    pub deadband: f64,
    pub t_min: f64,
    pub t_max: f64,
    /// Additive trim step in (0, 0.5).
    pub step: f64,
}

impl TrimController {
    /// Pure decision on the window's mean outlier rate against the
    /// current trim fraction. Changes already at their bound are
    /// suppressed.
    pub fn decide(&self, mean_outlier_rate: f64, trim_fraction: f64) -> Option<KnobDecision> {
        if !mean_outlier_rate.is_finite() {
            return None;
        }
        let to = if mean_outlier_rate > self.target + self.deadband {
            (trim_fraction + self.step).clamp(self.t_min, self.t_max)
        } else if mean_outlier_rate < self.target - self.deadband {
            (trim_fraction - self.step).clamp(self.t_min, self.t_max)
        } else {
            return None;
        };
        if to == trim_fraction {
            return None;
        }
        Some(KnobDecision {
            controller: "trim",
            change: KnobChange::TrimFraction { from: trim_fraction, to },
            signal: mean_outlier_rate,
        })
    }
}

/// Shard rebalancer: when the hottest shard's windowed flush count
/// exceeds the coldest's by a factor of `skew`, propose migrating one
/// client hot -> cold. Ties break toward the lowest shard id, and a
/// single-client hot shard is never drained.
#[derive(Debug, Clone, Copy)]
pub struct ShardRebalancer {
    /// Hottest/coldest flush-count ratio above which one client moves
    /// (>= 1; below it nothing moves — the hysteresis).
    pub skew: f64,
}

impl ShardRebalancer {
    /// Pure decision on windowed per-shard flush counts and current
    /// shard populations.
    pub fn decide(&self, flushes_per_shard: &[usize], shard_pop: &[usize]) -> Option<Migration> {
        if flushes_per_shard.len() < 2 || flushes_per_shard.len() != shard_pop.len() {
            return None;
        }
        let mut hot = 0usize;
        let mut cold = 0usize;
        for (s, &c) in flushes_per_shard.iter().enumerate() {
            if c > flushes_per_shard[hot] {
                hot = s;
            }
            if c < flushes_per_shard[cold] {
                cold = s;
            }
        }
        if hot == cold || shard_pop[hot] <= 1 {
            return None;
        }
        let skew =
            flushes_per_shard[hot] as f64 / flushes_per_shard[cold].max(1) as f64;
        if skew < self.skew {
            return None;
        }
        Some(Migration { from_shard: hot, to_shard: cold, signal: skew })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn staleness() -> StalenessController {
        StalenessController {
            target: 2.0,
            deadband: 1.0,
            k_min: 1,
            k_max: 8,
            alpha_min: 0.1,
            alpha_max: 1.0,
            alpha_step: 0.9,
        }
    }

    #[test]
    fn staleness_deadband_is_hysteresis() {
        let c = staleness();
        // Inside target +- deadband: no decision.
        assert!(c.decide(2.0, 4, 0.8).is_empty());
        assert!(c.decide(2.9, 4, 0.8).is_empty());
        assert!(c.decide(1.1, 4, 0.8).is_empty());
        // NaN (empty window) never decides.
        assert!(c.decide(f64::NAN, 4, 0.8).is_empty());
    }

    #[test]
    fn staleness_high_grows_buffer_and_damps_alpha() {
        let c = staleness();
        let ds = c.decide(4.0, 4, 0.8);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].change, KnobChange::BufferK { from: 4, to: 5 });
        match ds[1].change {
            KnobChange::Alpha0 { from, to } => {
                assert_eq!(from, 0.8);
                assert!((to - 0.72).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(ds[0].signal, 4.0);
    }

    #[test]
    fn staleness_low_shrinks_buffer_and_raises_alpha() {
        let c = staleness();
        let ds = c.decide(0.2, 4, 0.5);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds[0].change, KnobChange::BufferK { from: 4, to: 3 });
        match ds[1].change {
            KnobChange::Alpha0 { to, .. } => assert!((to - 0.5 / 0.9).abs() < 1e-12),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn staleness_clamps_to_bounds_and_suppresses_noops() {
        let c = staleness();
        // At k_max / alpha_min, a high-staleness evaluation changes nothing.
        assert!(c.decide(10.0, 8, 0.1).is_empty());
        // At k_min / alpha_max, a low-staleness evaluation changes nothing.
        assert!(c.decide(0.0, 1, 1.0).is_empty());
        // One step above the bound clamps to it.
        let ds = c.decide(10.0, 8, 0.105);
        assert_eq!(ds.len(), 1);
        match ds[0].change {
            KnobChange::Alpha0 { to, .. } => assert_eq!(to, 0.1),
            other => panic!("unexpected {other:?}"),
        }
    }

    fn compression() -> CompressionController {
        CompressionController {
            k_min: 0.05,
            k_max: 1.0,
            step: 2.0,
            residual_hi: 0.6,
            residual_lo: 0.2,
        }
    }

    #[test]
    fn compression_band_is_hysteresis() {
        let c = compression();
        assert_eq!(c.decide(0.4, Some(true), 0.25), None);
        assert_eq!(c.decide(f64::NAN, Some(true), 0.25), None);
    }

    #[test]
    fn compression_grows_k_on_high_residual() {
        let c = compression();
        let d = c.decide(0.8, Some(false), 0.25).unwrap();
        assert_eq!(d.change, KnobChange::KFraction { from: 0.25, to: 0.5 });
        assert_eq!(d.signal, 0.8);
        // Growth is clamped to k_max and no-ops at the bound.
        let d = c.decide(0.8, None, 0.7).unwrap();
        assert_eq!(d.change, KnobChange::KFraction { from: 0.7, to: 1.0 });
        assert_eq!(c.decide(0.8, None, 1.0), None);
    }

    #[test]
    fn compression_shrinks_only_with_accuracy_evidence() {
        let c = compression();
        let d = c.decide(0.1, Some(true), 0.4).unwrap();
        assert_eq!(d.change, KnobChange::KFraction { from: 0.4, to: 0.2 });
        // Degrading or unknown accuracy suppresses the shrink.
        assert_eq!(c.decide(0.1, Some(false), 0.4), None);
        assert_eq!(c.decide(0.1, None, 0.4), None);
        // Shrink clamps to k_min and no-ops at the bound.
        let d = c.decide(0.1, Some(true), 0.08).unwrap();
        assert_eq!(d.change, KnobChange::KFraction { from: 0.08, to: 0.05 });
        assert_eq!(c.decide(0.1, Some(true), 0.05), None);
    }

    fn trust() -> TrustController {
        TrustController { target: 0.1, deadband: 0.05, t_min: 0.1, t_max: 0.9, step: 0.05 }
    }

    #[test]
    fn trust_deadband_and_nan_are_hysteresis() {
        let c = trust();
        assert_eq!(c.decide(0.1, 0.5), None);
        assert_eq!(c.decide(0.14, 0.5), None);
        assert_eq!(c.decide(0.06, 0.5), None);
        assert_eq!(c.decide(f64::NAN, 0.5), None, "robust off must never decide");
    }

    #[test]
    fn trust_tightens_on_high_outlier_rate() {
        let c = trust();
        let d = c.decide(0.4, 0.5).unwrap();
        assert_eq!(d.controller, "trust");
        match d.change {
            KnobChange::TrustThreshold { from, to } => {
                assert_eq!(from, 0.5);
                assert!((to - 0.45).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(d.signal, 0.4);
        // Clamped at t_min; no-op at the bound.
        let d = c.decide(0.4, 0.12).unwrap();
        assert_eq!(d.change, KnobChange::TrustThreshold { from: 0.12, to: 0.1 });
        assert_eq!(c.decide(0.4, 0.1), None);
    }

    #[test]
    fn trust_relaxes_on_clean_window() {
        let c = trust();
        let d = c.decide(0.0, 0.5).unwrap();
        match d.change {
            KnobChange::TrustThreshold { from, to } => {
                assert_eq!(from, 0.5);
                assert!((to - 0.55).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Clamped at t_max; no-op at the bound.
        let d = c.decide(0.0, 0.88).unwrap();
        assert_eq!(d.change, KnobChange::TrustThreshold { from: 0.88, to: 0.9 });
        assert_eq!(c.decide(0.0, 0.9), None);
    }

    fn trim() -> TrimController {
        TrimController { target: 0.15, deadband: 0.05, t_min: 0.0, t_max: 0.45, step: 0.05 }
    }

    #[test]
    fn trim_deadband_and_nan_are_hysteresis() {
        let c = trim();
        assert_eq!(c.decide(0.15, 0.2), None);
        assert_eq!(c.decide(0.19, 0.2), None);
        assert_eq!(c.decide(0.11, 0.2), None);
        assert_eq!(c.decide(f64::NAN, 0.2), None, "robust off must never decide");
    }

    #[test]
    fn trim_widens_on_high_outlier_rate() {
        let c = trim();
        // Opposite sense of the trust controller: outliers -> trim MORE.
        let d = c.decide(0.4, 0.2).unwrap();
        assert_eq!(d.controller, "trim");
        match d.change {
            KnobChange::TrimFraction { from, to } => {
                assert_eq!(from, 0.2);
                assert!((to - 0.25).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        assert_eq!(d.signal, 0.4);
        // Clamped at t_max; no-op at the bound.
        let d = c.decide(0.4, 0.42).unwrap();
        assert_eq!(d.change, KnobChange::TrimFraction { from: 0.42, to: 0.45 });
        assert_eq!(c.decide(0.4, 0.45), None);
    }

    #[test]
    fn trim_relaxes_on_clean_window() {
        let c = trim();
        let d = c.decide(0.0, 0.2).unwrap();
        match d.change {
            KnobChange::TrimFraction { from, to } => {
                assert_eq!(from, 0.2);
                assert!((to - 0.15).abs() < 1e-12);
            }
            other => panic!("unexpected {other:?}"),
        }
        // Clamped at t_min; no-op at the bound.
        let d = c.decide(0.0, 0.03).unwrap();
        assert_eq!(d.change, KnobChange::TrimFraction { from: 0.03, to: 0.0 });
        assert_eq!(c.decide(0.0, 0.0), None);
    }

    #[test]
    fn rebalancer_migrates_hot_to_cold_above_skew() {
        let r = ShardRebalancer { skew: 2.0 };
        let m = r.decide(&[6, 2], &[3, 4]).unwrap();
        assert_eq!((m.from_shard, m.to_shard), (0, 1));
        assert_eq!(m.signal, 3.0);
        // Below the skew threshold: hysteresis holds.
        assert_eq!(r.decide(&[3, 2], &[3, 4]), None);
        // A never-flushed cold shard reads as maximal skew.
        let m = r.decide(&[5, 0], &[3, 4]).unwrap();
        assert_eq!((m.from_shard, m.to_shard), (0, 1));
        assert_eq!(m.signal, 5.0);
    }

    #[test]
    fn rebalancer_never_drains_a_singleton_or_acts_degenerate() {
        let r = ShardRebalancer { skew: 1.0 };
        // Hot shard with one client: no migration.
        assert_eq!(r.decide(&[9, 1], &[1, 6]), None);
        // Uniform counts: hot == cold, no migration.
        assert_eq!(r.decide(&[3, 3], &[4, 3]), None);
        // Single shard / mismatched inputs: no migration.
        assert_eq!(r.decide(&[3], &[7]), None);
        assert_eq!(r.decide(&[3, 1], &[7]), None);
        // Ties break to the lowest shard ids.
        let m = r.decide(&[4, 2, 4, 2], &[3, 3, 3, 3]).unwrap();
        assert_eq!((m.from_shard, m.to_shard), (0, 1));
    }
}
