//! Adaptive control plane: closes the loop from observed run telemetry
//! back into the engines' knobs.
//!
//! The paper's headline numbers come from *fixed* knobs (buffer size,
//! staleness weighting, compression budget) chosen offline per
//! experiment. This subsystem makes them closed-loop, in the spirit of
//! FedLuck's online compression/cadence co-adaptation and QuAFL's
//! heterogeneity-tracking buffered asynchrony:
//!
//! 1. a **telemetry bus** ([`telemetry::TelemetryBus`]) of bounded
//!    rolling windows over upload staleness, error-feedback residual
//!    mass, per-shard flush rates and wire bytes, fed from both engines
//!    at event-commit time;
//! 2. **controllers** ([`controllers`]) — pure, deterministic
//!    `fn(window) -> decision` policies retuning `buffer_k` /
//!    `alpha(tau)`, `k_fraction`, and the client-to-shard assignment;
//! 3. the [`ControlPlane`], which owns both and is polled by
//!    `coordinator::server` at deterministic commit points (every
//!    `control.interval` flushes/rounds; shard migrations only at
//!    reconcile boundaries), so serial == threaded stays bitwise.
//!
//! With `control.enabled = false` (the default) the plane is fully
//! inert: no telemetry is collected, no decision is ever taken, and
//! both engines produce record streams bitwise identical to a build
//! without this subsystem (asserted in `rust/tests/control.rs` and
//! pinned by the golden snapshots).

pub mod controllers;
pub mod telemetry;

pub use controllers::{
    CompressionController, KnobChange, KnobDecision, Migration, ShardRebalancer,
    StalenessController, TrimController, TrustController,
};
pub use telemetry::{FlushSample, TelemetryBus, TrustBook};

use crate::config::ControlConfig;
use crate::util::codec::{Dec, Enc};
use anyhow::Result;

/// Live knob values, snapshotted by the engine at each decision point.
#[derive(Debug, Clone, Copy)]
pub struct Knobs {
    pub buffer_k: usize,
    pub alpha0: f64,
    pub k_fraction: f64,
    /// The compression controller is inert unless top-k mode is active.
    pub topk: bool,
    pub down_k_fraction: f64,
    /// The downlink compression arm is inert unless sparse broadcasts
    /// (`compression.down_mode = topk`) are active.
    pub down_topk: bool,
    /// The staleness controller is inert on the barriered engine (its
    /// knobs only exist on the barrier-free one).
    pub barrier_free: bool,
    /// Current soft-quarantine threshold (`robust.trust_threshold`).
    pub trust_threshold: f64,
    /// The trust controller is inert unless a robust aggregation mode is
    /// active *and* trust scoring is on (`robust.trust = true`).
    pub trust_armed: bool,
    /// Current trimmed-mean strength (`robust.trim_fraction`).
    pub trim_fraction: f64,
    /// The trim controller is inert unless the trimmed-mean aggregator
    /// is active (`robust.mode = trimmed_mean`).
    pub trim_armed: bool,
}

/// The control plane: telemetry window + controller set, evaluated at
/// the engines' commit points.
pub struct ControlPlane {
    cfg: ControlConfig,
    bus: TelemetryBus,
    staleness: StalenessController,
    compression: CompressionController,
    rebalancer: ShardRebalancer,
    trust: TrustController,
    trim: TrimController,
    /// Flush index of the last *applied* migration (engine-reported via
    /// [`ControlPlane::note_migration`]). The rebalancer holds off until
    /// a full telemetry window of post-migration samples exists — the
    /// flush-rate skew that justified the move is exactly the data the
    /// move invalidated.
    last_migration: Option<usize>,
}

impl ControlPlane {
    pub fn new(cfg: &ControlConfig) -> Self {
        ControlPlane {
            bus: TelemetryBus::new(cfg.window),
            staleness: StalenessController {
                target: cfg.staleness_target,
                deadband: cfg.staleness_deadband,
                k_min: cfg.buffer_k_min,
                k_max: cfg.buffer_k_max,
                alpha_min: cfg.alpha_min,
                alpha_max: cfg.alpha_max,
                alpha_step: cfg.alpha_step,
            },
            compression: CompressionController {
                k_min: cfg.k_fraction_min,
                k_max: cfg.k_fraction_max,
                step: cfg.k_step,
                residual_hi: cfg.residual_hi,
                residual_lo: cfg.residual_lo,
            },
            rebalancer: ShardRebalancer { skew: cfg.rebalance_skew },
            trust: TrustController {
                target: cfg.trust_target,
                deadband: cfg.trust_deadband,
                t_min: cfg.trust_threshold_min,
                t_max: cfg.trust_threshold_max,
                step: cfg.trust_step,
            },
            trim: TrimController {
                target: cfg.trim_target,
                deadband: cfg.trim_deadband,
                t_min: cfg.trim_min,
                t_max: cfg.trim_max,
                step: cfg.trim_step,
            },
            last_migration: None,
            cfg: *cfg,
        }
    }

    /// Master switch: whether the plane observes and decides at all.
    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    /// The telemetry window (diagnostics/tests).
    pub fn bus(&self) -> &TelemetryBus {
        &self.bus
    }

    /// Feed one commit-time sample (no-op while disabled, so the
    /// disabled plane costs nothing and holds no state).
    pub fn observe(&mut self, sample: FlushSample) {
        if self.cfg.enabled {
            self.bus.push(sample);
        }
    }

    /// Whether the knob controllers evaluate at commit index `round`
    /// (every `control.interval` commits, once telemetry exists).
    pub fn due(&self, round: usize) -> bool {
        self.cfg.enabled && round % self.cfg.interval.max(1) == 0 && !self.bus.is_empty()
    }

    /// Evaluate the staleness + compression controllers against the
    /// current knob values. Pure in the window: same telemetry, same
    /// knobs -> same decisions.
    pub fn decide_knobs(&self, knobs: Knobs) -> Vec<KnobDecision> {
        let mut out = Vec::new();
        if !self.cfg.enabled {
            return out;
        }
        if self.cfg.staleness && knobs.barrier_free {
            out.extend(self.staleness.decide(
                self.bus.mean_staleness(),
                knobs.buffer_k,
                knobs.alpha0,
            ));
        }
        if self.cfg.compression && knobs.topk {
            if let Some(d) = self.compression.decide(
                self.bus.residual_ratio(),
                self.bus.acc_improving(1e-3),
                knobs.k_fraction,
            ) {
                out.push(d);
            }
        }
        if self.cfg.compression && knobs.down_topk {
            // Same stateless controller, driven by the downlink residual
            // ratio; its KFraction decision is remapped onto the
            // down_k_fraction knob.
            if let Some(d) = self.compression.decide(
                self.bus.down_residual_ratio(),
                self.bus.acc_improving(1e-3),
                knobs.down_k_fraction,
            ) {
                if let KnobChange::KFraction { from, to } = d.change {
                    out.push(KnobDecision {
                        change: KnobChange::DownKFraction { from, to },
                        ..d
                    });
                }
            }
        }
        if self.cfg.trust && knobs.trust_armed {
            let rate = self.bus.mean_outlier_rate();
            if let Some(d) = self.trust.decide(rate, knobs.trust_threshold) {
                out.push(d);
            }
        }
        if self.cfg.trim && knobs.trim_armed {
            let rate = self.bus.mean_outlier_rate();
            if let Some(d) = self.trim.decide(rate, knobs.trim_fraction) {
                out.push(d);
            }
        }
        out
    }

    /// Evaluate the shard rebalancer at flush index `flush` (the engine
    /// calls this only at reconcile boundaries, where every replica was
    /// just reset to the reconciled global). Cooldown: after an applied
    /// migration the rebalancer waits one full telemetry window, so it
    /// never acts twice on skew data the previous move invalidated.
    pub fn decide_rebalance(&self, flush: usize, shard_pop: &[usize]) -> Option<Migration> {
        if !(self.cfg.enabled && self.cfg.rebalance) || shard_pop.len() < 2 {
            return None;
        }
        if let Some(last) = self.last_migration {
            if flush.saturating_sub(last) < self.cfg.window {
                return None;
            }
        }
        let flushes = self.bus.per_shard_flushes(shard_pop.len());
        self.rebalancer.decide(&flushes, shard_pop)
    }

    /// Record that the engine actually applied a migration at flush
    /// index `flush` (it may decline one — e.g. no eligible client —
    /// in which case the cooldown must not start).
    pub fn note_migration(&mut self, flush: usize) {
        self.last_migration = Some(flush);
    }

    /// Serialize the plane's mutable state (telemetry window + migration
    /// cooldown) for a checkpoint. The controllers and config are pure
    /// and rebuilt from the experiment config at restore.
    pub fn save(&self, enc: &mut Enc) {
        self.bus.save(enc);
        match self.last_migration {
            Some(f) => {
                enc.bool(true);
                enc.usize(f);
            }
            None => enc.bool(false),
        }
    }

    /// Restore the mutable state saved by [`ControlPlane::save`] into a
    /// freshly constructed plane.
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        self.bus.load(dec)?;
        self.last_migration = if dec.bool()? { Some(dec.usize()?) } else { None };
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: usize, shard: usize, stale: usize) -> FlushSample {
        FlushSample {
            round,
            shard,
            vtime: round as f64,
            uploads: 2,
            staleness_sum: stale,
            staleness_max: stale,
            bytes_up: 10,
            residual_l1: 4.0,
            transmitted_l1: 1.0,
            down_residual_l1: 0.0,
            down_transmitted_l1: 0.0,
            acc_proxy: 0.5,
            outlier_rate: f64::NAN,
        }
    }

    fn enabled_cfg() -> ControlConfig {
        ControlConfig { enabled: true, ..Default::default() }
    }

    #[test]
    fn disabled_plane_is_inert() {
        let mut p = ControlPlane::new(&ControlConfig::default());
        assert!(!p.enabled());
        p.observe(sample(1, 0, 10));
        assert!(p.bus().is_empty(), "disabled plane must not collect telemetry");
        assert!(!p.due(4));
        let knobs = Knobs {
            buffer_k: 1,
            alpha0: 0.8,
            k_fraction: 0.1,
            topk: true,
            down_k_fraction: 0.1,
            down_topk: true,
            barrier_free: true,
            trust_threshold: 0.5,
            trust_armed: true,
            trim_fraction: 0.2,
            trim_armed: true,
        };
        assert!(p.decide_knobs(knobs).is_empty());
        assert_eq!(p.decide_rebalance(1, &[3, 4]), None);
    }

    #[test]
    fn due_respects_interval_and_requires_telemetry() {
        let cfg = ControlConfig { interval: 3, ..enabled_cfg() };
        let mut p = ControlPlane::new(&cfg);
        assert!(!p.due(3), "no telemetry yet");
        p.observe(sample(1, 0, 0));
        assert!(p.due(3));
        assert!(!p.due(4));
        assert!(p.due(6));
    }

    #[test]
    fn knob_decisions_respect_engine_and_mode_gates() {
        let mut p = ControlPlane::new(&enabled_cfg());
        // High staleness + high residual window.
        for r in 1..=4 {
            p.observe(sample(r, 0, 12));
        }
        let all = Knobs {
            buffer_k: 2,
            alpha0: 0.8,
            k_fraction: 0.25,
            topk: true,
            down_k_fraction: 0.25,
            down_topk: false,
            barrier_free: true,
            trust_threshold: 0.5,
            trust_armed: false,
            trim_fraction: 0.2,
            trim_armed: false,
        };
        let ds = p.decide_knobs(all);
        assert!(ds.iter().any(|d| d.controller == "staleness"));
        assert!(ds.iter().any(|d| d.controller == "compression"));
        // Barriered engine: staleness controller is inert.
        let barriered = Knobs { barrier_free: false, ..all };
        assert!(p.decide_knobs(barriered).iter().all(|d| d.controller == "compression"));
        // Dense mode: compression controller is inert.
        let dense = Knobs { topk: false, ..all };
        assert!(p.decide_knobs(dense).iter().all(|d| d.controller == "staleness"));
    }

    #[test]
    fn downlink_arm_is_driven_by_downlink_mass_only() {
        let mut p = ControlPlane::new(&enabled_cfg());
        // High *downlink* residual, no uplink mass at all: only the
        // DownKFraction decision may fire.
        for r in 1..=4 {
            p.observe(FlushSample {
                residual_l1: 0.0,
                transmitted_l1: 0.0,
                down_residual_l1: 4.0,
                down_transmitted_l1: 1.0,
                ..sample(r, 0, 0)
            });
        }
        let knobs = Knobs {
            buffer_k: 2,
            alpha0: 0.8,
            k_fraction: 0.25,
            topk: true,
            down_k_fraction: 0.25,
            down_topk: true,
            barrier_free: false,
            trust_threshold: 0.5,
            trust_armed: false,
            trim_fraction: 0.2,
            trim_armed: false,
        };
        let ds = p.decide_knobs(knobs);
        assert_eq!(ds.len(), 1, "uplink carries no mass -> no KFraction decision");
        match ds[0].change {
            KnobChange::DownKFraction { from, to } => {
                assert_eq!(from, 0.25);
                assert!(to > from, "high downlink residual must grow the budget");
            }
            other => panic!("unexpected {other:?}"),
        }
        // Dense broadcasts gate the downlink arm off entirely.
        let dense_down = Knobs { down_topk: false, ..knobs };
        assert!(p.decide_knobs(dense_down).is_empty());
    }

    #[test]
    fn trust_arm_needs_robust_evidence_and_the_armed_gate() {
        let mut p = ControlPlane::new(&enabled_cfg());
        // Robust-off samples (NaN outlier rate): armed or not, no signal.
        for r in 1..=4 {
            p.observe(sample(r, 0, 0));
        }
        let knobs = Knobs {
            buffer_k: 2,
            alpha0: 0.8,
            k_fraction: 0.25,
            topk: false,
            down_k_fraction: 0.25,
            down_topk: false,
            barrier_free: true,
            trust_threshold: 0.5,
            trust_armed: true,
            trim_fraction: 0.2,
            trim_armed: false,
        };
        assert!(p
            .decide_knobs(knobs)
            .iter()
            .all(|d| !matches!(d.change, KnobChange::TrustThreshold { .. })));
        // A dirty window tightens the threshold — but only when armed.
        for r in 5..=8 {
            p.observe(FlushSample { outlier_rate: 0.4, ..sample(r, 0, 0) });
        }
        let ds = p.decide_knobs(knobs);
        let trust: Vec<_> = ds
            .iter()
            .filter(|d| matches!(d.change, KnobChange::TrustThreshold { .. }))
            .collect();
        assert_eq!(trust.len(), 1);
        match trust[0].change {
            KnobChange::TrustThreshold { from, to } => {
                assert_eq!(from, 0.5);
                assert!(to < from, "dirty window must tighten the threshold");
            }
            other => panic!("unexpected {other:?}"),
        }
        let disarmed = Knobs { trust_armed: false, ..knobs };
        assert!(p
            .decide_knobs(disarmed)
            .iter()
            .all(|d| !matches!(d.change, KnobChange::TrustThreshold { .. })));
    }

    #[test]
    fn trim_arm_widens_on_dirty_window_only_when_armed() {
        let mut p = ControlPlane::new(&enabled_cfg());
        for r in 1..=4 {
            p.observe(FlushSample { outlier_rate: 0.4, ..sample(r, 0, 0) });
        }
        let knobs = Knobs {
            buffer_k: 2,
            alpha0: 0.8,
            k_fraction: 0.25,
            topk: false,
            down_k_fraction: 0.25,
            down_topk: false,
            barrier_free: true,
            trust_threshold: 0.5,
            trust_armed: false,
            trim_fraction: 0.1,
            trim_armed: true,
        };
        let trims: Vec<_> = p
            .decide_knobs(knobs)
            .into_iter()
            .filter(|d| matches!(d.change, KnobChange::TrimFraction { .. }))
            .collect();
        assert_eq!(trims.len(), 1);
        match trims[0].change {
            KnobChange::TrimFraction { from, to } => {
                assert_eq!(from, 0.1);
                assert!(to > from, "dirty window must widen the trim");
            }
            other => panic!("unexpected {other:?}"),
        }
        // Disarmed (robust mode != trimmed_mean): never.
        let disarmed = Knobs { trim_armed: false, ..knobs };
        assert!(p
            .decide_knobs(disarmed)
            .iter()
            .all(|d| !matches!(d.change, KnobChange::TrimFraction { .. })));
        // A clean window relaxes the trim back toward trim_min.
        for r in 5..=12 {
            p.observe(FlushSample { outlier_rate: 0.0, ..sample(r, 0, 0) });
        }
        let ds = p.decide_knobs(knobs);
        match ds.iter().find(|d| d.controller == "trim").expect("clean-window decision").change {
            KnobChange::TrimFraction { from, to } => {
                assert_eq!(from, 0.1);
                assert!(to < from, "clean window must relax the trim");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn plane_save_load_round_trips_decisions() {
        let cfg = enabled_cfg();
        let mut p = ControlPlane::new(&cfg);
        for r in 1..=4 {
            p.observe(FlushSample { outlier_rate: 0.4, ..sample(r, 0, 12) });
        }
        p.note_migration(3);
        let mut enc = Enc::new();
        p.save(&mut enc);
        let bytes = enc.into_bytes();
        let mut q = ControlPlane::new(&cfg);
        let mut dec = Dec::new(&bytes);
        q.load(&mut dec).unwrap();
        dec.finish().unwrap();
        let knobs = Knobs {
            buffer_k: 2,
            alpha0: 0.8,
            k_fraction: 0.25,
            topk: true,
            down_k_fraction: 0.25,
            down_topk: true,
            barrier_free: true,
            trust_threshold: 0.5,
            trust_armed: true,
            trim_fraction: 0.1,
            trim_armed: true,
        };
        assert_eq!(p.decide_knobs(knobs), q.decide_knobs(knobs));
        assert_eq!(p.decide_rebalance(5, &[4, 3]), q.decide_rebalance(5, &[4, 3]));
        assert_eq!(p.due(4), q.due(4));
    }

    #[test]
    fn rebalance_uses_windowed_flush_rates() {
        let cfg = ControlConfig { rebalance_skew: 2.0, ..enabled_cfg() };
        let mut p = ControlPlane::new(&cfg);
        for r in 1..=6 {
            p.observe(sample(r, 0, 0)); // all flushes on shard 0
        }
        let m = p.decide_rebalance(6, &[4, 3]).unwrap();
        assert_eq!((m.from_shard, m.to_shard), (0, 1));
        // Single shard: never.
        assert_eq!(p.decide_rebalance(6, &[7]), None);
    }

    #[test]
    fn rebalance_cooldown_spans_one_telemetry_window() {
        // After an applied migration the rebalancer must stay quiet until
        // a full window of post-migration samples exists — the skew that
        // justified the move is exactly the data the move invalidated.
        let cfg = ControlConfig { rebalance_skew: 1.0, window: 4, ..enabled_cfg() };
        let mut p = ControlPlane::new(&cfg);
        for r in 1..=4 {
            p.observe(sample(r, 0, 0));
        }
        assert!(p.decide_rebalance(4, &[4, 3]).is_some());
        p.note_migration(4);
        assert_eq!(p.decide_rebalance(6, &[4, 3]), None, "inside the cooldown");
        assert_eq!(p.decide_rebalance(7, &[4, 3]), None, "one short of the window");
        assert!(p.decide_rebalance(8, &[4, 3]).is_some(), "window fully turned over");
    }
}
