//! Telemetry bus of the adaptive control plane: bounded rolling windows
//! over the signals the controllers consume — upload staleness,
//! error-feedback residual mass, per-shard flush rates, wire bytes, and
//! an accuracy proxy.
//!
//! Both engines feed the bus at **event-commit time** (every buffer
//! flush of the barrier-free engine, every round of the barriered one),
//! so the controllers see a rolling window of recent behaviour instead
//! of the end-of-run rollups (`RunMetrics::staleness_histogram` /
//! `per_shard_flushes`). Samples are built exclusively from state that
//! is identical across execution strategies (never the deferred global
//! evaluation the threaded engine patches late), which is what keeps
//! adaptive runs bitwise thread-count invariant.

use std::collections::VecDeque;

/// One aggregation's worth of telemetry: a buffer flush of the
/// barrier-free engine, or one barriered communication round.
#[derive(Debug, Clone)]
pub struct FlushSample {
    /// Flush / round index that cut this sample.
    pub round: usize,
    /// Aggregator shard that flushed (0 for barriered / unsharded runs).
    pub shard: usize,
    /// Virtual time of the flush.
    pub vtime: f64,
    /// Uploads aggregated in this flush.
    pub uploads: usize,
    /// Sum of the flushed uploads' staleness values tau.
    pub staleness_sum: usize,
    /// Max staleness in the flushed buffer.
    pub staleness_max: usize,
    /// Uplink wire bytes of the window this flush closed.
    pub bytes_up: u64,
    /// Unsent selection-key mass of the flushed sparse encodes — exactly
    /// the error-feedback residual they wrote back when EF is on
    /// (`SparseDelta::key_l1 - sent_key_l1`); 0 in dense mode.
    pub residual_l1: f64,
    /// Transmitted selection-key mass of the flushed sparse encodes
    /// (`SparseDelta::sent_key_l1`); 0 in dense mode.
    pub transmitted_l1: f64,
    /// Downlink analogue of `residual_l1`: unsent selection-key mass of
    /// the sparse *broadcasts* since the previous sample (drained from
    /// the server's downlink compressor); 0 when `down_mode` is dense.
    pub down_residual_l1: f64,
    /// Downlink analogue of `transmitted_l1`; 0 when `down_mode` is
    /// dense.
    pub down_transmitted_l1: f64,
    /// Accuracy proxy available at commit time on every execution
    /// strategy: the mean of the fleet's last-known finite probe
    /// accuracies (NaN while nobody has reported yet).
    pub acc_proxy: f64,
}

/// Bounded rolling window of [`FlushSample`]s, oldest first.
#[derive(Debug, Clone)]
pub struct TelemetryBus {
    cap: usize,
    samples: VecDeque<FlushSample>,
}

impl TelemetryBus {
    /// A bus keeping the most recent `cap` samples (at least 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TelemetryBus { cap, samples: VecDeque::with_capacity(cap) }
    }

    /// Append a sample, evicting the oldest beyond the window bound.
    pub fn push(&mut self, sample: FlushSample) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The window's samples, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &FlushSample> {
        self.samples.iter()
    }

    /// Upload-weighted mean staleness over the window (NaN when the
    /// window holds no uploads).
    pub fn mean_staleness(&self) -> f64 {
        let uploads: usize = self.samples.iter().map(|s| s.uploads).sum();
        if uploads == 0 {
            return f64::NAN;
        }
        let stale: usize = self.samples.iter().map(|s| s.staleness_sum).sum();
        stale as f64 / uploads as f64
    }

    /// Fraction of delta mass the compression budget left behind:
    /// `residual / (residual + transmitted)` over the window (NaN when
    /// the window carries no mass — dense mode, or nothing flushed yet).
    pub fn residual_ratio(&self) -> f64 {
        let r: f64 = self.samples.iter().map(|s| s.residual_l1).sum();
        let t: f64 = self.samples.iter().map(|s| s.transmitted_l1).sum();
        if r + t <= 0.0 || !(r + t).is_finite() {
            return f64::NAN;
        }
        r / (r + t)
    }

    /// Downlink mirror of [`TelemetryBus::residual_ratio`]: the fraction
    /// of broadcast delta mass the `down_k_fraction` budget left behind
    /// (NaN when the window carries no downlink mass — dense broadcasts,
    /// or nothing synced yet).
    pub fn down_residual_ratio(&self) -> f64 {
        let r: f64 = self.samples.iter().map(|s| s.down_residual_l1).sum();
        let t: f64 = self.samples.iter().map(|s| s.down_transmitted_l1).sum();
        if r + t <= 0.0 || !(r + t).is_finite() {
            return f64::NAN;
        }
        r / (r + t)
    }

    /// Windowed flush counts per shard, for `s_count` shards (shards
    /// that never flushed in the window count 0).
    pub fn per_shard_flushes(&self, s_count: usize) -> Vec<usize> {
        let mut counts = vec![0usize; s_count];
        for s in &self.samples {
            if s.shard < s_count {
                counts[s.shard] += 1;
            }
        }
        counts
    }

    /// Whether the accuracy proxy is holding or improving across the
    /// window: mean of the newer half vs. the older half, with `eps`
    /// slack. `None` when fewer than two finite proxies exist (not
    /// enough evidence either way).
    pub fn acc_improving(&self, eps: f64) -> Option<bool> {
        let finite: Vec<f64> = self
            .samples
            .iter()
            .map(|s| s.acc_proxy)
            .filter(|a| a.is_finite())
            .collect();
        if finite.len() < 2 {
            return None;
        }
        let mid = finite.len() / 2;
        let older = finite[..mid].iter().sum::<f64>() / mid as f64;
        let newer = finite[mid..].iter().sum::<f64>() / (finite.len() - mid) as f64;
        Some(newer + eps >= older)
    }

    /// Total uplink bytes across the window.
    pub fn bytes_up(&self) -> u64 {
        self.samples.iter().map(|s| s.bytes_up).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: usize, shard: usize, uploads: usize, stale: usize, acc: f64) -> FlushSample {
        FlushSample {
            round,
            shard,
            vtime: round as f64,
            uploads,
            staleness_sum: stale,
            staleness_max: stale,
            bytes_up: 100,
            residual_l1: 1.0,
            transmitted_l1: 3.0,
            down_residual_l1: 0.0,
            down_transmitted_l1: 0.0,
            acc_proxy: acc,
        }
    }

    #[test]
    fn window_is_bounded_and_evicts_oldest() {
        let mut bus = TelemetryBus::new(3);
        for r in 1..=5 {
            bus.push(sample(r, 0, 1, 0, 0.5));
        }
        assert_eq!(bus.len(), 3);
        let rounds: Vec<usize> = bus.iter().map(|s| s.round).collect();
        assert_eq!(rounds, vec![3, 4, 5]);
        assert_eq!(bus.bytes_up(), 300);
    }

    #[test]
    fn zero_capacity_still_keeps_one() {
        let mut bus = TelemetryBus::new(0);
        bus.push(sample(1, 0, 1, 0, 0.5));
        bus.push(sample(2, 0, 1, 0, 0.5));
        assert_eq!(bus.len(), 1);
        assert_eq!(bus.iter().next().unwrap().round, 2);
    }

    #[test]
    fn mean_staleness_is_upload_weighted() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.mean_staleness().is_nan());
        bus.push(sample(1, 0, 3, 6, 0.5)); // mean 2 over 3 uploads
        bus.push(sample(2, 0, 1, 0, 0.5)); // mean 0 over 1 upload
        assert!((bus.mean_staleness() - 6.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn residual_ratio_over_window_mass() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.residual_ratio().is_nan());
        bus.push(sample(1, 0, 1, 0, 0.5)); // 1 residual vs 3 transmitted
        assert!((bus.residual_ratio() - 0.25).abs() < 1e-12);
        let mut dense = TelemetryBus::new(8);
        dense.push(FlushSample { residual_l1: 0.0, transmitted_l1: 0.0, ..sample(1, 0, 1, 0, 0.5) });
        assert!(dense.residual_ratio().is_nan(), "no mass must read as no signal");
    }

    #[test]
    fn down_residual_ratio_is_independent_of_uplink_mass() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.down_residual_ratio().is_nan());
        // Uplink mass alone must not fabricate a downlink signal.
        bus.push(sample(1, 0, 1, 0, 0.5));
        assert!(bus.down_residual_ratio().is_nan(), "dense broadcasts carry no downlink mass");
        bus.push(FlushSample {
            down_residual_l1: 3.0,
            down_transmitted_l1: 1.0,
            ..sample(2, 0, 1, 0, 0.5)
        });
        assert!((bus.down_residual_ratio() - 0.75).abs() < 1e-12);
        // And the uplink ratio stays untouched by downlink mass.
        assert!((bus.residual_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn per_shard_flushes_counts_window_only() {
        let mut bus = TelemetryBus::new(4);
        for r in 1..=6 {
            bus.push(sample(r, r % 2, 1, 0, 0.5));
        }
        // Window holds rounds 3..=6 -> shards [1, 0, 1, 0].
        assert_eq!(bus.per_shard_flushes(2), vec![2, 2]);
        assert_eq!(bus.per_shard_flushes(3), vec![2, 2, 0]);
    }

    #[test]
    fn acc_improving_compares_window_halves() {
        let mut bus = TelemetryBus::new(8);
        assert_eq!(bus.acc_improving(1e-3), None);
        bus.push(sample(1, 0, 1, 0, 0.4));
        assert_eq!(bus.acc_improving(1e-3), None, "one finite proxy is not evidence");
        bus.push(sample(2, 0, 1, 0, 0.5));
        assert_eq!(bus.acc_improving(1e-3), Some(true));
        let mut falling = TelemetryBus::new(8);
        falling.push(sample(1, 0, 1, 0, 0.6));
        falling.push(sample(2, 0, 1, 0, 0.3));
        assert_eq!(falling.acc_improving(1e-3), Some(false));
        // NaN proxies (nobody reported yet) are skipped, not poisonous.
        let mut nan = TelemetryBus::new(8);
        nan.push(sample(1, 0, 1, 0, f64::NAN));
        nan.push(sample(2, 0, 1, 0, 0.4));
        nan.push(sample(3, 0, 1, 0, 0.5));
        assert_eq!(nan.acc_improving(1e-3), Some(true));
    }
}
