//! Telemetry bus of the adaptive control plane: bounded rolling windows
//! over the signals the controllers consume — upload staleness,
//! error-feedback residual mass, per-shard flush rates, wire bytes, and
//! an accuracy proxy.
//!
//! Both engines feed the bus at **event-commit time** (every buffer
//! flush of the barrier-free engine, every round of the barriered one),
//! so the controllers see a rolling window of recent behaviour instead
//! of the end-of-run rollups (`RunMetrics::staleness_histogram` /
//! `per_shard_flushes`). Samples are built exclusively from state that
//! is identical across execution strategies (never the deferred global
//! evaluation the threaded engine patches late), which is what keeps
//! adaptive runs bitwise thread-count invariant.

use std::collections::VecDeque;

use crate::util::codec::{Dec, Enc};
use anyhow::Result;

/// One aggregation's worth of telemetry: a buffer flush of the
/// barrier-free engine, or one barriered communication round.
#[derive(Debug, Clone)]
pub struct FlushSample {
    /// Flush / round index that cut this sample.
    pub round: usize,
    /// Aggregator shard that flushed (0 for barriered / unsharded runs).
    pub shard: usize,
    /// Virtual time of the flush.
    pub vtime: f64,
    /// Uploads aggregated in this flush.
    pub uploads: usize,
    /// Sum of the flushed uploads' staleness values tau.
    pub staleness_sum: usize,
    /// Max staleness in the flushed buffer.
    pub staleness_max: usize,
    /// Uplink wire bytes of the window this flush closed.
    pub bytes_up: u64,
    /// Unsent selection-key mass of the flushed sparse encodes — exactly
    /// the error-feedback residual they wrote back when EF is on
    /// (`SparseDelta::key_l1 - sent_key_l1`); 0 in dense mode.
    pub residual_l1: f64,
    /// Transmitted selection-key mass of the flushed sparse encodes
    /// (`SparseDelta::sent_key_l1`); 0 in dense mode.
    pub transmitted_l1: f64,
    /// Downlink analogue of `residual_l1`: unsent selection-key mass of
    /// the sparse *broadcasts* since the previous sample (drained from
    /// the server's downlink compressor); 0 when `down_mode` is dense.
    pub down_residual_l1: f64,
    /// Downlink analogue of `transmitted_l1`; 0 when `down_mode` is
    /// dense.
    pub down_transmitted_l1: f64,
    /// Accuracy proxy available at commit time on every execution
    /// strategy: the mean of the fleet's last-known finite probe
    /// accuracies (NaN while nobody has reported yet).
    pub acc_proxy: f64,
    /// Mean per-payload outlier rate of this flush under a robust
    /// aggregation mode: for each flushed upload, the fraction of its
    /// participating coordinates whose lane was trimmed (or, for the
    /// median, ranked most extreme), averaged over the buffer. NaN when
    /// robust aggregation is off — no signal, not "zero outliers".
    pub outlier_rate: f64,
}

/// Bounded rolling window of [`FlushSample`]s, oldest first.
#[derive(Debug, Clone)]
pub struct TelemetryBus {
    cap: usize,
    samples: VecDeque<FlushSample>,
}

impl TelemetryBus {
    /// A bus keeping the most recent `cap` samples (at least 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        TelemetryBus { cap, samples: VecDeque::with_capacity(cap) }
    }

    /// Append a sample, evicting the oldest beyond the window bound.
    pub fn push(&mut self, sample: FlushSample) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(sample);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The window's samples, oldest first.
    pub fn iter(&self) -> impl Iterator<Item = &FlushSample> {
        self.samples.iter()
    }

    /// Upload-weighted mean staleness over the window (NaN when the
    /// window holds no uploads).
    pub fn mean_staleness(&self) -> f64 {
        let uploads: usize = self.samples.iter().map(|s| s.uploads).sum();
        if uploads == 0 {
            return f64::NAN;
        }
        let stale: usize = self.samples.iter().map(|s| s.staleness_sum).sum();
        stale as f64 / uploads as f64
    }

    /// Fraction of delta mass the compression budget left behind:
    /// `residual / (residual + transmitted)` over the window (NaN when
    /// the window carries no mass — dense mode, or nothing flushed yet).
    pub fn residual_ratio(&self) -> f64 {
        let r: f64 = self.samples.iter().map(|s| s.residual_l1).sum();
        let t: f64 = self.samples.iter().map(|s| s.transmitted_l1).sum();
        if r + t <= 0.0 || !(r + t).is_finite() {
            return f64::NAN;
        }
        r / (r + t)
    }

    /// Downlink mirror of [`TelemetryBus::residual_ratio`]: the fraction
    /// of broadcast delta mass the `down_k_fraction` budget left behind
    /// (NaN when the window carries no downlink mass — dense broadcasts,
    /// or nothing synced yet).
    pub fn down_residual_ratio(&self) -> f64 {
        let r: f64 = self.samples.iter().map(|s| s.down_residual_l1).sum();
        let t: f64 = self.samples.iter().map(|s| s.down_transmitted_l1).sum();
        if r + t <= 0.0 || !(r + t).is_finite() {
            return f64::NAN;
        }
        r / (r + t)
    }

    /// Windowed flush counts per shard, for `s_count` shards (shards
    /// that never flushed in the window count 0).
    pub fn per_shard_flushes(&self, s_count: usize) -> Vec<usize> {
        let mut counts = vec![0usize; s_count];
        for s in &self.samples {
            if s.shard < s_count {
                counts[s.shard] += 1;
            }
        }
        counts
    }

    /// Whether the accuracy proxy is holding or improving across the
    /// window: mean of the newer half vs. the older half, with `eps`
    /// slack. `None` when fewer than two finite proxies exist (not
    /// enough evidence either way).
    pub fn acc_improving(&self, eps: f64) -> Option<bool> {
        let finite: Vec<f64> = self
            .samples
            .iter()
            .map(|s| s.acc_proxy)
            .filter(|a| a.is_finite())
            .collect();
        if finite.len() < 2 {
            return None;
        }
        let mid = finite.len() / 2;
        let older = finite[..mid].iter().sum::<f64>() / mid as f64;
        let newer = finite[mid..].iter().sum::<f64>() / (finite.len() - mid) as f64;
        Some(newer + eps >= older)
    }

    /// Total uplink bytes across the window.
    pub fn bytes_up(&self) -> u64 {
        self.samples.iter().map(|s| s.bytes_up).sum()
    }

    /// Serialize the window for a checkpoint (cap + samples, oldest
    /// first).
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.cap);
        enc.usize(self.samples.len());
        for s in &self.samples {
            enc.usize(s.round);
            enc.usize(s.shard);
            enc.f64(s.vtime);
            enc.usize(s.uploads);
            enc.usize(s.staleness_sum);
            enc.usize(s.staleness_max);
            enc.u64(s.bytes_up);
            enc.f64(s.residual_l1);
            enc.f64(s.transmitted_l1);
            enc.f64(s.down_residual_l1);
            enc.f64(s.down_transmitted_l1);
            enc.f64(s.acc_proxy);
            enc.f64(s.outlier_rate);
        }
    }

    /// Restore the window saved by [`TelemetryBus::save`].
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        self.cap = dec.usize()?.max(1);
        let n = dec.usize()?;
        self.samples.clear();
        for _ in 0..n {
            self.samples.push_back(FlushSample {
                round: dec.usize()?,
                shard: dec.usize()?,
                vtime: dec.f64()?,
                uploads: dec.usize()?,
                staleness_sum: dec.usize()?,
                staleness_max: dec.usize()?,
                bytes_up: dec.u64()?,
                residual_l1: dec.f64()?,
                transmitted_l1: dec.f64()?,
                down_residual_l1: dec.f64()?,
                down_transmitted_l1: dec.f64()?,
                acc_proxy: dec.f64()?,
                outlier_rate: dec.f64()?,
            });
        }
        Ok(())
    }

    /// Mean outlier rate over the window's robust flushes (NaN when no
    /// sample in the window carries a finite rate — robust mode off, or
    /// nothing flushed yet). The [`crate::control::TrustController`]'s
    /// input signal.
    pub fn mean_outlier_rate(&self) -> f64 {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for s in &self.samples {
            if s.outlier_rate.is_finite() {
                sum += s.outlier_rate;
                n += 1;
            }
        }
        if n == 0 {
            return f64::NAN;
        }
        sum / n as f64
    }
}

/// Per-client rolling trust score: an exponentially-weighted mean of the
/// client's observed per-flush outlier rate (the update-deviation
/// statistic of ISSUE 8 / ASTRA's dynamic trust). Scores start at 0
/// (fully trusted); a client whose lanes keep getting trimmed drifts
/// toward 1. [`TrustBook::multiplier`] converts the score into the
/// soft-quarantine weight applied to the client's uploads at flush.
///
/// Updates happen only at the deterministic flush commit points and read
/// only the aggregation's outlier counts (identical across execution
/// strategies), so trust-on runs stay bitwise thread-count invariant.
#[derive(Debug, Clone)]
pub struct TrustBook {
    decay: f64,
    scores: Vec<f64>,
}

impl TrustBook {
    /// A book for `n` clients with EWMA factor `decay` in (0, 1): each
    /// observation moves the score by `1 − decay` of the gap.
    pub fn new(n: usize, decay: f64) -> Self {
        assert!(decay > 0.0 && decay < 1.0, "trust decay must be in (0, 1)");
        TrustBook { decay, scores: vec![0.0; n] }
    }

    /// Fold one flush's outlier rate for client `c` into its score.
    /// Non-finite rates are ignored (no evidence, no drift).
    pub fn update(&mut self, c: usize, rate: f64) {
        if rate.is_finite() {
            self.scores[c] = self.decay * self.scores[c] + (1.0 - self.decay) * rate;
        }
    }

    /// Current deviation score of client `c` (0 = trusted).
    pub fn score(&self, c: usize) -> f64 {
        self.scores[c]
    }

    /// Soft-quarantine weight for client `c`: 1.0 while the score is at
    /// or under `threshold`, then `threshold / score` (clamped below by
    /// `floor`) — suspicion scales the client's aggregation weight down
    /// smoothly instead of ejecting it, so a falsely accused straggler
    /// recovers as its score decays.
    pub fn multiplier(&self, c: usize, threshold: f64, floor: f64) -> f64 {
        let s = self.scores[c];
        if s <= threshold {
            1.0
        } else {
            (threshold / s).max(floor)
        }
    }

    /// Mean score across the fleet (diagnostics / metrics).
    pub fn mean_score(&self) -> f64 {
        if self.scores.is_empty() {
            return f64::NAN;
        }
        self.scores.iter().sum::<f64>() / self.scores.len() as f64
    }

    /// Serialize the book for a checkpoint (decay + scores, bit-exact).
    pub fn save(&self, enc: &mut Enc) {
        enc.f64(self.decay);
        enc.f64s(&self.scores);
    }

    /// Restore the state saved by [`TrustBook::save`].
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        self.decay = dec.f64()?;
        self.scores = dec.f64s()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(round: usize, shard: usize, uploads: usize, stale: usize, acc: f64) -> FlushSample {
        FlushSample {
            round,
            shard,
            vtime: round as f64,
            uploads,
            staleness_sum: stale,
            staleness_max: stale,
            bytes_up: 100,
            residual_l1: 1.0,
            transmitted_l1: 3.0,
            down_residual_l1: 0.0,
            down_transmitted_l1: 0.0,
            acc_proxy: acc,
            outlier_rate: f64::NAN,
        }
    }

    #[test]
    fn window_is_bounded_and_evicts_oldest() {
        let mut bus = TelemetryBus::new(3);
        for r in 1..=5 {
            bus.push(sample(r, 0, 1, 0, 0.5));
        }
        assert_eq!(bus.len(), 3);
        let rounds: Vec<usize> = bus.iter().map(|s| s.round).collect();
        assert_eq!(rounds, vec![3, 4, 5]);
        assert_eq!(bus.bytes_up(), 300);
    }

    #[test]
    fn zero_capacity_still_keeps_one() {
        let mut bus = TelemetryBus::new(0);
        bus.push(sample(1, 0, 1, 0, 0.5));
        bus.push(sample(2, 0, 1, 0, 0.5));
        assert_eq!(bus.len(), 1);
        assert_eq!(bus.iter().next().unwrap().round, 2);
    }

    #[test]
    fn mean_staleness_is_upload_weighted() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.mean_staleness().is_nan());
        bus.push(sample(1, 0, 3, 6, 0.5)); // mean 2 over 3 uploads
        bus.push(sample(2, 0, 1, 0, 0.5)); // mean 0 over 1 upload
        assert!((bus.mean_staleness() - 6.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn residual_ratio_over_window_mass() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.residual_ratio().is_nan());
        bus.push(sample(1, 0, 1, 0, 0.5)); // 1 residual vs 3 transmitted
        assert!((bus.residual_ratio() - 0.25).abs() < 1e-12);
        let mut dense = TelemetryBus::new(8);
        dense.push(FlushSample { residual_l1: 0.0, transmitted_l1: 0.0, ..sample(1, 0, 1, 0, 0.5) });
        assert!(dense.residual_ratio().is_nan(), "no mass must read as no signal");
    }

    #[test]
    fn down_residual_ratio_is_independent_of_uplink_mass() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.down_residual_ratio().is_nan());
        // Uplink mass alone must not fabricate a downlink signal.
        bus.push(sample(1, 0, 1, 0, 0.5));
        assert!(bus.down_residual_ratio().is_nan(), "dense broadcasts carry no downlink mass");
        bus.push(FlushSample {
            down_residual_l1: 3.0,
            down_transmitted_l1: 1.0,
            ..sample(2, 0, 1, 0, 0.5)
        });
        assert!((bus.down_residual_ratio() - 0.75).abs() < 1e-12);
        // And the uplink ratio stays untouched by downlink mass.
        assert!((bus.residual_ratio() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn per_shard_flushes_counts_window_only() {
        let mut bus = TelemetryBus::new(4);
        for r in 1..=6 {
            bus.push(sample(r, r % 2, 1, 0, 0.5));
        }
        // Window holds rounds 3..=6 -> shards [1, 0, 1, 0].
        assert_eq!(bus.per_shard_flushes(2), vec![2, 2]);
        assert_eq!(bus.per_shard_flushes(3), vec![2, 2, 0]);
    }

    #[test]
    fn mean_outlier_rate_skips_nan_samples() {
        let mut bus = TelemetryBus::new(8);
        assert!(bus.mean_outlier_rate().is_nan());
        bus.push(sample(1, 0, 1, 0, 0.5)); // robust off: NaN rate
        assert!(bus.mean_outlier_rate().is_nan(), "NaN samples are no evidence");
        bus.push(FlushSample { outlier_rate: 0.2, ..sample(2, 0, 1, 0, 0.5) });
        bus.push(FlushSample { outlier_rate: 0.4, ..sample(3, 0, 1, 0, 0.5) });
        assert!((bus.mean_outlier_rate() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn trust_book_ewma_and_soft_quarantine() {
        let mut book = TrustBook::new(2, 0.5);
        assert_eq!(book.score(0), 0.0);
        assert_eq!(book.multiplier(0, 0.5, 0.1), 1.0, "fresh clients are fully trusted");
        // Client 0 keeps tripping the trimmer; client 1 stays clean.
        for _ in 0..4 {
            book.update(0, 1.0);
            book.update(1, 0.0);
        }
        assert!((book.score(0) - 0.9375).abs() < 1e-12);
        assert_eq!(book.score(1), 0.0);
        // Soft quarantine: threshold / score, floored.
        let m = book.multiplier(0, 0.5, 0.1);
        assert!((m - 0.5 / 0.9375).abs() < 1e-12);
        assert_eq!(book.multiplier(0, 0.01, 0.1), 0.1, "floor bounds the down-weight");
        assert_eq!(book.multiplier(1, 0.5, 0.1), 1.0);
        // NaN observations (robust off that flush) must not move scores.
        let before = book.score(0);
        book.update(0, f64::NAN);
        assert_eq!(book.score(0), before);
        // Recovery: clean flushes decay the score back toward trust.
        for _ in 0..8 {
            book.update(0, 0.0);
        }
        assert!(book.score(0) < 0.005);
        assert_eq!(book.multiplier(0, 0.5, 0.1), 1.0);
        assert!((book.mean_score() - book.score(0) / 2.0).abs() < 1e-15);
    }

    #[test]
    fn bus_and_book_save_load_round_trip() {
        let mut bus = TelemetryBus::new(3);
        for r in 1..=5 {
            bus.push(FlushSample { outlier_rate: 0.1 * r as f64, ..sample(r, r % 2, 2, r, 0.5) });
        }
        let mut book = TrustBook::new(3, 0.75);
        book.update(1, 0.8);
        book.update(2, f64::NAN);
        let mut enc = Enc::new();
        bus.save(&mut enc);
        book.save(&mut enc);
        let bytes = enc.into_bytes();

        let mut bus2 = TelemetryBus::new(1);
        let mut book2 = TrustBook::new(1, 0.5);
        let mut dec = Dec::new(&bytes);
        bus2.load(&mut dec).unwrap();
        book2.load(&mut dec).unwrap();
        dec.finish().unwrap();

        assert_eq!(bus2.len(), bus.len());
        assert_eq!(bus2.mean_staleness().to_bits(), bus.mean_staleness().to_bits());
        assert_eq!(bus2.mean_outlier_rate().to_bits(), bus.mean_outlier_rate().to_bits());
        assert_eq!(bus2.per_shard_flushes(2), bus.per_shard_flushes(2));
        // Restored cap still evicts correctly.
        bus2.push(sample(6, 0, 1, 0, 0.5));
        assert_eq!(bus2.len(), 3);
        for c in 0..3 {
            assert_eq!(book2.score(c).to_bits(), book.score(c).to_bits());
        }
        book2.update(1, 0.8);
        book.update(1, 0.8);
        assert_eq!(book2.score(1).to_bits(), book.score(1).to_bits());
    }

    #[test]
    fn acc_improving_compares_window_halves() {
        let mut bus = TelemetryBus::new(8);
        assert_eq!(bus.acc_improving(1e-3), None);
        bus.push(sample(1, 0, 1, 0, 0.4));
        assert_eq!(bus.acc_improving(1e-3), None, "one finite proxy is not evidence");
        bus.push(sample(2, 0, 1, 0, 0.5));
        assert_eq!(bus.acc_improving(1e-3), Some(true));
        let mut falling = TelemetryBus::new(8);
        falling.push(sample(1, 0, 1, 0, 0.6));
        falling.push(sample(2, 0, 1, 0, 0.3));
        assert_eq!(falling.acc_improving(1e-3), Some(false));
        // NaN proxies (nobody reported yet) are skipped, not poisonous.
        let mut nan = TelemetryBus::new(8);
        nan.push(sample(1, 0, 1, 0, f64::NAN));
        nan.push(sample(2, 0, 1, 0, 0.4));
        nan.push(sample(3, 0, 1, 0, 0.5));
        assert_eq!(nan.acc_improving(1e-3), Some(true));
    }
}
