//! FedAvg aggregation (Algorithm 1 lines 15–16):
//! `theta^{t+1} = sum_{i in K} (n_i / n) theta_i^{t+1}` over the uploaded
//! models, weighted by local sample counts.
//!
//! Two entry families:
//!
//! * [`Aggregator::aggregate`] / [`Aggregator::aggregate_weighted`] — dense
//!   f32 model views (tests, diagnostics, the allocating reference path).
//! * [`Aggregator::aggregate_payloads`] — the hot path: wire-format
//!   [`QuantBuf`] payloads are dequantized-and-accumulated in one fused
//!   pass, fanned out across parameter chunks on scoped threads. No dense
//!   staging vector is ever materialized and steady-state rounds perform
//!   zero heap allocation (`tests/alloc_steady_state.rs` asserts this on
//!   the serial path; the parallel path additionally allocates only thread
//!   stacks at spawn).

use crate::model::quant::QuantBuf;
use crate::model::{weighted_average_into, ParamVec};
use crate::util::par;

/// Minimum parameter count per worker before fused aggregation fans out.
const PAR_MIN_DIM: usize = 8192;

/// Reusable aggregator (buffers survive across rounds — the hot path does
/// not allocate; see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Aggregator {
    scratch: Vec<f64>,
    /// Cached weight buffer: `aggregate` reuses it instead of collecting a
    /// fresh `Vec<f64>` every round.
    weights: Vec<f64>,
}

impl Aggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Aggregate `models` (with sample-count weights) into `out`.
    ///
    /// Panics if `models` is empty — the server must skip aggregation on
    /// rounds where nothing was uploaded (possible under EAFLM).
    pub fn aggregate(&mut self, models: &[&[f32]], sample_counts: &[usize], out: &mut ParamVec) {
        self.weights.clear();
        self.weights.extend(sample_counts.iter().map(|&n| n as f64));
        weighted_average_into(models, &self.weights, out, &mut self.scratch);
    }

    /// Aggregate with arbitrary positive weights (n_i, possibly decayed by
    /// staleness — the FedAsync-style extension).
    pub fn aggregate_weighted(&mut self, models: &[&[f32]], weights: &[f64], out: &mut ParamVec) {
        weighted_average_into(models, weights, out, &mut self.scratch);
    }

    /// Fused hot path: aggregate quantized wire payloads straight into
    /// `out`, dequantizing on the fly — no per-upload `round_trip`
    /// staging vector. Weights are normalized internally.
    ///
    /// Bit-identical to decoding every payload with
    /// [`crate::model::quant::Precision::round_trip`] and then calling
    /// [`aggregate_weighted`](Self::aggregate_weighted) (property-tested in
    /// `tests/proptests.rs`).
    pub fn aggregate_payloads(&mut self, payloads: &[QuantBuf], weights: &[f64], out: &mut [f32]) {
        let threads = par::threads_for(out.len(), PAR_MIN_DIM);
        self.aggregate_payloads_t(payloads, weights, out, threads);
    }

    /// Explicit-worker-count variant of [`aggregate_payloads`](Self::aggregate_payloads)
    /// (benches and thread-count equivalence tests). `threads == 1` is
    /// serial and allocation-free at steady state.
    pub fn aggregate_payloads_t(
        &mut self,
        payloads: &[QuantBuf],
        weights: &[f64],
        out: &mut [f32],
        threads: usize,
    ) {
        assert!(!payloads.is_empty(), "aggregate of zero payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        let dim = payloads[0].len();
        for p in payloads {
            assert_eq!(p.len(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        self.scratch.clear();
        self.scratch.resize(dim, 0.0);
        par::par_chunks_mut(self.scratch.as_mut_slice(), threads, 8, |start, acc| {
            for (p, &w) in payloads.iter().zip(weights) {
                p.accumulate_dequant_range(start, w / total, acc);
            }
        });
        for (o, &a) in out.iter_mut().zip(self.scratch.iter()) {
            *o = a as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quant::Precision;

    #[test]
    fn weights_by_sample_count() {
        let a = vec![0.0f32, 4.0];
        let b = vec![2.0f32, 0.0];
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 2];
        agg.aggregate(&[&a, &b], &[100, 300], &mut out);
        assert_eq!(out, vec![1.5, 1.0]);
    }

    #[test]
    fn reuse_across_rounds() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 3];
        let m1 = vec![1.0f32; 3];
        agg.aggregate(&[&m1], &[10], &mut out);
        assert_eq!(out, vec![1.0; 3]);
        let m2 = vec![5.0f32; 3];
        agg.aggregate(&[&m2], &[10], &mut out);
        assert_eq!(out, vec![5.0; 3]);
    }

    #[test]
    fn payload_aggregation_matches_dense_f32() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * -0.5).collect();
        let weights = [3.0f64, 1.0];
        let mut agg = Aggregator::new();
        let mut want = vec![0.0f32; 37];
        agg.aggregate_weighted(&[&a, &b], &weights, &mut want);
        let mut bufs = vec![QuantBuf::new(), QuantBuf::new()];
        bufs[0].encode(Precision::F32, &a);
        bufs[1].encode(Precision::F32, &b);
        let mut got = vec![0.0f32; 37];
        agg.aggregate_payloads(&bufs, &weights, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn empty_upload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate(&[], &[], &mut out);
    }

    #[test]
    #[should_panic(expected = "zero payloads")]
    fn empty_payload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate_payloads(&[], &[], &mut out);
    }
}
