//! FedAvg aggregation (Algorithm 1 lines 15–16):
//! `theta^{t+1} = sum_{i in K} (n_i / n) theta_i^{t+1}` over the uploaded
//! models, weighted by local sample counts.

use crate::model::{weighted_average_into, ParamVec};

/// Reusable aggregator (buffers survive across rounds — the hot path does
/// not allocate; see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Aggregator {
    scratch: Vec<f64>,
}

impl Aggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Aggregate `models` (with sample-count weights) into `out`.
    ///
    /// Panics if `models` is empty — the server must skip aggregation on
    /// rounds where nothing was uploaded (possible under EAFLM).
    pub fn aggregate(&mut self, models: &[&[f32]], sample_counts: &[usize], out: &mut ParamVec) {
        let weights: Vec<f64> = sample_counts.iter().map(|&n| n as f64).collect();
        weighted_average_into(models, &weights, out, &mut self.scratch);
    }

    /// Aggregate with arbitrary positive weights (n_i, possibly decayed by
    /// staleness — the FedAsync-style extension).
    pub fn aggregate_weighted(&mut self, models: &[&[f32]], weights: &[f64], out: &mut ParamVec) {
        weighted_average_into(models, weights, out, &mut self.scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weights_by_sample_count() {
        let a = vec![0.0f32, 4.0];
        let b = vec![2.0f32, 0.0];
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 2];
        agg.aggregate(&[&a, &b], &[100, 300], &mut out);
        assert_eq!(out, vec![1.5, 1.0]);
    }

    #[test]
    fn reuse_across_rounds() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 3];
        let m1 = vec![1.0f32; 3];
        agg.aggregate(&[&m1], &[10], &mut out);
        assert_eq!(out, vec![1.0; 3]);
        let m2 = vec![5.0f32; 3];
        agg.aggregate(&[&m2], &[10], &mut out);
        assert_eq!(out, vec![5.0; 3]);
    }

    #[test]
    #[should_panic]
    fn empty_upload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate(&[], &[], &mut out);
    }
}
