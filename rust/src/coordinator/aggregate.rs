//! FedAvg aggregation (Algorithm 1 lines 15–16):
//! `theta^{t+1} = sum_{i in K} (n_i / n) theta_i^{t+1}` over the uploaded
//! models, weighted by local sample counts.
//!
//! Two entry families:
//!
//! * [`Aggregator::aggregate`] / [`Aggregator::aggregate_weighted`] — dense
//!   f32 model views (tests, diagnostics, the allocating reference path).
//! * [`Aggregator::aggregate_payloads`] — the hot path: wire-format
//!   [`QuantBuf`] payloads are dequantized-and-accumulated in one fused
//!   pass, fanned out across parameter chunks on scoped threads. No dense
//!   staging vector is ever materialized and steady-state rounds perform
//!   zero heap allocation (`tests/alloc_steady_state.rs` asserts this on
//!   the serial path; the parallel path additionally allocates only thread
//!   stacks at spawn).

use crate::model::quant::QuantBuf;
use crate::model::sparse::SparseDelta;
use crate::model::{weighted_average_into, ParamVec};
use crate::util::par;

/// Minimum parameter count per worker before fused aggregation fans out.
const PAR_MIN_DIM: usize = 8192;

/// Reusable aggregator (buffers survive across rounds — the hot path does
/// not allocate; see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Aggregator {
    scratch: Vec<f64>,
    /// Cached weight buffer: `aggregate` reuses it instead of collecting a
    /// fresh `Vec<f64>` every round.
    weights: Vec<f64>,
    /// Pooled per-payload cursors for the serial sparse merge (the
    /// parallel path gives each worker its own small cursor vector).
    cursors: Vec<usize>,
}

impl Aggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Aggregate `models` (with sample-count weights) into `out`.
    ///
    /// Panics if `models` is empty — the server must skip aggregation on
    /// rounds where nothing was uploaded (possible under EAFLM).
    pub fn aggregate(&mut self, models: &[&[f32]], sample_counts: &[usize], out: &mut ParamVec) {
        self.weights.clear();
        self.weights.extend(sample_counts.iter().map(|&n| n as f64));
        weighted_average_into(models, &self.weights, out, &mut self.scratch);
    }

    /// Aggregate with arbitrary positive weights (n_i, possibly decayed by
    /// staleness — the FedAsync-style extension).
    pub fn aggregate_weighted(&mut self, models: &[&[f32]], weights: &[f64], out: &mut ParamVec) {
        weighted_average_into(models, weights, out, &mut self.scratch);
    }

    /// Fused hot path: aggregate quantized wire payloads straight into
    /// `out`, dequantizing on the fly — no per-upload `round_trip`
    /// staging vector. Weights are normalized internally.
    ///
    /// Bit-identical to decoding every payload with
    /// [`crate::model::quant::Precision::round_trip`] and then calling
    /// [`aggregate_weighted`](Self::aggregate_weighted) (property-tested in
    /// `tests/proptests.rs`).
    pub fn aggregate_payloads(&mut self, payloads: &[QuantBuf], weights: &[f64], out: &mut [f32]) {
        let threads = par::threads_for(out.len(), PAR_MIN_DIM);
        self.aggregate_payloads_t(payloads, weights, out, threads);
    }

    /// Explicit-worker-count variant of [`aggregate_payloads`](Self::aggregate_payloads)
    /// (benches and thread-count equivalence tests). `threads == 1` is
    /// serial and allocation-free at steady state.
    pub fn aggregate_payloads_t(
        &mut self,
        payloads: &[QuantBuf],
        weights: &[f64],
        out: &mut [f32],
        threads: usize,
    ) {
        assert!(!payloads.is_empty(), "aggregate of zero payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        let dim = payloads[0].len();
        for p in payloads {
            assert_eq!(p.len(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        self.scratch.clear();
        self.scratch.resize(dim, 0.0);
        par::par_chunks_mut(self.scratch.as_mut_slice(), threads, 8, |start, acc| {
            for (p, &w) in payloads.iter().zip(weights) {
                p.accumulate_dequant_range(start, w / total, acc);
            }
        });
        for (o, &a) in out.iter_mut().zip(self.scratch.iter()) {
            *o = a as f32;
        }
    }

    /// Fused sparse scatter path: mix top-k [`SparseDelta`] payloads into
    /// `out` (the global / shard replica) **in place**, touching only the
    /// transmitted coordinates — flush cost O(K·k) instead of O(K·n).
    ///
    /// For every coordinate `j` transmitted by at least one payload:
    ///
    /// ```text
    /// out[j] <- ( Σ_{i ∋ j} w_i·v_i[j]  +  (self_weight + Σ_{i ∌ j} w_i)·out[j] ) / total
    /// total  =  Σ_i w_i + self_weight
    /// ```
    ///
    /// i.e. masked FedAvg where the weight mass of payloads that did not
    /// transmit `j` (and the explicit `self_weight` — the barrier-free
    /// engine's `1 − ᾱ` keep-rate) falls back to the current value of
    /// `out`. Coordinates transmitted by no one are not read or written.
    ///
    /// When every payload transmits every coordinate (`k == dim`, i.e.
    /// `k_fraction = 1.0`) this is **bit-identical** to
    /// [`aggregate_payloads`](Self::aggregate_payloads) over the dense
    /// encodings of the same uploads — with `self_weight > 0` matching
    /// the dense path's convention of folding the current model in as one
    /// trailing f32 payload slot (property-tested in
    /// `rust/tests/sparse.rs`).
    pub fn aggregate_sparse_payloads(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        out: &mut [f32],
    ) {
        let nnz: usize = payloads.iter().map(|p| p.len()).sum();
        let threads = par::threads_for(nnz, PAR_MIN_DIM);
        self.aggregate_sparse_payloads_t(payloads, weights, self_weight, out, threads);
    }

    /// Explicit-worker-count variant of
    /// [`aggregate_sparse_payloads`](Self::aggregate_sparse_payloads).
    /// Workers own disjoint contiguous coordinate ranges of `out`, so
    /// every coordinate is computed by exactly one worker with exactly
    /// the same operations in the same order for every worker count —
    /// bit-identical results, like every kernel on `util::par`.
    /// `threads == 1` is serial and allocation-free at steady state
    /// (`rust/tests/alloc_sparse.rs`).
    pub fn aggregate_sparse_payloads_t(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        out: &mut [f32],
        threads: usize,
    ) {
        assert!(!payloads.is_empty(), "aggregate of zero sparse payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        assert!(
            self_weight >= 0.0 && self_weight.is_finite(),
            "self_weight must be finite and non-negative"
        );
        let dim = payloads[0].dim();
        for p in payloads {
            assert_eq!(p.dim(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        // Summation order matches the dense path with the self slot
        // pushed last, so the k == dim case normalizes identically.
        let total: f64 = weights.iter().sum::<f64>() + self_weight;
        assert!(total > 0.0, "weights must sum to a positive value");
        if threads <= 1 {
            self.cursors.clear();
            self.cursors.resize(payloads.len(), 0);
            scatter_merge_range(payloads, weights, self_weight, total, out, 0, &mut self.cursors);
        } else {
            par::par_chunks_mut(out, threads, 8, |start, chunk| {
                let mut cursors: Vec<usize> = payloads
                    .iter()
                    .map(|p| p.indices().partition_point(|&i| (i as usize) < start))
                    .collect();
                scatter_merge_range(
                    payloads,
                    weights,
                    self_weight,
                    total,
                    chunk,
                    start,
                    &mut cursors,
                );
            });
        }
    }
}

/// One edge aggregator of the two-tier (edge -> shard) aggregation tree.
///
/// The barrier-free engine with `engine.edge_fanout > 1` folds each upload
/// into its edge's running sums **at arrival time** (the uploading client
/// is blocked between upload and broadcast, and the shard version only
/// advances at flush, so the payload and its staleness weight are already
/// final when the upload lands). Per coordinate `j` the edge keeps
///
/// ```text
/// S[j] = Σ_i w_i · v_i[j]          (folded uploads i on this edge)
/// T[j] = Σ_{i transmitting j} w_i  (sparse mode only; dense T ≡ W)
/// ```
///
/// plus the scalar totals `W = Σ w_i`, `Σ alpha_i`, and the upload count.
/// At flush, [`combine_edges`] mixes the shard's edge set into the replica
/// in O(edges · dim) — independent of the buffer size K, so a deep buffer
/// costs the flush no more than its edge fan-in:
///
/// ```text
/// c      = min(Σ alpha / K, 1)                    (the legacy ᾱ clamp)
/// out[j] = (c/W)·ΣS[j] + (1 − (c/W)·ΣT[j])·out[j]
/// ```
///
/// which reproduces all four legacy flush cases (dense/sparse × ᾱ≥1/<1):
/// the legacy path pre-normalizes upload weights to sum to ᾱ with a
/// self-weight of 1−ᾱ, which is algebraically exactly this formula. The
/// summation *order* differs from the per-client flush-time encode, so
/// `edge_fanout > 1` is deterministic and thread-invariant but not bitwise
/// against `edge_fanout = 1` (the default, which keeps the legacy path and
/// the golden snapshots byte-stable).
#[derive(Default)]
pub struct EdgeAccum {
    s: Vec<f64>,
    t: Vec<f64>,
    w: f64,
    alpha: f64,
    count: usize,
}

impl EdgeAccum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear for the next flush window. `sparse` chooses whether the
    /// per-coordinate transmitted-mass vector `T` is kept (top-k mode) or
    /// elided (dense mode, where `T ≡ W`).
    pub fn reset(&mut self, dim: usize, sparse: bool) {
        self.s.clear();
        self.s.resize(dim, 0.0);
        self.t.clear();
        if sparse {
            self.t.resize(dim, 0.0);
        }
        self.w = 0.0;
        self.alpha = 0.0;
        self.count = 0;
    }

    /// Fold one dense upload with aggregation weight `w` (sample count ×
    /// staleness decay) and raw staleness weight `alpha`.
    pub fn fold_dense(&mut self, payload: &QuantBuf, w: f64, alpha: f64) {
        assert_eq!(payload.len(), self.s.len(), "edge fold dimension mismatch");
        assert!(self.t.is_empty(), "dense fold into a sparse-mode edge");
        payload.accumulate_dequant_range(0, w, &mut self.s);
        self.w += w;
        self.alpha += alpha;
        self.count += 1;
    }

    /// Fold one sparse top-k upload (see [`EdgeAccum::fold_dense`]).
    pub fn fold_sparse(&mut self, payload: &SparseDelta, w: f64, alpha: f64) {
        assert_eq!(payload.dim(), self.s.len(), "edge fold dimension mismatch");
        assert_eq!(self.t.len(), self.s.len(), "sparse fold into a dense-mode edge");
        for (pos, &idx) in payload.indices().iter().enumerate() {
            let j = idx as usize;
            self.s[j] += w * payload.value(pos) as f64;
            self.t[j] += w;
        }
        self.w += w;
        self.alpha += alpha;
        self.count += 1;
    }

    /// Uploads folded since the last reset.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Resident bytes of the accumulator vectors (fleet-scale bench).
    pub fn approx_bytes(&self) -> usize {
        (self.s.capacity() + self.t.capacity()) * std::mem::size_of::<f64>()
    }
}

/// Combine one shard's edge accumulators into its replica `out` (see
/// [`EdgeAccum`] for the formula). Panics if no edge folded any upload.
/// Edges that saw no upload this window contribute zero mass and are
/// skipped; the rest must agree on mode and dimension.
pub fn combine_edges(edges: &[EdgeAccum], out: &mut [f32]) {
    let kk: usize = edges.iter().map(|e| e.count).sum();
    assert!(kk > 0, "edge combine over an empty flush window");
    let w_total: f64 = edges.iter().map(|e| e.w).sum();
    assert!(w_total > 0.0, "edge weights must sum to a positive value");
    let alpha_sum: f64 = edges.iter().map(|e| e.alpha).sum();
    let c = (alpha_sum / kk as f64).min(1.0);
    let scale = c / w_total;
    let live: Vec<&EdgeAccum> = edges.iter().filter(|e| e.count > 0).collect();
    let sparse = live[0].t.len() == live[0].s.len() && !live[0].s.is_empty();
    for e in &live {
        assert_eq!(e.s.len(), out.len(), "edge/output dimension mismatch");
        assert_eq!(e.t.is_empty(), !sparse, "mixed dense/sparse edges in one shard");
    }
    for j in 0..out.len() {
        let mut s = 0.0f64;
        let mut t = 0.0f64;
        for e in &live {
            s += e.s[j];
            if sparse {
                t += e.t[j];
            }
        }
        if !sparse {
            t = w_total;
        }
        out[j] = (scale * s + (1.0 - scale * t) * out[j] as f64) as f32;
    }
}

/// Merge the payloads' sorted index streams over the coordinate range
/// `start .. start + out_chunk.len()`, mixing each transmitted coordinate
/// into `out_chunk` in payload order (see
/// [`Aggregator::aggregate_sparse_payloads`] for the formula).
/// `cursors[i]` must point at payload `i`'s first index `>= start`.
///
/// The min-scan over payloads is O(K) per emitted coordinate (O(K·union)
/// overall); with the small upload fan-ins of this engine (K = buffer /
/// fleet size) that beats a heap's bookkeeping and stays allocation-free.
fn scatter_merge_range(
    payloads: &[SparseDelta],
    weights: &[f64],
    self_weight: f64,
    total: f64,
    out_chunk: &mut [f32],
    start: usize,
    cursors: &mut [usize],
) {
    let end = start + out_chunk.len();
    loop {
        // Smallest not-yet-mixed transmitted coordinate in [start, end).
        let mut j = usize::MAX;
        for (p, &cur) in payloads.iter().zip(cursors.iter()) {
            if let Some(&idx) = p.indices().get(cur) {
                let idx = idx as usize;
                if idx < end && idx < j {
                    j = idx;
                }
            }
        }
        if j == usize::MAX {
            return;
        }
        // Accumulate every payload's contribution at j in payload order —
        // the exact lane order of the dense fused path — then give the
        // missing weight mass (plus the explicit self weight, last, to
        // mirror the dense trailing self slot) to the current value.
        let mut acc = 0.0f64;
        let mut miss = 0.0f64;
        for ((p, cur), &w) in payloads.iter().zip(cursors.iter_mut()).zip(weights) {
            if p.indices().get(*cur).is_some_and(|&idx| idx as usize == j) {
                acc += (w / total) * p.value(*cur) as f64;
                *cur += 1;
            } else {
                miss += w;
            }
        }
        miss += self_weight;
        if miss > 0.0 {
            acc += (miss / total) * out_chunk[j - start] as f64;
        }
        out_chunk[j - start] = acc as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quant::Precision;

    #[test]
    fn weights_by_sample_count() {
        let a = vec![0.0f32, 4.0];
        let b = vec![2.0f32, 0.0];
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 2];
        agg.aggregate(&[&a, &b], &[100, 300], &mut out);
        assert_eq!(out, vec![1.5, 1.0]);
    }

    #[test]
    fn reuse_across_rounds() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 3];
        let m1 = vec![1.0f32; 3];
        agg.aggregate(&[&m1], &[10], &mut out);
        assert_eq!(out, vec![1.0; 3]);
        let m2 = vec![5.0f32; 3];
        agg.aggregate(&[&m2], &[10], &mut out);
        assert_eq!(out, vec![5.0; 3]);
    }

    #[test]
    fn payload_aggregation_matches_dense_f32() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * -0.5).collect();
        let weights = [3.0f64, 1.0];
        let mut agg = Aggregator::new();
        let mut want = vec![0.0f32; 37];
        agg.aggregate_weighted(&[&a, &b], &weights, &mut want);
        let mut bufs = vec![QuantBuf::new(), QuantBuf::new()];
        bufs[0].encode(Precision::F32, &a);
        bufs[1].encode(Precision::F32, &b);
        let mut got = vec![0.0f32; 37];
        agg.aggregate_payloads(&bufs, &weights, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn empty_upload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate(&[], &[], &mut out);
    }

    #[test]
    #[should_panic(expected = "zero payloads")]
    fn empty_payload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate_payloads(&[], &[], &mut out);
    }

    #[test]
    fn sparse_full_k_matches_dense_bitwise() {
        let mut rng = crate::util::rng::Rng::new(21);
        let dim = 53;
        let models: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let base = vec![0.0f32; dim];
        let weights = [2.0f64, 5.0, 1.0];
        let mut agg = Aggregator::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            let mut dense: Vec<QuantBuf> = vec![QuantBuf::new(); 3];
            let mut sparse: Vec<SparseDelta> = vec![SparseDelta::new(); 3];
            for ((d, s), m) in dense.iter_mut().zip(sparse.iter_mut()).zip(&models) {
                d.encode(p, m);
                s.encode_topk(p, m, &base, None, dim);
            }
            let mut want = vec![0.0f32; dim];
            agg.aggregate_payloads(&dense, &weights, &mut want);
            let mut got = vec![0.5f32; dim]; // prior values must be overwritten
            agg.aggregate_sparse_payloads(&sparse, &weights, 0.0, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn sparse_partial_k_mixes_missing_mass_into_prior() {
        // Two payloads over dim 4: payload A transmits {0, 1}, B transmits
        // {1, 2}. Coordinate 3 is untouched; coordinate 0 mixes A with the
        // prior at B's weight; coordinate 1 is a pure FedAvg of A and B.
        let a_params = vec![10.0f32, 20.0, 0.0, 0.0];
        let b_params = vec![0.0f32, 40.0, 30.0, 0.0];
        let base = vec![0.0f32; 4];
        let mut sa = SparseDelta::new();
        let mut sb = SparseDelta::new();
        sa.encode_topk(Precision::F32, &a_params, &base, None, 2);
        sb.encode_topk(Precision::F32, &b_params, &base, None, 2);
        assert_eq!(sa.indices(), &[0, 1]);
        assert_eq!(sb.indices(), &[1, 2]);
        let mut out = vec![1.0f32, 1.0, 1.0, 1.0];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(&[sa, sb], &[1.0, 3.0], 0.0, &mut out);
        assert!((out[0] - (10.0 + 3.0) / 4.0).abs() < 1e-6, "{}", out[0]);
        assert!((out[1] - (20.0 + 3.0 * 40.0) / 4.0).abs() < 1e-6, "{}", out[1]);
        assert!((out[2] - (1.0 + 3.0 * 30.0) / 4.0).abs() < 1e-6, "{}", out[2]);
        assert_eq!(out[3], 1.0, "untransmitted coordinate must not move");
    }

    #[test]
    fn sparse_self_weight_keeps_prior_mass() {
        // One payload transmitting coordinate 0 with weight 1 and
        // self_weight 3: out[0] <- (v + 3·prior) / 4.
        let params = vec![8.0f32, 0.0];
        let base = vec![0.0f32, 0.0];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 1);
        let mut out = vec![4.0f32, 4.0];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(&[sd], &[1.0], 3.0, &mut out);
        assert!((out[0] - (8.0 + 3.0 * 4.0) / 4.0).abs() < 1e-6);
        assert_eq!(out[1], 4.0);
    }

    #[test]
    #[should_panic(expected = "zero sparse payloads")]
    fn empty_sparse_payload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate_sparse_payloads(&[], &[], 0.0, &mut out);
    }

    /// Legacy flush reference for the edge tests: pre-normalize weights to
    /// sum to ᾱ and give 1−ᾱ to the current model (the ᾱ<1 branch of
    /// `flush_shard`; with ᾱ≥1 weights pass through and the self slot is
    /// absent).
    fn legacy_flush_dense(
        models: &[Vec<f32>],
        weights: &[f64],
        alphas: &[f64],
        out: &mut [f32],
    ) {
        let abar: f64 = alphas.iter().sum::<f64>() / alphas.len() as f64;
        let mut agg = Aggregator::new();
        let mut views: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        if abar >= 1.0 {
            let mut tmp = out.to_vec();
            agg.aggregate_weighted(&views, weights, &mut tmp);
            out.copy_from_slice(&tmp);
        } else {
            let total: f64 = weights.iter().sum();
            let mut w: Vec<f64> = weights.iter().map(|&x| abar * x / total).collect();
            let keep = out.to_vec();
            views.push(&keep);
            w.push(1.0 - abar);
            let mut tmp = out.to_vec();
            agg.aggregate_weighted(&views, &w, &mut tmp);
            out.copy_from_slice(&tmp);
        }
    }

    #[test]
    fn edge_combine_dense_matches_legacy_flush() {
        let mut rng = crate::util::rng::Rng::new(33);
        let dim = 41;
        let models: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let samples = [3.0f64, 7.0, 2.0, 5.0, 4.0];
        for alphas in [vec![1.0f64; 5], vec![0.5, 0.25, 1.0, 0.125, 0.5]] {
            let weights: Vec<f64> =
                samples.iter().zip(&alphas).map(|(&n, &a)| n * a).collect();
            let prior: Vec<f32> = (0..dim).map(|j| (j as f32).sin()).collect();
            let mut want = prior.clone();
            legacy_flush_dense(&models, &weights, &alphas, &mut want);
            // Spread the five uploads over two edges.
            let mut edges = vec![EdgeAccum::new(), EdgeAccum::new()];
            for e in edges.iter_mut() {
                e.reset(dim, false);
            }
            let mut buf = QuantBuf::new();
            for (i, m) in models.iter().enumerate() {
                buf.encode(Precision::F32, m);
                edges[i % 2].fold_dense(&buf, weights[i], alphas[i]);
            }
            let mut got = prior.clone();
            combine_edges(&edges, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "edge {a} vs legacy {b}");
            }
        }
    }

    #[test]
    fn edge_combine_sparse_matches_scatter_reference() {
        // Two sparse uploads over dim 4 on separate edges, ᾱ = 0.5:
        // compare against aggregate_sparse_payloads with the legacy
        // pre-normalized weights and self-weight 1−ᾱ.
        let a_params = vec![10.0f32, 20.0, 0.0, 0.0];
        let b_params = vec![0.0f32, 40.0, 30.0, 0.0];
        let base = vec![0.0f32; 4];
        let mut sa = SparseDelta::new();
        let mut sb = SparseDelta::new();
        sa.encode_topk(Precision::F32, &a_params, &base, None, 2);
        sb.encode_topk(Precision::F32, &b_params, &base, None, 2);
        let (wa, wb) = (1.0f64, 3.0);
        let abar = 0.5f64;
        let mut want = vec![1.0f32, 1.0, 1.0, 1.0];
        let norm: Vec<f64> = vec![abar * wa / (wa + wb), abar * wb / (wa + wb)];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(
            &[sa.clone(), sb.clone()],
            &norm,
            1.0 - abar,
            &mut want,
        );
        let mut edges = vec![EdgeAccum::new(), EdgeAccum::new()];
        for e in edges.iter_mut() {
            e.reset(4, true);
        }
        edges[0].fold_sparse(&sa, wa, abar);
        edges[1].fold_sparse(&sb, wb, abar);
        let mut got = vec![1.0f32, 1.0, 1.0, 1.0];
        combine_edges(&edges, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5, "edge {x} vs reference {y}");
        }
        assert_eq!(got[3], 1.0, "untransmitted coordinate must not move");
    }

    #[test]
    fn edge_combine_skips_empty_edges() {
        let m = vec![2.0f32, 4.0];
        let mut buf = QuantBuf::new();
        buf.encode(Precision::F32, &m);
        let mut edges = vec![EdgeAccum::new(), EdgeAccum::new(), EdgeAccum::new()];
        for e in edges.iter_mut() {
            e.reset(2, false);
        }
        edges[1].fold_dense(&buf, 5.0, 1.0);
        assert!(edges[0].is_empty() && !edges[1].is_empty());
        let mut out = vec![0.0f32; 2];
        combine_edges(&edges, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "empty flush window")]
    fn edge_combine_empty_window_panics() {
        let mut e = EdgeAccum::new();
        e.reset(2, false);
        let mut out = vec![0.0f32; 2];
        combine_edges(&[e], &mut out);
    }
}
