//! FedAvg aggregation (Algorithm 1 lines 15–16):
//! `theta^{t+1} = sum_{i in K} (n_i / n) theta_i^{t+1}` over the uploaded
//! models, weighted by local sample counts.
//!
//! Two entry families:
//!
//! * [`Aggregator::aggregate`] / [`Aggregator::aggregate_weighted`] — dense
//!   f32 model views (tests, diagnostics, the allocating reference path).
//! * [`Aggregator::aggregate_payloads`] — the hot path: wire-format
//!   [`QuantBuf`] payloads are dequantized-and-accumulated in one fused
//!   pass, fanned out across parameter chunks on scoped threads. No dense
//!   staging vector is ever materialized and steady-state rounds perform
//!   zero heap allocation (`tests/alloc_steady_state.rs` asserts this on
//!   the serial path; the parallel path additionally allocates only thread
//!   stacks at spawn).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::RobustMode;
use crate::model::quant::QuantBuf;
use crate::model::sparse::SparseDelta;
use crate::model::{weighted_average_into, ParamVec};
use crate::util::par;

/// Minimum parameter count per worker before fused aggregation fans out.
const PAR_MIN_DIM: usize = 8192;

/// Lane tag of the implicit prior-model lane in the robust merges (the
/// weight mass of non-transmitting payloads plus the engine's explicit
/// self weight). Never counted as an outlier — it is not a payload.
const PRIOR_LANE: u32 = u32::MAX;

/// Byzantine-robust merge parameters (see [`Aggregator::aggregate_payloads_robust`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RobustSpec {
    pub mode: RobustMode,
    /// Per-end trim fraction of the trimmed mean (`t = floor(trim · lanes)`,
    /// clamped so at least one lane survives). Ignored by `Median`.
    pub trim: f64,
}

/// Pooled per-coordinate scratch of the robust merges: the value lanes of
/// one coordinate, the sorted lane order, and the trim mask. Reused across
/// coordinates (and rounds, on the serial path); parallel workers build
/// their own small instance per spawn, like the sparse cursor vectors.
#[derive(Default)]
struct LaneScratch {
    /// `(value, weight, payload index | PRIOR_LANE)` in lane order:
    /// transmitting payloads in payload order, the prior lane last —
    /// exactly the plain merge's summation order.
    lanes: Vec<(f64, f64, u32)>,
    /// Lane ids sorted by `(value total_cmp, lane id)`.
    order: Vec<u32>,
    /// Trim mask over lane ids.
    dropped: Vec<bool>,
}

/// Reusable aggregator (buffers survive across rounds — the hot path does
/// not allocate; see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct Aggregator {
    scratch: Vec<f64>,
    /// Cached weight buffer: `aggregate` reuses it instead of collecting a
    /// fresh `Vec<f64>` every round.
    weights: Vec<f64>,
    /// Pooled per-payload cursors for the serial sparse merge (the
    /// parallel path gives each worker its own small cursor vector).
    cursors: Vec<usize>,
    /// Pooled lane scratch of the serial robust merges.
    robust: LaneScratch,
    /// Pooled per-payload outlier counters of the robust merges (atomic so
    /// parallel workers over disjoint coordinate ranges can bump them with
    /// relaxed integer adds — commutative, hence thread-count invariant).
    counts: Vec<AtomicU64>,
}

impl Aggregator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Aggregate `models` (with sample-count weights) into `out`.
    ///
    /// Panics if `models` is empty — the server must skip aggregation on
    /// rounds where nothing was uploaded (possible under EAFLM).
    pub fn aggregate(&mut self, models: &[&[f32]], sample_counts: &[usize], out: &mut ParamVec) {
        self.weights.clear();
        self.weights.extend(sample_counts.iter().map(|&n| n as f64));
        weighted_average_into(models, &self.weights, out, &mut self.scratch);
    }

    /// Aggregate with arbitrary positive weights (n_i, possibly decayed by
    /// staleness — the FedAsync-style extension).
    pub fn aggregate_weighted(&mut self, models: &[&[f32]], weights: &[f64], out: &mut ParamVec) {
        weighted_average_into(models, weights, out, &mut self.scratch);
    }

    /// Fused hot path: aggregate quantized wire payloads straight into
    /// `out`, dequantizing on the fly — no per-upload `round_trip`
    /// staging vector. Weights are normalized internally.
    ///
    /// Bit-identical to decoding every payload with
    /// [`crate::model::quant::Precision::round_trip`] and then calling
    /// [`aggregate_weighted`](Self::aggregate_weighted) (property-tested in
    /// `tests/proptests.rs`).
    pub fn aggregate_payloads(&mut self, payloads: &[QuantBuf], weights: &[f64], out: &mut [f32]) {
        let threads = par::threads_for(out.len(), PAR_MIN_DIM);
        self.aggregate_payloads_t(payloads, weights, out, threads);
    }

    /// Explicit-worker-count variant of [`aggregate_payloads`](Self::aggregate_payloads)
    /// (benches and thread-count equivalence tests). `threads == 1` is
    /// serial and allocation-free at steady state.
    pub fn aggregate_payloads_t(
        &mut self,
        payloads: &[QuantBuf],
        weights: &[f64],
        out: &mut [f32],
        threads: usize,
    ) {
        assert!(!payloads.is_empty(), "aggregate of zero payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        let dim = payloads[0].len();
        for p in payloads {
            assert_eq!(p.len(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must sum to a positive value");
        self.scratch.clear();
        self.scratch.resize(dim, 0.0);
        par::par_chunks_mut(self.scratch.as_mut_slice(), threads, 8, |start, acc| {
            for (p, &w) in payloads.iter().zip(weights) {
                p.accumulate_dequant_range(start, w / total, acc);
            }
        });
        for (o, &a) in out.iter_mut().zip(self.scratch.iter()) {
            *o = a as f32;
        }
    }

    /// Fused sparse scatter path: mix top-k [`SparseDelta`] payloads into
    /// `out` (the global / shard replica) **in place**, touching only the
    /// transmitted coordinates — flush cost O(K·k) instead of O(K·n).
    ///
    /// For every coordinate `j` transmitted by at least one payload:
    ///
    /// ```text
    /// out[j] <- ( Σ_{i ∋ j} w_i·v_i[j]  +  (self_weight + Σ_{i ∌ j} w_i)·out[j] ) / total
    /// total  =  Σ_i w_i + self_weight
    /// ```
    ///
    /// i.e. masked FedAvg where the weight mass of payloads that did not
    /// transmit `j` (and the explicit `self_weight` — the barrier-free
    /// engine's `1 − ᾱ` keep-rate) falls back to the current value of
    /// `out`. Coordinates transmitted by no one are not read or written.
    ///
    /// When every payload transmits every coordinate (`k == dim`, i.e.
    /// `k_fraction = 1.0`) this is **bit-identical** to
    /// [`aggregate_payloads`](Self::aggregate_payloads) over the dense
    /// encodings of the same uploads — with `self_weight > 0` matching
    /// the dense path's convention of folding the current model in as one
    /// trailing f32 payload slot (property-tested in
    /// `rust/tests/sparse.rs`).
    pub fn aggregate_sparse_payloads(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        out: &mut [f32],
    ) {
        let nnz: usize = payloads.iter().map(|p| p.len()).sum();
        let threads = par::threads_for(nnz, PAR_MIN_DIM);
        self.aggregate_sparse_payloads_t(payloads, weights, self_weight, out, threads);
    }

    /// Explicit-worker-count variant of
    /// [`aggregate_sparse_payloads`](Self::aggregate_sparse_payloads).
    /// Workers own disjoint contiguous coordinate ranges of `out`, so
    /// every coordinate is computed by exactly one worker with exactly
    /// the same operations in the same order for every worker count —
    /// bit-identical results, like every kernel on `util::par`.
    /// `threads == 1` is serial and allocation-free at steady state
    /// (`rust/tests/alloc_sparse.rs`).
    pub fn aggregate_sparse_payloads_t(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        out: &mut [f32],
        threads: usize,
    ) {
        assert!(!payloads.is_empty(), "aggregate of zero sparse payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        assert!(
            self_weight >= 0.0 && self_weight.is_finite(),
            "self_weight must be finite and non-negative"
        );
        let dim = payloads[0].dim();
        for p in payloads {
            assert_eq!(p.dim(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        // Summation order matches the dense path with the self slot
        // pushed last, so the k == dim case normalizes identically.
        let total: f64 = weights.iter().sum::<f64>() + self_weight;
        assert!(total > 0.0, "weights must sum to a positive value");
        if threads <= 1 {
            self.cursors.clear();
            self.cursors.resize(payloads.len(), 0);
            scatter_merge_range(payloads, weights, self_weight, total, out, 0, &mut self.cursors);
        } else {
            par::par_chunks_mut(out, threads, 8, |start, chunk| {
                let mut cursors: Vec<usize> = payloads
                    .iter()
                    .map(|p| p.indices().partition_point(|&i| (i as usize) < start))
                    .collect();
                scatter_merge_range(
                    payloads,
                    weights,
                    self_weight,
                    total,
                    chunk,
                    start,
                    &mut cursors,
                );
            });
        }
    }

    /// Byzantine-robust dense merge: per coordinate, collect one value
    /// lane per payload (plus a prior lane reading `out` at
    /// `prior_weight`, when positive — the barrier-free engine's `1 − ᾱ`
    /// keep-mass, folded in *without* a trailing self payload slot so the
    /// prior cannot be trimmed into a wire round-trip), sort the lanes by
    /// `total_cmp` with lane-index tie-breaks, and reduce by coordinate-wise
    /// trimmed mean or weighted lower median (see [`RobustSpec`]).
    ///
    /// `outliers[i]` receives the number of coordinates at which payload
    /// `i`'s lane was trimmed (or, for `Median`, ranked most extreme) —
    /// the per-flush outlier statistic behind the trust scores. The prior
    /// lane is never counted.
    ///
    /// A coordinate whose lane count yields a trim of zero is reduced by
    /// **exactly** the plain merge's summation (lane order, prior last),
    /// so `trim = 0.0` is bitwise identical to
    /// [`aggregate_payloads`](Self::aggregate_payloads) (with
    /// `prior_weight > 0` matching the dense path's trailing-self-slot
    /// convention). `RobustMode::None` must use the plain entry points.
    pub fn aggregate_payloads_robust(
        &mut self,
        payloads: &[QuantBuf],
        weights: &[f64],
        prior_weight: f64,
        spec: RobustSpec,
        out: &mut [f32],
        outliers: &mut [u64],
    ) {
        let threads = par::threads_for(out.len(), PAR_MIN_DIM);
        self.aggregate_payloads_robust_t(
            payloads,
            weights,
            prior_weight,
            spec,
            out,
            outliers,
            threads,
        );
    }

    /// Explicit-worker-count variant of
    /// [`aggregate_payloads_robust`](Self::aggregate_payloads_robust).
    /// Workers own disjoint contiguous coordinate ranges and outlier
    /// counters are bumped with relaxed atomic adds (integer addition
    /// commutes), so values *and* counts are bit-identical for every
    /// worker count.
    #[allow(clippy::too_many_arguments)]
    pub fn aggregate_payloads_robust_t(
        &mut self,
        payloads: &[QuantBuf],
        weights: &[f64],
        prior_weight: f64,
        spec: RobustSpec,
        out: &mut [f32],
        outliers: &mut [u64],
        threads: usize,
    ) {
        assert!(spec.mode != RobustMode::None, "RobustMode::None must use aggregate_payloads");
        assert!(!payloads.is_empty(), "aggregate of zero payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        assert_eq!(payloads.len(), outliers.len(), "payloads/outliers length mismatch");
        assert!(
            prior_weight >= 0.0 && prior_weight.is_finite(),
            "prior_weight must be finite and non-negative"
        );
        let dim = payloads[0].len();
        for p in payloads {
            assert_eq!(p.len(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        let total: f64 = weights.iter().sum::<f64>() + prior_weight;
        assert!(total > 0.0, "weights must sum to a positive value");
        reset_counts(&mut self.counts, payloads.len());
        let counts = &self.counts[..payloads.len()];
        if threads <= 1 {
            robust_dense_range(
                payloads,
                weights,
                prior_weight,
                total,
                spec,
                out,
                0,
                counts,
                &mut self.robust,
            );
        } else {
            par::par_chunks_mut(out, threads, 8, |start, chunk| {
                let mut scratch = LaneScratch::default();
                robust_dense_range(
                    payloads,
                    weights,
                    prior_weight,
                    total,
                    spec,
                    chunk,
                    start,
                    counts,
                    &mut scratch,
                );
            });
        }
        for (o, c) in outliers.iter_mut().zip(counts) {
            *o = c.load(Ordering::Relaxed);
        }
    }

    /// Byzantine-robust sparse scatter merge: like
    /// [`aggregate_sparse_payloads`](Self::aggregate_sparse_payloads), but
    /// each transmitted coordinate's value lanes (transmitting payloads in
    /// payload order + one prior lane carrying the missing weight mass and
    /// `self_weight`) are reduced by coordinate-wise trimmed mean or
    /// weighted median instead of the weighted sum. Coordinates
    /// transmitted by no one are not read or written — robustness
    /// operates on the partially-overlapping top-k streams exactly as
    /// they arrive.
    ///
    /// `trim = 0.0` (and every coordinate whose lane count trims to zero)
    /// is bitwise identical to the plain scatter merge; `outliers` is
    /// filled as in
    /// [`aggregate_payloads_robust`](Self::aggregate_payloads_robust).
    pub fn aggregate_sparse_payloads_robust(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        spec: RobustSpec,
        out: &mut [f32],
        outliers: &mut [u64],
    ) {
        let nnz: usize = payloads.iter().map(|p| p.len()).sum();
        let threads = par::threads_for(nnz, PAR_MIN_DIM);
        self.aggregate_sparse_payloads_robust_t(
            payloads,
            weights,
            self_weight,
            spec,
            out,
            outliers,
            threads,
        );
    }

    /// Explicit-worker-count variant of
    /// [`aggregate_sparse_payloads_robust`](Self::aggregate_sparse_payloads_robust);
    /// bit-identical (values and outlier counts) for every worker count.
    #[allow(clippy::too_many_arguments)]
    pub fn aggregate_sparse_payloads_robust_t(
        &mut self,
        payloads: &[SparseDelta],
        weights: &[f64],
        self_weight: f64,
        spec: RobustSpec,
        out: &mut [f32],
        outliers: &mut [u64],
        threads: usize,
    ) {
        assert!(
            spec.mode != RobustMode::None,
            "RobustMode::None must use aggregate_sparse_payloads"
        );
        assert!(!payloads.is_empty(), "aggregate of zero sparse payloads");
        assert_eq!(payloads.len(), weights.len(), "payloads/weights length mismatch");
        assert_eq!(payloads.len(), outliers.len(), "payloads/outliers length mismatch");
        assert!(
            self_weight >= 0.0 && self_weight.is_finite(),
            "self_weight must be finite and non-negative"
        );
        let dim = payloads[0].dim();
        for p in payloads {
            assert_eq!(p.dim(), dim, "payload dimension mismatch");
        }
        assert_eq!(out.len(), dim, "output dimension mismatch");
        let total: f64 = weights.iter().sum::<f64>() + self_weight;
        assert!(total > 0.0, "weights must sum to a positive value");
        reset_counts(&mut self.counts, payloads.len());
        let counts = &self.counts[..payloads.len()];
        if threads <= 1 {
            self.cursors.clear();
            self.cursors.resize(payloads.len(), 0);
            robust_scatter_range(
                payloads,
                weights,
                self_weight,
                total,
                spec,
                out,
                0,
                &mut self.cursors,
                counts,
                &mut self.robust,
            );
        } else {
            par::par_chunks_mut(out, threads, 8, |start, chunk| {
                let mut cursors: Vec<usize> = payloads
                    .iter()
                    .map(|p| p.indices().partition_point(|&i| (i as usize) < start))
                    .collect();
                let mut scratch = LaneScratch::default();
                robust_scatter_range(
                    payloads,
                    weights,
                    self_weight,
                    total,
                    spec,
                    chunk,
                    start,
                    &mut cursors,
                    counts,
                    &mut scratch,
                );
            });
        }
        for (o, c) in outliers.iter_mut().zip(counts) {
            *o = c.load(Ordering::Relaxed);
        }
    }

}

/// Grow the pooled atomic outlier counters to `n` and zero the first `n`
/// (`AtomicU64` is not `Clone`, so no `resize`). A free function so the
/// caller keeps disjoint borrows of the aggregator's other scratch fields.
fn reset_counts(counts: &mut Vec<AtomicU64>, n: usize) {
    while counts.len() < n {
        counts.push(AtomicU64::new(0));
    }
    for c in &counts[..n] {
        c.store(0, Ordering::Relaxed);
    }
}

/// One edge aggregator of the two-tier (edge -> shard) aggregation tree.
///
/// The barrier-free engine with `engine.edge_fanout > 1` folds each upload
/// into its edge's running sums **at arrival time** (the uploading client
/// is blocked between upload and broadcast, and the shard version only
/// advances at flush, so the payload and its staleness weight are already
/// final when the upload lands). Per coordinate `j` the edge keeps
///
/// ```text
/// S[j] = Σ_i w_i · v_i[j]          (folded uploads i on this edge)
/// T[j] = Σ_{i transmitting j} w_i  (sparse mode only; dense T ≡ W)
/// ```
///
/// plus the scalar totals `W = Σ w_i`, `Σ alpha_i`, and the upload count.
/// At flush, [`combine_edges`] mixes the shard's edge set into the replica
/// in O(edges · dim) — independent of the buffer size K, so a deep buffer
/// costs the flush no more than its edge fan-in:
///
/// ```text
/// c      = min(Σ alpha / K, 1)                    (the legacy ᾱ clamp)
/// out[j] = (c/W)·ΣS[j] + (1 − (c/W)·ΣT[j])·out[j]
/// ```
///
/// which reproduces all four legacy flush cases (dense/sparse × ᾱ≥1/<1):
/// the legacy path pre-normalizes upload weights to sum to ᾱ with a
/// self-weight of 1−ᾱ, which is algebraically exactly this formula. The
/// summation *order* differs from the per-client flush-time encode, so
/// `edge_fanout > 1` is deterministic and thread-invariant but not bitwise
/// against `edge_fanout = 1` (the default, which keeps the legacy path and
/// the golden snapshots byte-stable).
#[derive(Default)]
pub struct EdgeAccum {
    s: Vec<f64>,
    t: Vec<f64>,
    w: f64,
    alpha: f64,
    count: usize,
}

impl EdgeAccum {
    pub fn new() -> Self {
        Self::default()
    }

    /// Clear for the next flush window. `sparse` chooses whether the
    /// per-coordinate transmitted-mass vector `T` is kept (top-k mode) or
    /// elided (dense mode, where `T ≡ W`).
    pub fn reset(&mut self, dim: usize, sparse: bool) {
        self.s.clear();
        self.s.resize(dim, 0.0);
        self.t.clear();
        if sparse {
            self.t.resize(dim, 0.0);
        }
        self.w = 0.0;
        self.alpha = 0.0;
        self.count = 0;
    }

    /// Fold one dense upload with aggregation weight `w` (sample count ×
    /// staleness decay) and raw staleness weight `alpha`.
    pub fn fold_dense(&mut self, payload: &QuantBuf, w: f64, alpha: f64) {
        assert_eq!(payload.len(), self.s.len(), "edge fold dimension mismatch");
        assert!(self.t.is_empty(), "dense fold into a sparse-mode edge");
        payload.accumulate_dequant_range(0, w, &mut self.s);
        self.w += w;
        self.alpha += alpha;
        self.count += 1;
    }

    /// Fold one sparse top-k upload (see [`EdgeAccum::fold_dense`]).
    pub fn fold_sparse(&mut self, payload: &SparseDelta, w: f64, alpha: f64) {
        assert_eq!(payload.dim(), self.s.len(), "edge fold dimension mismatch");
        assert_eq!(self.t.len(), self.s.len(), "sparse fold into a dense-mode edge");
        for (pos, &idx) in payload.indices().iter().enumerate() {
            let j = idx as usize;
            self.s[j] += w * payload.value(pos) as f64;
            self.t[j] += w;
        }
        self.w += w;
        self.alpha += alpha;
        self.count += 1;
    }

    /// Uploads folded since the last reset.
    pub fn count(&self) -> usize {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Resident bytes of the accumulator vectors (fleet-scale bench).
    pub fn approx_bytes(&self) -> usize {
        (self.s.capacity() + self.t.capacity()) * std::mem::size_of::<f64>()
    }

    /// Serialize the running sums into an engine checkpoint (see
    /// `Server::checkpoint_bytes`): mid-window folds must survive a
    /// kill/restore bitwise, so `checkpoint_every` composes with
    /// `edge_fanout > 1`.
    pub fn save(&self, enc: &mut crate::util::codec::Enc) {
        enc.f64s(&self.s);
        enc.f64s(&self.t);
        enc.f64(self.w);
        enc.f64(self.alpha);
        enc.usize(self.count);
    }

    /// Inverse of [`EdgeAccum::save`].
    pub fn load(dec: &mut crate::util::codec::Dec) -> anyhow::Result<Self> {
        Ok(EdgeAccum {
            s: dec.f64s()?,
            t: dec.f64s()?,
            w: dec.f64()?,
            alpha: dec.f64()?,
            count: dec.usize()?,
        })
    }
}

/// Combine one shard's edge accumulators into its replica `out` (see
/// [`EdgeAccum`] for the formula). Panics if no edge folded any upload.
/// Edges that saw no upload this window contribute zero mass and are
/// skipped; the rest must agree on mode and dimension.
pub fn combine_edges(edges: &[EdgeAccum], out: &mut [f32]) {
    let kk: usize = edges.iter().map(|e| e.count).sum();
    assert!(kk > 0, "edge combine over an empty flush window");
    let w_total: f64 = edges.iter().map(|e| e.w).sum();
    assert!(w_total > 0.0, "edge weights must sum to a positive value");
    let alpha_sum: f64 = edges.iter().map(|e| e.alpha).sum();
    let c = (alpha_sum / kk as f64).min(1.0);
    let scale = c / w_total;
    let live: Vec<&EdgeAccum> = edges.iter().filter(|e| e.count > 0).collect();
    let sparse = live[0].t.len() == live[0].s.len() && !live[0].s.is_empty();
    for e in &live {
        assert_eq!(e.s.len(), out.len(), "edge/output dimension mismatch");
        assert_eq!(e.t.is_empty(), !sparse, "mixed dense/sparse edges in one shard");
    }
    for j in 0..out.len() {
        let mut s = 0.0f64;
        let mut t = 0.0f64;
        for e in &live {
            s += e.s[j];
            if sparse {
                t += e.t[j];
            }
        }
        if !sparse {
            t = w_total;
        }
        out[j] = (scale * s + (1.0 - scale * t) * out[j] as f64) as f32;
    }
}

/// Merge the payloads' sorted index streams over the coordinate range
/// `start .. start + out_chunk.len()`, mixing each transmitted coordinate
/// into `out_chunk` in payload order (see
/// [`Aggregator::aggregate_sparse_payloads`] for the formula).
/// `cursors[i]` must point at payload `i`'s first index `>= start`.
///
/// The min-scan over payloads is O(K) per emitted coordinate (O(K·union)
/// overall); with the small upload fan-ins of this engine (K = buffer /
/// fleet size) that beats a heap's bookkeeping and stays allocation-free.
fn scatter_merge_range(
    payloads: &[SparseDelta],
    weights: &[f64],
    self_weight: f64,
    total: f64,
    out_chunk: &mut [f32],
    start: usize,
    cursors: &mut [usize],
) {
    let end = start + out_chunk.len();
    loop {
        // Smallest not-yet-mixed transmitted coordinate in [start, end).
        let mut j = usize::MAX;
        for (p, &cur) in payloads.iter().zip(cursors.iter()) {
            if let Some(&idx) = p.indices().get(cur) {
                let idx = idx as usize;
                if idx < end && idx < j {
                    j = idx;
                }
            }
        }
        if j == usize::MAX {
            return;
        }
        // Accumulate every payload's contribution at j in payload order —
        // the exact lane order of the dense fused path — then give the
        // missing weight mass (plus the explicit self weight, last, to
        // mirror the dense trailing self slot) to the current value.
        let mut acc = 0.0f64;
        let mut miss = 0.0f64;
        for ((p, cur), &w) in payloads.iter().zip(cursors.iter_mut()).zip(weights) {
            if p.indices().get(*cur).is_some_and(|&idx| idx as usize == j) {
                acc += (w / total) * p.value(*cur) as f64;
                *cur += 1;
            } else {
                miss += w;
            }
        }
        miss += self_weight;
        if miss > 0.0 {
            acc += (miss / total) * out_chunk[j - start] as f64;
        }
        out_chunk[j - start] = acc as f32;
    }
}

/// Robust dense merge over `start .. start + out_chunk.len()`: per
/// coordinate, one lane per payload in payload order (each dequantized via
/// [`QuantBuf::get`], bit-identical to the fused accumulate), plus the
/// prior lane last when `prior_weight > 0`.
#[allow(clippy::too_many_arguments)]
fn robust_dense_range(
    payloads: &[QuantBuf],
    weights: &[f64],
    prior_weight: f64,
    total: f64,
    spec: RobustSpec,
    out_chunk: &mut [f32],
    start: usize,
    counts: &[AtomicU64],
    scratch: &mut LaneScratch,
) {
    for (k, o) in out_chunk.iter_mut().enumerate() {
        let j = start + k;
        scratch.lanes.clear();
        for (pi, (p, &w)) in payloads.iter().zip(weights).enumerate() {
            scratch.lanes.push((p.get(j) as f64, w, pi as u32));
        }
        if prior_weight > 0.0 {
            scratch.lanes.push((*o as f64, prior_weight, PRIOR_LANE));
        }
        *o = robust_reduce_lanes(
            spec,
            total,
            &scratch.lanes,
            &mut scratch.order,
            &mut scratch.dropped,
            counts,
        );
    }
}

/// Robust sparse scatter merge: the min-scan of [`scatter_merge_range`],
/// but each transmitted coordinate's contributions become value lanes
/// (transmitters in payload order, then one prior lane carrying the
/// missing weight mass plus `self_weight`) reduced by
/// [`robust_reduce_lanes`]. `cursors[i]` must point at payload `i`'s first
/// index `>= start`.
#[allow(clippy::too_many_arguments)]
fn robust_scatter_range(
    payloads: &[SparseDelta],
    weights: &[f64],
    self_weight: f64,
    total: f64,
    spec: RobustSpec,
    out_chunk: &mut [f32],
    start: usize,
    cursors: &mut [usize],
    counts: &[AtomicU64],
    scratch: &mut LaneScratch,
) {
    let end = start + out_chunk.len();
    loop {
        let mut j = usize::MAX;
        for (p, &cur) in payloads.iter().zip(cursors.iter()) {
            if let Some(&idx) = p.indices().get(cur) {
                let idx = idx as usize;
                if idx < end && idx < j {
                    j = idx;
                }
            }
        }
        if j == usize::MAX {
            return;
        }
        scratch.lanes.clear();
        let mut miss = 0.0f64;
        for (pi, ((p, cur), &w)) in
            payloads.iter().zip(cursors.iter_mut()).zip(weights).enumerate()
        {
            if p.indices().get(*cur).is_some_and(|&idx| idx as usize == j) {
                scratch.lanes.push((p.value(*cur) as f64, w, pi as u32));
                *cur += 1;
            } else {
                miss += w;
            }
        }
        miss += self_weight;
        if miss > 0.0 {
            scratch.lanes.push((out_chunk[j - start] as f64, miss, PRIOR_LANE));
        }
        out_chunk[j - start] = robust_reduce_lanes(
            spec,
            total,
            &scratch.lanes,
            &mut scratch.order,
            &mut scratch.dropped,
            counts,
        );
    }
}

/// Sort lane ids by value (`total_cmp`) with lane-id tie-breaks —
/// deterministic for every input, including NaNs and signed zeros.
fn sort_order(lanes: &[(f64, f64, u32)], order: &mut Vec<u32>) {
    order.clear();
    order.extend(0..lanes.len() as u32);
    order.sort_unstable_by(|&a, &b| {
        lanes[a as usize].0.total_cmp(&lanes[b as usize].0).then(a.cmp(&b))
    });
}

/// Reduce one coordinate's value lanes to its merged value.
///
/// * `TrimmedMean` with an effective trim of zero replays the plain
///   merge's summation — `Σ (w/total)·v` in lane order — bit-for-bit.
///   With `t = min(floor(trim·lanes), (lanes−1)/2) > 0` the `t` smallest
///   and `t` largest lanes are dropped (their payloads' outlier counters
///   bumped), and the survivors are averaged over their own weight mass
///   in lane order.
/// * `Median` returns the weighted lower median: the first lane in value
///   order whose cumulative weight reaches half the total lane mass.
///   With ≥ 3 lanes the extreme-ranked payload lanes are counted as
///   outliers (rank, not trim, is the deviation signal here).
///
/// The prior lane ([`PRIOR_LANE`]) participates in sorting, trimming and
/// the median walk like any other lane but never touches `counts`.
fn robust_reduce_lanes(
    spec: RobustSpec,
    total: f64,
    lanes: &[(f64, f64, u32)],
    order: &mut Vec<u32>,
    dropped: &mut Vec<bool>,
    counts: &[AtomicU64],
) -> f32 {
    let l = lanes.len();
    match spec.mode {
        RobustMode::None => unreachable!("robust merge with RobustMode::None"),
        RobustMode::TrimmedMean => {
            let t = ((spec.trim * l as f64).floor() as usize).min(l.saturating_sub(1) / 2);
            if t == 0 {
                // Bitwise-plain fallback: identical operations in identical
                // order to scatter_merge_range / the fused dense path.
                let mut acc = 0.0f64;
                for &(v, w, _) in lanes {
                    acc += (w / total) * v;
                }
                return acc as f32;
            }
            sort_order(lanes, order);
            dropped.clear();
            dropped.resize(l, false);
            for &id in order[..t].iter().chain(order[l - t..].iter()) {
                dropped[id as usize] = true;
                let tag = lanes[id as usize].2;
                if tag != PRIOR_LANE {
                    counts[tag as usize].fetch_add(1, Ordering::Relaxed);
                }
            }
            // Renormalize over the surviving mass, in lane order so the
            // summation sequence is input-determined (not sort-determined).
            let mut wsum = 0.0f64;
            for (lane, &drop) in lanes.iter().zip(dropped.iter()) {
                if !drop {
                    wsum += lane.1;
                }
            }
            let mut acc = 0.0f64;
            for (&(v, w, _), &drop) in lanes.iter().zip(dropped.iter()) {
                if !drop {
                    acc += (w / wsum) * v;
                }
            }
            acc as f32
        }
        RobustMode::Median => {
            sort_order(lanes, order);
            if l >= 3 {
                for &id in [order[0], order[l - 1]].iter() {
                    let tag = lanes[id as usize].2;
                    if tag != PRIOR_LANE {
                        counts[tag as usize].fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            let wsum: f64 = lanes.iter().map(|&(_, w, _)| w).sum();
            let mut cum = 0.0f64;
            for &id in order.iter() {
                let (v, w, _) = lanes[id as usize];
                cum += w;
                if cum >= 0.5 * wsum {
                    return v as f32;
                }
            }
            lanes[order[l - 1] as usize].0 as f32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::quant::Precision;

    #[test]
    fn weights_by_sample_count() {
        let a = vec![0.0f32, 4.0];
        let b = vec![2.0f32, 0.0];
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 2];
        agg.aggregate(&[&a, &b], &[100, 300], &mut out);
        assert_eq!(out, vec![1.5, 1.0]);
    }

    #[test]
    fn reuse_across_rounds() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 3];
        let m1 = vec![1.0f32; 3];
        agg.aggregate(&[&m1], &[10], &mut out);
        assert_eq!(out, vec![1.0; 3]);
        let m2 = vec![5.0f32; 3];
        agg.aggregate(&[&m2], &[10], &mut out);
        assert_eq!(out, vec![5.0; 3]);
    }

    #[test]
    fn payload_aggregation_matches_dense_f32() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.25).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * -0.5).collect();
        let weights = [3.0f64, 1.0];
        let mut agg = Aggregator::new();
        let mut want = vec![0.0f32; 37];
        agg.aggregate_weighted(&[&a, &b], &weights, &mut want);
        let mut bufs = vec![QuantBuf::new(), QuantBuf::new()];
        bufs[0].encode(Precision::F32, &a);
        bufs[1].encode(Precision::F32, &b);
        let mut got = vec![0.0f32; 37];
        agg.aggregate_payloads(&bufs, &weights, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic]
    fn empty_upload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate(&[], &[], &mut out);
    }

    #[test]
    #[should_panic(expected = "zero payloads")]
    fn empty_payload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate_payloads(&[], &[], &mut out);
    }

    #[test]
    fn sparse_full_k_matches_dense_bitwise() {
        let mut rng = crate::util::rng::Rng::new(21);
        let dim = 53;
        let models: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let base = vec![0.0f32; dim];
        let weights = [2.0f64, 5.0, 1.0];
        let mut agg = Aggregator::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            let mut dense: Vec<QuantBuf> = vec![QuantBuf::new(); 3];
            let mut sparse: Vec<SparseDelta> = vec![SparseDelta::new(); 3];
            for ((d, s), m) in dense.iter_mut().zip(sparse.iter_mut()).zip(&models) {
                d.encode(p, m);
                s.encode_topk(p, m, &base, None, dim);
            }
            let mut want = vec![0.0f32; dim];
            agg.aggregate_payloads(&dense, &weights, &mut want);
            let mut got = vec![0.5f32; dim]; // prior values must be overwritten
            agg.aggregate_sparse_payloads(&sparse, &weights, 0.0, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn sparse_partial_k_mixes_missing_mass_into_prior() {
        // Two payloads over dim 4: payload A transmits {0, 1}, B transmits
        // {1, 2}. Coordinate 3 is untouched; coordinate 0 mixes A with the
        // prior at B's weight; coordinate 1 is a pure FedAvg of A and B.
        let a_params = vec![10.0f32, 20.0, 0.0, 0.0];
        let b_params = vec![0.0f32, 40.0, 30.0, 0.0];
        let base = vec![0.0f32; 4];
        let mut sa = SparseDelta::new();
        let mut sb = SparseDelta::new();
        sa.encode_topk(Precision::F32, &a_params, &base, None, 2);
        sb.encode_topk(Precision::F32, &b_params, &base, None, 2);
        assert_eq!(sa.indices(), &[0, 1]);
        assert_eq!(sb.indices(), &[1, 2]);
        let mut out = vec![1.0f32, 1.0, 1.0, 1.0];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(&[sa, sb], &[1.0, 3.0], 0.0, &mut out);
        assert!((out[0] - (10.0 + 3.0) / 4.0).abs() < 1e-6, "{}", out[0]);
        assert!((out[1] - (20.0 + 3.0 * 40.0) / 4.0).abs() < 1e-6, "{}", out[1]);
        assert!((out[2] - (1.0 + 3.0 * 30.0) / 4.0).abs() < 1e-6, "{}", out[2]);
        assert_eq!(out[3], 1.0, "untransmitted coordinate must not move");
    }

    #[test]
    fn sparse_self_weight_keeps_prior_mass() {
        // One payload transmitting coordinate 0 with weight 1 and
        // self_weight 3: out[0] <- (v + 3·prior) / 4.
        let params = vec![8.0f32, 0.0];
        let base = vec![0.0f32, 0.0];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 1);
        let mut out = vec![4.0f32, 4.0];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(&[sd], &[1.0], 3.0, &mut out);
        assert!((out[0] - (8.0 + 3.0 * 4.0) / 4.0).abs() < 1e-6);
        assert_eq!(out[1], 4.0);
    }

    #[test]
    #[should_panic(expected = "zero sparse payloads")]
    fn empty_sparse_payload_set_panics() {
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        agg.aggregate_sparse_payloads(&[], &[], 0.0, &mut out);
    }

    /// Legacy flush reference for the edge tests: pre-normalize weights to
    /// sum to ᾱ and give 1−ᾱ to the current model (the ᾱ<1 branch of
    /// `flush_shard`; with ᾱ≥1 weights pass through and the self slot is
    /// absent).
    fn legacy_flush_dense(
        models: &[Vec<f32>],
        weights: &[f64],
        alphas: &[f64],
        out: &mut [f32],
    ) {
        let abar: f64 = alphas.iter().sum::<f64>() / alphas.len() as f64;
        let mut agg = Aggregator::new();
        let mut views: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        if abar >= 1.0 {
            let mut tmp = out.to_vec();
            agg.aggregate_weighted(&views, weights, &mut tmp);
            out.copy_from_slice(&tmp);
        } else {
            let total: f64 = weights.iter().sum();
            let mut w: Vec<f64> = weights.iter().map(|&x| abar * x / total).collect();
            let keep = out.to_vec();
            views.push(&keep);
            w.push(1.0 - abar);
            let mut tmp = out.to_vec();
            agg.aggregate_weighted(&views, &w, &mut tmp);
            out.copy_from_slice(&tmp);
        }
    }

    #[test]
    fn edge_combine_dense_matches_legacy_flush() {
        let mut rng = crate::util::rng::Rng::new(33);
        let dim = 41;
        let models: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let samples = [3.0f64, 7.0, 2.0, 5.0, 4.0];
        for alphas in [vec![1.0f64; 5], vec![0.5, 0.25, 1.0, 0.125, 0.5]] {
            let weights: Vec<f64> =
                samples.iter().zip(&alphas).map(|(&n, &a)| n * a).collect();
            let prior: Vec<f32> = (0..dim).map(|j| (j as f32).sin()).collect();
            let mut want = prior.clone();
            legacy_flush_dense(&models, &weights, &alphas, &mut want);
            // Spread the five uploads over two edges.
            let mut edges = vec![EdgeAccum::new(), EdgeAccum::new()];
            for e in edges.iter_mut() {
                e.reset(dim, false);
            }
            let mut buf = QuantBuf::new();
            for (i, m) in models.iter().enumerate() {
                buf.encode(Precision::F32, m);
                edges[i % 2].fold_dense(&buf, weights[i], alphas[i]);
            }
            let mut got = prior.clone();
            combine_edges(&edges, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "edge {a} vs legacy {b}");
            }
        }
    }

    #[test]
    fn edge_combine_sparse_matches_scatter_reference() {
        // Two sparse uploads over dim 4 on separate edges, ᾱ = 0.5:
        // compare against aggregate_sparse_payloads with the legacy
        // pre-normalized weights and self-weight 1−ᾱ.
        let a_params = vec![10.0f32, 20.0, 0.0, 0.0];
        let b_params = vec![0.0f32, 40.0, 30.0, 0.0];
        let base = vec![0.0f32; 4];
        let mut sa = SparseDelta::new();
        let mut sb = SparseDelta::new();
        sa.encode_topk(Precision::F32, &a_params, &base, None, 2);
        sb.encode_topk(Precision::F32, &b_params, &base, None, 2);
        let (wa, wb) = (1.0f64, 3.0);
        let abar = 0.5f64;
        let mut want = vec![1.0f32, 1.0, 1.0, 1.0];
        let norm: Vec<f64> = vec![abar * wa / (wa + wb), abar * wb / (wa + wb)];
        let mut agg = Aggregator::new();
        agg.aggregate_sparse_payloads(
            &[sa.clone(), sb.clone()],
            &norm,
            1.0 - abar,
            &mut want,
        );
        let mut edges = vec![EdgeAccum::new(), EdgeAccum::new()];
        for e in edges.iter_mut() {
            e.reset(4, true);
        }
        edges[0].fold_sparse(&sa, wa, abar);
        edges[1].fold_sparse(&sb, wb, abar);
        let mut got = vec![1.0f32, 1.0, 1.0, 1.0];
        combine_edges(&edges, &mut got);
        for (x, y) in got.iter().zip(&want) {
            assert!((x - y).abs() < 1e-5, "edge {x} vs reference {y}");
        }
        assert_eq!(got[3], 1.0, "untransmitted coordinate must not move");
    }

    #[test]
    fn edge_combine_skips_empty_edges() {
        let m = vec![2.0f32, 4.0];
        let mut buf = QuantBuf::new();
        buf.encode(Precision::F32, &m);
        let mut edges = vec![EdgeAccum::new(), EdgeAccum::new(), EdgeAccum::new()];
        for e in edges.iter_mut() {
            e.reset(2, false);
        }
        edges[1].fold_dense(&buf, 5.0, 1.0);
        assert!(edges[0].is_empty() && !edges[1].is_empty());
        let mut out = vec![0.0f32; 2];
        combine_edges(&edges, &mut out);
        assert_eq!(out, vec![2.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "empty flush window")]
    fn edge_combine_empty_window_panics() {
        let mut e = EdgeAccum::new();
        e.reset(2, false);
        let mut out = vec![0.0f32; 2];
        combine_edges(&[e], &mut out);
    }

    const TRIM0: RobustSpec = RobustSpec { mode: RobustMode::TrimmedMean, trim: 0.0 };

    #[test]
    fn robust_trim0_dense_matches_plain_bitwise() {
        let mut rng = crate::util::rng::Rng::new(77);
        let dim = 67;
        let models: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let weights = [2.0f64, 5.0, 1.0, 3.0];
        let mut bufs: Vec<QuantBuf> = vec![QuantBuf::new(); 4];
        for (b, m) in bufs.iter_mut().zip(&models) {
            b.encode(Precision::F32, m);
        }
        let mut agg = Aggregator::new();
        // No prior: robust(prior = 0) vs plain.
        let mut want = vec![0.0f32; dim];
        agg.aggregate_payloads(&bufs, &weights, &mut want);
        let mut got = vec![0.0f32; dim];
        let mut outliers = vec![0u64; 4];
        agg.aggregate_payloads_robust(&bufs, &weights, 0.0, TRIM0, &mut got, &mut outliers);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(outliers, vec![0; 4], "trim = 0 must never count outliers");
        // With a prior: plain path folds the prior in as a trailing F32
        // payload slot; the robust path takes it as prior_weight.
        let prior: Vec<f32> = (0..dim).map(|j| (j as f32).cos()).collect();
        let mut with_slot = bufs.clone();
        let mut slot = QuantBuf::new();
        slot.encode(Precision::F32, &prior);
        with_slot.push(slot);
        let mut w_slot = weights.to_vec();
        w_slot.push(0.75);
        let mut want = vec![0.0f32; dim];
        agg.aggregate_payloads(&with_slot, &w_slot, &mut want);
        let mut got = prior.clone();
        agg.aggregate_payloads_robust(&bufs, &weights, 0.75, TRIM0, &mut got, &mut outliers);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn robust_trim0_sparse_matches_plain_bitwise() {
        let mut rng = crate::util::rng::Rng::new(78);
        let dim = 61;
        let base = vec![0.0f32; dim];
        let mut payloads: Vec<SparseDelta> = Vec::new();
        for _ in 0..4 {
            let m: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
            let mut sd = SparseDelta::new();
            sd.encode_topk(Precision::F32, &m, &base, None, dim / 3);
            payloads.push(sd);
        }
        let weights = [1.0f64, 4.0, 2.0, 3.0];
        let prior: Vec<f32> = (0..dim).map(|j| (j as f32).sin()).collect();
        let mut agg = Aggregator::new();
        for self_weight in [0.0f64, 0.5] {
            let mut want = prior.clone();
            agg.aggregate_sparse_payloads(&payloads, &weights, self_weight, &mut want);
            let mut got = prior.clone();
            let mut outliers = vec![0u64; 4];
            agg.aggregate_sparse_payloads_robust(
                &payloads,
                &weights,
                self_weight,
                TRIM0,
                &mut got,
                &mut outliers,
            );
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "self_weight {self_weight}");
            }
            assert_eq!(outliers, vec![0; 4]);
        }
    }

    #[test]
    fn trimmed_mean_drops_extremes_and_counts_them() {
        // Five single-coordinate payloads [0, 1, 2, 3, 100], equal weight,
        // trim 0.25 -> t = floor(1.25) = 1: drop 0 and 100, mean of 1,2,3.
        let mut bufs: Vec<QuantBuf> = Vec::new();
        for v in [0.0f32, 1.0, 2.0, 3.0, 100.0] {
            let mut b = QuantBuf::new();
            b.encode(Precision::F32, &[v]);
            bufs.push(b);
        }
        let weights = [1.0f64; 5];
        let spec = RobustSpec { mode: RobustMode::TrimmedMean, trim: 0.25 };
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32; 1];
        let mut outliers = vec![0u64; 5];
        agg.aggregate_payloads_robust(&bufs, &weights, 0.0, spec, &mut out, &mut outliers);
        assert!((out[0] - 2.0).abs() < 1e-6, "{}", out[0]);
        assert_eq!(outliers, vec![1, 0, 0, 0, 1]);
    }

    #[test]
    fn trimmed_mean_prior_lane_is_trimmable_but_uncounted() {
        // Payload lanes 5 and 6, prior 100 at weight 1, trim 0.34 over
        // three lanes -> t = 1: drops 5 (payload 0, counted) and the prior
        // (never counted); the survivor 6 carries the full mass.
        let mut bufs: Vec<QuantBuf> = Vec::new();
        for v in [5.0f32, 6.0] {
            let mut b = QuantBuf::new();
            b.encode(Precision::F32, &[v]);
            bufs.push(b);
        }
        let spec = RobustSpec { mode: RobustMode::TrimmedMean, trim: 0.34 };
        let mut agg = Aggregator::new();
        let mut out = vec![100.0f32];
        let mut outliers = vec![0u64; 2];
        agg.aggregate_payloads_robust(&bufs, &[1.0, 1.0], 1.0, spec, &mut out, &mut outliers);
        assert!((out[0] - 6.0).abs() < 1e-6, "{}", out[0]);
        assert_eq!(outliers, vec![1, 0]);
    }

    #[test]
    fn median_returns_weighted_lower_median() {
        let mut bufs: Vec<QuantBuf> = Vec::new();
        for v in [0.0f32, 10.0, 100.0] {
            let mut b = QuantBuf::new();
            b.encode(Precision::F32, &[v]);
            bufs.push(b);
        }
        let spec = RobustSpec { mode: RobustMode::Median, trim: 0.0 };
        let mut agg = Aggregator::new();
        // Equal weights: cumulative mass reaches 1.5 at the middle lane.
        let mut out = vec![0.0f32];
        let mut outliers = vec![0u64; 3];
        agg.aggregate_payloads_robust(&bufs, &[1.0; 3], 0.0, spec, &mut out, &mut outliers);
        assert_eq!(out[0], 10.0);
        assert_eq!(outliers, vec![1, 0, 1], "extreme ranks are the deviation signal");
        // Skewed weights: the heavy smallest lane alone crosses half mass.
        agg.aggregate_payloads_robust(&bufs, &[5.0, 1.0, 1.0], 0.0, spec, &mut out, &mut outliers);
        assert_eq!(out[0], 0.0);
    }

    #[test]
    fn robust_sparse_is_thread_count_invariant() {
        let mut rng = crate::util::rng::Rng::new(79);
        let dim = 1201;
        let base = vec![0.0f32; dim];
        let mut payloads: Vec<SparseDelta> = Vec::new();
        for _ in 0..6 {
            let m: Vec<f32> = (0..dim).map(|_| rng.gauss() as f32).collect();
            let mut sd = SparseDelta::new();
            sd.encode_topk(Precision::F16, &m, &base, None, dim / 2);
            payloads.push(sd);
        }
        let weights = [1.0f64, 2.0, 3.0, 1.5, 2.5, 0.5];
        let prior: Vec<f32> = (0..dim).map(|j| (j as f32 * 0.01).sin()).collect();
        for mode in [RobustMode::TrimmedMean, RobustMode::Median] {
            let spec = RobustSpec { mode, trim: 0.25 };
            let mut agg = Aggregator::new();
            let mut want = prior.clone();
            let mut want_outliers = vec![0u64; 6];
            agg.aggregate_sparse_payloads_robust_t(
                &payloads,
                &weights,
                0.5,
                spec,
                &mut want,
                &mut want_outliers,
                1,
            );
            for threads in [2usize, 4, 7] {
                let mut got = prior.clone();
                let mut outliers = vec![0u64; 6];
                agg.aggregate_sparse_payloads_robust_t(
                    &payloads,
                    &weights,
                    0.5,
                    spec,
                    &mut got,
                    &mut outliers,
                    threads,
                );
                for (a, b) in got.iter().zip(&want) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?} threads {threads}");
                }
                assert_eq!(outliers, want_outliers, "{mode:?} threads {threads}");
            }
            assert!(
                want_outliers.iter().sum::<u64>() > 0,
                "{mode:?}: expected some outlier attribution on random lanes"
            );
        }
    }

    #[test]
    fn robust_dense_is_thread_count_invariant() {
        let mut rng = crate::util::rng::Rng::new(80);
        let dim = 997;
        let models: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..dim).map(|_| rng.gauss() as f32).collect())
            .collect();
        let weights = [2.0f64, 1.0, 3.0, 2.0, 1.0];
        let mut bufs: Vec<QuantBuf> = vec![QuantBuf::new(); 5];
        for (b, m) in bufs.iter_mut().zip(&models) {
            b.encode(Precision::Int8, m);
        }
        let prior: Vec<f32> = (0..dim).map(|j| (j as f32 * 0.02).cos()).collect();
        let spec = RobustSpec { mode: RobustMode::TrimmedMean, trim: 0.2 };
        let mut agg = Aggregator::new();
        let mut want = prior.clone();
        let mut want_outliers = vec![0u64; 5];
        agg.aggregate_payloads_robust_t(
            &bufs,
            &weights,
            0.25,
            spec,
            &mut want,
            &mut want_outliers,
            1,
        );
        for threads in [3usize, 8] {
            let mut got = prior.clone();
            let mut outliers = vec![0u64; 5];
            agg.aggregate_payloads_robust_t(
                &bufs,
                &weights,
                0.25,
                spec,
                &mut got,
                &mut outliers,
                threads,
            );
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads {threads}");
            }
            assert_eq!(outliers, want_outliers, "threads {threads}");
        }
    }

    #[test]
    #[should_panic(expected = "RobustMode::None")]
    fn robust_mode_none_panics() {
        let mut b = QuantBuf::new();
        b.encode(Precision::F32, &[1.0]);
        let spec = RobustSpec { mode: RobustMode::None, trim: 0.0 };
        let mut agg = Aggregator::new();
        let mut out = vec![0.0f32];
        let mut outliers = vec![0u64];
        agg.aggregate_payloads_robust(&[b], &[1.0], 0.0, spec, &mut out, &mut outliers);
    }
}
