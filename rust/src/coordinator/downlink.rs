//! Server-side downlink compressor: sparse broadcasts against
//! per-client acked bases (the mirror image of the sparse upload path).
//!
//! QAFeL-style bidirectional compression (PAPERS.md, arXiv 2206.10032)
//! needs the server to know exactly what model each client last held:
//! the broadcast then ships only the top-k coordinates of
//! `global − base` and the client rebuilds the new global on top of the
//! base it acked. This module keeps that hidden state — one
//! [`DownlinkSlot`] (last-acked base + error-feedback residual) per
//! *active* client — and reuses the upload path's [`SparseDelta`] wire
//! format, so at `k == dim` the frame is byte- and bit-identical to the
//! dense broadcast.
//!
//! Invariants the engines rely on:
//!
//! * A client with no slot (first contact, or freshly hydrated from the
//!   parked set) **must** receive a dense frame: [`Downlink::encode_for`]
//!   returns `None` and the caller ships the full model, then records the
//!   new shared base with [`Downlink::ack_dense`]. A sparse delta against
//!   a base the client never acked would silently diverge the fleet.
//! * After a sparse encode the slot's base is advanced by scattering the
//!   *decoded* transmitted values — exactly the computation the client
//!   performs in `fleet::Client::sync_sparse` — so server and client
//!   bases stay bitwise identical at every precision.
//! * Parking a client drops its slot ([`Downlink::drop_client`]): the
//!   parked record keeps only a summarized upload residual (a full base
//!   would be ~`4·dim` bytes per parked client, defeating fleet
//!   virtualization), so re-entry always pays one dense frame.
//!
//! The encoder accumulates the selection-key mass it transmitted and
//! left behind (drained by [`Downlink::take_mass`]) so the control
//! plane's compression controller can drive `down_k_fraction` from the
//! downlink residual ratio, symmetrically to the uplink knob.

use crate::model::quant::Precision;
use crate::model::sparse::SparseDelta;
use crate::util::codec::{Dec, Enc};
use anyhow::Result;

/// Per-client downlink state: the model the client last acked and the
/// server-side error-feedback residual for this client's broadcasts.
struct DownlinkSlot {
    base: Vec<f32>,
    residual: Vec<f32>,
}

/// Server-side downlink compressor state for one engine.
pub struct Downlink {
    precision: Precision,
    error_feedback: bool,
    /// Indexed by client id; `None` until the client acks a dense frame.
    /// Boxed so the idle entries of a virtualized fleet cost one pointer.
    slots: Vec<Option<Box<DownlinkSlot>>>,
    /// Reusable encode buffer (steady-state encodes allocate nothing).
    delta: SparseDelta,
    /// Selection-key mass left untransmitted / transmitted since the
    /// last [`Downlink::take_mass`] drain.
    residual_l1: f64,
    transmitted_l1: f64,
    /// Lifetime counters (diagnostics/tests).
    forced_dense: u64,
    sparse_syncs: u64,
}

impl Downlink {
    pub fn new(num_clients: usize, precision: Precision, error_feedback: bool) -> Self {
        let mut slots = Vec::with_capacity(num_clients);
        slots.resize_with(num_clients, || None);
        Downlink {
            precision,
            error_feedback,
            slots,
            delta: SparseDelta::new(),
            residual_l1: 0.0,
            transmitted_l1: 0.0,
            forced_dense: 0,
            sparse_syncs: 0,
        }
    }

    /// Whether `client` holds an acked base a sparse delta can build on.
    pub fn has_base(&self, client: usize) -> bool {
        self.slots.get(client).is_some_and(|s| s.is_some())
    }

    /// The base `client` last acked (tests/debug assertions).
    pub fn base_of(&self, client: usize) -> Option<&[f32]> {
        self.slots.get(client)?.as_ref().map(|s| s.base.as_slice())
    }

    /// Encode the top-`k` sparse broadcast `model − base` for `client`,
    /// advance the slot's base to the decoded post-sync model, and
    /// return the frame. `None` when the client holds no acked base —
    /// the caller must ship a dense frame and [`Downlink::ack_dense`] it.
    pub fn encode_for(&mut self, client: usize, model: &[f32], k: usize) -> Option<&SparseDelta> {
        let slot = self.slots.get_mut(client)?.as_deref_mut()?;
        debug_assert_eq!(slot.base.len(), model.len(), "downlink base/model length mismatch");
        let residual = self.error_feedback.then_some(&mut slot.residual[..]);
        self.delta.encode_topk(self.precision, model, &slot.base, residual, k);
        let sent = self.delta.sent_key_l1();
        self.residual_l1 += self.delta.key_l1() - sent;
        self.transmitted_l1 += sent;
        // Server-side replay of the client's apply: overwrite the
        // transmitted coordinates with their *decoded* values.
        self.delta.scatter_into(&mut slot.base);
        self.sparse_syncs += 1;
        Some(&self.delta)
    }

    /// Record that `client` just received (and therefore acked) the full
    /// dense model `decoded` — the broadcast bytes as the client decodes
    /// them, not the raw f32 global. Creates the slot on first contact;
    /// resets the error-feedback residual either way (a dense frame
    /// clears all downlink debt).
    pub fn ack_dense(&mut self, client: usize, decoded: &[f32]) {
        if client >= self.slots.len() {
            self.slots.resize_with(client + 1, || None);
        }
        self.forced_dense += 1;
        match &mut self.slots[client] {
            Some(slot) => {
                slot.base.copy_from_slice(decoded);
                slot.residual.iter_mut().for_each(|r| *r = 0.0);
            }
            empty => {
                *empty = Some(Box::new(DownlinkSlot {
                    base: decoded.to_vec(),
                    residual: vec![0.0; decoded.len()],
                }));
            }
        }
    }

    /// Forget `client`'s base (active-set rotation parks it); its next
    /// sync is forced dense.
    pub fn drop_client(&mut self, client: usize) {
        if let Some(slot) = self.slots.get_mut(client) {
            *slot = None;
        }
    }

    /// Drain the accumulated (residual, transmitted) selection-key mass
    /// since the previous drain — the downlink analogue of the uplink's
    /// per-flush residual telemetry.
    pub fn take_mass(&mut self) -> (f64, f64) {
        let out = (self.residual_l1, self.transmitted_l1);
        self.residual_l1 = 0.0;
        self.transmitted_l1 = 0.0;
        out
    }

    /// Dense frames shipped because no acked base existed (plus explicit
    /// dense-mode acks routed through [`Downlink::ack_dense`]).
    pub fn forced_dense(&self) -> u64 {
        self.forced_dense
    }

    /// Sparse frames encoded over the lifetime of this compressor.
    pub fn sparse_syncs(&self) -> u64 {
        self.sparse_syncs
    }

    /// Whether `client`'s acked base is bitwise identical to `expected`
    /// — the runtime form of the engines' base-agreement `debug_assert`,
    /// promoted to a recoverable check when fault injection is armed (a
    /// mismatch routes the client through a forced dense re-sync instead
    /// of silently diverging the fleet).
    pub fn base_matches(&self, client: usize, expected: &[f32]) -> bool {
        match self.base_of(client) {
            Some(base) => {
                base.len() == expected.len()
                    && base.iter().zip(expected).all(|(a, b)| a.to_bits() == b.to_bits())
            }
            None => false,
        }
    }

    /// Serialize the compressor's mutable state (slots + mass + lifetime
    /// counters) for a checkpoint. Precision and error-feedback mode are
    /// config-derived and rebuilt at restore.
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.slots.len());
        for slot in &self.slots {
            match slot {
                Some(s) => {
                    enc.bool(true);
                    enc.f32s(&s.base);
                    enc.f32s(&s.residual);
                }
                None => enc.bool(false),
            }
        }
        enc.f64(self.residual_l1);
        enc.f64(self.transmitted_l1);
        enc.u64(self.forced_dense);
        enc.u64(self.sparse_syncs);
    }

    /// Restore the state saved by [`Downlink::save`].
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        let n = dec.usize()?;
        self.slots.clear();
        self.slots.reserve(n);
        for _ in 0..n {
            self.slots.push(if dec.bool()? {
                Some(Box::new(DownlinkSlot { base: dec.f32s()?, residual: dec.f32s()? }))
            } else {
                None
            });
        }
        self.residual_l1 = dec.f64()?;
        self.transmitted_l1 = dec.f64()?;
        self.forced_dense = dec.u64()?;
        self.sparse_syncs = dec.u64()?;
        Ok(())
    }

    /// Approximate heap footprint of the live slots (capacity planning,
    /// mirrors `Fleet::approx_parked_bytes`).
    pub fn approx_bytes(&self) -> u64 {
        self.slots
            .iter()
            .flatten()
            .map(|s| 4 * (s.base.len() + s.residual.len()) as u64)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::sparse::sparse_payload_bytes;

    fn model(n: usize, seed: f32) -> Vec<f32> {
        (0..n).map(|i| seed + i as f32 * 0.25).collect()
    }

    #[test]
    fn no_base_forces_dense_until_acked() {
        let mut dl = Downlink::new(2, Precision::F32, true);
        assert!(!dl.has_base(0));
        assert!(dl.encode_for(0, &model(8, 1.0), 4).is_none());
        dl.ack_dense(0, &model(8, 1.0));
        assert!(dl.has_base(0));
        assert_eq!(dl.forced_dense(), 1);
        assert!(dl.encode_for(0, &model(8, 2.0), 4).is_some());
        assert_eq!(dl.sparse_syncs(), 1);
        // The other client is untouched.
        assert!(!dl.has_base(1));
    }

    #[test]
    fn sparse_encode_advances_base_to_client_view() {
        let n = 16;
        let mut dl = Downlink::new(1, Precision::F32, true);
        let base = model(n, 0.0);
        dl.ack_dense(0, &base);
        let global = model(n, 3.0);
        // Client-side replay: base with the transmitted coords overwritten.
        let mut client = base.clone();
        {
            let delta = dl.encode_for(0, &global, 4).unwrap();
            assert_eq!(delta.len(), 4);
            assert_eq!(delta.payload_bytes(), sparse_payload_bytes(Precision::F32, 4, n));
            delta.scatter_into(&mut client);
        }
        assert_eq!(dl.base_of(0).unwrap(), &client[..]);
        // At full k the frame carries the whole decoded model and the
        // base converges to it exactly.
        dl.encode_for(0, &global, n).unwrap();
        assert_eq!(dl.base_of(0).unwrap(), &global[..]);
    }

    #[test]
    fn drop_client_forces_dense_reentry() {
        let mut dl = Downlink::new(3, Precision::F32, false);
        dl.ack_dense(2, &model(4, 1.0));
        assert!(dl.has_base(2));
        dl.drop_client(2);
        assert!(!dl.has_base(2));
        assert!(dl.encode_for(2, &model(4, 2.0), 2).is_none());
        // Re-ack resurrects the slot.
        dl.ack_dense(2, &model(4, 2.0));
        assert!(dl.encode_for(2, &model(4, 3.0), 2).is_some());
    }

    #[test]
    fn error_feedback_accumulates_and_dense_ack_clears_it() {
        let mut ef = Downlink::new(1, Precision::F32, true);
        let mut no_ef = Downlink::new(1, Precision::F32, false);
        ef.ack_dense(0, &vec![0.0; 4]);
        no_ef.ack_dense(0, &vec![0.0; 4]);
        // Two rounds with a budget of 1. Round 1 ships coord 0 either
        // way; with EF coord 1's unsent 0.9 carries as debt. Round 2's
        // raw deltas are [2.0, 1.5, ...] (coord 0 still loudest) but the
        // EF key for coord 1 is 1.5 + 0.9 = 2.4, flipping the selection.
        let g1 = vec![1.0f32, 0.9, 0.0, 0.0];
        let g2 = vec![3.0f32, 1.5, 0.0, 0.0];
        assert_eq!(ef.encode_for(0, &g1, 1).unwrap().indices(), &[0]);
        assert_eq!(no_ef.encode_for(0, &g1, 1).unwrap().indices(), &[0]);
        let (r_ef, t_ef) = ef.take_mass();
        assert!(r_ef > 0.0 && t_ef > 0.0);
        assert_eq!(ef.encode_for(0, &g2, 1).unwrap().indices(), &[1]);
        assert_eq!(no_ef.encode_for(0, &g2, 1).unwrap().indices(), &[0]);
        // A dense ack clears all downlink debt.
        ef.ack_dense(0, &g2);
        ef.take_mass();
        ef.encode_for(0, &g2, 1).unwrap();
        assert_eq!(ef.take_mass(), (0.0, 0.0), "zero delta after dense ack");
    }

    #[test]
    fn mass_drain_resets_counters() {
        let mut dl = Downlink::new(1, Precision::F32, true);
        dl.ack_dense(0, &vec![0.0; 4]);
        dl.encode_for(0, &model(4, 1.0), 2).unwrap();
        let (r, t) = dl.take_mass();
        assert!(r > 0.0 && t > 0.0);
        assert_eq!(dl.take_mass(), (0.0, 0.0));
    }

    #[test]
    fn base_matches_is_bitwise() {
        let mut dl = Downlink::new(2, Precision::F32, true);
        let m = model(4, 1.0);
        assert!(!dl.base_matches(0, &m), "no slot, no agreement");
        dl.ack_dense(0, &m);
        assert!(dl.base_matches(0, &m));
        let mut off = m.clone();
        off[2] += 1e-6;
        assert!(!dl.base_matches(0, &off));
        assert!(!dl.base_matches(0, &m[..3]));
    }

    #[test]
    fn save_load_round_trips_slots_and_counters() {
        let mut dl = Downlink::new(3, Precision::F32, true);
        dl.ack_dense(0, &model(6, 1.0));
        dl.ack_dense(2, &model(6, 2.0));
        dl.encode_for(0, &model(6, 3.0), 2).unwrap();
        let mut enc = Enc::new();
        dl.save(&mut enc);
        let bytes = enc.into_bytes();

        let mut dl2 = Downlink::new(3, Precision::F32, true);
        let mut dec = Dec::new(&bytes);
        dl2.load(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(dl2.base_of(0).unwrap(), dl.base_of(0).unwrap());
        assert_eq!(dl2.base_of(2).unwrap(), dl.base_of(2).unwrap());
        assert!(!dl2.has_base(1));
        assert_eq!(dl2.forced_dense(), dl.forced_dense());
        assert_eq!(dl2.sparse_syncs(), dl.sparse_syncs());
        // Undrained mass survives the round trip bit-exactly...
        assert_eq!(dl2.take_mass(), dl.take_mass());
        // ...and subsequent encodes stay bitwise identical.
        let g = model(6, 4.0);
        let a = dl.encode_for(0, &g, 2).unwrap().checksum();
        let b = dl2.encode_for(0, &g, 2).unwrap().checksum();
        assert_eq!(a, b);
        assert_eq!(dl.base_of(0).unwrap(), dl2.base_of(0).unwrap());
    }

    #[test]
    fn approx_bytes_tracks_live_slots() {
        let mut dl = Downlink::new(4, Precision::F32, true);
        assert_eq!(dl.approx_bytes(), 0);
        dl.ack_dense(0, &vec![0.0; 10]);
        dl.ack_dense(3, &vec![0.0; 10]);
        assert_eq!(dl.approx_bytes(), 2 * 4 * 20);
        dl.drop_client(0);
        assert_eq!(dl.approx_bytes(), 4 * 20);
    }
}
