//! L3 coordinator — the paper's system contribution.
//!
//! * [`policy`] — the upload-gating policies: AFL (upload always), VAFL
//!   (Eq. 1–2 communication-value gate), EAFLM (Eq. 3 gradient gate).
//! * [`aggregate`] — FedAvg weighted aggregation (Algorithm 1 line 16).
//! * [`downlink`] — server-side sparse broadcast compressor: per-client
//!   acked bases + error-feedback residuals (bidirectional compression).
//! * [`staleness`] — `alpha(tau)` mixing rules for on-arrival aggregation.
//! * [`server`] — the round engines orchestrating the fleet, the network
//!   simulator, the virtual clock, and the metrics stack: the paper's
//!   barriered round loop and the barrier-free event-driven engine.

pub mod aggregate;
pub mod downlink;
pub mod policy;
pub mod registry;
pub mod server;
pub mod staleness;

pub use downlink::Downlink;
pub use policy::{AflPolicy, EaflmPolicy, SelectionPolicy, VaflPolicy};
pub use registry::{ClientRegistry, DropoutModel};
pub use server::{Server, ServerContext};
pub use staleness::MixingRule;
