//! Upload-gating policies: which clients the server asks for a model
//! upload after seeing the round's [`ClientReport`]s.
//!
//! * [`AflPolicy`] — plain asynchronous FedAvg: everyone uploads (the
//!   paper's "ordinary asynchronous training" baseline; CCR = 0 by
//!   definition).
//! * [`VaflPolicy`] — the paper's contribution (Eq. 1–2): amplify each
//!   client's raw gradient-change norm into V_i, upload iff V_i >= mean V.
//! * [`EaflmPolicy`] — Lu et al.'s gate (paper Eq. 3): a client is "lazy"
//!   (skipped) when its gradient norm falls below a threshold driven by
//!   the recent movement of the global model.

use crate::config::{Algorithm, EaflmParams, ValueFnConfig};
use crate::fleet::{amplify_value, ClientReport};

/// Context the server exposes to a policy at selection time.
pub struct PolicyContext<'a> {
    pub round: usize,
    pub n_clients: usize,
    /// Global parameter history, most recent last (theta^{t}, theta^{t-1},
    /// ... as far back as the policy asked for).
    pub global_history: &'a [Vec<f32>],
}

/// Decision for one round.
#[derive(Debug, Clone)]
pub struct Selection {
    /// `selected[i]` — upload requested from reports[i]'s client.
    pub selected: Vec<bool>,
    /// The effective values the decision used (diagnostics: Fig. 5 / logs).
    pub values: Vec<f64>,
    /// The threshold the policy applied (mean-V for VAFL, Eq. 3 RHS for
    /// EAFLM, 0 for AFL).
    pub threshold: f64,
}

/// An upload-gating policy (the paper's pluggable contribution point).
pub trait SelectionPolicy {
    fn name(&self) -> &'static str;

    /// How many recent global models the policy needs (server keeps a
    /// bounded history).
    fn history_depth(&self) -> usize {
        0
    }

    /// Decide which of this round's reporters upload their model.
    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection;
}

/// Build the policy for an [`Algorithm`].
pub fn make_policy(
    algorithm: Algorithm,
    value_cfg: ValueFnConfig,
    eaflm: EaflmParams,
) -> Box<dyn SelectionPolicy> {
    match algorithm {
        Algorithm::Afl => Box::new(AflPolicy),
        Algorithm::Vafl => Box::new(VaflPolicy { value_cfg }),
        Algorithm::Eaflm => Box::new(EaflmPolicy { params: eaflm }),
    }
}

/// Plain async FedAvg: every reporter uploads.
pub struct AflPolicy;

impl SelectionPolicy for AflPolicy {
    fn name(&self) -> &'static str {
        "afl"
    }

    fn select(&mut self, reports: &[ClientReport], _ctx: &PolicyContext<'_>) -> Selection {
        Selection {
            selected: vec![true; reports.len()],
            values: reports.iter().map(|r| r.value).collect(),
            threshold: 0.0,
        }
    }
}

/// VAFL (paper Eq. 1–2): V_i = raw_i * (1 + N/1e3)^{Acc_i}; upload iff
/// V_i >= mean(V).
pub struct VaflPolicy {
    pub value_cfg: ValueFnConfig,
}

impl SelectionPolicy for VaflPolicy {
    fn name(&self) -> &'static str {
        "vafl"
    }

    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection {
        // Non-finite raw values (a diverged or corrupt client) carry zero
        // communication value rather than poisoning the mean.
        let values: Vec<f64> = reports
            .iter()
            .map(|r| {
                let v = amplify_value(r.value, r.acc, ctx.n_clients, self.value_cfg);
                if v.is_finite() {
                    v
                } else {
                    0.0
                }
            })
            .collect();
        // Paper Eq. 2: V_i >= (sum_j V_j) / N. N is the fleet size; when
        // every client reports each round (this engine), it equals the
        // report count.
        let mean = values.iter().sum::<f64>() / ctx.n_clients as f64;
        Selection {
            selected: values.iter().map(|&v| v >= mean).collect(),
            values,
            threshold: mean,
        }
    }
}

/// EAFLM (paper Eq. 3, §IV-D): skip client i when
/// `||grad_i||^2 <= (1/(alpha^2 * beta * m^2)) * ||sum_d xi_d (theta^{k-d} -
/// theta^{k-1-d})||^2` with xi_d = 1/D. With D = 1 the RHS reduces to the
/// squared norm of the last global step, scaled.
pub struct EaflmPolicy {
    pub params: EaflmParams,
}

impl SelectionPolicy for EaflmPolicy {
    fn name(&self) -> &'static str {
        "eaflm"
    }

    fn history_depth(&self) -> usize {
        self.params.depth + 1
    }

    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection {
        let m = ctx.n_clients as f64;
        let a2bm2 = self.params.alpha * self.params.alpha * self.params.beta * m * m;
        // RHS: || sum_{d=1..D} xi_d (theta^{k-d} - theta^{k-1-d}) ||^2.
        let hist = ctx.global_history;
        let threshold = if hist.len() < 2 {
            // No movement history yet: no client is considered lazy.
            0.0
        } else {
            let depth = self.params.depth.min(hist.len() - 1);
            let xi = 1.0 / depth as f64;
            let dim = hist[0].len();
            let mut combo = vec![0.0f64; dim];
            for d in 1..=depth {
                let newer = &hist[hist.len() - d];
                let older = &hist[hist.len() - d - 1];
                for ((c, &a), &b) in combo.iter_mut().zip(newer).zip(older) {
                    *c += xi * (a as f64 - b as f64);
                }
            }
            let norm_sq: f64 = combo.iter().map(|&v| v * v).sum();
            norm_sq / a2bm2
        };
        let selected: Vec<bool> = reports
            .iter()
            .map(|r| r.grad_norm_sq > threshold)
            .collect();
        Selection {
            selected,
            values: reports.iter().map(|r| r.grad_norm_sq).collect(),
            threshold,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(id: usize, value: f64, acc: f64, grad_norm_sq: f64) -> ClientReport {
        ClientReport {
            client_id: id,
            round: 1,
            value,
            acc,
            grad_norm_sq,
            train_loss: 1.0,
            num_samples: 100,
            compute_seconds: 1.0,
        }
    }

    #[test]
    fn afl_selects_everyone() {
        let reports = vec![report(0, 0.0, 0.0, 0.0), report(1, 9.0, 0.9, 9.0)];
        let ctx = PolicyContext { round: 1, n_clients: 2, global_history: &[] };
        let s = AflPolicy.select(&reports, &ctx);
        assert_eq!(s.selected, vec![true, true]);
    }

    #[test]
    fn vafl_gates_on_mean() {
        // values 1, 2, 9 -> mean 4 -> only the 9 uploads.
        let reports = vec![
            report(0, 1.0, 0.0, 0.0),
            report(1, 2.0, 0.0, 0.0),
            report(2, 9.0, 0.0, 0.0),
        ];
        let ctx = PolicyContext { round: 1, n_clients: 3, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![false, false, true]);
        assert!((s.threshold - 4.0).abs() < 1e-12);
    }

    #[test]
    fn vafl_acc_term_boosts_accurate_clients() {
        // Same raw value; the accurate client's amplified V must exceed the
        // inaccurate one's.
        let reports = vec![report(0, 1.0, 0.99, 0.0), report(1, 1.0, 0.01, 0.0)];
        let ctx = PolicyContext { round: 1, n_clients: 500, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert!(s.values[0] > s.values[1]);
    }

    #[test]
    fn eaflm_first_rounds_select_all() {
        let reports = vec![report(0, 0.0, 0.0, 1e-9), report(1, 0.0, 0.0, 5.0)];
        let ctx = PolicyContext { round: 1, n_clients: 2, global_history: &[] };
        let mut p = EaflmPolicy { params: EaflmParams::default() };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![true, true]);
    }

    #[test]
    fn eaflm_skips_lazy_clients_once_history_exists() {
        // Global step of norm 2 (per dim 1.0 over 4 dims) with beta pinned
        // to 1: threshold = 4 / (0.98^2 * 1 * 4) ≈ 1.0412. grad_norm_sq 0.5
        // is lazy, 9 is not. (The crate default beta is the calibrated
        // 0.05 — see DESIGN.md §6 — so pin it here.)
        let h0 = vec![0.0f32; 4];
        let h1 = vec![1.0f32; 4];
        let hist = vec![h0, h1];
        let reports = vec![report(0, 0.0, 0.0, 0.5), report(1, 0.0, 0.0, 9.0)];
        let ctx = PolicyContext { round: 3, n_clients: 2, global_history: &hist };
        let mut p = EaflmPolicy { params: EaflmParams { beta: 1.0, ..Default::default() } };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![false, true]);
        assert!((s.threshold - 4.0 / (0.98f64.powi(2) * 4.0)).abs() < 1e-9);
    }

    #[test]
    fn make_policy_dispatches() {
        let cfg = ValueFnConfig::default();
        let ea = EaflmParams::default();
        assert_eq!(make_policy(Algorithm::Afl, cfg, ea).name(), "afl");
        assert_eq!(make_policy(Algorithm::Vafl, cfg, ea).name(), "vafl");
        assert_eq!(make_policy(Algorithm::Eaflm, cfg, ea).name(), "eaflm");
    }
}
