//! Upload-gating policies: which clients the server asks for a model
//! upload after seeing the round's [`ClientReport`]s.
//!
//! * [`AflPolicy`] — plain asynchronous FedAvg: everyone uploads (the
//!   paper's "ordinary asynchronous training" baseline; CCR = 0 by
//!   definition).
//! * [`VaflPolicy`] — the paper's contribution (Eq. 1–2): amplify each
//!   client's raw gradient-change norm into V_i, upload iff V_i >= mean V.
//! * [`EaflmPolicy`] — Lu et al.'s gate (paper Eq. 3): a client is "lazy"
//!   (skipped) when its gradient norm falls below a threshold driven by
//!   the recent movement of the global model.

use crate::config::{Algorithm, EaflmParams, ValueFnConfig};
use crate::fleet::{amplify_value, ClientReport};

/// Context the server exposes to a policy at selection time.
pub struct PolicyContext<'a> {
    pub round: usize,
    pub n_clients: usize,
    /// Global parameter history, most recent last (theta^{t}, theta^{t-1},
    /// ... as far back as the policy asked for).
    pub global_history: &'a [Vec<f32>],
}

/// Context for the event-driven single-report gate (barrier-free engine):
/// there is no per-round report batch, so the policy sees the fleet's
/// *last-known* values instead.
pub struct AsyncGateContext<'a> {
    pub n_clients: usize,
    /// Most recent effective value per fleet slot (NaN = never reported).
    /// The deciding client's own slot holds its *previous* value; the gate
    /// substitutes the fresh one.
    pub last_values: &'a [f64],
    /// Global parameter history, most recent last.
    pub global_history: &'a [Vec<f32>],
}

/// One report's gate decision in the event-driven engine.
#[derive(Debug, Clone, Copy)]
pub struct GateDecision {
    /// Request a model upload from this client.
    pub upload: bool,
    /// The effective value the decision used (stored as the client's
    /// last-known value).
    pub value: f64,
    /// The threshold applied.
    pub threshold: f64,
}

/// Decision for one round.
#[derive(Debug, Clone)]
pub struct Selection {
    /// `selected[i]` — upload requested from reports[i]'s client.
    pub selected: Vec<bool>,
    /// The effective values the decision used (diagnostics: Fig. 5 / logs).
    pub values: Vec<f64>,
    /// The threshold the policy applied (mean-V for VAFL, Eq. 3 RHS for
    /// EAFLM, 0 for AFL).
    pub threshold: f64,
}

/// An upload-gating policy (the paper's pluggable contribution point).
pub trait SelectionPolicy {
    fn name(&self) -> &'static str;

    /// How many recent global models the policy needs (server keeps a
    /// bounded history).
    fn history_depth(&self) -> usize {
        0
    }

    /// Decide which of this round's reporters upload their model.
    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection;

    /// Decide one report as it arrives (barrier-free engine). The gated
    /// upload set over any event stream is a subset of the report stream
    /// (property-tested in `rust/tests/engine_async.rs`).
    fn gate_report(&mut self, report: &ClientReport, ctx: &AsyncGateContext<'_>) -> GateDecision;
}

/// Build the policy for an [`Algorithm`].
pub fn make_policy(
    algorithm: Algorithm,
    value_cfg: ValueFnConfig,
    eaflm: EaflmParams,
) -> Box<dyn SelectionPolicy> {
    match algorithm {
        Algorithm::Afl => Box::new(AflPolicy),
        Algorithm::Vafl => Box::new(VaflPolicy { value_cfg }),
        Algorithm::Eaflm => Box::new(EaflmPolicy { params: eaflm }),
    }
}

/// Plain async FedAvg: every reporter uploads.
pub struct AflPolicy;

impl SelectionPolicy for AflPolicy {
    fn name(&self) -> &'static str {
        "afl"
    }

    fn select(&mut self, reports: &[ClientReport], _ctx: &PolicyContext<'_>) -> Selection {
        Selection {
            selected: vec![true; reports.len()],
            values: reports.iter().map(|r| r.value).collect(),
            threshold: 0.0,
        }
    }

    fn gate_report(&mut self, report: &ClientReport, _ctx: &AsyncGateContext<'_>) -> GateDecision {
        GateDecision { upload: true, value: report.value, threshold: 0.0 }
    }
}

/// VAFL (paper Eq. 1–2): V_i = raw_i * (1 + N/1e3)^{Acc_i}; upload iff
/// V_i >= mean(V).
pub struct VaflPolicy {
    pub value_cfg: ValueFnConfig,
}

impl SelectionPolicy for VaflPolicy {
    fn name(&self) -> &'static str {
        "vafl"
    }

    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection {
        // Non-finite raw values (a diverged or corrupt client) carry zero
        // communication value rather than poisoning the mean.
        let values: Vec<f64> = reports
            .iter()
            .map(|r| {
                let v = amplify_value(r.value, r.acc, ctx.n_clients, self.value_cfg);
                if v.is_finite() {
                    v
                } else {
                    0.0
                }
            })
            .collect();
        // Paper Eq. 2: V_i >= (sum_j V_j) / N. N is the fleet size; when
        // every client reports each round (this engine), it equals the
        // report count.
        let mean = values.iter().sum::<f64>() / ctx.n_clients as f64;
        Selection {
            selected: values.iter().map(|&v| v >= mean).collect(),
            values,
            threshold: mean,
        }
    }

    fn gate_report(&mut self, report: &ClientReport, ctx: &AsyncGateContext<'_>) -> GateDecision {
        // Eq. 2 against the fleet's last-known values, with this client's
        // slot substituted by its fresh V. Slots that have never reported
        // contribute 0 — early on the threshold is low and everyone
        // communicates, matching the paper's fast initial convergence. The
        // max-valued client always passes (its V bounds the mean), so the
        // event stream can never gate every upload forever.
        let v = {
            let amp = amplify_value(report.value, report.acc, ctx.n_clients, self.value_cfg);
            if amp.is_finite() {
                amp
            } else {
                0.0
            }
        };
        let sum: f64 = ctx
            .last_values
            .iter()
            .enumerate()
            .map(|(i, &lv)| {
                if i == report.client_id {
                    v
                } else if lv.is_finite() {
                    lv
                } else {
                    0.0
                }
            })
            .sum();
        let mean = sum / ctx.n_clients as f64;
        GateDecision { upload: v >= mean, value: v, threshold: mean }
    }
}

/// EAFLM (paper Eq. 3, §IV-D): skip client i when
/// `||grad_i||^2 <= (1/(alpha^2 * beta * m^2)) * ||sum_d xi_d (theta^{k-d} -
/// theta^{k-1-d})||^2` with xi_d = 1/D. With D = 1 the RHS reduces to the
/// squared norm of the last global step, scaled.
pub struct EaflmPolicy {
    pub params: EaflmParams,
}

impl SelectionPolicy for EaflmPolicy {
    fn name(&self) -> &'static str {
        "eaflm"
    }

    fn history_depth(&self) -> usize {
        self.params.depth + 1
    }

    fn select(&mut self, reports: &[ClientReport], ctx: &PolicyContext<'_>) -> Selection {
        let threshold = eaflm_threshold(&self.params, ctx.global_history, ctx.n_clients);
        let selected: Vec<bool> = reports
            .iter()
            .map(|r| r.grad_norm_sq > threshold)
            .collect();
        Selection {
            selected,
            values: reports.iter().map(|r| r.grad_norm_sq).collect(),
            threshold,
        }
    }

    fn gate_report(&mut self, report: &ClientReport, ctx: &AsyncGateContext<'_>) -> GateDecision {
        // Eq. 3 is already a per-client threshold test; the event-driven
        // gate applies it against the history at arrival time.
        let threshold = eaflm_threshold(&self.params, ctx.global_history, ctx.n_clients);
        GateDecision {
            upload: report.grad_norm_sq > threshold,
            value: report.grad_norm_sq,
            threshold,
        }
    }
}

/// Eq. 3 RHS: `|| sum_{d=1..D} xi_d (theta^{k-d} - theta^{k-1-d}) ||^2 /
/// (alpha^2 beta m^2)` with `xi_d = 1/D`. Zero (select everyone) before
/// any movement history exists.
fn eaflm_threshold(params: &EaflmParams, hist: &[Vec<f32>], n_clients: usize) -> f64 {
    let m = n_clients as f64;
    let a2bm2 = params.alpha * params.alpha * params.beta * m * m;
    if hist.len() < 2 {
        return 0.0;
    }
    let depth = params.depth.min(hist.len() - 1);
    let xi = 1.0 / depth as f64;
    let dim = hist[0].len();
    let mut combo = vec![0.0f64; dim];
    for d in 1..=depth {
        let newer = &hist[hist.len() - d];
        let older = &hist[hist.len() - d - 1];
        for ((c, &a), &b) in combo.iter_mut().zip(newer).zip(older) {
            *c += xi * (a as f64 - b as f64);
        }
    }
    let norm_sq: f64 = combo.iter().map(|&v| v * v).sum();
    norm_sq / a2bm2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(id: usize, value: f64, acc: f64, grad_norm_sq: f64) -> ClientReport {
        ClientReport {
            client_id: id,
            round: 1,
            value,
            acc,
            grad_norm_sq,
            train_loss: 1.0,
            num_samples: 100,
            compute_seconds: 1.0,
        }
    }

    #[test]
    fn afl_selects_everyone() {
        let reports = vec![report(0, 0.0, 0.0, 0.0), report(1, 9.0, 0.9, 9.0)];
        let ctx = PolicyContext { round: 1, n_clients: 2, global_history: &[] };
        let s = AflPolicy.select(&reports, &ctx);
        assert_eq!(s.selected, vec![true, true]);
    }

    #[test]
    fn vafl_gates_on_mean() {
        // values 1, 2, 9 -> mean 4 -> only the 9 uploads.
        let reports = vec![
            report(0, 1.0, 0.0, 0.0),
            report(1, 2.0, 0.0, 0.0),
            report(2, 9.0, 0.0, 0.0),
        ];
        let ctx = PolicyContext { round: 1, n_clients: 3, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![false, false, true]);
        assert!((s.threshold - 4.0).abs() < 1e-12);
    }

    #[test]
    fn vafl_acc_term_boosts_accurate_clients() {
        // Same raw value; the accurate client's amplified V must exceed the
        // inaccurate one's.
        let reports = vec![report(0, 1.0, 0.99, 0.0), report(1, 1.0, 0.01, 0.0)];
        let ctx = PolicyContext { round: 1, n_clients: 500, global_history: &[] };
        let mut p = VaflPolicy { value_cfg: ValueFnConfig::default() };
        let s = p.select(&reports, &ctx);
        assert!(s.values[0] > s.values[1]);
    }

    #[test]
    fn eaflm_first_rounds_select_all() {
        let reports = vec![report(0, 0.0, 0.0, 1e-9), report(1, 0.0, 0.0, 5.0)];
        let ctx = PolicyContext { round: 1, n_clients: 2, global_history: &[] };
        let mut p = EaflmPolicy { params: EaflmParams::default() };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![true, true]);
    }

    #[test]
    fn eaflm_skips_lazy_clients_once_history_exists() {
        // Global step of norm 2 (per dim 1.0 over 4 dims) with beta pinned
        // to 1: threshold = 4 / (0.98^2 * 1 * 4) ≈ 1.0412. grad_norm_sq 0.5
        // is lazy, 9 is not. (The crate default beta is the calibrated
        // 0.05 — see DESIGN.md §6 — so pin it here.)
        let h0 = vec![0.0f32; 4];
        let h1 = vec![1.0f32; 4];
        let hist = vec![h0, h1];
        let reports = vec![report(0, 0.0, 0.0, 0.5), report(1, 0.0, 0.0, 9.0)];
        let ctx = PolicyContext { round: 3, n_clients: 2, global_history: &hist };
        let mut p = EaflmPolicy { params: EaflmParams { beta: 1.0, ..Default::default() } };
        let s = p.select(&reports, &ctx);
        assert_eq!(s.selected, vec![false, true]);
        assert!((s.threshold - 4.0 / (0.98f64.powi(2) * 4.0)).abs() < 1e-9);
    }

    #[test]
    fn afl_gate_always_uploads() {
        let ctx = AsyncGateContext { n_clients: 3, last_values: &[f64::NAN; 3], global_history: &[] };
        let d = AflPolicy.gate_report(&report(1, 0.0, 0.0, 0.0), &ctx);
        assert!(d.upload);
        assert_eq!(d.threshold, 0.0);
    }

    #[test]
    fn vafl_gate_uses_last_known_values() {
        let mut p = VaflPolicy { value_cfg: ValueFnConfig { use_acc_term: false } };
        // Fleet of 4; others last reported 8, 8, 8. A fresh value of 2
        // gives mean (8+8+8+2)/4 = 6.5 -> gated out.
        let last = [8.0, 8.0, 8.0, f64::NAN];
        let ctx = AsyncGateContext { n_clients: 4, last_values: &last, global_history: &[] };
        let d = p.gate_report(&report(3, 2.0, 0.0, 0.0), &ctx);
        assert!(!d.upload);
        assert!((d.threshold - 6.5).abs() < 1e-12);
        // A fresh value of 30 clears the mean comfortably.
        let d = p.gate_report(&report(3, 30.0, 0.0, 0.0), &ctx);
        assert!(d.upload);
    }

    #[test]
    fn vafl_gate_never_reported_slots_count_zero() {
        let mut p = VaflPolicy { value_cfg: ValueFnConfig { use_acc_term: false } };
        let last = [f64::NAN; 5];
        let ctx = AsyncGateContext { n_clients: 5, last_values: &last, global_history: &[] };
        // First-ever report: mean = v/5 <= v, so it always uploads.
        let d = p.gate_report(&report(2, 1.0, 0.0, 0.0), &ctx);
        assert!(d.upload);
        assert!((d.threshold - 0.2).abs() < 1e-12);
    }

    #[test]
    fn vafl_gate_max_value_client_always_passes() {
        // Own V >= mean whenever own V is the fleet max (sum <= N * V).
        let mut p = VaflPolicy { value_cfg: ValueFnConfig { use_acc_term: false } };
        let last = [3.0, 7.0, 1.0];
        let ctx = AsyncGateContext { n_clients: 3, last_values: &last, global_history: &[] };
        let d = p.gate_report(&report(2, 7.5, 0.0, 0.0), &ctx);
        assert!(d.upload);
    }

    #[test]
    fn eaflm_gate_matches_batch_threshold() {
        let h0 = vec![0.0f32; 4];
        let h1 = vec![1.0f32; 4];
        let hist = vec![h0, h1];
        let params = EaflmParams { beta: 1.0, ..Default::default() };
        let mut p = EaflmPolicy { params };
        let ctx = AsyncGateContext { n_clients: 2, last_values: &[f64::NAN; 2], global_history: &hist };
        let lazy = p.gate_report(&report(0, 0.0, 0.0, 0.5), &ctx);
        let busy = p.gate_report(&report(1, 0.0, 0.0, 9.0), &ctx);
        assert!(!lazy.upload);
        assert!(busy.upload);
        // Same threshold as the batch path on the same history.
        let pctx = PolicyContext { round: 3, n_clients: 2, global_history: &hist };
        let s = p.select(&[report(0, 0.0, 0.0, 0.5)], &pctx);
        assert_eq!(lazy.threshold.to_bits(), s.threshold.to_bits());
    }

    #[test]
    fn make_policy_dispatches() {
        let cfg = ValueFnConfig::default();
        let ea = EaflmParams::default();
        assert_eq!(make_policy(Algorithm::Afl, cfg, ea).name(), "afl");
        assert_eq!(make_policy(Algorithm::Vafl, cfg, ea).name(), "vafl");
        assert_eq!(make_policy(Algorithm::Eaflm, cfg, ea).name(), "eaflm");
    }
}
