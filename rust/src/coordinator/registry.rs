//! Client registry: fleet membership and availability.
//!
//! The paper's motivation (§I) is exactly this failure mode — "when a few
//! clients are disconnected due to network problems, other clients and
//! server have to wait for them". The registry models per-round client
//! availability: a client can drop with a configured probability, stays
//! offline for a geometric number of rounds, then rejoins and resumes from
//! its (now stale) local model. The round engine consults the registry so
//! dropped clients neither train, report, nor receive broadcasts.

use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use anyhow::Result;

/// Dropout model parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DropoutModel {
    /// Probability an active client drops at the start of a round.
    pub drop_prob: f64,
    /// Mean offline duration in rounds (geometric; >= 1).
    pub mean_offline_rounds: f64,
}

impl DropoutModel {
    /// No dropout (the paper's main experiments — all clients stay up).
    pub fn none() -> Self {
        DropoutModel { drop_prob: 0.0, mean_offline_rounds: 1.0 }
    }

    /// A flaky edge fleet (failure-injection tests and ablations).
    pub fn flaky(drop_prob: f64) -> Self {
        DropoutModel { drop_prob, mean_offline_rounds: 2.0 }
    }
}

/// Fleet membership + availability tracking.
pub struct ClientRegistry {
    /// Compact per-client availability: `0` = active, `k > 0` = offline
    /// for `k` more steps. The geometric offline duration is capped at 50
    /// steps (see [`advance`](Self::advance)), so a `u8` encodes every
    /// reachable state exactly — one byte per client keeps the registry
    /// at a million clients to a megabyte instead of the 16 MB the
    /// previous enum representation cost (measured in
    /// `benches/fleet_scale.rs` via [`ClientRegistry::approx_bytes`]).
    status: Vec<u8>,
    model: DropoutModel,
    rng: Rng,
    /// Total (client, round) drop events, for metrics.
    pub total_drop_rounds: usize,
}

impl ClientRegistry {
    pub fn new(n_clients: usize, model: DropoutModel, rng: Rng) -> Self {
        ClientRegistry {
            status: vec![0u8; n_clients],
            model,
            rng,
            total_drop_rounds: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.status.len()
    }

    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    pub fn is_active(&self, client: usize) -> bool {
        self.status[client] == 0
    }

    pub fn active_count(&self) -> usize {
        self.status.iter().filter(|&&s| s == 0).count()
    }

    /// Resident bytes of the status storage (the fleet-scale bench
    /// reports this so registry overhead at 10⁶ clients stays measured
    /// and bounded).
    pub fn approx_bytes(&self) -> usize {
        self.status.capacity() * std::mem::size_of::<u8>()
    }

    /// Advance one client's drop/recover chain by a single step: offline
    /// timers tick down (rejoining at zero), active clients may drop for a
    /// geometric number of steps with the configured mean. The shared
    /// sampler keeps the barriered ([`tick`](Self::tick)) and barrier-free
    /// ([`poll`](Self::poll)) engines on the same dropout model.
    /// Status encoding: `0` = active, `k > 0` = `k` steps still offline.
    fn advance(status: u8, model: &DropoutModel, rng: &mut Rng) -> u8 {
        if status > 0 {
            status - 1
        } else if model.drop_prob > 0.0 && rng.f64() < model.drop_prob {
            // Geometric offline duration with the configured mean, capped
            // at 50 steps (the cap is what makes u8 storage exact).
            let p = 1.0 / model.mean_offline_rounds.max(1.0);
            let mut dur = 1u8;
            while rng.f64() > p && dur < 50 {
                dur += 1;
            }
            dur
        } else {
            0
        }
    }

    /// Advance availability by one round: offline timers tick down, active
    /// clients may drop. Guarantees at least one active client (the server
    /// cannot run a round against an empty fleet; the paper's fleets never
    /// fully vanish either).
    pub fn tick(&mut self) {
        for i in 0..self.status.len() {
            self.status[i] = Self::advance(self.status[i], &self.model, &mut self.rng);
        }
        if self.active_count() == 0 {
            // Revive the first client: quorum of one.
            self.status[0] = 0;
        }
        self.total_drop_rounds += self.status.len() - self.active_count();
    }

    /// Indices of currently active clients.
    pub fn active_clients(&self) -> Vec<usize> {
        (0..self.status.len()).filter(|&i| self.is_active(i)).collect()
    }

    /// Event-driven availability poll (barrier-free engine): advance
    /// *one* client's drop/recover chain by one step (the shared `advance`
    /// sampler, so both engines draw from the same distribution *per
    /// step*) and report whether it may start a local round now. Per
    /// client because there is no global round to tick on; no quorum
    /// guarantee is needed — other clients keep their own clocks running,
    /// and a dropped client retries after a backoff.
    ///
    /// Caveat: the step unit differs between engines — [`tick`](Self::tick)
    /// draws once per *global round*, `poll` once per *local round start*,
    /// so with `drop_prob > 0` a fast client in the barrier-free engine
    /// faces the drop lottery more often per virtual second than a
    /// barriered one. Cross-engine comparisons under dropout measure
    /// per-attempt availability, not identical time-based availability.
    pub fn poll(&mut self, client: usize) -> bool {
        self.status[client] = Self::advance(self.status[client], &self.model, &mut self.rng);
        let active = self.is_active(client);
        if !active {
            self.total_drop_rounds += 1;
        }
        active
    }

    /// Serialize the registry's mutable state (status timers, drop
    /// counter, RNG stream position) for a checkpoint. The dropout model
    /// is config-derived and rebuilt at restore.
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.status.len());
        for &s in &self.status {
            enc.u8(s);
        }
        enc.usize(self.total_drop_rounds);
        let (s, spare) = self.rng.state();
        enc.u64s(&s);
        enc.opt_f64(spare);
    }

    /// Restore the state saved by [`ClientRegistry::save`].
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        let n = dec.usize()?;
        self.status.clear();
        self.status.reserve(n);
        for _ in 0..n {
            self.status.push(dec.u8()?);
        }
        self.total_drop_rounds = dec.usize()?;
        let s = dec.u64s()?;
        anyhow::ensure!(s.len() == 4, "registry rng state must hold 4 words, got {}", s.len());
        self.rng = Rng::from_state([s[0], s[1], s[2], s[3]], dec.opt_f64()?);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_dropout_keeps_everyone_active() {
        let mut reg = ClientRegistry::new(5, DropoutModel::none(), Rng::new(1));
        for _ in 0..20 {
            reg.tick();
            assert_eq!(reg.active_count(), 5);
        }
        assert_eq!(reg.total_drop_rounds, 0);
    }

    #[test]
    fn flaky_fleet_drops_and_recovers() {
        let mut reg = ClientRegistry::new(5, DropoutModel::flaky(0.3), Rng::new(2));
        let mut saw_drop = false;
        let mut saw_recovery_after_drop = false;
        let mut was_dropped = vec![false; 5];
        for _ in 0..60 {
            reg.tick();
            for i in 0..5 {
                if !reg.is_active(i) {
                    saw_drop = true;
                    was_dropped[i] = true;
                } else if was_dropped[i] {
                    saw_recovery_after_drop = true;
                }
            }
            assert!(reg.active_count() >= 1);
        }
        assert!(saw_drop);
        assert!(saw_recovery_after_drop);
        assert!(reg.total_drop_rounds > 0);
    }

    #[test]
    fn quorum_of_one_enforced() {
        let mut reg = ClientRegistry::new(2, DropoutModel::flaky(1.0), Rng::new(3));
        for _ in 0..10 {
            reg.tick();
            assert!(reg.active_count() >= 1);
        }
    }

    #[test]
    fn active_clients_lists_indices() {
        let mut reg = ClientRegistry::new(3, DropoutModel::none(), Rng::new(4));
        reg.tick();
        assert_eq!(reg.active_clients(), vec![0, 1, 2]);
    }

    #[test]
    fn poll_never_drops_without_dropout() {
        let mut reg = ClientRegistry::new(3, DropoutModel::none(), Rng::new(5));
        for _ in 0..50 {
            for c in 0..3 {
                assert!(reg.poll(c));
            }
        }
        assert_eq!(reg.total_drop_rounds, 0);
    }

    #[test]
    fn poll_drops_and_recovers_deterministically() {
        let run = |seed| {
            let mut reg = ClientRegistry::new(2, DropoutModel::flaky(0.5), Rng::new(seed));
            (0..200).map(|i| reg.poll(i % 2)).collect::<Vec<bool>>()
        };
        let trace = run(11);
        assert!(trace.iter().any(|&a| !a), "never dropped");
        assert!(trace.iter().skip(1).any(|&a| a), "never recovered");
        assert_eq!(trace, run(11));
        // A dropped client must come back within its bounded offline span.
        let mut reg = ClientRegistry::new(1, DropoutModel::flaky(1.0), Rng::new(3));
        let mut recovered = false;
        let mut polls_down = 0;
        for _ in 0..200 {
            if reg.poll(0) {
                recovered = true;
                break;
            }
            polls_down += 1;
        }
        assert!(recovered, "still offline after {polls_down} polls");
    }

    #[test]
    fn save_load_resumes_the_drop_lottery_bitwise() {
        let mut reg = ClientRegistry::new(4, DropoutModel::flaky(0.4), Rng::new(7));
        for _ in 0..9 {
            reg.tick();
        }
        let mut enc = Enc::new();
        reg.save(&mut enc);
        let bytes = enc.into_bytes();
        let mut reg2 = ClientRegistry::new(4, DropoutModel::flaky(0.4), Rng::new(999));
        let mut dec = Dec::new(&bytes);
        reg2.load(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(reg2.total_drop_rounds, reg.total_drop_rounds);
        assert_eq!(reg2.active_clients(), reg.active_clients());
        // The restored RNG continues the same lottery, tick and poll.
        for _ in 0..30 {
            reg.tick();
            reg2.tick();
            assert_eq!(reg2.active_clients(), reg.active_clients());
        }
        for i in 0..40 {
            assert_eq!(reg.poll(i % 4), reg2.poll(i % 4));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let run = |seed| {
            let mut reg = ClientRegistry::new(4, DropoutModel::flaky(0.4), Rng::new(seed));
            let mut trace = Vec::new();
            for _ in 0..30 {
                reg.tick();
                trace.push(reg.active_clients());
            }
            trace
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
