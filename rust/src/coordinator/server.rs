//! The asynchronous federated round engine (paper Algorithm 1, server
//! side), orchestrating the fleet, the network simulator, the virtual
//! clock, and the metrics stack.
//!
//! Per round `t`:
//!
//! 1. Every client runs its local round (lines 4–7) — `r x E` SGD passes
//!    through PJRT — and its **V report** (68 bytes) arrives at
//!    `now + compute + uplink`. The engine's event queue orders arrivals;
//!    stragglers are visible as idle time.
//! 2. The policy (lines 8–14: VAFL's Eq. 2 gate / EAFLM's Eq. 3 gate / AFL)
//!    picks the upload set from the reports.
//! 3. Selected clients receive an upload request and ship their **model
//!    upload** (the counted, gated quantity — Table III); the aggregation
//!    (lines 15–16) runs when the last upload lands.
//! 4. The new global model is broadcast to the *selected* clients (the
//!    paper's server "returns the model obtained by the algorithm to the
//!    client"); skipped clients keep training their local models — that is
//!    the asynchrony that makes models "old" and drives Eq. 1.
//! 5. The server evaluates the global model on its held-out test set
//!    (Fig. 4/6 curves) and the metrics stack records the round.

use std::collections::VecDeque;
use std::sync::{mpsc, Arc};

use anyhow::{anyhow, Result};

use crate::config::{AttackMode, CompressionMode, ExperimentConfig, RobustMode};
use crate::control::{ControlPlane, FlushSample, KnobChange, Knobs, TrustBook};
use crate::coordinator::aggregate::{combine_edges, Aggregator, EdgeAccum, RobustSpec};
use crate::coordinator::downlink::Downlink;
use crate::coordinator::policy::{AsyncGateContext, PolicyContext, SelectionPolicy};
use crate::coordinator::registry::ClientRegistry;
use crate::coordinator::staleness::MixingRule;
use crate::model::quant::{Precision, QuantBuf};
use crate::model::sparse::{sparse_payload_bytes, sparse_payload_bytes_layers, SparseDelta};
use crate::data::synth::Dataset;
use crate::fleet::{AttackProfile, Client, ClientReport, Fleet, FleetData};
use crate::metrics::{ControlRecord, FaultCounters, RoundRecord, RunMetrics};
use crate::model::ParamVec;
use crate::netsim::{FaultPlan, FrameFate, LinkProfile, Message, INTEGRITY_HEADER_BYTES};
use crate::obs::{Counter, Gauge, ObsPlane, ObsShared, SpanPhase, NO_CLIENT};
use crate::runtime::{evaluate_with_params, Executor, ExecutorPool};
use crate::sim::EventQueue;
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use crate::{log_debug, log_info};

/// Events of the round engines on the virtual clock. The barriered engine
/// only ever schedules [`EngineEvent::Report`]s (its barrier drains them
/// per round); the barrier-free engine drives the full lifecycle
/// `Start -> Report -> (gate) -> Upload -> flush -> Start`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineEvent {
    /// The client may begin its next local round.
    Start { client: usize },
    /// The client's V report (68 B) landed at the server.
    Report { client: usize },
    /// The client's model upload landed at the server, carrying `bytes`
    /// wire bytes — attached to the event so uplink byte accounting is
    /// attributed to the aggregation window the upload *arrives* in (the
    /// window a flush actually consumes), not the one that requested it.
    /// Corollary: `bytes_up` counts **delivered** payloads — an upload
    /// still in flight when the engine stops (it is abandoned with the
    /// queue, having joined no window) is excluded, bounded by the final
    /// record's `in_flight`. Downlink request bytes stay at request time
    /// (the request *was* delivered to the client).
    ///
    /// With fault injection armed the event also carries the sender's
    /// per-client monotone sequence number `seq` (duplicate suppression)
    /// and the retransmit `attempt` index (0 = first transmission) so the
    /// capped-backoff retransmit loop is a pure function of the event.
    /// Fault-free runs always carry `seq = 0, attempt = 0`, keeping the
    /// event stream identical to pre-fault builds.
    Upload { client: usize, bytes: u64, seq: u64, attempt: u32 },
    /// A crashed client's downtime expired; rehydrate it as a fresh
    /// joiner (fault injection only).
    Restart { client: usize },
}

impl EngineEvent {
    /// Checkpoint codec for the queue payloads (see `EventQueue::save`).
    fn save(&self, enc: &mut Enc) {
        match *self {
            EngineEvent::Start { client } => {
                enc.u8(0);
                enc.usize(client);
            }
            EngineEvent::Report { client } => {
                enc.u8(1);
                enc.usize(client);
            }
            EngineEvent::Upload { client, bytes, seq, attempt } => {
                enc.u8(2);
                enc.usize(client);
                enc.u64(bytes);
                enc.u64(seq);
                enc.u32(attempt);
            }
            EngineEvent::Restart { client } => {
                enc.u8(3);
                enc.usize(client);
            }
        }
    }

    fn load(dec: &mut Dec) -> Result<Self> {
        Ok(match dec.u8()? {
            0 => EngineEvent::Start { client: dec.usize()? },
            1 => EngineEvent::Report { client: dec.usize()? },
            2 => EngineEvent::Upload {
                client: dec.usize()?,
                bytes: dec.u64()?,
                seq: dec.u64()?,
                attempt: dec.u32()?,
            },
            3 => EngineEvent::Restart { client: dec.usize()? },
            tag => anyhow::bail!("unknown engine event tag {tag}"),
        })
    }
}

/// Per-aggregation-window counters of the barrier-free engine (reset at
/// every buffer flush). Window telemetry is fleet-wide even under
/// sharding: reports and bytes count when their events fire and are
/// attributed to whichever flush closes the window next.
#[derive(Debug, Default)]
struct FlushWindow {
    reports: usize,
    train_loss_sum: f64,
    bytes_up: u64,
    bytes_down: u64,
    /// Control-frame share of `bytes_up` / `bytes_down` (V reports /
    /// upload requests); the payload share is the difference.
    bytes_up_ctrl: u64,
    bytes_down_ctrl: u64,
    threshold: f64,
    /// Speculative local rounds committed as-is since the last flush.
    spec_committed: usize,
    /// Speculative local rounds whose fork state was superseded and were
    /// recomputed serially at the commit point.
    spec_replayed: usize,
    /// Fault-layer counters of the window (all zero while faults are
    /// disabled).
    faults: FaultCounters,
}

/// Static per-local-round knobs, bundled so speculative dispatches can
/// capture them by value.
#[derive(Clone, Copy)]
struct RoundKnobs {
    passes: usize,
    batches: usize,
    lr: f32,
    train_flops: u64,
    eval_flops: u64,
}

/// What a speculative worker sends back: the trained ghost client and the
/// round's report.
type SpecResult = (Client, Result<ClientReport>);

/// A deferred flush-time evaluation: (record index to patch, result).
type PendingEval = (usize, mpsc::Receiver<Result<(f64, f64)>>);

/// An in-flight speculative local round of the threaded barrier-free
/// engine: the trained ghost client and its report arrive on `rx` when a
/// pool worker finishes. `epoch` is the origin client's training-state
/// version at fork time — commit requires it to still match, otherwise the
/// round is replayed serially at the commit point (see
/// [`Client::commit_speculation`]).
struct Speculation {
    epoch: u64,
    rx: mpsc::Receiver<SpecResult>,
}

/// Mutable per-run state of the barrier-free engine, grouped so the event
/// handlers and the shard flush path can borrow it independently of the
/// server's own fields.
struct EngineState {
    /// Reports awaiting their arrival event, one slot per client.
    pending: Vec<Option<ClientReport>>,
    /// Fleet-wide last-known gate values / probe accuracies.
    last_values: Vec<f64>,
    last_accs: Vec<f64>,
    /// Completed local rounds per client (the report's round index).
    local_rounds: Vec<usize>,
    /// Shard version each client last synced against.
    synced_version: Vec<u64>,
    /// Offline retry backoff: one local-round span of that client.
    backoff: Vec<f64>,
    /// In-flight speculative local rounds (threaded engine only).
    spec: Vec<Option<Speculation>>,
    window: FlushWindow,
    /// Deferred pool-side evaluations, resolved before the engine returns.
    pending_evals: Vec<PendingEval>,
    /// Consecutive gated-out reports; a long streak force-uploads the next
    /// report so a fully-lazy fleet cannot starve the engine.
    skip_streak: usize,
    /// Model uploads currently on the wire.
    in_flight: usize,
    /// Aggregator shard of each client (round-robin at start; the
    /// control plane's rebalancer may migrate clients at reconcile
    /// boundaries).
    shard_of: Vec<usize>,
    /// Clients per shard (kept in sync with `shard_of`).
    shard_pop: Vec<usize>,
    /// Whether each client has a model upload on the wire (used to pick
    /// migratable clients — an in-flight upload pins its sender).
    upload_in_flight: Vec<bool>,
    /// Sparse top-k budget each client's outstanding upload was *sized*
    /// with at request time. The flush encodes with this snapshot, not
    /// the current `k_for`, so the frame on the wire always matches the
    /// bytes and transfer time it was charged — even when the
    /// compression controller retunes `k_fraction` while uploads are in
    /// flight. Unused in dense mode.
    upload_k: Vec<usize>,
    /// Per-shard buffer-of-K threshold (clamped to the shard population).
    shard_k: Vec<usize>,
    /// Per-shard aggregation buffers: (client, staleness tau, arrival).
    buffers: Vec<Vec<(usize, usize, f64)>>,
    /// Per-shard flush counter = the shard's model version.
    shard_version: Vec<u64>,
    /// Per-shard reconciliation weights (total local samples).
    shard_weight: Vec<f64>,
    /// Per-shard global-model history, most recent last (S > 1 only;
    /// empty at S == 1, where the server's own history serves). Keeps the
    /// EAFLM Eq. 3 gate thresholding on consecutive movement of the
    /// *same* replica instead of an interleaved mix of all of them.
    /// Reconcile restarts are not pushed: histories track the
    /// flush-to-flush movement of each replica lineage, so the first
    /// flush after a reconcile measures movement from the replica's last
    /// flushed model (the same re-anchoring semantics as the accuracy
    /// curve — see EXPERIMENTS.md §Engines).
    shard_history: Vec<Vec<Vec<f32>>>,
    /// FIFO of parked clients awaiting a concurrency slot
    /// (`fleet.active_set > 0` only; empty when the whole fleet is
    /// hydrated, which keeps the engine on the legacy path bitwise). A
    /// flushed client parks and joins the back; the front hydrates into
    /// the freed slot (see `flush_shard`'s broadcast loop).
    waiting: VecDeque<usize>,
    /// Edge-tier accumulators, `shards × edge_fanout` of them, indexed
    /// `shard * edge_fanout + edge` (`engine.edge_fanout > 1` only;
    /// empty otherwise). Uploads fold in at arrival; flushes combine a
    /// shard's edge slice in O(edges · dim), independent of buffer depth.
    edges: Vec<EdgeAccum>,
    /// Per-shard residual / transmitted selection-key mass accumulated at
    /// upload arrival — edge mode's replacement for
    /// `Server::sparse_flush_mass`, which reads flush-time encodes that
    /// edge mode never performs. Zeroed when a flush samples them.
    edge_residual: Vec<f64>,
    edge_transmitted: Vec<f64>,
    /// Per-client monotone upload sequence numbers (fault injection):
    /// `tx_seq` is stamped on each transmission at the sender, `rx_seq`
    /// is the highest sequence the server has accepted — a frame whose
    /// `seq <= rx_seq` is a stale duplicate and is suppressed. Always
    /// zero while faults are disabled.
    tx_seq: Vec<u64>,
    rx_seq: Vec<u64>,
}

fn save_report(r: &ClientReport, enc: &mut Enc) {
    enc.usize(r.client_id);
    enc.usize(r.round);
    enc.f64(r.value);
    enc.f64(r.acc);
    enc.f64(r.grad_norm_sq);
    enc.f64(r.train_loss);
    enc.usize(r.num_samples);
    enc.f64(r.compute_seconds);
}

fn load_report(dec: &mut Dec) -> Result<ClientReport> {
    Ok(ClientReport {
        client_id: dec.usize()?,
        round: dec.usize()?,
        value: dec.f64()?,
        acc: dec.f64()?,
        grad_norm_sq: dec.f64()?,
        train_loss: dec.f64()?,
        num_samples: dec.usize()?,
        compute_seconds: dec.f64()?,
    })
}

impl FlushWindow {
    fn save(&self, enc: &mut Enc) {
        enc.usize(self.reports);
        enc.f64(self.train_loss_sum);
        enc.u64(self.bytes_up);
        enc.u64(self.bytes_down);
        enc.u64(self.bytes_up_ctrl);
        enc.u64(self.bytes_down_ctrl);
        enc.f64(self.threshold);
        enc.usize(self.spec_committed);
        enc.usize(self.spec_replayed);
        self.faults.save(enc);
    }

    fn load(dec: &mut Dec) -> Result<Self> {
        Ok(FlushWindow {
            reports: dec.usize()?,
            train_loss_sum: dec.f64()?,
            bytes_up: dec.u64()?,
            bytes_down: dec.u64()?,
            bytes_up_ctrl: dec.u64()?,
            bytes_down_ctrl: dec.u64()?,
            threshold: dec.f64()?,
            spec_committed: dec.usize()?,
            spec_replayed: dec.usize()?,
            faults: FaultCounters::load(dec)?,
        })
    }
}

impl EngineState {
    /// Serialize the engine's mutable per-run state for a checkpoint.
    /// Speculations and deferred evaluations are deliberately excluded:
    /// evals are drained before every snapshot, and a restored `Start`
    /// pops with an empty speculation slot and replays its round serially
    /// — bitwise identical to committing the fork. The edge tier's
    /// running sums ARE serialized ([`EdgeAccum::save`]), so
    /// `checkpoint_every` composes with `engine.edge_fanout > 1`.
    fn save(&self, enc: &mut Enc) {
        enc.usize(self.pending.len());
        for p in &self.pending {
            enc.bool(p.is_some());
            if let Some(r) = p {
                save_report(r, enc);
            }
        }
        enc.f64s(&self.last_values);
        enc.f64s(&self.last_accs);
        enc.usizes(&self.local_rounds);
        enc.u64s(&self.synced_version);
        enc.f64s(&self.backoff);
        self.window.save(enc);
        enc.usize(self.skip_streak);
        enc.usize(self.in_flight);
        enc.usizes(&self.shard_of);
        enc.usizes(&self.shard_pop);
        enc.bools(&self.upload_in_flight);
        enc.usizes(&self.upload_k);
        enc.usizes(&self.shard_k);
        enc.usize(self.buffers.len());
        for b in &self.buffers {
            enc.usize(b.len());
            for &(c, tau, at) in b {
                enc.usize(c);
                enc.usize(tau);
                enc.f64(at);
            }
        }
        enc.u64s(&self.shard_version);
        enc.f64s(&self.shard_weight);
        enc.usize(self.shard_history.len());
        for h in &self.shard_history {
            enc.usize(h.len());
            for m in h {
                enc.f32s(m);
            }
        }
        let waiting: Vec<usize> = self.waiting.iter().copied().collect();
        enc.usizes(&waiting);
        enc.f64s(&self.edge_residual);
        enc.f64s(&self.edge_transmitted);
        enc.u64s(&self.tx_seq);
        enc.u64s(&self.rx_seq);
        enc.usize(self.edges.len());
        for e in &self.edges {
            e.save(enc);
        }
    }

    /// Restore the state saved by [`EngineState::save`] into a freshly
    /// built engine state of the same configuration.
    fn load(&mut self, dec: &mut Dec) -> Result<()> {
        let n = dec.usize()?;
        self.pending.clear();
        for _ in 0..n {
            self.pending.push(if dec.bool()? { Some(load_report(dec)?) } else { None });
        }
        self.last_values = dec.f64s()?;
        self.last_accs = dec.f64s()?;
        self.local_rounds = dec.usizes()?;
        self.synced_version = dec.u64s()?;
        self.backoff = dec.f64s()?;
        self.window = FlushWindow::load(dec)?;
        self.skip_streak = dec.usize()?;
        self.in_flight = dec.usize()?;
        self.shard_of = dec.usizes()?;
        self.shard_pop = dec.usizes()?;
        self.upload_in_flight = dec.bools()?;
        self.upload_k = dec.usizes()?;
        self.shard_k = dec.usizes()?;
        let bn = dec.usize()?;
        self.buffers.clear();
        for _ in 0..bn {
            let len = dec.usize()?;
            let mut b = Vec::with_capacity(len);
            for _ in 0..len {
                b.push((dec.usize()?, dec.usize()?, dec.f64()?));
            }
            self.buffers.push(b);
        }
        self.shard_version = dec.u64s()?;
        self.shard_weight = dec.f64s()?;
        let hn = dec.usize()?;
        self.shard_history.clear();
        for _ in 0..hn {
            let len = dec.usize()?;
            let mut h = Vec::with_capacity(len);
            for _ in 0..len {
                h.push(dec.f32s()?);
            }
            self.shard_history.push(h);
        }
        self.waiting = dec.usizes()?.into_iter().collect();
        self.edge_residual = dec.f64s()?;
        self.edge_transmitted = dec.f64s()?;
        self.tx_seq = dec.u64s()?;
        self.rx_seq = dec.u64s()?;
        let en = dec.usize()?;
        anyhow::ensure!(
            en == self.edges.len(),
            "checkpoint edge-tier shape mismatch: saved {en}, engine has {}",
            self.edges.len()
        );
        self.edges.clear();
        for _ in 0..en {
            self.edges.push(EdgeAccum::load(dec)?);
        }
        Ok(())
    }
}

/// Append `model` to `history` (recycling retired entries through
/// `pool`), bounded to the `keep` most recent entries — shared by the
/// server's own history and the per-shard gate histories.
fn push_bounded_history(
    history: &mut Vec<Vec<f32>>,
    pool: &mut Vec<Vec<f32>>,
    keep: usize,
    model: &[f32],
) {
    let mut entry = pool.pop().unwrap_or_default();
    entry.clear();
    entry.extend_from_slice(model);
    history.push(entry);
    while history.len() > keep {
        pool.push(history.remove(0));
    }
}

/// Mean of the finite entries of `xs` (NaN when none are finite) — the
/// control plane's accuracy proxy over last-known probe accuracies,
/// available identically on every execution strategy (unlike the global
/// eval, which the threaded engine defers).
fn mean_finite(xs: &[f64]) -> f64 {
    let mut sum = 0.0f64;
    let mut n = 0usize;
    for &x in xs {
        if x.is_finite() {
            sum += x;
            n += 1;
        }
    }
    if n == 0 {
        f64::NAN
    } else {
        sum / n as f64
    }
}

/// One client local round with the bundled knobs — the single call shape
/// shared by the serial engine, the speculative worker job, and the
/// replay fallback, so the three can never drift apart.
fn run_local_round(
    client: &mut Client,
    exec: &mut dyn Executor,
    round: usize,
    knobs: RoundKnobs,
) -> Result<ClientReport> {
    client.local_round(
        exec,
        round,
        knobs.passes,
        knobs.batches,
        knobs.lr,
        knobs.train_flops,
        knobs.eval_flops,
    )
}

/// Dispatch client `client`'s *next* local round to the pool against a
/// snapshot of its training state. Called exactly where the engine
/// schedules `Start { client }`; the result is committed (or replayed)
/// when that event pops, so the committed record stream is independent of
/// worker timing. No-op on the serial engine (`pool == None`).
fn dispatch_speculation(
    fleet: &Fleet,
    st: &mut EngineState,
    pool: Option<&ExecutorPool>,
    obs: Option<&Arc<ObsShared>>,
    client: usize,
    vtime: f64,
    knobs: RoundKnobs,
) -> Result<()> {
    let Some(pool) = pool else { return Ok(()) };
    debug_assert!(st.spec[client].is_none(), "double dispatch for client {client}");
    let ghost = fleet.client(client).speculate();
    let epoch = fleet.client(client).epoch();
    let round = st.local_rounds[client] + 1;
    let (tx, rx) = mpsc::channel();
    let obs = obs.cloned();
    pool.submit(Box::new(move |exec| {
        let ws = obs.as_ref().map_or(0.0, |o| o.now_us());
        let mut ghost = ghost;
        let rep = run_local_round(&mut ghost, exec, round, knobs);
        if let Some(o) = &obs {
            // Worker-side wall span; drained (and only then published)
            // at the next flush commit, so arming tracing never touches
            // the engine's deterministic state.
            o.wall_span(SpanPhase::SpecExecute, client as u32, vtime, ws);
        }
        // The engine may have abandoned this speculation (run ended);
        // a closed channel is not an error.
        let _ = tx.send((ghost, rep));
    }))?;
    st.spec[client] = Some(Speculation { epoch, rx });
    Ok(())
}

/// Static context the server needs besides the fleet. The test set is
/// `Arc`-shared so deferred evaluations can run on pool workers without
/// copying it.
pub struct ServerContext {
    pub link: LinkProfile,
    pub train_flops: u64,
    pub eval_flops: u64,
    pub model_payload_bytes: u64,
    pub test_images: Arc<Vec<f32>>,
    pub test_labels: Arc<Vec<i32>>,
}

/// The federated server.
pub struct Server {
    cfg: ExperimentConfig,
    ctx: ServerContext,
    /// The client fleet: in-flight clients hold full dense state, the
    /// parked majority is a compact record hydrated on dispatch (see
    /// `crate::fleet`). With `fleet.active_set = 0` every client is
    /// hydrated at construction and the engines behave exactly as if the
    /// fleet were a plain `Vec<Client>`.
    fleet: Fleet,
    policy: Box<dyn SelectionPolicy>,
    /// Current global model theta^t.
    pub global: ParamVec,
    /// Recent global models, oldest first (bounded by the policy's needs).
    history: Vec<Vec<f32>>,
    /// Retired history buffers, recycled so steady-state rounds do not
    /// allocate (see EXPERIMENTS.md §Perf).
    history_pool: Vec<Vec<f32>>,
    agg: Aggregator,
    /// Reusable per-upload wire buffers, grown lazily to the largest
    /// aggregation fan-in seen (plus one extra slot the barrier-free
    /// engine uses to fold the current global model into a
    /// staleness-weighted mix) — never to fleet size, so a million-client
    /// fleet does not pay a million idle codec buffers
    /// (`benches/fleet_scale.rs`). Uploads are encoded here and
    /// aggregated by the fused dequantize-accumulate path, never staged
    /// as dense `Vec<f32>`.
    upload_bufs: Vec<QuantBuf>,
    /// Reusable sparse wire buffers for `compression.mode = topk`, grown
    /// like `upload_bufs` (the mix's self-weight replaces the extra
    /// global slot of the dense path). Unused in dense mode.
    sparse_bufs: Vec<SparseDelta>,
    /// Scratch wire buffers for the edge tier's arrival-time encode
    /// (`engine.edge_fanout > 1`): each payload folds into its edge
    /// accumulator immediately, so one buffer serves every upload.
    edge_buf: QuantBuf,
    edge_sparse: SparseDelta,
    /// The model's per-layer parameter sizes (from `ParamSpec::layers`,
    /// installed by [`Server::set_layer_sizes`]) and the matching
    /// per-layer top-k budgets from `compression.layer_k_fractions`.
    /// `layer_ks` empty = flat top-k (the legacy single-budget race).
    layer_sizes: Vec<usize>,
    layer_ks: Vec<usize>,
    /// Wire bytes of one model upload under the configured compression
    /// (dense: `ctx.model_payload_bytes`; topk: the exact sparse frame
    /// for k of n values). Broadcast frames are priced per-broadcast
    /// from the downlink compressor's actual encode (dense `down_mode`:
    /// always `ctx.model_payload_bytes`).
    upload_payload_bytes: u64,
    /// Server-side downlink compressor (`compression.down_mode = topk`):
    /// per-active-client acked bases + error-feedback residuals, sparse
    /// broadcast frames in the upload wire format. Holds no slots (and
    /// is never consulted) in dense downlink mode.
    downlink: Downlink,
    /// Wire bytes of one dense broadcast frame under the effective
    /// downlink precision. `compression.down_precision = None` reads
    /// `ctx.model_payload_bytes`, keeping pre-split byte streams bitwise.
    down_payload_bytes: u64,
    /// Per-client trust scores (rolling outlier-rate EWMA; see
    /// `control::telemetry::TrustBook`). Only updated and consulted while
    /// `robust.trust` is armed.
    trust: TrustBook,
    /// Per-payload trimmed-coordinate counts of the latest robust
    /// aggregation, reused across flushes. Empty while `robust.mode` is
    /// `none`.
    outlier_counts: Vec<u64>,
    /// Reusable FedAvg weight buffer for the selected upload set.
    upload_weights: Vec<f64>,
    /// Reusable broadcast codec buffer + decoded broadcast model.
    bcast_buf: QuantBuf,
    bcast_model: Vec<f32>,
    queue: EventQueue<EngineEvent>,
    net_rng: Rng,
    pub metrics: RunMetrics,
    /// Availability registry (dropout model; all-active by default).
    pub registry: ClientRegistry,
    /// Adaptive control plane (`[control]`): telemetry window +
    /// deterministic controllers, polled at commit points. Fully inert
    /// while `control.enabled = false`.
    control: ControlPlane,
    /// Last-known probe accuracy per client — the barriered engine's
    /// accuracy proxy for control telemetry (the barrier-free engine
    /// keeps its own in `EngineState::last_accs`). Persisting across
    /// rounds keeps the proxy's sample composition stable under dropout:
    /// a low-accuracy client going offline must not read as an accuracy
    /// jump. Only maintained while the control plane is enabled.
    last_accs: Vec<f64>,
    round: usize,
    /// Deterministic fault-injection plan (`[faults] enabled = true`):
    /// per-frame fates, crash schedules and outage windows from RNG
    /// streams forked off the experiment root. `None` while disabled —
    /// fault-free runs build no plan and consume no extra randomness.
    faults: Option<FaultPlan>,
    /// Transfers whose link-layer retry loop was stopped by the attempt
    /// cap instead of an observed success draw (see
    /// `LinkProfile::sample_attempts_counted`); exported as
    /// `RunMetrics::link_capped`.
    link_capped: u64,
    /// Fault counters of the in-progress barriered round (the
    /// barrier-free engine keeps its own in `FlushWindow::faults`).
    round_faults: FaultCounters,
    /// Latest committed checkpoint (`faults.checkpoint_every > 0`),
    /// refreshed at deterministic commit points.
    checkpoint: Option<Vec<u8>>,
    /// A snapshot queued by [`Server::restore_checkpoint`]; consumed at
    /// the start of the next `run*` call, which resumes mid-stream.
    restore: Option<Vec<u8>>,
    /// Kill switch for crash tests: abandon the run right after this many
    /// commits (flushes / rounds) have been recorded. 0 = run to the end.
    stop_after: usize,
    /// Observability plane (`[obs]`): span recorder + unified
    /// `MetricRegistry`. The registry is always live (it mirrors the
    /// counters behind existing CSV columns); span tracing arms only
    /// under `obs.enabled`, and a disarmed plane records nothing — the
    /// golden snapshots pin bitwise identity.
    obs: ObsPlane,
}

impl Server {
    pub fn new(
        cfg: ExperimentConfig,
        ctx: ServerContext,
        mut fleet: Fleet,
        policy: Box<dyn SelectionPolicy>,
        init_params: ParamVec,
        root_rng: &Rng,
    ) -> Self {
        let metrics = RunMetrics::new(&cfg.name, policy.name(), cfg.target_acc);
        let history = vec![init_params.clone()];
        let n_clients = fleet.len();
        // Hydrate-everything mode: materialize the whole fleet up front —
        // the engines then behave (and the goldens stay) exactly as
        // before lazy state existed. With `active_set > 0` (barrier-free
        // only, config-validated) the engine hydrates its initial window
        // itself and the rest stay compact records.
        if cfg.fleet.active_set == 0 {
            fleet.hydrate_all(&init_params);
        }
        let registry = ClientRegistry::new(n_clients, cfg.dropout, root_rng.fork("dropout"));
        let upload_payload_bytes = match cfg.compression.mode {
            CompressionMode::Dense => ctx.model_payload_bytes,
            CompressionMode::TopK => {
                let n = init_params.len();
                sparse_payload_bytes(cfg.upload_precision, cfg.compression.k_for(n), n)
            }
        };
        let down_payload_bytes = cfg
            .compression
            .down_precision
            .map_or(ctx.model_payload_bytes, |p| p.payload_bytes(init_params.len()));
        let faults = cfg.faults.enabled.then(|| FaultPlan::new(&cfg.faults, root_rng));
        // One wall-span ring per potential pool worker plus slack for the
        // engine thread and scoped barriered workers.
        let obs = ObsPlane::new(&cfg.obs, crate::util::par::max_threads() + 2);
        Server {
            obs,
            net_rng: root_rng.fork("netsim"),
            registry,
            faults,
            link_capped: 0,
            round_faults: FaultCounters::default(),
            checkpoint: None,
            restore: None,
            stop_after: 0,
            control: ControlPlane::new(&cfg.control),
            last_accs: vec![f64::NAN; n_clients],
            downlink: Downlink::new(
                n_clients,
                cfg.compression.down_precision_or(cfg.upload_precision),
                cfg.compression.error_feedback,
            ),
            down_payload_bytes,
            trust: TrustBook::new(n_clients, cfg.robust.trust_decay),
            outlier_counts: Vec::new(),
            cfg,
            ctx,
            fleet,
            policy,
            global: init_params,
            history,
            history_pool: Vec::new(),
            agg: Aggregator::new(),
            upload_bufs: Vec::new(),
            sparse_bufs: Vec::new(),
            edge_buf: QuantBuf::new(),
            edge_sparse: SparseDelta::new(),
            layer_sizes: Vec::new(),
            layer_ks: Vec::new(),
            upload_payload_bytes,
            upload_weights: Vec::new(),
            bcast_buf: QuantBuf::new(),
            bcast_model: Vec::new(),
            queue: EventQueue::new(),
            metrics,
            round: 0,
        }
    }

    pub fn round(&self) -> usize {
        self.round
    }

    pub fn num_clients(&self) -> usize {
        self.fleet.len()
    }

    /// Immutable view of a client (tests/diagnostics). Panics if the
    /// client is parked — use [`Server::fleet`] for park-aware access.
    pub fn client(&self, i: usize) -> &Client {
        self.fleet.client(i)
    }

    /// The fleet (tests/diagnostics/benches: parked-record accounting,
    /// hydration counters).
    pub fn fleet(&self) -> &Fleet {
        &self.fleet
    }

    /// Grow the reusable per-upload wire buffers to at least `count`
    /// slots (plus the dense path's trailing self slot). Sized by the
    /// actual aggregation fan-in, not fleet size.
    fn ensure_wire_slots(&mut self, count: usize) {
        match self.cfg.compression.mode {
            CompressionMode::Dense => {
                if self.upload_bufs.len() < count + 1 {
                    self.upload_bufs.resize_with(count + 1, QuantBuf::new);
                }
            }
            CompressionMode::TopK => {
                if self.sparse_bufs.len() < count {
                    self.sparse_bufs.resize_with(count, SparseDelta::new);
                }
            }
        }
    }

    /// Install the model's per-layer parameter layout (the PJRT backend
    /// passes `ParamSpec::layers`; the mock backend registers one flat
    /// layer). When `compression.layer_k_fractions` is configured this
    /// activates per-layer top-k selection and re-prices the upload frame
    /// via [`sparse_payload_bytes_layers`]; otherwise it only remembers
    /// the layout. Call once after construction, before running.
    pub fn set_layer_sizes(&mut self, sizes: Vec<usize>) {
        assert_eq!(
            sizes.iter().sum::<usize>(),
            self.global.len(),
            "layer sizes must partition the model"
        );
        match self.cfg.compression.layer_ks(&sizes) {
            Some(ks) if self.cfg.compression.mode == CompressionMode::TopK => {
                self.upload_payload_bytes =
                    sparse_payload_bytes_layers(self.cfg.upload_precision, &ks, &sizes);
                self.layer_ks = ks;
                self.layer_sizes = sizes;
            }
            _ => {
                self.layer_ks.clear();
                self.layer_sizes = sizes;
            }
        }
    }

    /// Run one communication round (sequential local rounds). Returns the
    /// record pushed to metrics.
    pub fn run_round(&mut self, exec: &mut dyn Executor) -> Result<RoundRecord> {
        self.round += 1;
        let round = self.round;

        // --- 0. Availability (paper §I: "dropped users"). Inactive clients
        // neither train nor report this round.
        self.registry.tick();

        // --- 1. Local rounds + V reports (Algorithm 1 lines 4-7). The
        // barriered engine always runs fully hydrated (`fleet.active_set`
        // is barrier-free-only, config-validated), so every slot is live.
        let vnow = self.queue.now();
        let mut reports: Vec<ClientReport> = Vec::new();
        for i in 0..self.fleet.len() {
            if !self.registry.is_active(i) {
                self.fleet.client_mut(i).mark_stale();
                continue;
            }
            let ws = self.obs.wall_start();
            reports.push(self.fleet.client_mut(i).local_round(
                exec,
                round,
                self.cfg.local_passes,
                self.cfg.batches_per_pass,
                self.cfg.lr,
                self.ctx.train_flops,
                self.ctx.eval_flops,
            )?);
            self.obs.wall_span(SpanPhase::ClientExecute, i as u32, vnow, ws);
        }
        self.finish_round(reports, exec)
    }

    /// Run one communication round with the active clients' local rounds on
    /// OS threads against a shared [`crate::runtime::ExecutorService`] —
    /// the paper's deployment shape (concurrent edge devices, one compute
    /// substrate). Bit-identical to [`Server::run_round`]: every random
    /// stream is per-client, and reports are collected in client order.
    pub fn run_round_threaded(
        &mut self,
        svc: &crate::runtime::ExecutorService,
    ) -> Result<RoundRecord> {
        self.round += 1;
        let round = self.round;
        self.registry.tick();

        let passes = self.cfg.local_passes;
        let batches = self.cfg.batches_per_pass;
        let lr = self.cfg.lr;
        let (tf, ef) = (self.ctx.train_flops, self.ctx.eval_flops);
        let registry = &self.registry;
        let vnow = self.queue.now();
        let shared = self.obs.shared();
        let mut slots: Vec<Option<Result<ClientReport>>> =
            (0..self.fleet.len()).map(|_| None).collect();
        std::thread::scope(|scope| {
            for ((i, client), slot) in
                self.fleet.iter_hydrated_mut().zip(slots.iter_mut())
            {
                if !registry.is_active(i) {
                    client.mark_stale();
                    continue;
                }
                let mut handle = svc.handle();
                let sh = shared.clone();
                scope.spawn(move || {
                    let ws = sh.as_ref().map_or(0.0, |s| s.now_us());
                    *slot = Some(client.local_round(
                        &mut handle,
                        round,
                        passes,
                        batches,
                        lr,
                        tf,
                        ef,
                    ));
                    if let Some(s) = &sh {
                        s.wall_span(SpanPhase::ClientExecute, i as u32, vnow, ws);
                    }
                });
            }
        });
        let mut reports = Vec::new();
        for slot in slots {
            if let Some(r) = slot {
                reports.push(r?);
            }
        }
        let mut handle = svc.handle();
        self.finish_round(reports, &mut handle)
    }

    /// Stages 2-5 of the round: arrival ordering, gating, upload +
    /// aggregation, broadcast, evaluation, metrics.
    fn finish_round(
        &mut self,
        reports: Vec<ClientReport>,
        exec: &mut dyn Executor,
    ) -> Result<RoundRecord> {
        let round = self.round;
        let n = self.fleet.len();
        let round_start = self.queue.now();
        // Uplink of each report (68 B) lands after the client's compute.
        let report_arrival: Vec<f64> = reports
            .iter()
            .map(|rep| {
                let uplink = self.ctx.link.transfer_seconds_counted(
                    &Message::ValueReport,
                    &mut self.net_rng,
                    &mut self.link_capped,
                );
                round_start + rep.compute_seconds + uplink
            })
            .collect();
        let n_active = reports.len();
        // Order arrivals on the event queue (deterministic tie-break).
        for (i, &t) in report_arrival.iter().enumerate() {
            self.queue
                .schedule_at(t, EngineEvent::Report { client: reports[i].client_id });
        }
        let mut last_arrival = round_start;
        while let Some(e) = self.queue.pop() {
            last_arrival = e.time;
        }
        let idle_seconds: f64 =
            report_arrival.iter().map(|&t| last_arrival - t).sum();
        // Control frames (V reports up, upload requests down) are
        // tracked separately from model payloads so byte-level CCR can
        // compare payload against payload (`RoundRecord::bytes_up` /
        // `bytes_down` stay the ctrl+payload totals for compatibility).
        let mut bytes_up_ctrl: u64 = n_active as u64 * Message::ValueReport.bytes();
        let mut bytes_up: u64 = bytes_up_ctrl;
        let mut bytes_down: u64 = 0;
        let mut bytes_down_ctrl: u64 = 0;

        // --- 2. Gate (lines 8-14).
        let selection = {
            let pctx = PolicyContext {
                round,
                n_clients: n,
                global_history: &self.history,
            };
            self.policy.select(&reports, &pctx)
        };
        let n_selected = selection.selected.iter().filter(|&&s| s).count();
        log_debug!(
            "server",
            "round {round}: threshold={:.4e} selected={n_selected}/{n_active} (fleet {n})",
            selection.threshold
        );
        // Map report-indexed decisions back to fleet-indexed vectors
        // (dropped clients: not selected, NaN value/acc for the record).
        let mut fleet_selected = vec![false; n];
        let mut fleet_values = vec![f64::NAN; n];
        let mut fleet_accs = vec![f64::NAN; n];
        for (ri, rep) in reports.iter().enumerate() {
            fleet_selected[rep.client_id] = selection.selected[ri];
            fleet_values[rep.client_id] = selection.values[ri];
            fleet_accs[rep.client_id] = rep.acc;
        }

        // --- 3. Upload + aggregate (lines 15-16). Uploads cross the wire
        // at the configured precision (extension; f32 = the paper) and the
        // server aggregates exactly what it received: each selected client
        // encodes into a reusable wire buffer and the fused
        // dequantize-accumulate path consumes the payload bytes directly —
        // no per-upload `round_trip` staging Vec, and zero steady-state
        // heap allocation with serial kernels (even f32 goes through the
        // codec, which for f32 is a byte-exact memcpy).
        let mut agg_time = last_arrival;
        let mut upload_staleness: Vec<usize> = Vec::with_capacity(n_selected);
        let robust = self.cfg.robust.mode != RobustMode::None;
        let trust_on = robust && self.cfg.robust.trust;
        let mut quarantined = 0usize;
        // Selected uploads whose retransmit budget ran dry (faults only).
        let mut lost_uploads = 0usize;
        // NaN = no robust signal this round (mode off or empty selection),
        // distinct from a clean 0.0 rate.
        let mut outlier_rate = f64::NAN;
        let flush_ws = self.obs.wall_start();
        if n_selected > 0 {
            self.ensure_wire_slots(n_selected);
            let payload = self.upload_payload_bytes;
            let precision = self.cfg.upload_precision;
            let mode = self.cfg.compression.mode;
            let sparse_k = self.cfg.compression.k_for(self.global.len());
            let error_feedback = self.cfg.compression.error_feedback;
            self.upload_weights.clear();
            let mut used = 0usize;
            for i in 0..n {
                if fleet_selected[i] {
                    let req = self.ctx.link.transfer_seconds_counted(
                        &Message::UploadRequest,
                        &mut self.net_rng,
                        &mut self.link_capped,
                    );
                    let up = self.ctx.link.transfer_seconds_counted(
                        &Message::ModelUpload { payload_bytes: payload },
                        &mut self.net_rng,
                        &mut self.link_capped,
                    );
                    agg_time = agg_time.max(last_arrival + req + up);
                    bytes_down += Message::UploadRequest.bytes();
                    bytes_down_ctrl += Message::UploadRequest.bytes();
                    // Fault layer (armed only): the payload frame carries
                    // an integrity header and may be terminally lost,
                    // corrupted, or duplicated. Loss/corruption triggers
                    // sender retransmits with capped exponential backoff;
                    // every attempt's wire bytes are charged. A client
                    // whose retransmit budget runs dry drops out of this
                    // round's aggregation (its next report re-enters the
                    // gate as usual).
                    let mut delivered = true;
                    if let Some(plan) = self.faults.as_mut() {
                        let frame = payload + INTEGRITY_HEADER_BYTES;
                        let mut arrival = last_arrival + req + up;
                        let mut attempt = 0u32;
                        loop {
                            match plan.up_fate(arrival) {
                                FrameFate::Delivered => break,
                                FrameFate::Duplicated => {
                                    // Intact, plus a stale copy later: both
                                    // cross the wire; the copy is suppressed
                                    // by its stale sequence number.
                                    self.round_faults.dup_suppressed += 1;
                                    bytes_up += frame;
                                    break;
                                }
                                fate => {
                                    if fate == FrameFate::Lost {
                                        self.round_faults.frames_lost += 1;
                                    } else {
                                        self.round_faults.frames_corrupt += 1;
                                    }
                                    // The failed attempt's bytes were
                                    // transmitted even though they never
                                    // arrived.
                                    bytes_up += frame;
                                    if attempt >= plan.max_retransmits() {
                                        delivered = false;
                                        break;
                                    }
                                    attempt += 1;
                                    self.round_faults.retransmits += 1;
                                    let redo = self.ctx.link.transfer_seconds_counted(
                                        &Message::ModelUpload { payload_bytes: frame },
                                        &mut self.net_rng,
                                        &mut self.link_capped,
                                    );
                                    let prev = arrival;
                                    arrival += plan.backoff(attempt) + redo;
                                    self.obs.virt_span(
                                        SpanPhase::Retransmit,
                                        i as u32,
                                        prev,
                                        arrival,
                                    );
                                    agg_time = agg_time.max(arrival);
                                }
                            }
                        }
                        if delivered {
                            // The delivered frame's header; its payload is
                            // charged below with the fault-free path.
                            bytes_up += INTEGRITY_HEADER_BYTES;
                        }
                    }
                    if !delivered {
                        // Terminal loss: the server never received this
                        // upload, so the client neither joins the
                        // aggregation nor gets the broadcast.
                        fleet_selected[i] = false;
                        lost_uploads += 1;
                        continue;
                    }
                    upload_staleness.push(self.fleet.client(i).staleness);
                    bytes_up += payload;
                    match mode {
                        CompressionMode::Dense => self
                            .fleet
                            .client_mut(i)
                            .encode_upload(precision, &mut self.upload_bufs[used]),
                        CompressionMode::TopK if self.layer_ks.is_empty() => {
                            self.fleet.client_mut(i).encode_sparse_upload(
                                precision,
                                sparse_k,
                                error_feedback,
                                &mut self.sparse_bufs[used],
                            )
                        }
                        CompressionMode::TopK => {
                            self.fleet.client_mut(i).encode_sparse_upload_layers(
                                precision,
                                &self.layer_sizes,
                                &self.layer_ks,
                                error_feedback,
                                &mut self.sparse_bufs[used],
                            )
                        }
                    }
                    // FedAvg weight n_i, optionally decayed by staleness
                    // (FedAsync-style extension; None = paper's Alg. 1),
                    // then soft-quarantined by the trust score (armed
                    // trust only — disarmed runs keep weights bitwise).
                    let decay = self
                        .cfg
                        .staleness_decay
                        .map_or(1.0, |d| d.powi(self.fleet.client(i).staleness as i32));
                    let mut w = self.fleet.client(i).num_samples() as f64 * decay;
                    if trust_on {
                        let m = self.trust.multiplier(
                            i,
                            self.cfg.robust.trust_threshold,
                            self.cfg.robust.trust_floor,
                        );
                        if m < 1.0 {
                            quarantined += 1;
                        }
                        w *= m;
                    }
                    self.upload_weights.push(w);
                    used += 1;
                }
            }
            if robust {
                self.outlier_counts.clear();
                self.outlier_counts.resize(used, 0);
            }
            let spec = RobustSpec {
                mode: self.cfg.robust.mode,
                trim: self.cfg.robust.trim_fraction,
            };
            // With fault injection every selected upload may have been
            // lost; an empty fan-in leaves the global model untouched.
            match mode {
                _ if used == 0 => {}
                CompressionMode::Dense if robust => self.agg.aggregate_payloads_robust(
                    &self.upload_bufs[..used],
                    &self.upload_weights,
                    0.0,
                    spec,
                    &mut self.global,
                    &mut self.outlier_counts,
                ),
                CompressionMode::Dense => self.agg.aggregate_payloads(
                    &self.upload_bufs[..used],
                    &self.upload_weights,
                    &mut self.global,
                ),
                CompressionMode::TopK if robust => self.agg.aggregate_sparse_payloads_robust(
                    &self.sparse_bufs[..used],
                    &self.upload_weights,
                    0.0,
                    spec,
                    &mut self.global,
                    &mut self.outlier_counts,
                ),
                // Masked FedAvg: transmitted coordinates mix exactly like
                // the dense path; a coordinate some upload omitted keeps
                // that upload's weight mass on the current global.
                CompressionMode::TopK => self.agg.aggregate_sparse_payloads(
                    &self.sparse_bufs[..used],
                    &self.upload_weights,
                    0.0,
                    &mut self.global,
                ),
            }
            if robust && used > 0 {
                // Per-payload trimmed-coordinate rates feed the trust book
                // (payload order here is ascending client id).
                let dim = self.global.len();
                let mut rate_sum = 0.0f64;
                let mut j = 0usize;
                for i in 0..n {
                    if !fleet_selected[i] {
                        continue;
                    }
                    let denom = match mode {
                        CompressionMode::Dense => dim,
                        CompressionMode::TopK => self.sparse_bufs[j].len(),
                    };
                    let rate = if denom == 0 {
                        0.0
                    } else {
                        self.outlier_counts[j] as f64 / denom as f64
                    };
                    rate_sum += rate;
                    if trust_on {
                        self.trust.update(i, rate);
                    }
                    j += 1;
                }
                outlier_rate = rate_sum / used as f64;
            }
        }
        if n_selected > 0 {
            self.obs.virt_span(SpanPhase::Flush, NO_CLIENT, last_arrival, agg_time);
            self.obs.wall_span(SpanPhase::Flush, NO_CLIENT, agg_time, flush_ws);
        }
        self.queue.advance_to(agg_time);

        // --- 4. Broadcast to participants; skipped clients go stale.
        // The broadcast crosses the wire at the effective downlink
        // precision (`compression.down_precision`, defaulting to the
        // upload precision); the codec runs once per round into reusable
        // buffers.
        let bcast_ws = self.obs.wall_start();
        let down_precision = self.cfg.compression.down_precision_or(self.cfg.upload_precision);
        let bcast_model: Option<&[f32]> = if down_precision == Precision::F32 {
            None
        } else {
            self.bcast_buf.encode(down_precision, &self.global);
            // No clear(): after round 1 the resize is a no-op and
            // decode_into overwrites every element anyway.
            self.bcast_model.resize(self.global.len(), 0.0);
            self.bcast_buf.decode_into(&mut self.bcast_model);
            Some(&self.bcast_model)
        };
        let mut bcast_done = agg_time;
        let down_topk = self.cfg.compression.down_mode == CompressionMode::TopK;
        let down_k = self.cfg.compression.down_k_for(self.global.len());
        let armed = self.faults.is_some();
        for i in 0..n {
            if n_selected > 0 && fleet_selected[i] {
                // Runtime promotion of the base-agreement debug_assert
                // (armed only): a divergent acked base — e.g. from a frame
                // the client never actually applied — routes through a
                // forced dense re-sync instead of shipping a delta against
                // the wrong base.
                if armed
                    && down_topk
                    && self.downlink.has_base(i)
                    && !self.downlink.base_matches(i, self.fleet.client(i).sync_base())
                {
                    self.round_faults.resyncs += 1;
                    self.round_faults.recoveries += 1;
                    self.downlink.drop_client(i);
                }
                // Encode (or force-dense) first: the frame's actual wire
                // size drives both the transfer time and the bytes
                // charged, so they can never diverge from the encode.
                let payload_bytes = if down_topk {
                    match self.downlink.encode_for(i, &self.global, down_k) {
                        Some(delta) => {
                            let b = delta.payload_bytes();
                            self.fleet.client_mut(i).sync_sparse(delta);
                            b
                        }
                        // No acked base (first contact): dense frame,
                        // which establishes the shared base.
                        None => {
                            let target = bcast_model.unwrap_or(&self.global);
                            self.fleet.client_mut(i).sync(target);
                            self.downlink.ack_dense(i, target);
                            self.down_payload_bytes
                        }
                    }
                } else {
                    self.fleet.client_mut(i).sync(bcast_model.unwrap_or(&self.global));
                    self.down_payload_bytes
                };
                debug_assert!(
                    armed
                        || !down_topk
                        || self.downlink.base_of(i) == Some(self.fleet.client(i).sync_base()),
                    "downlink base diverged from client {i}'s acked base"
                );
                let mut frame_bytes = payload_bytes;
                // Fault layer (armed only): the broadcast frame carries an
                // integrity header and may be lost or corrupted in
                // transit; the client NACKs (one 68 B control frame up)
                // and the server answers with a forced dense re-sync,
                // which always re-establishes the shared base.
                if let Some(plan) = self.faults.as_mut() {
                    frame_bytes += INTEGRITY_HEADER_BYTES;
                    let fate = plan.down_fate();
                    if matches!(fate, FrameFate::Lost | FrameFate::Corrupt) {
                        if fate == FrameFate::Lost {
                            self.round_faults.frames_lost += 1;
                        } else {
                            self.round_faults.frames_corrupt += 1;
                        }
                        self.round_faults.resyncs += 1;
                        // The failed frame still occupied the wire.
                        bytes_down += frame_bytes;
                        let failed = self.ctx.link.transfer_seconds_counted(
                            &Message::ModelBroadcast { payload_bytes: frame_bytes },
                            &mut self.net_rng,
                            &mut self.link_capped,
                        );
                        bcast_done = bcast_done.max(agg_time + failed);
                        // NACK control frame on the uplink.
                        bytes_up += Message::ValueReport.bytes();
                        bytes_up_ctrl += Message::ValueReport.bytes();
                        // Forced dense re-sync (idempotent for clients the
                        // dense path already synced).
                        let target = bcast_model.unwrap_or(&self.global);
                        self.fleet.client_mut(i).sync(target);
                        if down_topk {
                            self.downlink.ack_dense(i, target);
                        }
                        frame_bytes = self.down_payload_bytes + INTEGRITY_HEADER_BYTES;
                    }
                }
                let down = self.ctx.link.transfer_seconds_counted(
                    &Message::ModelBroadcast { payload_bytes: frame_bytes },
                    &mut self.net_rng,
                    &mut self.link_capped,
                );
                bcast_done = bcast_done.max(agg_time + down);
                bytes_down += frame_bytes;
            } else if self.registry.is_active(i) {
                self.fleet.client_mut(i).mark_stale();
            }
        }
        if n_selected > 0 {
            self.obs.virt_span(SpanPhase::DownlinkEncode, NO_CLIENT, agg_time, bcast_done);
            self.obs.wall_span(SpanPhase::DownlinkEncode, NO_CLIENT, bcast_done, bcast_ws);
        }
        self.queue.advance_to(bcast_done);

        self.push_history();

        // --- 5. Evaluate + record.
        let (global_acc, global_loss) = if round % self.cfg.eval_every == 0 {
            let ws = self.obs.wall_start();
            let r = evaluate_with_params(
                exec,
                &self.global,
                &self.ctx.test_images[..],
                &self.ctx.test_labels[..],
            )?;
            self.obs.wall_span(SpanPhase::Eval, NO_CLIENT, self.queue.now(), ws);
            r
        } else {
            (f64::NAN, f64::NAN)
        };

        // Uploads count *delivered* payloads; a selected upload whose
        // retransmit budget ran dry (faults only) joined no aggregation.
        let n_delivered = n_selected - lost_uploads;
        let cum_uploads =
            self.metrics.records.last().map_or(0, |r| r.cum_uploads) + n_delivered;
        // Compact records (fleet-scale runs): drop the O(n) per-round
        // vectors — at 10⁶ clients they would dominate resident memory.
        let compact = self.cfg.fleet.compact_records;
        let record = RoundRecord {
            round,
            vtime: self.queue.now(),
            global_acc,
            global_loss,
            train_loss: reports.iter().map(|r| r.train_loss).sum::<f64>()
                / n_active.max(1) as f64,
            uploads: n_delivered,
            cum_uploads,
            bytes_up,
            bytes_down,
            bytes_up_ctrl,
            bytes_down_ctrl,
            threshold: selection.threshold,
            values: if compact { Vec::new() } else { fleet_values },
            selected: if compact { Vec::new() } else { fleet_selected },
            client_accs: if compact { Vec::new() } else { fleet_accs },
            idle_seconds,
            reports: n_active,
            in_flight: 0,
            upload_staleness,
            shard: 0,
            spec_committed: 0,
            spec_replayed: 0,
            quarantined,
            trust_mean: if trust_on { self.trust.mean_score() } else { f64::NAN },
            faults: std::mem::take(&mut self.round_faults),
        };
        if global_acc.is_finite() {
            log_info!(
                "server",
                "[{}] round {round:>3}: acc={global_acc:.4} uploads={n_selected}/{n_active} cum={cum_uploads} vt={:.1}s",
                self.metrics.algorithm,
                self.queue.now()
            );
        }
        if self.control.enabled() {
            // Same commit-time telemetry shape as the barrier-free
            // engine: one sample per aggregation, shard always 0. The
            // accuracy proxy reads *last-known* accs, so a client
            // dropping offline never shifts the mean's composition.
            for rep in &reports {
                self.last_accs[rep.client_id] = rep.acc;
            }
            let (residual_l1, transmitted_l1) = self.sparse_flush_mass(n_selected);
            let (down_residual_l1, down_transmitted_l1) = self.down_flush_mass();
            self.control.observe(FlushSample {
                round,
                shard: 0,
                vtime: self.queue.now(),
                uploads: n_selected,
                staleness_sum: record.upload_staleness.iter().sum(),
                staleness_max: record.staleness_max(),
                bytes_up: record.bytes_up,
                residual_l1,
                transmitted_l1,
                down_residual_l1,
                down_transmitted_l1,
                acc_proxy: mean_finite(&self.last_accs),
                outlier_rate,
            });
            if self.control.due(round) {
                let now = self.queue.now();
                let ws = self.obs.wall_start();
                self.control_tick_barriered(round, now);
                self.obs.wall_span(SpanPhase::ControlTick, NO_CLIENT, now, ws);
            }
        }
        if self.cfg.trace_events {
            // The barriered engine has no per-event lifecycle to trace;
            // one line per round keeps `--realtime` coherent alongside
            // any control-decision lines.
            self.metrics.event_trace.push((
                self.queue.now(),
                format!("round {round}  uploads={n_selected}/{n_active}  cum={cum_uploads}"),
            ));
        }
        self.mirror_record(&record);
        // Round commit = the barriered engine's drain point for any
        // worker-ring wall spans (`run_round_threaded`).
        self.obs.drain();
        self.metrics.push(record.clone());
        self.metrics.link_capped = self.link_capped;
        Ok(record)
    }

    /// Mirror one committed record's counters onto the unified
    /// [`MetricRegistry`] — the registry is the single source of truth
    /// the Prometheus exporter reads, while the CSV/JSON columns keep
    /// their historical names and order (`tests/obs.rs` pins that the
    /// registry totals and the summed record columns agree).
    fn mirror_record(&mut self, r: &RoundRecord) {
        let reg = &mut self.obs.registry;
        reg.inc(Counter::Flushes);
        reg.add(Counter::Uploads, r.uploads as u64);
        reg.add(Counter::SpecCommitted, r.spec_committed as u64);
        reg.add(Counter::SpecReplayed, r.spec_replayed as u64);
        reg.add(Counter::Quarantined, r.quarantined as u64);
        reg.add(Counter::Retransmits, r.faults.retransmits);
        reg.add(Counter::FramesLost, r.faults.frames_lost);
        reg.add(Counter::FramesCorrupt, r.faults.frames_corrupt);
        reg.add(Counter::DupSuppressed, r.faults.dup_suppressed);
        reg.add(Counter::Resyncs, r.faults.resyncs);
        reg.add(Counter::Recoveries, r.faults.recoveries);
        // `link_capped` is a lifetime total on the server; the registry
        // carries the same cumulative value via deltas (restores reload
        // the registry alongside `link_capped`, keeping them in step).
        let capped = self.link_capped.saturating_sub(reg.counter(Counter::LinkCapped));
        reg.add(Counter::LinkCapped, capped);
        reg.set_gauge(Gauge::TrustMean, r.trust_mean);
        reg.set_gauge(Gauge::InFlight, r.in_flight as f64);
        reg.set_gauge(Gauge::QueueDepth, self.queue.len() as f64);
    }

    /// Fold the final observability report into `RunMetrics::obs`
    /// (idempotent; `None` while disarmed, so disarmed JSON stays
    /// byte-identical). The engines call it when a run completes; the
    /// threaded-barriered driver in `experiments::run` calls it after
    /// its external round loop.
    pub fn finalize_obs(&mut self) {
        if self.metrics.obs.is_none() {
            self.metrics.obs = self.obs.finalize_report();
        }
    }

    /// Bound the history to what the policy needs (plus the current);
    /// retired entries are recycled through `history_pool`, so the
    /// steady-state round never allocates here.
    fn push_history(&mut self) {
        let g = std::mem::take(&mut self.global);
        self.push_history_from(&g);
        self.global = g;
    }

    /// [`Server::push_history`] for an explicit model (the unsharded
    /// engines push the global; sharded flushes go to the per-shard
    /// histories in `EngineState` instead).
    fn push_history_from(&mut self, model: &[f32]) {
        let keep = self.policy.history_depth().max(1) + 1;
        push_bounded_history(&mut self.history, &mut self.history_pool, keep, model);
    }

    /// Run all configured rounds. With a queued [`Server::restore_checkpoint`]
    /// snapshot the loop resumes mid-stream; with
    /// `faults.checkpoint_every > 0` it refreshes [`Server::checkpoint_bytes`]
    /// at round boundaries; with a [`Server::stop_after`] kill switch it
    /// abandons the run right after that many rounds (crash tests).
    pub fn run(&mut self, exec: &mut dyn Executor) -> Result<()> {
        if let Some(bytes) = self.restore.take() {
            let ws = self.obs.wall_start();
            self.apply_barriered_checkpoint(&bytes)?;
            self.obs.wall_span(SpanPhase::CheckpointRestore, NO_CLIENT, self.queue.now(), ws);
        }
        while self.round < self.cfg.rounds {
            self.run_round(exec)?;
            let every = self.cfg.faults.checkpoint_every;
            if every > 0 && self.round % every == 0 {
                let ws = self.obs.wall_start();
                // Counted before the snapshot so the registry the
                // checkpoint carries already includes this save.
                self.obs.registry.inc(Counter::Checkpoints);
                self.checkpoint = Some(self.save_barriered_checkpoint());
                self.obs.wall_span(SpanPhase::CheckpointSave, NO_CLIENT, self.queue.now(), ws);
            }
            if self.stop_after > 0 && self.round >= self.stop_after {
                return Ok(());
            }
        }
        self.finalize_obs();
        Ok(())
    }

    /// Latest committed checkpoint snapshot (`faults.checkpoint_every`).
    pub fn checkpoint_bytes(&self) -> Option<&[u8]> {
        self.checkpoint.as_deref()
    }

    /// Queue a checkpoint snapshot for the next `run*` call, which resumes
    /// the killed run mid-stream on this freshly built (same-config) server.
    pub fn restore_checkpoint(&mut self, bytes: &[u8]) {
        self.restore = Some(bytes.to_vec());
    }

    /// Abandon the run right after `commits` rounds/flushes have been
    /// recorded — the deterministic "kill -9" of the crash-recovery tests.
    /// 0 disables the switch.
    pub fn stop_after(&mut self, commits: usize) {
        self.stop_after = commits;
    }

    const CKPT_MAGIC: &'static [u8; 8] = b"VAFLCKPT";
    /// v2: edge-tier accumulators in `EngineState` + the obs
    /// `MetricRegistry` in the shared core.
    const CKPT_VERSION: u32 = 2;

    /// Serialize the mutable server state shared by both engines. Config-
    /// derived state (aggregator scratch, wire buffers, policies — all
    /// stateless) is rebuilt by constructing the same-config server the
    /// snapshot is later applied to.
    fn save_core(&self, enc: &mut Enc) {
        enc.usize(self.round);
        enc.f32s(&self.global);
        enc.usize(self.history.len());
        for h in &self.history {
            enc.f32s(h);
        }
        enc.f64s(&self.last_accs);
        // Knob floats the control plane may have retuned away from config.
        enc.f64(self.cfg.compression.k_fraction);
        enc.f64(self.cfg.compression.down_k_fraction);
        enc.f64(self.cfg.robust.trust_threshold);
        enc.f64(self.cfg.robust.trim_fraction);
        enc.u64(self.link_capped);
        self.queue.save(enc, |p, e| p.save(e));
        let (s, spare) = self.net_rng.state();
        enc.u64s(&s);
        enc.opt_f64(spare);
        self.fleet.save(enc);
        self.registry.save(enc);
        self.downlink.save(enc);
        self.trust.save(enc);
        self.control.save(enc);
        enc.bool(self.faults.is_some());
        if let Some(plan) = &self.faults {
            plan.save(enc);
        }
        // The committed metrics prefix: restore replays nothing — the
        // record stream continues bitwise from here.
        enc.usize(self.metrics.records.len());
        for r in &self.metrics.records {
            r.save(enc);
        }
        enc.usize(self.metrics.control_records.len());
        for c in &self.metrics.control_records {
            c.save(enc);
        }
        enc.usize(self.metrics.engine_events);
        // The unified metric registry rides the checkpoint so counter
        // totals resume bitwise (spans do not — a restored run's trace
        // covers the post-restore stream only).
        self.obs.registry.save(enc);
    }

    /// Restore the state saved by [`Server::save_core`] into this freshly
    /// built same-config server.
    fn load_core(&mut self, dec: &mut Dec) -> Result<()> {
        self.round = dec.usize()?;
        self.global = dec.f32s()?;
        let hn = dec.usize()?;
        self.history.clear();
        for _ in 0..hn {
            self.history.push(dec.f32s()?);
        }
        self.last_accs = dec.f64s()?;
        let kf = dec.f64()?;
        self.set_k_fraction(kf);
        let dkf = dec.f64()?;
        self.set_down_k_fraction(dkf);
        self.cfg.robust.trust_threshold = dec.f64()?;
        self.cfg.robust.trim_fraction = dec.f64()?;
        self.link_capped = dec.u64()?;
        self.queue = EventQueue::load(dec, EngineEvent::load)?;
        let s = dec.u64s()?;
        anyhow::ensure!(s.len() == 4, "bad net_rng state length {}", s.len());
        self.net_rng = Rng::from_state([s[0], s[1], s[2], s[3]], dec.opt_f64()?);
        self.fleet.load(dec)?;
        self.registry.load(dec)?;
        self.downlink.load(dec)?;
        self.trust.load(dec)?;
        self.control.load(dec)?;
        let armed = dec.bool()?;
        anyhow::ensure!(
            armed == self.faults.is_some(),
            "checkpoint fault-arming disagrees with this server's config"
        );
        if let Some(plan) = self.faults.as_mut() {
            plan.load(dec)?;
        }
        let rn = dec.usize()?;
        self.metrics.records.clear();
        for _ in 0..rn {
            self.metrics.records.push(RoundRecord::load(dec)?);
        }
        let cn = dec.usize()?;
        self.metrics.control_records.clear();
        for _ in 0..cn {
            self.metrics.control_records.push(ControlRecord::load(dec)?);
        }
        self.metrics.engine_events = dec.usize()?;
        self.metrics.link_capped = self.link_capped;
        self.obs.registry = crate::obs::MetricRegistry::load(dec)?;
        Ok(())
    }

    /// Full barriered-engine checkpoint (engine tag 0): the shared core is
    /// the whole mutable state — the barriered loop keeps nothing else
    /// between rounds.
    fn save_barriered_checkpoint(&self) -> Vec<u8> {
        let mut enc = Enc::new();
        enc.header(Self::CKPT_MAGIC, Self::CKPT_VERSION);
        enc.u8(0);
        self.save_core(&mut enc);
        enc.into_bytes()
    }

    fn apply_barriered_checkpoint(&mut self, bytes: &[u8]) -> Result<()> {
        let mut dec = Dec::new(bytes);
        dec.expect_header(Self::CKPT_MAGIC, Self::CKPT_VERSION)?;
        anyhow::ensure!(dec.u8()? == 0, "not a barriered-engine checkpoint");
        self.load_core(&mut dec)?;
        dec.finish()
    }

    /// Full barrier-free-engine checkpoint (engine tag 1): the shared
    /// core plus the event loop's own state — retuned knobs, the flush
    /// counter, the engine state, and (S > 1) the shard model replicas.
    fn save_async_checkpoint(
        &self,
        st: &EngineState,
        k: usize,
        mixing: MixingRule,
        flushes: usize,
        shard_models: &[Vec<f32>],
    ) -> Vec<u8> {
        let mut enc = Enc::new();
        enc.header(Self::CKPT_MAGIC, Self::CKPT_VERSION);
        enc.u8(1);
        self.save_core(&mut enc);
        enc.usize(k);
        enc.f64(mixing.alpha0());
        enc.usize(flushes);
        st.save(&mut enc);
        enc.usize(shard_models.len());
        for m in shard_models {
            enc.f32s(m);
        }
        enc.into_bytes()
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_async_checkpoint(
        &mut self,
        bytes: &[u8],
        st: &mut EngineState,
        k: &mut usize,
        mixing: &mut MixingRule,
        flushes: &mut usize,
        shard_models: &mut Vec<Vec<f32>>,
    ) -> Result<()> {
        let mut dec = Dec::new(bytes);
        dec.expect_header(Self::CKPT_MAGIC, Self::CKPT_VERSION)?;
        anyhow::ensure!(dec.u8()? == 1, "not a barrier-free-engine checkpoint");
        self.load_core(&mut dec)?;
        *k = dec.usize()?;
        *mixing = mixing.with_alpha0(dec.f64()?);
        *flushes = dec.usize()?;
        st.load(&mut dec)?;
        let sn = dec.usize()?;
        shard_models.clear();
        for _ in 0..sn {
            shard_models.push(dec.f32s()?);
        }
        dec.finish()
    }

    /// Run the barrier-free event-driven engine for `cfg.rounds`
    /// aggregations (buffer flushes).
    ///
    /// Clients run on independent virtual clocks: each `Start -> local
    /// round -> Report` is gated on arrival ([`SelectionPolicy::
    /// gate_report`] against the fleet's last-known values), gated clients
    /// upload, and the server aggregates once `async_engine.buffer_k`
    /// uploads have accumulated — folding the buffer into the global model
    /// with the staleness-weighted mixing rule `alpha(tau)`
    /// ([`MixingRule`]). Skipped clients keep training their (now stale)
    /// local models immediately; no one ever waits for a straggler.
    ///
    /// Determinism: the engine is a single-threaded event loop over the
    /// deterministic [`EventQueue`] (time, then sequence number), every
    /// stochastic choice flows from named per-stream forks of the
    /// experiment seed, and the parallel kernels underneath are
    /// bit-identical for every worker count — so two runs with the same
    /// seed and `VAFL_THREADS` produce identical `RoundRecord` streams
    /// (asserted in `rust/tests/engine_async.rs` and pinned by the
    /// golden-run snapshot).
    ///
    /// With `buffer_k == num_clients` and `alpha == 1` the engine
    /// degenerates to the barriered algorithm: every flush contains
    /// exactly one upload per (gated) client and the mix is plain FedAvg
    /// replacement.
    pub fn run_event_driven(&mut self, exec: &mut dyn Executor) -> Result<()> {
        self.run_event_driven_inner(exec, None)
    }

    /// [`Server::run_event_driven`] with client compute overlapped on an
    /// [`ExecutorPool`] via speculative execution.
    ///
    /// Wherever the event loop schedules a `Start`, the client's next
    /// local round is immediately dispatched to a pool worker against a
    /// snapshot of its training state ([`Client::speculate`]); the result
    /// is committed strictly when that `Start` pops, in virtual-event
    /// order. A client's training inputs cannot change between schedule
    /// and pop in this engine (it is blocked between upload and
    /// broadcast), so the common case commits the speculation as-is; if
    /// the forked state was ever superseded (tracked by the client's
    /// training-state epoch), the round is recomputed serially at the
    /// commit point. Either way the committed `RoundRecord` stream is
    /// **bitwise identical** to the serial engine (asserted in
    /// `rust/tests/engine_async.rs`); only wall-clock changes. Flush-time
    /// model evaluations are overlapped the same way and patched into
    /// their records before this method returns.
    pub fn run_event_driven_threaded(
        &mut self,
        exec: &mut dyn Executor,
        pool: &ExecutorPool,
    ) -> Result<()> {
        self.run_event_driven_inner(exec, Some(pool))
    }

    fn run_event_driven_inner(
        &mut self,
        exec: &mut dyn Executor,
        pool: Option<&ExecutorPool>,
    ) -> Result<()> {
        let n = self.fleet.len();
        // `k` and `mixing` are engine-local state, not config reads: the
        // control plane's staleness controller may retune both at commit
        // points (`control_tick_async`). Upload payload bytes are read
        // from `self` at each schedule so `k_fraction` retunes apply to
        // the next upload on the wire.
        let mut k = self.cfg.async_engine.buffer_k.clamp(1, n);
        let mut mixing = self.cfg.async_engine.mixing;
        let knobs = RoundKnobs {
            passes: self.cfg.local_passes,
            batches: self.cfg.batches_per_pass,
            lr: self.cfg.lr,
            train_flops: self.ctx.train_flops,
            eval_flops: self.ctx.eval_flops,
        };

        // Shard layout: the fleet is partitioned round-robin across
        // `engine.shards` aggregator shards, each with its own buffer-of-K
        // (clamped to the shard population so no shard can starve its own
        // buffer) and, for S > 1, its own model replica reconciled into
        // the true global every `engine.reconcile_every` flushes. S == 1
        // runs directly on `self.global` — bitwise the unsharded engine.
        let s_count = self.cfg.engine_opts.shards.clamp(1, n);
        let reconcile_every = self.cfg.engine_opts.reconcile_every.max(1);
        let shard_of: Vec<usize> = (0..n).map(|c| c % s_count).collect();
        let mut shard_pop = vec![0usize; s_count];
        for &s in &shard_of {
            shard_pop[s] += 1;
        }
        let shard_k: Vec<usize> = shard_pop.iter().map(|&p| k.clamp(1, p.max(1))).collect();
        let mut shard_weight = vec![0.0f64; s_count];
        for (c, &s) in shard_of.iter().enumerate() {
            // Sample counts come from the fleet's park-aware accessor —
            // reading them must not hydrate anyone.
            shard_weight[s] += self.fleet.num_samples(c) as f64;
        }
        let mut shard_models: Vec<Vec<f32>> = if s_count > 1 {
            (0..s_count).map(|_| self.global.clone()).collect()
        } else {
            Vec::new()
        };
        // Per-shard gate history (S > 1): each replica starts its history
        // at the current global, mirroring `Server::new`'s seeding of the
        // S == 1 history.
        let shard_history: Vec<Vec<Vec<f32>>> = if s_count > 1 {
            (0..s_count).map(|_| vec![self.global.clone()]).collect()
        } else {
            Vec::new()
        };

        // Active-set window: only the first `active` clients hydrate and
        // run; the rest wait parked in FIFO order and rotate in as
        // flushed clients park (see `flush_shard`'s broadcast loop).
        // `active == n` (including `active_set == 0`, where `Server::new`
        // hydrated everyone) leaves `waiting` empty and the engine on the
        // legacy path, bitwise.
        let active = if self.cfg.fleet.active_set == 0 {
            n
        } else {
            self.cfg.fleet.active_set.min(n)
        };

        // Edge tier (`engine.edge_fanout > 1`): per-(shard, edge) running
        // sums, folded at upload arrival and combined at flush.
        let fanout = self.cfg.engine_opts.edge_fanout;
        let mut edges: Vec<EdgeAccum> = Vec::new();
        if fanout > 1 {
            let dim = self.global.len();
            let sparse = self.cfg.compression.mode == CompressionMode::TopK;
            edges.resize_with(s_count * fanout, EdgeAccum::new);
            for e in edges.iter_mut() {
                e.reset(dim, sparse);
            }
        }

        let mut st = EngineState {
            pending: (0..n).map(|_| None).collect(),
            last_values: vec![f64::NAN; n],
            last_accs: vec![f64::NAN; n],
            local_rounds: vec![0usize; n],
            synced_version: vec![0u64; n],
            backoff: vec![1.0f64; n],
            spec: (0..n).map(|_| None).collect(),
            window: FlushWindow::default(),
            pending_evals: Vec::new(),
            skip_streak: 0,
            in_flight: 0,
            shard_of,
            shard_pop,
            upload_in_flight: vec![false; n],
            upload_k: vec![0usize; n],
            shard_k,
            buffers: (0..s_count).map(|_| Vec::with_capacity(k)).collect(),
            shard_version: vec![0u64; s_count],
            shard_weight,
            shard_history,
            waiting: (active..n).collect(),
            edges,
            edge_residual: vec![0.0f64; s_count],
            edge_transmitted: vec![0.0f64; s_count],
            tx_seq: vec![0u64; n],
            rx_seq: vec![0u64; n],
        };

        let mut flushes = 0usize;
        let events_before = self.queue.total_popped();
        let t0 = self.queue.now();
        // Worker-side observability sink (armed + threaded only): cloned
        // into every speculative dispatch so pool workers can record
        // `SpecExecute` wall spans without touching engine state.
        let obs_shared = self.obs.shared();
        if let Some(bytes) = self.restore.take() {
            // Resume a killed run mid-stream: the queue, fleet, RNG
            // streams, and the committed record prefix all restore
            // bitwise. Speculations are deliberately not re-dispatched —
            // a restored `Start` pops with an empty slot and replays its
            // round serially, which is bitwise identical to committing
            // the speculation (the engine's core invariant).
            let ws = self.obs.wall_start();
            self.apply_async_checkpoint(
                &bytes,
                &mut st,
                &mut k,
                &mut mixing,
                &mut flushes,
                &mut shard_models,
            )?;
            self.obs.wall_span(
                SpanPhase::CheckpointRestore,
                NO_CLIENT,
                self.queue.now(),
                ws,
            );
        } else {
            for i in 0..active {
                // No-op when already hydrated (`active_set == 0` / reruns).
                self.fleet.hydrate(i, &self.global);
                self.queue.schedule_at(t0, EngineEvent::Start { client: i });
                dispatch_speculation(
                    &self.fleet,
                    &mut st,
                    pool,
                    obs_shared.as_ref(),
                    i,
                    t0,
                    knobs,
                )?;
            }
        }

        while flushes < self.cfg.rounds {
            let ev = self
                .queue
                .pop()
                .expect("event-driven engine starved (no events, no pending flush)");
            let t = ev.time;
            match ev.payload {
                EngineEvent::Start { client } => {
                    if !self.registry.poll(client) {
                        // Offline: the local model goes stale and the
                        // client retries after one local-round span. An
                        // in-flight speculation stays pending — staleness
                        // never feeds the local round, so the fork is
                        // still valid for the retry.
                        self.fleet.client_mut(client).mark_stale();
                        self.queue
                            .schedule_at(t + st.backoff[client], EngineEvent::Start { client });
                        continue;
                    }
                    if let Some(plan) = self.faults.as_mut() {
                        if plan.crash() {
                            // Crash: the client loses its volatile training
                            // state and parks; it reboots from a fresh dense
                            // sync after the configured downtime (see the
                            // `Restart` arm). A speculation forked from the
                            // now-lost state is dropped — its worker's send
                            // fails harmlessly, as in the post-loop drain.
                            st.spec[client] = None;
                            self.fleet.park(client);
                            if self.cfg.compression.down_mode == CompressionMode::TopK {
                                // The acked downlink base died with the
                                // client.
                                self.downlink.drop_client(client);
                            }
                            if self.cfg.trace_events {
                                self.metrics.event_trace.push((t, format!("crash c{client}")));
                            }
                            self.queue.schedule_at(
                                t + plan.crash_downtime(),
                                EngineEvent::Restart { client },
                            );
                            continue;
                        }
                    }
                    st.local_rounds[client] += 1;
                    let exec_ws = self.obs.wall_start();
                    let rep = match st.spec[client].take() {
                        Some(spec) => {
                            let (ghost, rep) = spec.rx.recv().map_err(|_| {
                                anyhow!("speculative worker dropped client {client}'s round")
                            })?;
                            if spec.epoch == self.fleet.client(client).epoch() {
                                st.window.spec_committed += 1;
                                self.obs.virt_span(SpanPhase::SpecCommit, client as u32, t, t);
                                self.fleet.client_mut(client).commit_speculation(ghost);
                                rep?
                            } else {
                                // The forked state was superseded: replay
                                // the round serially at the commit point.
                                // Unreachable in the current engine (a
                                // client's training inputs cannot change
                                // while its round is in flight) — this is
                                // the safety net for future engine
                                // changes, and the serial==threaded
                                // equivalence tests pin its correctness
                                // the moment any change makes it live.
                                crate::log_warn!(
                                    "server",
                                    "speculation for client {client} superseded; replaying serially"
                                );
                                st.window.spec_replayed += 1;
                                self.obs.virt_span(SpanPhase::SpecReplay, client as u32, t, t);
                                run_local_round(
                                    self.fleet.client_mut(client),
                                    exec,
                                    st.local_rounds[client],
                                    knobs,
                                )?
                            }
                        }
                        None => run_local_round(
                            self.fleet.client_mut(client),
                            exec,
                            st.local_rounds[client],
                            knobs,
                        )?,
                    };
                    // Wall time covers the commit work on the engine thread
                    // (recv + commit, or the serial replay); virtual time
                    // covers the simulated compute span the record sees.
                    self.obs.wall_span(SpanPhase::ClientExecute, client as u32, t, exec_ws);
                    self.obs.virt_span(
                        SpanPhase::ClientExecute,
                        client as u32,
                        t,
                        t + rep.compute_seconds,
                    );
                    st.backoff[client] = rep.compute_seconds.max(1e-9);
                    if self.cfg.trace_events {
                        self.metrics.event_trace.push((
                            t,
                            format!(
                                "start c{client}  local_round={}  compute={:.2}s",
                                st.local_rounds[client], rep.compute_seconds
                            ),
                        ));
                    }
                    let uplink = self.ctx.link.transfer_seconds_counted(
                        &Message::ValueReport,
                        &mut self.net_rng,
                        &mut self.link_capped,
                    );
                    let arrive = t + rep.compute_seconds + uplink;
                    st.pending[client] = Some(rep);
                    self.queue.schedule_at(arrive, EngineEvent::Report { client });
                }
                EngineEvent::Report { client } => {
                    let rep =
                        st.pending[client].take().expect("report without a local round");
                    st.window.bytes_up += Message::ValueReport.bytes();
                    st.window.bytes_up_ctrl += Message::ValueReport.bytes();
                    let decision = {
                        // Sharded runs gate against the reporting
                        // client's own shard history, so EAFLM's Eq. 3
                        // threshold measures consecutive movement of the
                        // same replica.
                        let gctx = AsyncGateContext {
                            n_clients: n,
                            last_values: &st.last_values,
                            global_history: if s_count == 1 {
                                &self.history
                            } else {
                                &st.shard_history[st.shard_of[client]]
                            },
                        };
                        self.policy.gate_report(&rep, &gctx)
                    };
                    st.last_values[client] = decision.value;
                    st.last_accs[client] = rep.acc;
                    st.window.reports += 1;
                    st.window.train_loss_sum += rep.train_loss;
                    st.window.threshold = decision.threshold;
                    let force = !decision.upload && st.skip_streak >= 8 * n;
                    if self.cfg.trace_events {
                        self.metrics.event_trace.push((
                            t,
                            format!(
                                "report c{client}  upload={}  in_flight={}",
                                if decision.upload || force { "yes" } else { "no" },
                                st.in_flight
                            ),
                        ));
                    }
                    if decision.upload || force {
                        if force {
                            log_debug!(
                                "server",
                                "forcing upload from client {client} after {} gated reports",
                                st.skip_streak
                            );
                        }
                        st.skip_streak = 0;
                        // Read the payload size per upload, not per run:
                        // the compression controller may have retuned
                        // `k_fraction` (and with it the sparse frame
                        // size) since the engine started. The budget is
                        // snapshotted alongside so the flush-time encode
                        // matches the bytes charged here.
                        let upload_payload = self.upload_payload_bytes;
                        st.upload_k[client] =
                            self.cfg.compression.k_for(self.global.len());
                        let req = self.ctx.link.transfer_seconds_counted(
                            &Message::UploadRequest,
                            &mut self.net_rng,
                            &mut self.link_capped,
                        );
                        let up = self.ctx.link.transfer_seconds_counted(
                            &Message::ModelUpload { payload_bytes: upload_payload },
                            &mut self.net_rng,
                            &mut self.link_capped,
                        );
                        st.window.bytes_down += Message::UploadRequest.bytes();
                        st.window.bytes_down_ctrl += Message::UploadRequest.bytes();
                        st.in_flight += 1;
                        st.upload_in_flight[client] = true;
                        // Faults armed: stamp the frame with the client's
                        // next monotone sequence number (duplicate
                        // suppression at the receiver) and let reordering
                        // hold the frame past its natural arrival.
                        let mut arrive = t + req + up;
                        let seq = if let Some(plan) = self.faults.as_mut() {
                            arrive += plan.reorder_delay();
                            st.tx_seq[client] += 1;
                            st.tx_seq[client]
                        } else {
                            0
                        };
                        // Uplink bytes ride on the event and count when
                        // the upload lands (see `EngineEvent::Upload`).
                        self.queue.schedule_at(
                            arrive,
                            EngineEvent::Upload {
                                client,
                                bytes: upload_payload,
                                seq,
                                attempt: 0,
                            },
                        );
                    } else {
                        st.skip_streak += 1;
                        self.fleet.client_mut(client).mark_stale();
                        // Keep training the (now stale) local model.
                        self.queue.schedule_at(t, EngineEvent::Start { client });
                        dispatch_speculation(
                            &self.fleet,
                            &mut st,
                            pool,
                            obs_shared.as_ref(),
                            client,
                            t,
                            knobs,
                        )?;
                    }
                }
                EngineEvent::Upload { client, bytes, seq, attempt } => {
                    // Fault layer (armed only): every arriving frame pays
                    // the integrity header; its fate decides between
                    // delivery, duplicate suppression, retransmission
                    // with capped exponential backoff, and giving up.
                    let mut frame = bytes;
                    if let Some(plan) = self.faults.as_mut() {
                        frame += INTEGRITY_HEADER_BYTES;
                        if seq <= st.rx_seq[client] {
                            // Stale duplicate of an already-accepted
                            // transmission: it occupied the wire but has
                            // no effect on the engine.
                            st.window.faults.dup_suppressed += 1;
                            st.window.bytes_up += frame;
                            continue;
                        }
                        match plan.up_fate(t) {
                            FrameFate::Delivered => {}
                            FrameFate::Duplicated => {
                                // This copy lands; the network injects a
                                // second copy that pops later and is
                                // suppressed by its sequence number.
                                self.queue.schedule_at(
                                    t + plan.reorder_delay(),
                                    EngineEvent::Upload { client, bytes, seq, attempt },
                                );
                            }
                            fate => {
                                if fate == FrameFate::Lost {
                                    st.window.faults.frames_lost += 1;
                                } else {
                                    st.window.faults.frames_corrupt += 1;
                                }
                                // The failed frame still occupied the wire.
                                st.window.bytes_up += frame;
                                if attempt >= plan.max_retransmits() {
                                    // Retransmit budget exhausted: abandon
                                    // the round. The client goes stale and
                                    // starts a fresh local round instead
                                    // of blocking on a flush that will
                                    // never include it.
                                    st.in_flight -= 1;
                                    st.upload_in_flight[client] = false;
                                    self.fleet.client_mut(client).mark_stale();
                                    self.queue
                                        .schedule_at(t, EngineEvent::Start { client });
                                    dispatch_speculation(
                                        &self.fleet,
                                        &mut st,
                                        pool,
                                        obs_shared.as_ref(),
                                        client,
                                        t,
                                        knobs,
                                    )?;
                                    continue;
                                }
                                st.window.faults.retransmits += 1;
                                let redo = self.ctx.link.transfer_seconds_counted(
                                    &Message::ModelUpload { payload_bytes: bytes },
                                    &mut self.net_rng,
                                    &mut self.link_capped,
                                );
                                let retry_at = t + plan.backoff(attempt + 1) + redo;
                                self.obs.virt_span(
                                    SpanPhase::Retransmit,
                                    client as u32,
                                    t,
                                    retry_at,
                                );
                                self.queue.schedule_at(
                                    retry_at,
                                    EngineEvent::Upload {
                                        client,
                                        bytes,
                                        seq,
                                        attempt: attempt + 1,
                                    },
                                );
                                continue;
                            }
                        }
                        st.rx_seq[client] = seq;
                    }
                    st.in_flight -= 1;
                    st.upload_in_flight[client] = false;
                    st.window.bytes_up += frame;
                    let s = st.shard_of[client];
                    // saturating: a rebalanced client's synced version is
                    // re-anchored to its new shard's counter, which a
                    // concurrent flush of the old shard could outrun.
                    let tau =
                        st.shard_version[s].saturating_sub(st.synced_version[client]) as usize;
                    st.buffers[s].push((client, tau, t));
                    self.obs.virt_span(SpanPhase::BufferFill, client as u32, t, t);
                    if fanout > 1 {
                        // Two-tier aggregation: fold the payload into its
                        // edge accumulator now. The uploader is blocked
                        // until the flush broadcasts, and the shard's
                        // version only advances at flush, so both the
                        // encoded params and tau are already final here.
                        self.fold_edge_upload(&mut st, client, s, tau, mixing, fanout);
                    }
                    if self.cfg.trace_events {
                        self.metrics.event_trace.push((
                            t,
                            format!(
                                "upload c{client}  +{bytes}B  shard={s}  buffer={}/{}  in_flight={}",
                                st.buffers[s].len(),
                                st.shard_k[s],
                                st.in_flight
                            ),
                        ));
                    }
                    if st.buffers[s].len() < st.shard_k[s] {
                        continue;
                    }
                    flushes += 1;
                    st.shard_version[s] += 1;
                    let version = st.shard_version[s];
                    // The flush's virtual extent spans from the oldest
                    // buffered arrival to the flush commit.
                    let flush_ws = self.obs.wall_start();
                    let flush_v0 =
                        st.buffers[s].iter().map(|&(_, _, at)| at).fold(t, f64::min);
                    // Flush against the shard's model (S == 1: the global
                    // itself, moved out for the duration of the flush).
                    let mut model = if s_count == 1 {
                        std::mem::take(&mut self.global)
                    } else {
                        std::mem::take(&mut shard_models[s])
                    };
                    let res = self.flush_shard(
                        exec, pool, &mut st, s, flushes, t, version, mixing, knobs, &mut model,
                    );
                    if s_count == 1 {
                        self.global = model;
                    } else {
                        shard_models[s] = model;
                    }
                    res?;
                    self.obs.virt_span(SpanPhase::Flush, NO_CLIENT, flush_v0, t);
                    self.obs.wall_span(SpanPhase::Flush, NO_CLIENT, t, flush_ws);
                    if s_count > 1 && flushes % reconcile_every == 0 {
                        self.reconcile_shards(&mut shard_models, &st.shard_weight);
                        // Adaptive shard rebalancing happens only at
                        // reconcile boundaries: every replica was just
                        // reset to the reconciled global, so a migrated
                        // client never mixes replica lineages mid-stream.
                        self.maybe_rebalance(&mut st, k, flushes, t);
                    }
                    // Knob controllers evaluate on the committed flush
                    // stream (same deterministic position serially and
                    // threaded).
                    if self.control.due(flushes) {
                        let ws = self.obs.wall_start();
                        self.control_tick_async(&mut st, &mut k, &mut mixing, flushes, t);
                        self.obs.wall_span(SpanPhase::ControlTick, NO_CLIENT, t, ws);
                    }
                    // Deterministic commit point: snapshot the full engine
                    // state right after the flush (and its control tick)
                    // committed. Pool-side evaluations are drained first so
                    // the snapshotted record prefix is complete.
                    let every = self.cfg.faults.checkpoint_every;
                    if every > 0 && flushes % every == 0 {
                        self.drain_pending_evals(&mut st)?;
                        let ws = self.obs.wall_start();
                        // Counted before the snapshot so the registry the
                        // checkpoint carries already includes this save —
                        // a restored run and a continuous run agree.
                        self.obs.registry.inc(Counter::Checkpoints);
                        self.checkpoint = Some(self.save_async_checkpoint(
                            &st,
                            k,
                            mixing,
                            flushes,
                            &shard_models,
                        ));
                        self.obs.wall_span(SpanPhase::CheckpointSave, NO_CLIENT, t, ws);
                    }
                    if self.stop_after > 0 && flushes >= self.stop_after {
                        // The deterministic "kill -9" of the recovery
                        // tests: abandon the run right after this commit.
                        break;
                    }
                }
                EngineEvent::Restart { client } => {
                    // Reboot after a crash: rehydrate from the current
                    // shard model (a dense frame — the crash lost both the
                    // local model and any acked downlink base), re-anchor
                    // the staleness clock, and rejoin the local-round loop
                    // once the sync frame lands.
                    let s = st.shard_of[client];
                    let target: &[f32] =
                        if s_count == 1 { &self.global } else { &shard_models[s] };
                    self.fleet.hydrate(client, target);
                    if self.cfg.compression.down_mode == CompressionMode::TopK {
                        self.downlink.ack_dense(client, target);
                    }
                    st.synced_version[client] = st.shard_version[s];
                    st.window.faults.recoveries += 1;
                    let dense = self.down_payload_bytes + INTEGRITY_HEADER_BYTES;
                    st.window.bytes_down += dense;
                    let down = self.ctx.link.transfer_seconds_counted(
                        &Message::ModelBroadcast { payload_bytes: dense },
                        &mut self.net_rng,
                        &mut self.link_capped,
                    );
                    if self.cfg.trace_events {
                        self.metrics.event_trace.push((t, format!("restart c{client}")));
                    }
                    self.queue.schedule_at(t + down, EngineEvent::Start { client });
                    dispatch_speculation(
                        &self.fleet,
                        &mut st,
                        pool,
                        obs_shared.as_ref(),
                        client,
                        t,
                        knobs,
                    )?;
                }
            }
        }
        // Committed events = pops of the main loop (the sim's commit-order
        // bookkeeping), identical for serial and threaded execution;
        // abandoned events below are excluded.
        self.metrics.engine_events += (self.queue.total_popped() - events_before) as usize;
        // Abandon in-flight events so a later (barriered) round on the
        // same server does not see them. In-flight speculations are
        // dropped with the engine state; their workers' result sends fail
        // harmlessly and the pool drains on shutdown.
        while self.queue.pop().is_some() {}
        // Fold every shard's outstanding work into the true global even
        // when the run ended between reconciliation points.
        if s_count > 1 {
            self.reconcile_shards(&mut shard_models, &st.shard_weight);
        }
        // Recycle the per-shard gate histories so a later run on the same
        // server reuses their buffers instead of reallocating.
        for h in st.shard_history.drain(..) {
            self.history_pool.extend(h);
        }
        // Fleet lifecycle counters (lifetime totals, so reruns on the
        // same server report the final state).
        self.metrics.fleet_hydrations = self.fleet.hydrations();
        self.metrics.fleet_parks = self.fleet.parks();
        self.metrics.peak_active = self.fleet.peak_active();
        self.metrics.link_capped = self.link_capped;
        self.drain_pending_evals(&mut st)?;
        // A `stop_after` kill abandons the run before this point, so a
        // crashed run (like a crashed process) publishes no obs report.
        if !(self.stop_after > 0 && flushes >= self.stop_after) {
            self.finalize_obs();
        }
        Ok(())
    }

    /// Fold one just-arrived upload into its edge accumulator
    /// (`engine.edge_fanout > 1`). Encoding at arrival is byte-identical
    /// to the legacy flush-time encode — the client's params are pristine
    /// until the flush broadcasts — so one scratch buffer serves every
    /// upload and the flush never touches per-client state. The edge of a
    /// client interleaves the shard layout: `(client / shards) % fanout`,
    /// so round-robin shard assignment spreads each shard's population
    /// evenly over its edges.
    fn fold_edge_upload(
        &mut self,
        st: &mut EngineState,
        client: usize,
        shard: usize,
        tau: usize,
        mixing: MixingRule,
        fanout: usize,
    ) {
        let s_count = st.shard_version.len();
        let ei = shard * fanout + (client / s_count) % fanout;
        let a = mixing.alpha(tau);
        let w = self.fleet.num_samples(client) as f64 * a;
        let precision = self.cfg.upload_precision;
        match self.cfg.compression.mode {
            CompressionMode::Dense => {
                self.fleet.client_mut(client).encode_upload(precision, &mut self.edge_buf);
                st.edges[ei].fold_dense(&self.edge_buf, w, a);
            }
            CompressionMode::TopK => {
                let error_feedback = self.cfg.compression.error_feedback;
                if self.layer_ks.is_empty() {
                    self.fleet.client_mut(client).encode_sparse_upload(
                        precision,
                        st.upload_k[client],
                        error_feedback,
                        &mut self.edge_sparse,
                    );
                } else {
                    self.fleet.client_mut(client).encode_sparse_upload_layers(
                        precision,
                        &self.layer_sizes,
                        &self.layer_ks,
                        error_feedback,
                        &mut self.edge_sparse,
                    );
                }
                if self.control.enabled() && self.cfg.control.compression {
                    let sent = self.edge_sparse.sent_key_l1();
                    st.edge_transmitted[shard] += sent;
                    st.edge_residual[shard] += (self.edge_sparse.key_l1() - sent).max(0.0);
                }
                st.edges[ei].fold_sparse(&self.edge_sparse, w, a);
            }
        }
    }

    /// Aggregate shard `shard`'s flushed buffer into `model` with
    /// staleness-weighted mixing, broadcast to its clients, restart (and,
    /// threaded, re-dispatch) them, evaluate, and cut one [`RoundRecord`].
    ///
    /// At `shards > 1` the record's accuracy/loss evaluate the flushing
    /// shard's *replica* (`model`), not the reconciled global — the first
    /// flush after each reconcile evaluates a replica freshly restarted
    /// from the global, which re-anchors the trajectory (see
    /// EXPERIMENTS.md §Engines). At S=1 the replica *is* the global.
    #[allow(clippy::too_many_arguments)]
    fn flush_shard(
        &mut self,
        exec: &mut dyn Executor,
        pool: Option<&ExecutorPool>,
        st: &mut EngineState,
        shard: usize,
        flush_idx: usize,
        now: f64,
        version: u64,
        mixing: MixingRule,
        knobs: RoundKnobs,
        model: &mut Vec<f32>,
    ) -> Result<()> {
        let n = self.fleet.len();
        let kk = st.buffers[shard].len();
        let precision = self.cfg.upload_precision;
        // Dense broadcast frames are priced at the effective downlink
        // precision (`down_precision = None` reads `ctx` — bitwise).
        let payload = self.down_payload_bytes;
        let fanout = self.cfg.engine_opts.edge_fanout;
        let robust = self.cfg.robust.mode != RobustMode::None;
        let trust_on = robust && self.cfg.robust.trust;
        let mut quarantined = 0usize;
        let mut outlier_rate = f64::NAN;
        let obs_shared = self.obs.shared();
        self.round = flush_idx;

        // Deterministic aggregation order — and a bitwise match with the
        // barriered engine's client-order FedAvg when the buffer spans the
        // whole fleet.
        st.buffers[shard].sort_by_key(|e| e.0);

        let mode = self.cfg.compression.mode;
        if fanout > 1 {
            // Two-tier aggregation: every buffered upload was already
            // folded into its edge accumulator at arrival, so the flush
            // only combines `fanout` edge summaries — O(edges * dim)
            // regardless of the buffer size — and resets them for the
            // next window.
            let dim = model.len();
            let sparse = mode == CompressionMode::TopK;
            let er = shard * fanout..(shard + 1) * fanout;
            combine_edges(&st.edges[er.clone()], model);
            for e in &mut st.edges[er] {
                e.reset(dim, sparse);
            }
        } else {
            // Buffered clients are blocked between upload and broadcast, so
            // encoding their (pristine) params now is byte-identical to
            // encoding at send time — including the sparse budget, which is
            // the per-upload snapshot taken when the upload was sized and
            // charged (`EngineState::upload_k`), not the current `k_for`.
            self.ensure_wire_slots(kk);
            let error_feedback = self.cfg.compression.error_feedback;
            for (j, &(c, _, _)) in st.buffers[shard].iter().enumerate() {
                match mode {
                    CompressionMode::Dense => self
                        .fleet
                        .client_mut(c)
                        .encode_upload(precision, &mut self.upload_bufs[j]),
                    CompressionMode::TopK if self.layer_ks.is_empty() => {
                        self.fleet.client_mut(c).encode_sparse_upload(
                            precision,
                            st.upload_k[c],
                            error_feedback,
                            &mut self.sparse_bufs[j],
                        )
                    }
                    CompressionMode::TopK => {
                        self.fleet.client_mut(c).encode_sparse_upload_layers(
                            precision,
                            &self.layer_sizes,
                            &self.layer_ks,
                            error_feedback,
                            &mut self.sparse_bufs[j],
                        )
                    }
                }
            }
            // FedAvg weights n_i scaled by alpha(tau_i), then
            // soft-quarantined by the trust score (armed trust only, so
            // disarmed runs keep weights bitwise); the buffer's mean
            // alpha is the shard's mixing rate, deliberately untouched by
            // trust — quarantine shifts relative shares, not how much of
            // the prior model survives.
            self.upload_weights.clear();
            let mut alpha_sum = 0.0f64;
            for &(c, tau, _) in st.buffers[shard].iter() {
                let a = mixing.alpha(tau);
                alpha_sum += a;
                let mut w = self.fleet.num_samples(c) as f64 * a;
                if trust_on {
                    let m = self.trust.multiplier(
                        c,
                        self.cfg.robust.trust_threshold,
                        self.cfg.robust.trust_floor,
                    );
                    if m < 1.0 {
                        quarantined += 1;
                    }
                    w *= m;
                }
                self.upload_weights.push(w);
            }
            let abar = (alpha_sum / kk as f64).min(1.0);
            if robust {
                self.outlier_counts.clear();
                self.outlier_counts.resize(kk, 0);
            }
            let spec = RobustSpec {
                mode: self.cfg.robust.mode,
                trim: self.cfg.robust.trim_fraction,
            };
            if abar >= 1.0 {
                // Pure FedAvg replacement (the barriered rule). The sparse
                // path is the masked equivalent: untransmitted coordinate
                // mass falls back to the current shard model.
                match mode {
                    CompressionMode::Dense if robust => self.agg.aggregate_payloads_robust(
                        &self.upload_bufs[..kk],
                        &self.upload_weights,
                        0.0,
                        spec,
                        model,
                        &mut self.outlier_counts,
                    ),
                    CompressionMode::Dense => self.agg.aggregate_payloads(
                        &self.upload_bufs[..kk],
                        &self.upload_weights,
                        model,
                    ),
                    CompressionMode::TopK if robust => {
                        self.agg.aggregate_sparse_payloads_robust(
                            &self.sparse_bufs[..kk],
                            &self.upload_weights,
                            0.0,
                            spec,
                            model,
                            &mut self.outlier_counts,
                        )
                    }
                    CompressionMode::TopK => self.agg.aggregate_sparse_payloads(
                        &self.sparse_bufs[..kk],
                        &self.upload_weights,
                        0.0,
                        model,
                    ),
                }
            } else {
                // theta <- (1 - abar) * theta + abar * fedavg(buffer): the
                // buffered weights are pre-normalized to sum to abar. Dense:
                // the current shard model rides along as one extra f32
                // payload (slot kk) with weight 1 - abar; sparse: the same
                // 1 - abar enters as the scatter's self-weight, which the
                // merge applies last per coordinate — the identical lane
                // order, so k_fraction = 1.0 stays bitwise dense. The
                // robust merges take the same 1 - abar as the prior lane's
                // weight instead of a trailing payload slot.
                let wsum: f64 = self.upload_weights.iter().sum();
                for w in self.upload_weights.iter_mut() {
                    *w = abar * *w / wsum;
                }
                match mode {
                    CompressionMode::Dense if robust => self.agg.aggregate_payloads_robust(
                        &self.upload_bufs[..kk],
                        &self.upload_weights,
                        1.0 - abar,
                        spec,
                        model,
                        &mut self.outlier_counts,
                    ),
                    CompressionMode::Dense => {
                        self.upload_weights.push(1.0 - abar);
                        self.upload_bufs[kk].encode(Precision::F32, model);
                        self.agg.aggregate_payloads(
                            &self.upload_bufs[..kk + 1],
                            &self.upload_weights,
                            model,
                        );
                    }
                    CompressionMode::TopK if robust => {
                        self.agg.aggregate_sparse_payloads_robust(
                            &self.sparse_bufs[..kk],
                            &self.upload_weights,
                            1.0 - abar,
                            spec,
                            model,
                            &mut self.outlier_counts,
                        )
                    }
                    CompressionMode::TopK => self.agg.aggregate_sparse_payloads(
                        &self.sparse_bufs[..kk],
                        &self.upload_weights,
                        1.0 - abar,
                        model,
                    ),
                }
            }
            if robust {
                // Per-payload trimmed-coordinate rates feed the trust book
                // (the buffer is sorted by client id, so the order — and
                // with it every EWMA trajectory — is deterministic).
                let dim = model.len();
                let mut rate_sum = 0.0f64;
                for (j, &(c, _, _)) in st.buffers[shard].iter().enumerate() {
                    let denom = match mode {
                        CompressionMode::Dense => dim,
                        CompressionMode::TopK => self.sparse_bufs[j].len(),
                    };
                    let rate = if denom == 0 {
                        0.0
                    } else {
                        self.outlier_counts[j] as f64 / denom as f64
                    };
                    rate_sum += rate;
                    if trust_on {
                        self.trust.update(c, rate);
                    }
                }
                outlier_rate = rate_sum / kk as f64;
            }
        }

        // Broadcast the new shard model to the flushed clients (at the
        // effective downlink precision, codec once per flush), restart
        // their clocks, and — threaded — dispatch their next speculative
        // local round against the state they just synced.
        let bcast_ws = self.obs.wall_start();
        let mut bcast_end = now;
        let down_precision = self.cfg.compression.down_precision_or(precision);
        let bcast_model: Option<&[f32]> = if down_precision == Precision::F32 {
            None
        } else {
            self.bcast_buf.encode(down_precision, model);
            self.bcast_model.resize(model.len(), 0.0);
            self.bcast_buf.decode_into(&mut self.bcast_model);
            Some(&self.bcast_model)
        };
        // Indexed loop (not an iterator): the speculative dispatch below
        // re-borrows the engine state mutably, and an index avoids
        // allocating a snapshot of the flushed ids on the hot flush path.
        let down_topk = self.cfg.compression.down_mode == CompressionMode::TopK;
        let armed = self.faults.is_some();
        #[allow(clippy::needless_range_loop)]
        for bi in 0..kk {
            let c = st.buffers[shard][bi].0;
            if let Some(w) = st.waiting.pop_front() {
                // Active-set rotation: this broadcast slot goes to the
                // longest-waiting parked client instead of the uploader.
                // The flushed client demotes to a parked record (its dense
                // state is superseded by the broadcast anyway) and rejoins
                // the back of the queue; the newcomer hydrates from the
                // broadcast model and is re-anchored to its *own* shard's
                // current version — it may live on a different shard than
                // the one that just flushed, and its staleness clock must
                // start from what it actually synced.
                //
                // The newcomer never acked any downlink base (`hydrate`
                // rebuilds it from a parked record, and storing a full
                // base per parked client would defeat fleet
                // virtualization), so a sparse downlink MUST ship this
                // frame dense: it establishes the shared base the next
                // sparse delta builds on. The parked client's slot is
                // dropped for the same reason.
                let mut frame_bytes = payload;
                let mut extra = 0.0f64;
                if let Some(plan) = self.faults.as_mut() {
                    // The hydration frame rides the same faulty downlink:
                    // a lost/corrupt frame is NACKed and re-sent dense
                    // (it already was dense — the re-send is a retry).
                    frame_bytes += INTEGRITY_HEADER_BYTES;
                    let fate = plan.down_fate();
                    if matches!(fate, FrameFate::Lost | FrameFate::Corrupt) {
                        if fate == FrameFate::Lost {
                            st.window.faults.frames_lost += 1;
                        } else {
                            st.window.faults.frames_corrupt += 1;
                        }
                        st.window.faults.resyncs += 1;
                        st.window.bytes_down += frame_bytes;
                        extra += self.ctx.link.transfer_seconds_counted(
                            &Message::ModelBroadcast { payload_bytes: frame_bytes },
                            &mut self.net_rng,
                            &mut self.link_capped,
                        );
                        // NACK control frame on the uplink.
                        st.window.bytes_up += Message::ValueReport.bytes();
                        st.window.bytes_up_ctrl += Message::ValueReport.bytes();
                    }
                }
                let down = self.ctx.link.transfer_seconds_counted(
                    &Message::ModelBroadcast { payload_bytes: frame_bytes },
                    &mut self.net_rng,
                    &mut self.link_capped,
                );
                let down = extra + down;
                st.window.bytes_down += frame_bytes;
                let target = bcast_model.unwrap_or(&model[..]);
                self.fleet.park(c);
                self.fleet.hydrate(w, target);
                if down_topk {
                    self.downlink.drop_client(c);
                    self.downlink.ack_dense(w, target);
                }
                st.synced_version[w] = st.shard_version[st.shard_of[w]];
                bcast_end = bcast_end.max(now + down);
                self.queue.schedule_at(now + down, EngineEvent::Start { client: w });
                dispatch_speculation(
                    &self.fleet,
                    st,
                    pool,
                    obs_shared.as_ref(),
                    w,
                    now,
                    knobs,
                )?;
                st.waiting.push_back(c);
            } else {
                // Runtime promotion of the base-agreement debug_assert
                // (armed only): a divergent acked base routes through a
                // forced dense re-sync instead of shipping a delta
                // against the wrong base.
                if armed
                    && down_topk
                    && self.downlink.has_base(c)
                    && !self.downlink.base_matches(c, self.fleet.client(c).sync_base())
                {
                    st.window.faults.resyncs += 1;
                    st.window.faults.recoveries += 1;
                    self.downlink.drop_client(c);
                }
                // The downlink budget is read per broadcast and the
                // frame is charged from its own encode, so a mid-run
                // `down_k_fraction` retune can never desynchronize the
                // charged bytes from the bytes on the wire (the
                // downlink mirror of the `upload_k` snapshot).
                let frame_bytes = if down_topk {
                    let down_k = self.cfg.compression.down_k_for(model.len());
                    match self.downlink.encode_for(c, &model[..], down_k) {
                        Some(delta) => {
                            let b = delta.payload_bytes();
                            self.fleet.client_mut(c).sync_sparse(delta);
                            b
                        }
                        // First contact since hydration: no acked base,
                        // force-dense (establishes it).
                        None => {
                            let target = bcast_model.unwrap_or(&model[..]);
                            self.fleet.client_mut(c).sync(target);
                            self.downlink.ack_dense(c, target);
                            payload
                        }
                    }
                } else {
                    self.fleet.client_mut(c).sync(bcast_model.unwrap_or(&model[..]));
                    payload
                };
                debug_assert!(
                    armed
                        || !down_topk
                        || self.downlink.base_of(c) == Some(self.fleet.client(c).sync_base()),
                    "downlink base diverged from client {c}'s acked base"
                );
                let mut frame_bytes = frame_bytes;
                let mut extra = 0.0f64;
                // Fault layer (armed only): a lost or corrupt broadcast
                // is NACKed (one 68 B control frame up) and answered
                // with a forced dense re-sync, which always
                // re-establishes the shared base.
                if let Some(plan) = self.faults.as_mut() {
                    frame_bytes += INTEGRITY_HEADER_BYTES;
                    let fate = plan.down_fate();
                    if matches!(fate, FrameFate::Lost | FrameFate::Corrupt) {
                        if fate == FrameFate::Lost {
                            st.window.faults.frames_lost += 1;
                        } else {
                            st.window.faults.frames_corrupt += 1;
                        }
                        st.window.faults.resyncs += 1;
                        // The failed frame still occupied the wire.
                        st.window.bytes_down += frame_bytes;
                        extra += self.ctx.link.transfer_seconds_counted(
                            &Message::ModelBroadcast { payload_bytes: frame_bytes },
                            &mut self.net_rng,
                            &mut self.link_capped,
                        );
                        st.window.bytes_up += Message::ValueReport.bytes();
                        st.window.bytes_up_ctrl += Message::ValueReport.bytes();
                        // Forced dense re-sync (idempotent for clients
                        // the dense path already synced).
                        let target = bcast_model.unwrap_or(&model[..]);
                        self.fleet.client_mut(c).sync(target);
                        if down_topk {
                            self.downlink.ack_dense(c, target);
                        }
                        frame_bytes = payload + INTEGRITY_HEADER_BYTES;
                    }
                }
                let down = self.ctx.link.transfer_seconds_counted(
                    &Message::ModelBroadcast { payload_bytes: frame_bytes },
                    &mut self.net_rng,
                    &mut self.link_capped,
                );
                let down = extra + down;
                st.window.bytes_down += frame_bytes;
                st.synced_version[c] = version;
                bcast_end = bcast_end.max(now + down);
                self.queue.schedule_at(now + down, EngineEvent::Start { client: c });
                dispatch_speculation(
                    &self.fleet,
                    st,
                    pool,
                    obs_shared.as_ref(),
                    c,
                    now,
                    knobs,
                )?;
            }
        }
        if kk > 0 {
            self.obs.virt_span(SpanPhase::DownlinkEncode, NO_CLIENT, now, bcast_end);
            self.obs.wall_span(SpanPhase::DownlinkEncode, NO_CLIENT, now, bcast_ws);
        }
        if st.shard_history.is_empty() {
            self.push_history_from(&model[..]);
        } else {
            // Sharded gate history: the flushed model extends its own
            // replica's window (see the `EngineState::shard_history` docs).
            let keep = self.policy.history_depth().max(1) + 1;
            push_bounded_history(
                &mut st.shard_history[shard],
                &mut self.history_pool,
                keep,
                &model[..],
            );
        }

        let (global_acc, global_loss) = if flush_idx % self.cfg.eval_every != 0 {
            (f64::NAN, f64::NAN)
        } else if let Some(pool) = pool {
            // Overlap the evaluation: snapshot the model, run on a pool
            // worker, patch the record before the engine returns. The
            // values are identical to inline evaluation.
            let params = model.clone();
            let images = Arc::clone(&self.ctx.test_images);
            let labels = Arc::clone(&self.ctx.test_labels);
            let (tx, rx) = mpsc::channel();
            let obs = obs_shared.clone();
            pool.submit(Box::new(move |ex| {
                let ws = obs.as_ref().map_or(0.0, |o| o.now_us());
                let r = evaluate_with_params(ex, &params, &images[..], &labels[..]);
                if let Some(o) = &obs {
                    o.wall_span(SpanPhase::Eval, NO_CLIENT, now, ws);
                }
                let _ = tx.send(r);
            }))?;
            st.pending_evals.push((self.metrics.records.len(), rx));
            (f64::NAN, f64::NAN)
        } else {
            let ws = self.obs.wall_start();
            let r = evaluate_with_params(
                exec,
                &model[..],
                &self.ctx.test_images[..],
                &self.ctx.test_labels[..],
            )?;
            self.obs.wall_span(SpanPhase::Eval, NO_CLIENT, now, ws);
            r
        };

        // Buffer wait: how long each upload sat before the flush.
        let idle_seconds: f64 = st.buffers[shard].iter().map(|&(_, _, at)| now - at).sum();
        // At fleet scale the O(n)-per-flush record columns dominate memory;
        // `fleet.compact_records` drops them (scalar telemetry is kept).
        let compact = self.cfg.fleet.compact_records;
        let fleet_selected = if compact {
            Vec::new()
        } else {
            let mut sel = vec![false; n];
            for &(c, _, _) in st.buffers[shard].iter() {
                sel[c] = true;
            }
            sel
        };
        let cum_uploads = self.metrics.records.last().map_or(0, |r| r.cum_uploads) + kk;
        // Window telemetry is attributed to the flush that closes the
        // window: reports/bytes count when their events fire, so an upload
        // can land in a later flush than the report that caused it. A
        // window that saw no reports records NaN (no data), not 0.0.
        let (train_loss, threshold) = if st.window.reports == 0 {
            (f64::NAN, f64::NAN)
        } else {
            (st.window.train_loss_sum / st.window.reports as f64, st.window.threshold)
        };
        let record = RoundRecord {
            round: flush_idx,
            vtime: now,
            global_acc,
            global_loss,
            train_loss,
            uploads: kk,
            cum_uploads,
            bytes_up: st.window.bytes_up,
            bytes_down: st.window.bytes_down,
            bytes_up_ctrl: st.window.bytes_up_ctrl,
            bytes_down_ctrl: st.window.bytes_down_ctrl,
            threshold,
            values: if compact { Vec::new() } else { st.last_values.to_vec() },
            selected: fleet_selected,
            client_accs: if compact { Vec::new() } else { st.last_accs.to_vec() },
            idle_seconds,
            reports: st.window.reports,
            in_flight: st.in_flight,
            upload_staleness: st.buffers[shard].iter().map(|&(_, tau, _)| tau).collect(),
            shard,
            spec_committed: st.window.spec_committed,
            spec_replayed: st.window.spec_replayed,
            quarantined,
            trust_mean: if trust_on { self.trust.mean_score() } else { f64::NAN },
            faults: std::mem::take(&mut st.window.faults),
        };
        if global_acc.is_finite() {
            log_info!(
                "server",
                "[{}] flush {flush_idx:>3}: acc={global_acc:.4} shard={shard} buffer={kk} in_flight={} stale_max={} vt={now:.1}s",
                self.metrics.algorithm,
                st.in_flight,
                record.staleness_max()
            );
        }
        if self.control.enabled() {
            // The sample is built from commit-time state only — the
            // deferred global eval of the threaded engine is
            // deliberately NOT part of it.
            let (residual_l1, transmitted_l1) = if fanout > 1 {
                // Edge mode encodes at arrival, so the mass was accumulated
                // there; read-and-reset the shard's window sums.
                let r = (st.edge_residual[shard], st.edge_transmitted[shard]);
                st.edge_residual[shard] = 0.0;
                st.edge_transmitted[shard] = 0.0;
                r
            } else {
                self.sparse_flush_mass(kk)
            };
            let (down_residual_l1, down_transmitted_l1) = self.down_flush_mass();
            self.control.observe(FlushSample {
                round: flush_idx,
                shard,
                vtime: now,
                uploads: kk,
                staleness_sum: st.buffers[shard].iter().map(|&(_, tau, _)| tau).sum(),
                staleness_max: record.staleness_max(),
                bytes_up: record.bytes_up,
                residual_l1,
                transmitted_l1,
                down_residual_l1,
                down_transmitted_l1,
                acc_proxy: mean_finite(&st.last_accs),
                outlier_rate,
            });
        }
        if self.cfg.trace_events {
            self.metrics.event_trace.push((
                now,
                format!(
                    "flush #{flush_idx}  shard={shard}  uploads={kk}  stale_max={}  in_flight={}",
                    record.staleness_max(),
                    st.in_flight
                ),
            ));
        }
        self.mirror_record(&record);
        self.metrics.push(record);
        st.window = FlushWindow::default();
        st.buffers[shard].clear();
        // Flush commit = the barrier-free engine's drain point for
        // worker-side wall spans (a deterministic position in the
        // committed stream, so the virtual-time trace never depends on
        // worker timing).
        self.obs.drain();
        Ok(())
    }

    /// Reconcile the shard model replicas into the true global
    /// (sample-count-weighted average) and restart every shard from it.
    /// Transparent to staleness accounting: shard versions do not advance.
    fn reconcile_shards(&mut self, shard_models: &mut [Vec<f32>], weights: &[f64]) {
        let views: Vec<&[f32]> = shard_models.iter().map(|m| m.as_slice()).collect();
        self.agg.aggregate_weighted(&views, weights, &mut self.global);
        log_debug!(
            "server",
            "reconciled {} shard models into the global (flush {})",
            shard_models.len(),
            self.round
        );
        for m in shard_models.iter_mut() {
            m.copy_from_slice(&self.global);
        }
    }

    /// Residual/transmitted selection-key mass over the first `count`
    /// just-encoded sparse flush buffers — the compression controller's
    /// signal, shared by both engines' commit paths. Runs on the
    /// event-loop thread over encode-time state, so the sample is
    /// identical for serial and threaded execution. `(0, 0)` — an empty
    /// signal, never consumed — in dense mode and when the compression
    /// controller is disarmed (the sums walk the full key scratch, O(n)
    /// per buffered upload; don't pay that for a signal nobody reads).
    fn sparse_flush_mass(&self, count: usize) -> (f64, f64) {
        if self.cfg.compression.mode != CompressionMode::TopK || !self.cfg.control.compression {
            return (0.0, 0.0);
        }
        let mut residual = 0.0f64;
        let mut transmitted = 0.0f64;
        for buf in self.sparse_bufs.iter().take(count) {
            let sent = buf.sent_key_l1();
            transmitted += sent;
            residual += (buf.key_l1() - sent).max(0.0);
        }
        (residual, transmitted)
    }

    /// Downlink analogue of [`Server::sparse_flush_mass`]: drain the
    /// (residual, transmitted) selection-key mass the downlink
    /// compressor accumulated since the previous commit sample. Gated
    /// exactly like the uplink mass so the disabled control plane stays
    /// inert and cost-free.
    fn down_flush_mass(&mut self) -> (f64, f64) {
        if self.cfg.compression.down_mode != CompressionMode::TopK
            || !self.cfg.control.compression
        {
            return (0.0, 0.0);
        }
        self.downlink.take_mass()
    }

    /// Apply a retuned `compression.k_fraction` and recompute the wire
    /// size of one model upload under it; subsequent uploads (next
    /// barriered round / next barrier-free upload request) ship the new
    /// frame. The downlink budget is the separate `down_k_fraction` knob.
    fn set_k_fraction(&mut self, to: f64) {
        self.cfg.compression.k_fraction = to;
        let n = self.global.len();
        self.upload_payload_bytes = match self.cfg.compression.mode {
            CompressionMode::Dense => self.ctx.model_payload_bytes,
            CompressionMode::TopK => sparse_payload_bytes(
                self.cfg.upload_precision,
                self.cfg.compression.k_for(n),
                n,
            ),
        };
    }

    /// Apply a retuned `compression.down_k_fraction`. Takes effect at
    /// the next broadcast: the engines size, charge, and time every
    /// downlink frame from the actual encode at broadcast time, so a
    /// mid-run retune can never desynchronize charged and encoded bytes
    /// (the downlink mirror of the `upload_k` snapshot discipline).
    fn set_down_k_fraction(&mut self, to: f64) {
        self.cfg.compression.down_k_fraction = to;
    }

    /// Log one applied control decision (metrics stream + optional
    /// realtime trace).
    #[allow(clippy::too_many_arguments)]
    fn push_control_record(
        &mut self,
        round: usize,
        now: f64,
        controller: &str,
        knob: &str,
        old: f64,
        new: f64,
        signal: f64,
        client: Option<usize>,
    ) {
        log_debug!(
            "server",
            "control {controller}: {knob} {old:.4} -> {new:.4} (signal {signal:.4}, round {round})"
        );
        if self.cfg.trace_events {
            self.metrics.event_trace.push((
                now,
                match client {
                    Some(c) => format!(
                        "control {controller}: c{c} {knob} {old:.0} -> {new:.0} (signal {signal:.3})"
                    ),
                    None => format!(
                        "control {controller}: {knob} {old:.4} -> {new:.4} (signal {signal:.3})"
                    ),
                },
            ));
        }
        self.metrics.control_records.push(ControlRecord {
            round,
            vtime: now,
            controller: controller.to_string(),
            knob: knob.to_string(),
            old,
            new,
            signal,
            client,
        });
    }

    /// Barrier-free knob-controller tick: evaluate the staleness and
    /// compression controllers against the telemetry window and apply
    /// their decisions. Runs on the event-loop thread at a fixed
    /// position of the committed flush stream, so serial == threaded
    /// stays bitwise.
    fn control_tick_async(
        &mut self,
        st: &mut EngineState,
        k: &mut usize,
        mixing: &mut MixingRule,
        flushes: usize,
        now: f64,
    ) {
        let knobs = Knobs {
            buffer_k: *k,
            alpha0: mixing.alpha0(),
            k_fraction: self.cfg.compression.k_fraction,
            topk: self.cfg.compression.mode == CompressionMode::TopK,
            down_k_fraction: self.cfg.compression.down_k_fraction,
            down_topk: self.cfg.compression.down_mode == CompressionMode::TopK,
            barrier_free: true,
            trust_threshold: self.cfg.robust.trust_threshold,
            trust_armed: self.cfg.robust.mode != RobustMode::None && self.cfg.robust.trust,
            trim_fraction: self.cfg.robust.trim_fraction,
            trim_armed: self.cfg.robust.mode == RobustMode::TrimmedMean,
        };
        for d in self.control.decide_knobs(knobs) {
            match d.change {
                KnobChange::BufferK { from, to } => {
                    // Cap at the largest shard population: no shard's
                    // threshold can exceed its population, so stepping
                    // past the cap would be pure integrator windup —
                    // phantom values the controller would have to unwind
                    // one interval at a time before the buffer actually
                    // responded again. A grow decision the cap pushes
                    // back to (or below) the current value is a no-op,
                    // never an inversion: with single-client shards the
                    // effective thresholds are already pop-clamped and
                    // there is nothing to batch more.
                    let cap = st.shard_pop.iter().copied().max().unwrap_or(1);
                    let capped = to.min(cap);
                    if capped == *k || (to > from && capped < *k) {
                        continue;
                    }
                    let to = capped;
                    *k = to;
                    // Re-clamp every shard's threshold to its population.
                    // A buffer already holding >= the new threshold
                    // flushes on its next upload arrival (flush checks
                    // happen at arrival), which keeps the change a pure
                    // commit-stream function.
                    for (sk, &p) in st.shard_k.iter_mut().zip(&st.shard_pop) {
                        *sk = to.clamp(1, p.max(1));
                    }
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "buffer_k",
                        from as f64,
                        to as f64,
                        d.signal,
                        None,
                    );
                }
                KnobChange::Alpha0 { from, to } => {
                    *mixing = mixing.with_alpha0(to);
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "alpha0",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::KFraction { from, to } => {
                    self.set_k_fraction(to);
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "k_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::DownKFraction { from, to } => {
                    self.set_down_k_fraction(to);
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "down_k_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::TrustThreshold { from, to } => {
                    // Takes effect at the next flush's weight build; the
                    // trust book itself is untouched, so relaxing the
                    // threshold immediately un-quarantines clients whose
                    // scores now clear it.
                    self.cfg.robust.trust_threshold = to;
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "trust_threshold",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::TrimFraction { from, to } => {
                    // Takes effect at the next flush's robust aggregation
                    // (`RobustSpec` reads the config at flush time).
                    self.cfg.robust.trim_fraction = to;
                    self.push_control_record(
                        flushes,
                        now,
                        d.controller,
                        "trim_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
            }
        }
    }

    /// Barriered knob-controller tick: only the compression controller
    /// applies (buffer/alpha are barrier-free knobs; `decide_knobs`
    /// already gates them on `barrier_free`).
    fn control_tick_barriered(&mut self, round: usize, now: f64) {
        let knobs = Knobs {
            buffer_k: self.cfg.async_engine.buffer_k,
            alpha0: self.cfg.async_engine.mixing.alpha0(),
            k_fraction: self.cfg.compression.k_fraction,
            topk: self.cfg.compression.mode == CompressionMode::TopK,
            down_k_fraction: self.cfg.compression.down_k_fraction,
            down_topk: self.cfg.compression.down_mode == CompressionMode::TopK,
            barrier_free: false,
            trust_threshold: self.cfg.robust.trust_threshold,
            trust_armed: self.cfg.robust.mode != RobustMode::None && self.cfg.robust.trust,
            trim_fraction: self.cfg.robust.trim_fraction,
            trim_armed: self.cfg.robust.mode == RobustMode::TrimmedMean,
        };
        for d in self.control.decide_knobs(knobs) {
            match d.change {
                KnobChange::KFraction { from, to } => {
                    self.set_k_fraction(to);
                    self.push_control_record(
                        round,
                        now,
                        d.controller,
                        "k_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::DownKFraction { from, to } => {
                    self.set_down_k_fraction(to);
                    self.push_control_record(
                        round,
                        now,
                        d.controller,
                        "down_k_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::TrustThreshold { from, to } => {
                    self.cfg.robust.trust_threshold = to;
                    self.push_control_record(
                        round,
                        now,
                        d.controller,
                        "trust_threshold",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                KnobChange::TrimFraction { from, to } => {
                    self.cfg.robust.trim_fraction = to;
                    self.push_control_record(
                        round,
                        now,
                        d.controller,
                        "trim_fraction",
                        from,
                        to,
                        d.signal,
                        None,
                    );
                }
                // Buffer/alpha are barrier-free knobs; `decide_knobs`
                // never emits them here.
                KnobChange::BufferK { .. } | KnobChange::Alpha0 { .. } => {}
            }
        }
    }

    /// Evaluate the shard rebalancer at a reconcile boundary and migrate
    /// one client off the hottest shard if the windowed flush-rate skew
    /// warrants it. The migrated client is the lowest-id client of the
    /// hot shard with nothing pinned to it: no buffered upload and no
    /// upload on the wire (a pending V report is fine — gating and
    /// staleness follow the *current* shard at event time).
    fn maybe_rebalance(&mut self, st: &mut EngineState, k: usize, flushes: usize, now: f64) {
        let Some(m) = self.control.decide_rebalance(flushes, &st.shard_pop) else {
            return;
        };
        let Some(c) = (0..st.shard_of.len()).find(|&c| {
            st.shard_of[c] == m.from_shard
                && !st.upload_in_flight[c]
                && !st.buffers[m.from_shard].iter().any(|&(b, _, _)| b == c)
        }) else {
            return;
        };
        // A parked client is a perfectly fine migration target: shard
        // assignment lives entirely in the engine state, so the record
        // moves shards without being hydrated.
        let w = self.fleet.num_samples(c) as f64;
        st.shard_of[c] = m.to_shard;
        st.shard_pop[m.from_shard] -= 1;
        st.shard_pop[m.to_shard] += 1;
        st.shard_weight[m.from_shard] -= w;
        st.shard_weight[m.to_shard] += w;
        // Preserve the client's versions-behind estimate across the two
        // shards' version counters.
        let behind = st.shard_version[m.from_shard].saturating_sub(st.synced_version[c]);
        st.synced_version[c] = st.shard_version[m.to_shard].saturating_sub(behind);
        // Re-clamp buffer thresholds to the new populations.
        for (sk, &p) in st.shard_k.iter_mut().zip(&st.shard_pop) {
            *sk = k.clamp(1, p.max(1));
        }
        // Start the cooldown only for an *applied* migration.
        self.control.note_migration(flushes);
        self.push_control_record(
            flushes,
            now,
            "rebalance",
            "client_shard",
            m.from_shard as f64,
            m.to_shard as f64,
            m.signal,
            Some(c),
        );
    }

    /// Resolve deferred pool-side evaluations into their records (threaded
    /// engine). Values are identical to inline evaluation — only the
    /// wall-clock point where they were computed differs.
    fn drain_pending_evals(&mut self, st: &mut EngineState) -> Result<()> {
        for (idx, rx) in st.pending_evals.drain(..) {
            let (acc, loss) = rx
                .recv()
                .map_err(|_| anyhow!("evaluation worker dropped its result"))??;
            let r = &mut self.metrics.records[idx];
            r.global_acc = acc;
            r.global_loss = loss;
            if acc.is_finite() {
                log_info!(
                    "server",
                    "[{}] flush {:>3}: acc={acc:.4} shard={} buffer={} in_flight={} stale_max={} vt={:.1}s",
                    self.metrics.algorithm,
                    r.round,
                    r.shard,
                    r.uploads,
                    r.in_flight,
                    r.staleness_max(),
                    r.vtime
                );
            }
        }
        Ok(())
    }

    /// Evaluate the current global model on the server test set.
    pub fn evaluate_global(&self, exec: &mut dyn Executor) -> Result<(f64, f64)> {
        evaluate_with_params(
            exec,
            &self.global,
            &self.ctx.test_images[..],
            &self.ctx.test_labels[..],
        )
    }

    /// The held-out test set (used by examples for extra reporting).
    pub fn test_set(&self) -> (&[f32], &[i32]) {
        (&self.ctx.test_images[..], &self.ctx.test_labels[..])
    }
}

/// Build a server + fleet from a config, a materialized dataset partition,
/// and an initial model.
#[allow(clippy::too_many_arguments)]
pub fn build_server(
    cfg: &ExperimentConfig,
    shards: Vec<crate::data::ClientShard>,
    test: Dataset,
    init_params: ParamVec,
    policy: Box<dyn SelectionPolicy>,
    batch_size: usize,
    flops: (u64, u64),
    payload_bytes: u64,
) -> Server {
    let data = FleetData::Eager(shards.into_iter().map(Arc::new).collect());
    build_server_with_data(cfg, data, test, init_params, policy, batch_size, flops, payload_bytes)
}

/// [`build_server`] over any [`FleetData`] source — the fleet-scale path
/// passes [`FleetData::Lazy`] so client shards are synthesized on hydration
/// instead of being resident for the whole fleet up front.
#[allow(clippy::too_many_arguments)]
pub fn build_server_with_data(
    cfg: &ExperimentConfig,
    data: FleetData,
    test: Dataset,
    init_params: ParamVec,
    policy: Box<dyn SelectionPolicy>,
    batch_size: usize,
    flops: (u64, u64),
    payload_bytes: u64,
) -> Server {
    let root_rng = Rng::new(cfg.seed);
    let input_dim = test.input_dim();
    // Probe set = leading slice of the test set (paper: clients measure
    // Acc_i on the test set; the probe keeps per-round cost bounded).
    let probe_n = cfg.probe_samples.min(test.len());
    let probe_images = Arc::new(test.images[..probe_n * input_dim].to_vec());
    let probe_labels = Arc::new(test.labels[..probe_n].to_vec());

    let mut fleet = Fleet::new(
        data,
        batch_size,
        probe_images,
        probe_labels,
        cfg.fleet.residual_budget,
        root_rng.clone(),
    );
    if cfg.attack.mode != AttackMode::None && cfg.attack.fraction > 0.0 {
        // Attack assignment must precede Server::new — set_attacks
        // asserts no client is hydrated yet, so the very first gradient
        // any compromised client ever produces is already poisoned.
        fleet.set_attacks(attack_table(cfg, fleet.len(), &root_rng));
    }

    let ctx = ServerContext {
        link: cfg.link.clone(),
        train_flops: flops.0,
        eval_flops: flops.1,
        model_payload_bytes: payload_bytes,
        test_images: Arc::new(test.images),
        test_labels: Arc::new(test.labels),
    };
    Server::new(cfg.clone(), ctx, fleet, policy, init_params, &root_rng)
}

/// Build the per-client attack table for a fleet of `n` clients: a
/// seed-derived shuffle picks `round(n * fraction)` compromised ids, so
/// the same seed always corrupts the same clients regardless of which
/// attack mode (or fleet rotation schedule) is in play.
fn attack_table(cfg: &ExperimentConfig, n: usize, root: &Rng) -> Vec<AttackProfile> {
    let profile = match cfg.attack.mode {
        AttackMode::None => return vec![AttackProfile::Benign; n],
        AttackMode::LabelFlip => AttackProfile::LabelFlip,
        AttackMode::SignFlip => AttackProfile::SignFlip,
        AttackMode::Scale => AttackProfile::Scale { gain: cfg.attack.scale as f32 },
        AttackMode::Backdoor => AttackProfile::Backdoor {
            coords: cfg.attack.backdoor_coords,
            boost: cfg.attack.backdoor_boost as f32,
        },
    };
    let mut ids: Vec<usize> = (0..n).collect();
    let mut r = root.fork("attack");
    r.shuffle(&mut ids);
    let count = ((n as f64 * cfg.attack.fraction).round() as usize).min(n);
    let mut table = vec![AttackProfile::Benign; n];
    for &id in ids.iter().take(count) {
        table[id] = profile;
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algorithm, Backend};
    use crate::coordinator::policy::make_policy;
    use crate::data::synth::SynthConfig;
    use crate::data::{partition, PartitionScheme};
    use crate::runtime::MockExecutor;

    fn mini_cfg(algorithm: Algorithm) -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            algorithm,
            num_clients: 3,
            partition: PartitionScheme::Iid,
            samples_per_client: 96,
            test_samples: 64,
            probe_samples: 32,
            rounds: 4,
            local_passes: 1,
            batches_per_pass: 2,
            lr: 0.5,
            target_acc: 0.2,
            seed: 7,
            backend: Backend::Mock,
            ..Default::default()
        }
    }

    fn build(algorithm: Algorithm) -> (Server, MockExecutor) {
        let cfg = mini_cfg(algorithm);
        let exec = MockExecutor::standard();
        let (shards, test) = partition(
            cfg.partition,
            cfg.num_clients,
            cfg.samples_per_client,
            cfg.test_samples,
            &SynthConfig::default(),
            &Rng::new(cfg.seed),
        );
        let policy = make_policy(cfg.algorithm, cfg.value_fn, cfg.eaflm);
        let server = build_server(
            &cfg,
            shards,
            test,
            vec![0.0; exec.param_count()],
            policy,
            exec.batch_size(),
            (1_000_000, 300_000),
            4 * exec.param_count() as u64 + 64,
        );
        (server, exec)
    }

    #[test]
    fn afl_uploads_everyone_every_round() {
        let (mut server, mut exec) = build(Algorithm::Afl);
        server.run(&mut exec).unwrap();
        for r in &server.metrics.records {
            assert_eq!(r.uploads, 3);
        }
        assert_eq!(server.metrics.total_uploads(), 12);
    }

    #[test]
    fn vafl_gates_some_uploads() {
        let (mut server, mut exec) = build(Algorithm::Vafl);
        server.run(&mut exec).unwrap();
        let total = server.metrics.total_uploads();
        // Eq. 2 with >= mean: at least one per round, at most all.
        assert!(total >= 4 && total < 12, "total {total}");
        for r in &server.metrics.records {
            assert!(r.uploads >= 1);
        }
    }

    #[test]
    fn virtual_time_is_monotone_and_positive() {
        let (mut server, mut exec) = build(Algorithm::Vafl);
        server.run(&mut exec).unwrap();
        let mut last = 0.0;
        for r in &server.metrics.records {
            assert!(r.vtime > last);
            last = r.vtime;
        }
    }

    #[test]
    fn skipped_clients_accumulate_staleness() {
        let (mut server, mut exec) = build(Algorithm::Vafl);
        server.run(&mut exec).unwrap();
        // Someone must have been skipped at least once across the run...
        let any_skip = server
            .metrics
            .records
            .iter()
            .any(|r| r.selected.iter().any(|&s| !s));
        assert!(any_skip);
    }

    #[test]
    fn deterministic_across_runs() {
        let (mut s1, mut e1) = build(Algorithm::Vafl);
        let (mut s2, mut e2) = build(Algorithm::Vafl);
        s1.run(&mut e1).unwrap();
        s2.run(&mut e2).unwrap();
        for (a, b) in s1.metrics.records.iter().zip(&s2.metrics.records) {
            assert_eq!(a.global_acc.to_bits(), b.global_acc.to_bits());
            assert_eq!(a.selected, b.selected);
            assert_eq!(a.vtime.to_bits(), b.vtime.to_bits());
        }
    }

    #[test]
    fn model_actually_learns_under_all_policies() {
        for algo in Algorithm::ALL {
            let (mut server, mut exec) = build(algo);
            let cfg_rounds = 12;
            for _ in 0..cfg_rounds {
                server.run_round(&mut exec).unwrap();
            }
            let acc = server.metrics.final_accuracy();
            assert!(acc > 0.3, "{}: acc {acc}", algo.name());
        }
    }

    #[test]
    fn bytes_accounting_counts_uploads() {
        let (mut server, mut exec) = build(Algorithm::Afl);
        let rec = server.run_round(&mut exec).unwrap();
        let payload = 4 * exec.param_count() as u64 + 64;
        // 3 value reports + 3 model uploads.
        assert_eq!(rec.bytes_up, 3 * 68 + 3 * payload);
        // 3 upload requests + 3 broadcasts.
        assert_eq!(rec.bytes_down, 3 * 64 + 3 * payload);
        // Hand-counted control/payload split: the totals above decompose
        // into fixed-size control frames (68-byte V reports up, 64-byte
        // upload requests down) and model payloads — nothing else.
        assert_eq!(rec.bytes_up_ctrl, 3 * 68);
        assert_eq!(rec.bytes_down_ctrl, 3 * 64);
        assert_eq!(rec.bytes_up_payload(), 3 * payload);
        assert_eq!(rec.bytes_down_payload(), 3 * payload);
    }
}
