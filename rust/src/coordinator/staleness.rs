//! Staleness-aware mixing rules for the barrier-free engine.
//!
//! When the server aggregates on-arrival, an upload may have been computed
//! against a global model that is `tau` versions old. The mixing rule
//! `alpha(tau)` controls how much such an upload moves the global model:
//! the flushed buffer is folded in as
//! `theta <- (1 - abar) * theta + abar * fedavg(buffer)` with per-upload
//! FedAvg weights `n_i * alpha(tau_i)` and `abar` the buffer's mean
//! `alpha(tau_i)` — the standard async-FL family (FedAsync's constant /
//! polynomial rules, plus a hinge variant). `alpha == 1` everywhere
//! degenerates to the barriered engine's plain FedAvg replacement.
//!
//! Every rule is bounded in `(0, alpha0]` and monotone non-increasing in
//! `tau` (property-tested in `rust/tests/engine_async.rs`).

use anyhow::{bail, Result};

/// The mixing rule `alpha(tau)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MixingRule {
    /// `alpha(tau) = alpha0` — staleness-blind.
    Constant { alpha: f64 },
    /// `alpha(tau) = alpha0 * (1 + tau)^-exponent` (FedAsync's polynomial).
    Polynomial { alpha: f64, exponent: f64 },
    /// `alpha(tau) = alpha0` while `tau <= grace`, then
    /// `alpha0 / (1 + slope * (tau - grace))` (FedAsync's hinge).
    Hinge { alpha: f64, grace: usize, slope: f64 },
}

impl Default for MixingRule {
    /// Gentle polynomial decay — a sensible default for on-arrival
    /// aggregation (buffer of 1), where raw replacement (`alpha = 1`)
    /// would let any single straggler overwrite the global model.
    fn default() -> Self {
        MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 }
    }
}

impl MixingRule {
    pub fn name(&self) -> &'static str {
        match self {
            MixingRule::Constant { .. } => "constant",
            MixingRule::Polynomial { .. } => "polynomial",
            MixingRule::Hinge { .. } => "hinge",
        }
    }

    /// Mixing weight for an upload that is `tau` global versions stale.
    pub fn alpha(&self, tau: usize) -> f64 {
        match *self {
            MixingRule::Constant { alpha } => alpha,
            MixingRule::Polynomial { alpha, exponent } => {
                alpha * (1.0 + tau as f64).powf(-exponent)
            }
            MixingRule::Hinge { alpha, grace, slope } => {
                if tau <= grace {
                    alpha
                } else {
                    alpha / (1.0 + slope * (tau - grace) as f64)
                }
            }
        }
    }

    /// The same rule re-parameterized to base rate `alpha0` — the
    /// adaptive control plane retunes only the base rate; the shape
    /// parameters (exponent, grace, slope) are kept.
    pub fn with_alpha0(&self, alpha0: f64) -> MixingRule {
        match *self {
            MixingRule::Constant { .. } => MixingRule::Constant { alpha: alpha0 },
            MixingRule::Polynomial { exponent, .. } => {
                MixingRule::Polynomial { alpha: alpha0, exponent }
            }
            MixingRule::Hinge { grace, slope, .. } => {
                MixingRule::Hinge { alpha: alpha0, grace, slope }
            }
        }
    }

    /// Base rate `alpha(0)` (the rule's upper bound).
    pub fn alpha0(&self) -> f64 {
        match *self {
            MixingRule::Constant { alpha }
            | MixingRule::Polynomial { alpha, .. }
            | MixingRule::Hinge { alpha, .. } => alpha,
        }
    }

    pub fn validate(&self) -> Result<()> {
        let a0 = self.alpha0();
        if !(0.0 < a0 && a0 <= 1.0) {
            bail!("mixing alpha must be in (0, 1], got {a0}");
        }
        match *self {
            MixingRule::Constant { .. } => {}
            MixingRule::Polynomial { exponent, .. } => {
                if !(exponent >= 0.0 && exponent.is_finite()) {
                    bail!("mixing exponent must be finite and >= 0, got {exponent}");
                }
            }
            MixingRule::Hinge { slope, .. } => {
                if !(slope >= 0.0 && slope.is_finite()) {
                    bail!("mixing hinge slope must be finite and >= 0, got {slope}");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_ignores_staleness() {
        let r = MixingRule::Constant { alpha: 0.7 };
        assert_eq!(r.alpha(0), 0.7);
        assert_eq!(r.alpha(100), 0.7);
    }

    #[test]
    fn polynomial_decays_from_alpha0() {
        let r = MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 };
        assert!((r.alpha(0) - 0.8).abs() < 1e-12);
        assert!((r.alpha(3) - 0.8 / 2.0).abs() < 1e-12); // (1+3)^-0.5 = 1/2
        assert!(r.alpha(10) < r.alpha(3));
    }

    #[test]
    fn hinge_flat_then_decaying() {
        let r = MixingRule::Hinge { alpha: 0.6, grace: 2, slope: 1.0 };
        assert_eq!(r.alpha(0), 0.6);
        assert_eq!(r.alpha(2), 0.6);
        assert!((r.alpha(3) - 0.3).abs() < 1e-12);
        assert!((r.alpha(4) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn validation_rejects_bad_params() {
        assert!(MixingRule::Constant { alpha: 0.0 }.validate().is_err());
        assert!(MixingRule::Constant { alpha: 1.5 }.validate().is_err());
        assert!(MixingRule::Polynomial { alpha: 0.5, exponent: -1.0 }
            .validate()
            .is_err());
        assert!(MixingRule::Hinge { alpha: 0.5, grace: 1, slope: f64::NAN }
            .validate()
            .is_err());
        assert!(MixingRule::default().validate().is_ok());
    }

    #[test]
    fn with_alpha0_keeps_shape_parameters() {
        let p = MixingRule::Polynomial { alpha: 0.8, exponent: 0.5 }.with_alpha0(0.4);
        assert_eq!(p, MixingRule::Polynomial { alpha: 0.4, exponent: 0.5 });
        let h = MixingRule::Hinge { alpha: 0.9, grace: 3, slope: 0.25 }.with_alpha0(0.6);
        assert_eq!(h, MixingRule::Hinge { alpha: 0.6, grace: 3, slope: 0.25 });
        let c = MixingRule::Constant { alpha: 1.0 }.with_alpha0(0.2);
        assert_eq!(c, MixingRule::Constant { alpha: 0.2 });
        assert_eq!(c.alpha0(), 0.2);
    }

    #[test]
    fn names() {
        assert_eq!(MixingRule::default().name(), "polynomial");
        assert_eq!(MixingRule::Constant { alpha: 1.0 }.name(), "constant");
        assert_eq!(
            MixingRule::Hinge { alpha: 1.0, grace: 0, slope: 1.0 }.name(),
            "hinge"
        );
    }
}
