//! Mini-batch iteration over a client shard (paper Algorithm 1 line 19:
//! "Split user data into local mini-batch size B"), with per-epoch
//! reshuffling and fixed-size batches (tail wraps around, as PyTorch's
//! drop_last=False + fixed-shape XLA executables require a full batch).

use crate::util::rng::Rng;

use super::synth::Dataset;

/// Epoch-reshuffling batcher producing fixed-size `[B, d]` batches.
/// `Clone` snapshots the full iteration state (order, cursor, RNG), which
/// the speculative client forks rely on.
#[derive(Clone)]
pub struct Batcher {
    order: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
    /// Total reshuffles performed since construction (`new` counts as 1).
    /// Together with `cursor` this is the complete iteration position: a
    /// parked client records `(reshuffles, cursor)` and [`Batcher::restore`]
    /// replays exactly that many shuffles on a fresh identity order to
    /// land on the same `(order, cursor, rng)` triple bit-for-bit.
    reshuffles: u64,
}

impl Batcher {
    pub fn new(n: usize, batch: usize, rng: Rng) -> Self {
        assert!(n > 0, "empty shard");
        assert!(batch > 0);
        let mut b = Batcher { order: (0..n).collect(), cursor: 0, batch, rng, reshuffles: 0 };
        b.reshuffle();
        b
    }

    /// Rebuild a batcher at a recorded iteration position: replay
    /// `reshuffles` shuffles (from the same seed RNG `Batcher::new` was
    /// given) over the identity order, then seek to `cursor`. By
    /// construction `restore(n, b, rng, 1, 0)` is bitwise
    /// `Batcher::new(n, b, rng)`, and more generally restoring the
    /// `(reshuffles(), cursor())` of a live batcher built from the same
    /// RNG yields a batcher whose future batch stream is identical —
    /// the parked-client hydration contract (see `fleet`).
    pub fn restore(n: usize, batch: usize, rng: Rng, reshuffles: u64, cursor: usize) -> Self {
        assert!(n > 0, "empty shard");
        assert!(batch > 0);
        assert!(reshuffles >= 1, "a batcher has always shuffled at least once");
        assert!(cursor <= n, "cursor beyond shard");
        let mut b = Batcher { order: (0..n).collect(), cursor: 0, batch, rng, reshuffles: 0 };
        for _ in 0..reshuffles {
            b.reshuffle();
        }
        b.cursor = cursor;
        b
    }

    /// Reshuffle count since construction (≥ 1); see [`Batcher::restore`].
    pub fn reshuffles(&self) -> u64 {
        self.reshuffles
    }

    /// Position within the current epoch order; see [`Batcher::restore`].
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.order);
        self.cursor = 0;
        self.reshuffles += 1;
    }

    /// Number of full batches per epoch (at least 1; short shards wrap).
    pub fn batches_per_epoch(&self) -> usize {
        (self.order.len() / self.batch).max(1)
    }

    /// Fill `x`/`y` with the next batch from `data`. Returns `true` if this
    /// batch completed an epoch (triggering a reshuffle).
    pub fn next_batch(&mut self, data: &Dataset, x: &mut [f32], y: &mut [i32]) -> bool {
        let d = data.input_dim();
        assert_eq!(x.len(), self.batch * d);
        assert_eq!(y.len(), self.batch);
        let n = self.order.len();
        let mut wrapped = false;
        for i in 0..self.batch {
            if self.cursor >= n {
                self.reshuffle();
                wrapped = true;
            }
            let idx = self.order[self.cursor];
            self.cursor += 1;
            x[i * d..(i + 1) * d].copy_from_slice(data.image(idx));
            y[i] = data.labels[idx];
        }
        if self.cursor >= n {
            self.reshuffle();
            wrapped = true;
        }
        wrapped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};

    #[test]
    fn batches_cover_epoch_without_repeats() {
        let ds = generate(64, &SynthConfig::default(), &mut Rng::new(1));
        let mut b = Batcher::new(64, 16, Rng::new(2));
        assert_eq!(b.batches_per_epoch(), 4);
        let d = ds.input_dim();
        let mut x = vec![0.0; 16 * d];
        let mut y = vec![0; 16];
        let mut seen = std::collections::HashSet::new();
        for step in 0..4 {
            let wrapped = b.next_batch(&ds, &mut x, &mut y);
            assert_eq!(wrapped, step == 3);
            for i in 0..16 {
                // identify the sample by its first 8 pixels
                let sig: Vec<u32> = x[i * d..i * d + 8].iter().map(|v| v.to_bits()).collect();
                assert!(seen.insert(sig), "repeat within epoch");
            }
        }
    }

    #[test]
    fn short_shard_wraps() {
        let ds = generate(5, &SynthConfig::default(), &mut Rng::new(3));
        let mut b = Batcher::new(5, 8, Rng::new(4));
        assert_eq!(b.batches_per_epoch(), 1);
        let mut x = vec![0.0; 8 * ds.input_dim()];
        let mut y = vec![0; 8];
        let wrapped = b.next_batch(&ds, &mut x, &mut y);
        assert!(wrapped);
        // All labels must come from the shard.
        for &l in &y {
            assert!(ds.labels.contains(&l));
        }
    }

    #[test]
    fn restore_resumes_the_exact_batch_stream() {
        let ds = generate(32, &SynthConfig::default(), &mut Rng::new(9));
        let seed_rng = Rng::new(77);
        // restore(.., 1, 0) must be bitwise Batcher::new.
        let fresh = Batcher::new(32, 8, seed_rng.clone());
        let restored = Batcher::restore(32, 8, seed_rng.clone(), 1, 0);
        assert_eq!(fresh.order, restored.order);
        assert_eq!(fresh.cursor, restored.cursor);
        assert_eq!(fresh.reshuffles(), restored.reshuffles());
        // Run a live batcher an arbitrary number of steps, park its
        // (reshuffles, cursor), restore, and compare future streams.
        for steps in [0usize, 1, 3, 4, 7, 11] {
            let mut live = Batcher::new(32, 8, seed_rng.clone());
            let mut x = vec![0.0; 8 * ds.input_dim()];
            let mut y = vec![0; 8];
            for _ in 0..steps {
                live.next_batch(&ds, &mut x, &mut y);
            }
            let mut back =
                Batcher::restore(32, 8, seed_rng.clone(), live.reshuffles(), live.cursor());
            for _ in 0..6 {
                let mut y2 = vec![0; 8];
                let w1 = live.next_batch(&ds, &mut x, &mut y);
                let w2 = back.next_batch(&ds, &mut x, &mut y2);
                assert_eq!(w1, w2, "wrap parity after {steps} steps");
                assert_eq!(y, y2, "batch stream after {steps} steps");
            }
        }
    }

    #[test]
    fn deterministic_given_rng() {
        let ds = generate(32, &SynthConfig::default(), &mut Rng::new(5));
        let run = |seed| {
            let mut b = Batcher::new(32, 8, Rng::new(seed));
            let mut x = vec![0.0; 8 * ds.input_dim()];
            let mut y = vec![0; 8];
            let mut all = Vec::new();
            for _ in 0..6 {
                b.next_batch(&ds, &mut x, &mut y);
                all.extend_from_slice(&y);
            }
            all
        };
        assert_eq!(run(7), run(7));
        assert_ne!(run(7), run(8));
    }
}
