//! Data substrate: the SynthDigits corpus (MNIST stand-in, see DESIGN.md
//! §2.3), the paper's IID / Non-IID client partitioners (Fig. 3), batching,
//! and distribution statistics.

pub mod batcher;
pub mod partition;
pub mod stats;
pub mod synth;

pub use batcher::Batcher;
pub use partition::{partition, ClientShard, LazyPartition, PartitionScheme};
pub use synth::{Dataset, SynthConfig};
