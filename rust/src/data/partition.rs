//! Client data partitioners: the paper's IID and Non-IID distributions
//! (§IV-C, Fig. 3).
//!
//! * IID: the training pool is split equally; every client holds samples of
//!   all 10 labels in near-equal proportion.
//! * Non-IID: label- and quantity-skewed — "some clients containing all
//!   labels and a large number of samples under each label, and some
//!   clients containing only a small number of labels and some samples
//!   under each label". Two schemes:
//!   - `PaperSkew`: deterministic tiers reproducing Fig. 3's qualitative
//!     shape (first clients rich/full-label, later clients poor/few-label).
//!   - `Dirichlet { alpha }`: the standard label-skew generator from the
//!     FL literature, for ablations.

use crate::util::rng::Rng;

use super::synth::{self, Dataset, SynthConfig};

/// How client shards are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PartitionScheme {
    /// Equal-size, all labels per client.
    Iid,
    /// The paper's Fig. 3 tiered skew (rich full-label clients down to poor
    /// few-label clients).
    PaperSkew,
    /// Dirichlet(alpha) label proportions per client, quantity skew via a
    /// power-law over client sizes.
    Dirichlet { alpha: f64 },
}

/// One client's local data.
#[derive(Debug, Clone)]
pub struct ClientShard {
    pub client_id: usize,
    pub data: Dataset,
}

impl ClientShard {
    pub fn num_samples(&self) -> usize {
        self.data.len()
    }
}

/// Per-client class-count matrix for a scheme, without generating pixels.
/// `samples_per_client` is the *average* shard size (paper: 20k for 3
/// clients, 10k for 7).
pub fn class_counts(
    scheme: PartitionScheme,
    num_clients: usize,
    samples_per_client: usize,
    rng: &mut Rng,
) -> Vec<[usize; 10]> {
    assert!(num_clients > 0);
    match scheme {
        PartitionScheme::Iid => (0..num_clients)
            .map(|_| {
                let base = samples_per_client / 10;
                let mut c = [base; 10];
                // Distribute the remainder deterministically.
                for k in 0..samples_per_client - base * 10 {
                    c[k % 10] += 1;
                }
                c
            })
            .collect(),
        PartitionScheme::PaperSkew => paper_skew_counts(num_clients, samples_per_client, rng),
        PartitionScheme::Dirichlet { alpha } => (0..num_clients)
            .map(|_| {
                // Quantity skew: shard size in [0.4, 1.6] x average.
                let size =
                    ((samples_per_client as f64) * rng.range_f64(0.4, 1.6)) as usize;
                let props = rng.dirichlet(alpha, 10);
                let mut c = [0usize; 10];
                for (k, p) in props.iter().enumerate() {
                    c[k] = (p * size as f64).round() as usize;
                }
                c
            })
            .collect(),
    }
}

/// Fig. 3-style tiers: client 0 is "rich" (all labels, full size); richness
/// decays with client index — the last clients hold ~35 % of the average
/// size over only 3-4 labels.
fn paper_skew_counts(
    num_clients: usize,
    samples_per_client: usize,
    rng: &mut Rng,
) -> Vec<[usize; 10]> {
    let mut out = Vec::with_capacity(num_clients);
    for i in 0..num_clients {
        // Tier in [0,1]: 1 = richest, 0 = poorest.
        let tier = if num_clients == 1 {
            1.0
        } else {
            1.0 - i as f64 / (num_clients as f64 - 1.0)
        };
        // Labels: rich clients all 10, poor clients 3.
        let n_labels = (3.0 + tier * 7.0).round() as usize;
        // Size: 35 %..165 % of the average by tier.
        let size = ((0.35 + 1.3 * tier) * samples_per_client as f64) as usize;
        // Which labels: a contiguous run starting at a rotating offset, so
        // the union across clients covers all classes.
        let start = (i * 10) / num_clients.max(1);
        let mut c = [0usize; 10];
        // Label proportions inside the shard: mild random tilt.
        let mut weights = vec![0.0f64; n_labels];
        for w in weights.iter_mut() {
            *w = rng.range_f64(0.5, 1.5);
        }
        let wsum: f64 = weights.iter().sum();
        for (j, w) in weights.iter().enumerate() {
            let label = (start + j) % 10;
            c[label] = ((w / wsum) * size as f64).round() as usize;
        }
        out.push(c);
    }
    out
}

/// A deferred partition: the per-client class-count matrix plus the
/// generator seed, materializing any client's shard **on demand** —
/// bit-identical to the shard eager [`partition`] would have produced
/// (same per-client named fork, same serial render, independent of the
/// order shards are materialized). Holds O(n) counts instead of
/// O(n · samples · dim) pixels; the active-set fleet hydrates parked
/// clients' shards from this source.
#[derive(Clone)]
pub struct LazyPartition {
    counts: Vec<[usize; 10]>,
    cfg: SynthConfig,
    seed_rng: Rng,
}

impl LazyPartition {
    pub fn new(
        scheme: PartitionScheme,
        num_clients: usize,
        samples_per_client: usize,
        cfg: &SynthConfig,
        seed_rng: &Rng,
    ) -> Self {
        let counts = class_counts(
            scheme,
            num_clients,
            samples_per_client,
            &mut seed_rng.fork("partition-counts"),
        );
        LazyPartition { counts, cfg: cfg.clone(), seed_rng: seed_rng.clone() }
    }

    pub fn num_clients(&self) -> usize {
        self.counts.len()
    }

    /// Shard size without materializing pixels (the FedAvg weight n_i and
    /// the batcher length a parked record needs).
    pub fn num_samples(&self, client_id: usize) -> usize {
        self.counts[client_id].iter().sum()
    }

    /// Render client `client_id`'s shard — bit-identical to eager
    /// [`partition`]'s shard for the same seed, whenever and however
    /// often it is called.
    pub fn materialize(&self, client_id: usize) -> ClientShard {
        ClientShard {
            client_id,
            data: synth::generate_with_counts(
                &self.counts[client_id],
                &self.cfg,
                &mut self.seed_rng.fork(&format!("client-{client_id}")),
            ),
        }
    }

    /// The balanced held-out server test set (same stream as [`partition`]).
    pub fn test_set(&self, test_samples: usize) -> Dataset {
        let per = test_samples / 10;
        let mut tc = [per; 10];
        for k in 0..test_samples - per * 10 {
            tc[k % 10] += 1;
        }
        synth::generate_with_counts(&tc, &self.cfg, &mut self.seed_rng.fork("test-set"))
    }

    /// Approximate resident bytes of this source (the counts matrix).
    pub fn approx_bytes(&self) -> usize {
        self.counts.len() * std::mem::size_of::<[usize; 10]>()
    }
}

/// Build all client shards plus a balanced, held-out server test set.
///
/// The generator streams are forked per client, so shard contents don't
/// depend on the order clients are materialized. Implemented on top of
/// [`LazyPartition`] so the eager and lazy paths cannot drift.
pub fn partition(
    scheme: PartitionScheme,
    num_clients: usize,
    samples_per_client: usize,
    test_samples: usize,
    cfg: &SynthConfig,
    seed_rng: &Rng,
) -> (Vec<ClientShard>, Dataset) {
    let lazy = LazyPartition::new(scheme, num_clients, samples_per_client, cfg, seed_rng);
    let shards = (0..num_clients).map(|id| lazy.materialize(id)).collect();
    let test = lazy.test_set(test_samples);
    (shards, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    #[test]
    fn iid_counts_balanced() {
        let c = class_counts(PartitionScheme::Iid, 3, 1005, &mut rng());
        assert_eq!(c.len(), 3);
        for client in &c {
            assert_eq!(client.iter().sum::<usize>(), 1005);
            let (mn, mx) = (client.iter().min().unwrap(), client.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn paper_skew_shape() {
        let c = class_counts(PartitionScheme::PaperSkew, 7, 1000, &mut rng());
        let sizes: Vec<usize> = c.iter().map(|x| x.iter().sum()).collect();
        let labels: Vec<usize> =
            c.iter().map(|x| x.iter().filter(|&&v| v > 0).count()).collect();
        // Rich first client: all labels, big shard. Poor last: few labels,
        // small shard.
        assert_eq!(labels[0], 10);
        assert!(labels[6] <= 4);
        assert!(sizes[0] > sizes[6] * 3, "sizes {sizes:?}");
        // Union covers all classes.
        let mut union = [0usize; 10];
        for client in &c {
            for (k, &v) in client.iter().enumerate() {
                union[k] += v;
            }
        }
        assert!(union.iter().all(|&v| v > 0), "union {union:?}");
    }

    #[test]
    fn dirichlet_counts_skewed() {
        let c = class_counts(PartitionScheme::Dirichlet { alpha: 0.3 }, 5, 1000, &mut rng());
        // At alpha=0.3 at least one client should be visibly label-skewed:
        // its top class holds > 40% of its samples.
        let skewed = c.iter().any(|client| {
            let total: usize = client.iter().sum();
            let top = *client.iter().max().unwrap();
            total > 0 && (top as f64) / (total as f64) > 0.4
        });
        assert!(skewed, "{c:?}");
    }

    #[test]
    fn partition_materializes_shards_and_test() {
        let (shards, test) = partition(
            PartitionScheme::Iid,
            3,
            120,
            100,
            &SynthConfig::default(),
            &rng(),
        );
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.num_samples() == 120));
        assert_eq!(test.len(), 100);
        let h = test.class_histogram();
        assert!(h.iter().all(|&v| v == 10));
    }

    #[test]
    fn lazy_partition_matches_eager_in_any_order() {
        let cfg = SynthConfig::default();
        let (eager, test) = partition(PartitionScheme::PaperSkew, 4, 50, 20, &cfg, &rng());
        let lazy = LazyPartition::new(PartitionScheme::PaperSkew, 4, 50, &cfg, &rng());
        assert_eq!(lazy.num_clients(), 4);
        // Materialize out of order, twice — every render must be
        // bit-identical to the eager shard.
        for &id in &[3usize, 0, 2, 1, 0, 3] {
            let s = lazy.materialize(id);
            assert_eq!(s.client_id, id);
            assert_eq!(s.data.labels, eager[id].data.labels);
            assert_eq!(
                s.data.images.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                eager[id].data.images.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(lazy.num_samples(id), eager[id].num_samples());
        }
        let t = lazy.test_set(20);
        assert_eq!(t.labels, test.labels);
        assert_eq!(t.images, test.images);
    }

    #[test]
    fn partition_deterministic_and_order_independent() {
        let cfg = SynthConfig::default();
        let (a, _) = partition(PartitionScheme::PaperSkew, 4, 50, 20, &cfg, &rng());
        let (b, _) = partition(PartitionScheme::PaperSkew, 4, 50, 20, &cfg, &rng());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data.labels, y.data.labels);
            assert_eq!(x.data.images, y.data.images);
        }
    }
}
