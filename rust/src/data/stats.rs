//! Distribution statistics — regenerates the paper's Fig. 3 ("Dataset
//! distribution of clients in different experiments") as text tables /
//! JSON, from the same partitioner the experiments use.

use crate::util::json::{obj, Value};

use super::partition::ClientShard;

/// Per-client label histogram table.
#[derive(Debug, Clone)]
pub struct DistributionTable {
    /// `rows[c][k]` = samples of class `k` on client `c`.
    pub rows: Vec<[usize; 10]>,
}

impl DistributionTable {
    pub fn from_shards(shards: &[ClientShard]) -> Self {
        DistributionTable { rows: shards.iter().map(|s| s.data.class_histogram()).collect() }
    }

    /// Total samples per client.
    pub fn client_totals(&self) -> Vec<usize> {
        self.rows.iter().map(|r| r.iter().sum()).collect()
    }

    /// Labels held (count > 0) per client.
    pub fn client_label_counts(&self) -> Vec<usize> {
        self.rows.iter().map(|r| r.iter().filter(|&&v| v > 0).count()).collect()
    }

    /// A normalized skew measure in [0, 1]: mean over clients of
    /// (1 - H(labels)/log 10), where H is the label entropy. 0 = balanced
    /// IID, -> 1 as each client collapses to a single label.
    pub fn skewness(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        let ln10 = (10.0f64).ln();
        let mut total = 0.0;
        for r in &self.rows {
            let n: usize = r.iter().sum();
            if n == 0 {
                continue;
            }
            let mut h = 0.0;
            for &c in r {
                if c > 0 {
                    let p = c as f64 / n as f64;
                    h -= p * p.ln();
                }
            }
            total += 1.0 - h / ln10;
        }
        total / self.rows.len() as f64
    }

    /// Render as the Fig. 3 text table.
    pub fn to_text(&self, title: &str) -> String {
        let mut s = format!("{title}\nclient |");
        for k in 0..10 {
            s += &format!(" {k:>5}");
        }
        s += " | total labels\n";
        s += &"-".repeat(s.lines().last().unwrap().len());
        s += "\n";
        for (c, r) in self.rows.iter().enumerate() {
            s += &format!("{:>6} |", c + 1);
            for v in r {
                s += &format!(" {v:>5}");
            }
            s += &format!(
                " | {:>5} {:>6}\n",
                r.iter().sum::<usize>(),
                r.iter().filter(|&&v| v > 0).count()
            );
        }
        s += &format!("label-skewness = {:.3}\n", self.skewness());
        s
    }

    /// JSON form for the report pipeline.
    pub fn to_json(&self) -> Value {
        obj(vec![
            (
                "clients",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| Value::from(r.iter().map(|&v| v).collect::<Vec<usize>>()))
                        .collect(),
                ),
            ),
            ("skewness", Value::from(self.skewness())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{partition, PartitionScheme};
    use crate::data::synth::SynthConfig;
    use crate::util::rng::Rng;

    fn table(scheme: PartitionScheme) -> DistributionTable {
        let (shards, _) =
            partition(scheme, 5, 200, 50, &SynthConfig::default(), &Rng::new(1));
        DistributionTable::from_shards(&shards)
    }

    #[test]
    fn iid_has_low_skew() {
        let t = table(PartitionScheme::Iid);
        assert!(t.skewness() < 0.01, "{}", t.skewness());
        assert!(t.client_label_counts().iter().all(|&c| c == 10));
    }

    #[test]
    fn paper_skew_has_higher_skew_than_iid() {
        let iid = table(PartitionScheme::Iid).skewness();
        let skew = table(PartitionScheme::PaperSkew).skewness();
        assert!(skew > iid + 0.1, "iid {iid} vs skew {skew}");
    }

    #[test]
    fn text_table_renders_all_clients() {
        let t = table(PartitionScheme::PaperSkew);
        let text = t.to_text("experiment d");
        assert!(text.contains("experiment d"));
        assert_eq!(text.lines().count(), 2 + 1 + 5 + 1); // title+hdr+rule+5 rows+skew
    }

    #[test]
    fn json_shape() {
        let t = table(PartitionScheme::Iid);
        let v = t.to_json();
        assert_eq!(v.get("clients").unwrap().as_arr().unwrap().len(), 5);
        assert!(v.get("skewness").unwrap().as_f64().is_some());
    }
}
