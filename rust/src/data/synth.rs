//! SynthDigits: a deterministic, procedurally generated 28x28 ten-class
//! digit corpus standing in for MNIST (no network access in this
//! environment; see DESIGN.md §2.3 for why the substitution preserves the
//! paper's comparisons).
//!
//! Each digit class is a set of strokes (line segments / arcs on a unit
//! canvas). A sample renders its class glyph through a random affine
//! transform (translate / rotate / scale / shear), random stroke thickness,
//! and additive pixel noise — so classes overlap enough that the task is
//! non-trivial and reaching the paper's 94 % threshold takes real training.

use crate::util::rng::Rng;

/// One segment of a digit glyph, in unit-canvas coordinates.
#[derive(Debug, Clone, Copy)]
struct Seg {
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
}

const S: fn(f32, f32, f32, f32) -> Seg = |x0, y0, x1, y1| Seg { x0, y0, x1, y1 };

/// Polyline glyphs for digits 0-9 on a [0,1]^2 canvas (x right, y down).
/// Seven-segment-inspired but with diagonals so classes are distinguishable
/// under jitter without being trivially linearly separable.
fn glyph(digit: usize) -> Vec<Seg> {
    let (l, r, t, b, m) = (0.25, 0.75, 0.15, 0.85, 0.5);
    match digit {
        0 => vec![S(l, t, r, t), S(r, t, r, b), S(r, b, l, b), S(l, b, l, t), S(l, b, r, t)],
        1 => vec![S(m, t, m, b), S(l, 0.3, m, t), S(l, b, r, b)],
        2 => vec![S(l, 0.25, l, t), S(l, t, r, t), S(r, t, r, m), S(r, m, l, b), S(l, b, r, b)],
        3 => vec![S(l, t, r, t), S(r, t, r, b), S(r, b, l, b), S(l, m, r, m)],
        4 => vec![S(l, t, l, m), S(l, m, r, m), S(r, t, r, b)],
        5 => vec![S(r, t, l, t), S(l, t, l, m), S(l, m, r, m), S(r, m, r, b), S(r, b, l, b)],
        6 => vec![S(r, t, l, t), S(l, t, l, b), S(l, b, r, b), S(r, b, r, m), S(r, m, l, m)],
        7 => vec![S(l, t, r, t), S(r, t, m, b), S(0.35, m, 0.65, m)],
        8 => vec![S(l, t, r, t), S(r, t, r, b), S(r, b, l, b), S(l, b, l, t), S(l, m, r, m)],
        9 => vec![S(l, b, r, b), S(r, b, r, t), S(r, t, l, t), S(l, t, l, m), S(l, m, r, m)],
        _ => panic!("digit out of range"),
    }
}

/// Generation parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Canvas side (the paper's MNIST geometry: 28).
    pub image_dim: usize,
    /// Max translation as a fraction of the canvas.
    pub max_shift: f32,
    /// Max rotation in radians.
    pub max_rot: f32,
    /// Scale range (uniform in [1-s, 1+s]).
    pub max_scale: f32,
    /// Max shear coefficient.
    pub max_shear: f32,
    /// Stroke half-thickness range in canvas units.
    pub thickness: (f32, f32),
    /// Additive Gaussian pixel noise sigma.
    pub pixel_noise: f32,
    /// Probability of inverting a background pixel streak (clutter).
    pub clutter: f32,
}

impl SynthConfig {
    /// The harder variant used by robustness ablations (stronger affine
    /// jitter + noise; roughly the difficulty of the original default).
    pub fn hard() -> Self {
        SynthConfig {
            max_shift: 0.08,
            max_rot: 0.30,
            max_scale: 0.15,
            max_shear: 0.15,
            pixel_noise: 0.18,
            clutter: 0.04,
            ..Default::default()
        }
    }
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            image_dim: 28,
            max_shift: 0.06,
            max_rot: 0.18,
            max_scale: 0.10,
            max_shear: 0.08,
            thickness: (0.04, 0.075),
            pixel_noise: 0.10,
            clutter: 0.02,
        }
    }
}

/// A flat dataset: `images` is `[n, dim*dim]` row-major in `[0,1]`,
/// `labels[i] in 0..10`.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub images: Vec<f32>,
    pub labels: Vec<i32>,
    pub dim: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn input_dim(&self) -> usize {
        self.dim * self.dim
    }

    /// Borrow sample `i`'s pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        let d = self.input_dim();
        &self.images[i * d..(i + 1) * d]
    }

    /// Gather a subset by indices into a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let d = self.input_dim();
        let mut images = Vec::with_capacity(idx.len() * d);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Dataset { images, labels, dim: self.dim }
    }

    /// Per-class sample counts.
    pub fn class_histogram(&self) -> [usize; 10] {
        let mut h = [0usize; 10];
        for &l in &self.labels {
            h[l as usize] += 1;
        }
        h
    }
}

/// Render one sample of `digit` into `out` (len `dim*dim`), deterministic in
/// the RNG state.
fn render(digit: usize, cfg: &SynthConfig, rng: &mut Rng, out: &mut [f32]) {
    let dim = cfg.image_dim;
    debug_assert_eq!(out.len(), dim * dim);
    // Random affine: canvas -> canvas.
    let rot = rng.range_f64(-cfg.max_rot as f64, cfg.max_rot as f64) as f32;
    let scale = 1.0 + rng.range_f64(-cfg.max_scale as f64, cfg.max_scale as f64) as f32;
    let shear = rng.range_f64(-cfg.max_shear as f64, cfg.max_shear as f64) as f32;
    let dx = rng.range_f64(-cfg.max_shift as f64, cfg.max_shift as f64) as f32;
    let dy = rng.range_f64(-cfg.max_shift as f64, cfg.max_shift as f64) as f32;
    let thick =
        rng.range_f64(cfg.thickness.0 as f64, cfg.thickness.1 as f64) as f32;
    let (sin, cos) = (rot.sin(), rot.cos());

    // Transform glyph segments about the canvas center.
    let tf = |x: f32, y: f32| -> (f32, f32) {
        let (cx, cy) = (x - 0.5, y - 0.5);
        let xs = scale * (cx + shear * cy);
        let ys = scale * cy;
        let xr = cos * xs - sin * ys;
        let yr = sin * xs + cos * ys;
        (xr + 0.5 + dx, yr + 0.5 + dy)
    };
    let segs: Vec<Seg> = glyph(digit)
        .into_iter()
        .map(|s| {
            let (x0, y0) = tf(s.x0, s.y0);
            let (x1, y1) = tf(s.x1, s.y1);
            Seg { x0, y0, x1, y1 }
        })
        .collect();

    // Rasterize: intensity from distance to nearest segment.
    let inv = 1.0 / dim as f32;
    for py in 0..dim {
        for px in 0..dim {
            let x = (px as f32 + 0.5) * inv;
            let y = (py as f32 + 0.5) * inv;
            let mut d2min = f32::INFINITY;
            for s in &segs {
                let d2 = dist2_to_segment(x, y, s);
                if d2 < d2min {
                    d2min = d2;
                }
            }
            let d = d2min.sqrt();
            // Smooth falloff: 1 inside the stroke, decaying over one pixel.
            let v = if d <= thick {
                1.0
            } else {
                (1.0 - (d - thick) / (1.5 * inv)).max(0.0)
            };
            out[py * dim + px] = v;
        }
    }

    // Clutter: a few random bright pixels (sensor junk).
    let n_clutter = (cfg.clutter * dim as f32 * dim as f32 * rng.f32()) as usize;
    for _ in 0..n_clutter {
        let i = rng.below(dim * dim);
        out[i] = out[i].max(0.4 + 0.6 * rng.f32());
    }

    // Pixel noise.
    if cfg.pixel_noise > 0.0 {
        for v in out.iter_mut() {
            *v = (*v + cfg.pixel_noise * rng.gauss() as f32).clamp(0.0, 1.0);
        }
    }
}

fn dist2_to_segment(x: f32, y: f32, s: &Seg) -> f32 {
    let (vx, vy) = (s.x1 - s.x0, s.y1 - s.y0);
    let (wx, wy) = (x - s.x0, y - s.y0);
    let len2 = vx * vx + vy * vy;
    let t = if len2 > 0.0 {
        ((wx * vx + wy * vy) / len2).clamp(0.0, 1.0)
    } else {
        0.0
    };
    let (dx, dy) = (wx - t * vx, wy - t * vy);
    dx * dx + dy * dy
}

/// Generate `n` samples with labels drawn uniformly (balanced in
/// expectation), deterministic in `rng`.
///
/// Rendering fans out across scoped threads ([`crate::util::par`]): a
/// serial prologue draws the label stream and one SplitMix-derived seed per
/// sample from `rng`, then each sample rasterizes from its own stream into
/// its disjoint slice of the image buffer — so the dataset is bit-identical
/// for every worker count.
pub fn generate(n: usize, cfg: &SynthConfig, rng: &mut Rng) -> Dataset {
    generate_t(n, cfg, rng, crate::util::par::threads_for(n, 16))
}

/// Explicit-worker-count variant of [`generate`] (benches and the
/// thread-count equivalence property tests).
pub fn generate_t(n: usize, cfg: &SynthConfig, rng: &mut Rng, threads: usize) -> Dataset {
    let d = cfg.image_dim * cfg.image_dim;
    let mut images = vec![0.0f32; n * d];
    let mut labels = vec![0i32; n];
    let mut seeds = Vec::with_capacity(n);
    for lab in labels.iter_mut() {
        *lab = rng.below(10) as i32;
        seeds.push(rng.next_u64());
    }
    let labels_ref = &labels;
    let seeds_ref = &seeds;
    crate::util::par::par_chunks_mut(&mut images, threads, d, move |start, chunk| {
        let first = start / d;
        for (j, out) in chunk.chunks_mut(d).enumerate() {
            let i = first + j;
            let mut srng = Rng::new(seeds_ref[i]);
            render(labels_ref[i] as usize, cfg, &mut srng, out);
        }
    });
    Dataset { images, labels, dim: cfg.image_dim }
}

/// Generate `n` samples with the given per-class counts
/// (`counts.iter().sum() == n` is enforced).
pub fn generate_with_counts(counts: &[usize; 10], cfg: &SynthConfig, rng: &mut Rng) -> Dataset {
    let n: usize = counts.iter().sum();
    let d = cfg.image_dim * cfg.image_dim;
    let mut images = vec![0.0f32; n * d];
    let mut labels = vec![0i32; n];
    let mut i = 0;
    for (digit, &c) in counts.iter().enumerate() {
        for _ in 0..c {
            labels[i] = digit as i32;
            render(digit, cfg, rng, &mut images[i * d..(i + 1) * d]);
            i += 1;
        }
    }
    // Shuffle so batches are class-mixed.
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    let ds = Dataset { images, labels, dim: cfg.image_dim };
    ds.subset(&order)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let cfg = SynthConfig::default();
        let a = generate(20, &cfg, &mut Rng::new(1));
        let b = generate(20, &cfg, &mut Rng::new(1));
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
        let c = generate(20, &cfg, &mut Rng::new(2));
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn generation_identical_for_every_worker_count() {
        let cfg = SynthConfig::default();
        let base = generate_t(33, &cfg, &mut Rng::new(11), 1);
        for threads in 2..=8 {
            let ds = generate_t(33, &cfg, &mut Rng::new(11), threads);
            assert_eq!(ds.labels, base.labels, "threads={threads}");
            assert_eq!(ds.images, base.images, "threads={threads}");
        }
    }

    #[test]
    fn pixels_in_unit_range() {
        let ds = generate(50, &SynthConfig::default(), &mut Rng::new(3));
        assert!(ds.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert_eq!(ds.images.len(), 50 * 784);
    }

    #[test]
    fn glyphs_have_ink() {
        // Every class must render a visibly inked image (no empty glyphs).
        let cfg = SynthConfig { pixel_noise: 0.0, clutter: 0.0, ..Default::default() };
        let mut rng = Rng::new(4);
        for digit in 0..10 {
            let mut px = vec![0.0f32; 784];
            render(digit, &cfg, &mut rng, &mut px);
            let ink: f32 = px.iter().sum();
            assert!(ink > 20.0, "digit {digit} ink {ink}");
        }
    }

    #[test]
    fn classes_are_distinguishable_without_noise() {
        // Noise-free class means must differ pairwise by a sane margin —
        // guards against two glyphs collapsing to the same shape.
        let cfg = SynthConfig {
            pixel_noise: 0.0,
            clutter: 0.0,
            max_shift: 0.0,
            max_rot: 0.0,
            max_scale: 0.0,
            max_shear: 0.0,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let mut protos = Vec::new();
        for digit in 0..10 {
            let mut px = vec![0.0f32; 784];
            render(digit, &cfg, &mut rng, &mut px);
            protos.push(px);
        }
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d2: f32 = protos[i]
                    .iter()
                    .zip(&protos[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(d2 > 5.0, "digits {i} and {j} too similar: {d2}");
            }
        }
    }

    #[test]
    fn counts_respected_and_shuffled() {
        let mut counts = [0usize; 10];
        counts[2] = 30;
        counts[7] = 10;
        let ds = generate_with_counts(&counts, &SynthConfig::default(), &mut Rng::new(6));
        assert_eq!(ds.len(), 40);
        let h = ds.class_histogram();
        assert_eq!(h[2], 30);
        assert_eq!(h[7], 10);
        // Shuffled: the first 30 are not all class 2.
        assert!(ds.labels[..30].iter().any(|&l| l != 2));
    }

    #[test]
    fn subset_gathers() {
        let ds = generate(10, &SynthConfig::default(), &mut Rng::new(7));
        let sub = ds.subset(&[3, 5]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.labels[0], ds.labels[3]);
        assert_eq!(sub.image(1), ds.image(5));
    }
}
