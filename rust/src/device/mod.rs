//! Device models: the paper's physical testbed (five Raspberry Pi 4Bs and
//! two laptops, §IV-A) as compute-latency profiles.
//!
//! What VAFL actually depends on is *heterogeneous round latency* —
//! stragglers produce stale models and differentiated gradient-change
//! norms. The profile maps the analytic FLOPs of a training step (from
//! `params_spec.json`) to virtual seconds through a sustained-GFLOPS
//! estimate, a memory-pressure factor (the 4 GB Pi swaps under PySyft +
//! ResNet, per the paper's setup), and multiplicative log-normal jitter.
//! The *numerics* always run for real through PJRT; only the clock is
//! synthetic.

use crate::util::rng::Rng;

/// A device compute profile.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceProfile {
    pub name: String,
    /// Sustained f32 GFLOP/s for this workload class.
    pub gflops: f64,
    /// Multiplier > 1 when the workload doesn't fit comfortably in RAM.
    pub mem_pressure: f64,
    /// Sigma of multiplicative log-normal latency jitter.
    pub jitter_sigma: f64,
}

impl DeviceProfile {
    /// Raspberry Pi 4B, 8 GB (Cortex-A72 @ 1.5 GHz, 4 cores; NEON fp32
    /// sustained ~13.5 GFLOPS for small convs under PyTorch).
    pub fn rpi4_8gb() -> Self {
        DeviceProfile {
            name: "rpi4-8gb".into(),
            gflops: 13.5,
            mem_pressure: 1.0,
            jitter_sigma: 0.10,
        }
    }

    /// Raspberry Pi 4B, 4 GB — same SoC, but the paper's software stack
    /// pressures 4 GB, adding stalls.
    pub fn rpi4_4gb() -> Self {
        DeviceProfile {
            name: "rpi4-4gb".into(),
            gflops: 13.5,
            mem_pressure: 1.35,
            jitter_sigma: 0.18,
        }
    }

    /// Client laptop (i5-9300H, 4 cores @ 2.4 GHz).
    pub fn laptop_i5() -> Self {
        DeviceProfile {
            name: "laptop-i5".into(),
            gflops: 140.0,
            mem_pressure: 1.0,
            jitter_sigma: 0.06,
        }
    }

    /// Server laptop (i7-9750H, 6 cores @ 2.59 GHz) — used when a laptop
    /// process doubles as a client (paper experiment b runs 2 processes on
    /// the i5 laptop; profile `laptop_shared` halves throughput instead).
    pub fn laptop_i7() -> Self {
        DeviceProfile {
            name: "laptop-i7".into(),
            gflops: 190.0,
            mem_pressure: 1.0,
            jitter_sigma: 0.06,
        }
    }

    /// One of two client processes sharing the i5 laptop (experiment b).
    pub fn laptop_shared() -> Self {
        DeviceProfile {
            name: "laptop-i5-shared".into(),
            gflops: 70.0,
            mem_pressure: 1.0,
            jitter_sigma: 0.12,
        }
    }

    /// Look up a profile by name (config files name devices).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "rpi4-8gb" => Some(Self::rpi4_8gb()),
            "rpi4-4gb" => Some(Self::rpi4_4gb()),
            "laptop-i5" => Some(Self::laptop_i5()),
            "laptop-i7" => Some(Self::laptop_i7()),
            "laptop-i5-shared" => Some(Self::laptop_shared()),
            _ => None,
        }
    }

    /// Virtual seconds to execute `flops` of model compute on this device.
    pub fn compute_seconds(&self, flops: u64, rng: &mut Rng) -> f64 {
        let base = flops as f64 / (self.gflops * 1e9);
        base * self.mem_pressure * rng.lognormal_jitter(self.jitter_sigma)
    }

    /// The canonical profile table, in a fixed order. Compact fleet
    /// records ([`crate::fleet::ParkedClient`]) store a 1-byte index into
    /// this table instead of a heap-named profile, so a million parked
    /// clients cost a million bytes of device state, not a million
    /// `String`s.
    pub fn table() -> [DeviceProfile; 5] {
        [
            Self::rpi4_4gb(),
            Self::rpi4_8gb(),
            Self::laptop_i5(),
            Self::laptop_i7(),
            Self::laptop_shared(),
        ]
    }

    /// Index into [`DeviceProfile::table`] of client `i`'s device in the
    /// `paper_fleet(num_clients)` mix — the allocation-free form of
    /// [`DeviceProfile::paper_fleet`], used by the virtualized fleet to
    /// assign devices to parked records without materializing profiles.
    pub fn paper_fleet_index(num_clients: usize, i: usize) -> u8 {
        match num_clients {
            3 => [0u8, 1, 1][i],
            7 => [0u8, 1, 1, 1, 1, 4, 4][i],
            _ => [0u8, 1, 1, 4][i % 4],
        }
    }

    /// The paper's client fleets.
    ///
    /// * 3 clients (exps a, c): 3 Raspberry Pis, one with 4 GB.
    /// * 7 clients (exps b, d): 5 Pis (one 4 GB) + 2 processes on the i5
    ///   laptop.
    ///
    /// Defined through [`DeviceProfile::paper_fleet_index`] so the eager
    /// and compact-record device assignments cannot drift.
    pub fn paper_fleet(num_clients: usize) -> Vec<DeviceProfile> {
        let table = Self::table();
        (0..num_clients)
            .map(|i| table[Self::paper_fleet_index(num_clients, i) as usize].clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_time_scales_with_flops_and_speed() {
        let mut rng = Rng::new(1);
        let pi = DeviceProfile {
            jitter_sigma: 0.0,
            ..DeviceProfile::rpi4_8gb()
        };
        let laptop = DeviceProfile {
            jitter_sigma: 0.0,
            ..DeviceProfile::laptop_i5()
        };
        let t_pi = pi.compute_seconds(1_000_000_000, &mut rng);
        let t_lt = laptop.compute_seconds(1_000_000_000, &mut rng);
        assert!(t_pi > 9.0 * t_lt, "pi {t_pi} laptop {t_lt}");
        let t2 = pi.compute_seconds(2_000_000_000, &mut rng);
        assert!((t2 / t_pi - 2.0).abs() < 1e-9);
    }

    #[test]
    fn mem_pressure_slows_the_4gb_pi() {
        let mut rng = Rng::new(2);
        let fast = DeviceProfile { jitter_sigma: 0.0, ..DeviceProfile::rpi4_8gb() };
        let slow = DeviceProfile { jitter_sigma: 0.0, ..DeviceProfile::rpi4_4gb() };
        assert!(
            slow.compute_seconds(1_000_000, &mut rng)
                > fast.compute_seconds(1_000_000, &mut rng)
        );
    }

    #[test]
    fn jitter_varies_but_is_deterministic_per_stream() {
        let p = DeviceProfile::rpi4_8gb();
        let mut a = Rng::new(3);
        let mut b = Rng::new(3);
        let xs: Vec<f64> = (0..5).map(|_| p.compute_seconds(1_000_000, &mut a)).collect();
        let ys: Vec<f64> = (0..5).map(|_| p.compute_seconds(1_000_000, &mut b)).collect();
        assert_eq!(xs, ys);
        assert!(xs.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn paper_fleets() {
        assert_eq!(DeviceProfile::paper_fleet(3).len(), 3);
        let f7 = DeviceProfile::paper_fleet(7);
        assert_eq!(f7.len(), 7);
        assert_eq!(f7.iter().filter(|d| d.name.starts_with("rpi4")).count(), 5);
        assert_eq!(DeviceProfile::paper_fleet(11).len(), 11);
        // The paper fleets: 3 = {4gb, 8gb, 8gb}, 7 = 5 Pis + 2 shared-i5.
        assert_eq!(DeviceProfile::paper_fleet(3)[0], DeviceProfile::rpi4_4gb());
        assert_eq!(f7[5], DeviceProfile::laptop_shared());
    }

    #[test]
    fn paper_fleet_index_matches_table_lookup() {
        let table = DeviceProfile::table();
        for n in [1usize, 3, 7, 11, 23] {
            let fleet = DeviceProfile::paper_fleet(n);
            for (i, d) in fleet.iter().enumerate() {
                let idx = DeviceProfile::paper_fleet_index(n, i) as usize;
                assert_eq!(&table[idx], d, "fleet {n} client {i}");
            }
        }
    }

    #[test]
    fn by_name_round_trips() {
        for n in ["rpi4-8gb", "rpi4-4gb", "laptop-i5", "laptop-i7", "laptop-i5-shared"] {
            assert_eq!(DeviceProfile::by_name(n).unwrap().name, n);
        }
        assert!(DeviceProfile::by_name("gpu-cluster").is_none());
    }
}
