//! Figure emitters: the paper's Figs. 3–6 as ASCII charts + CSV/JSON, from
//! the same metric streams the experiments produce.

use crate::data::stats::DistributionTable;
use crate::metrics::RunMetrics;

/// Render an ASCII line chart of (x, y) series (y in [0, 1]).
///
/// Good enough to eyeball convergence order in a terminal; the CSVs carry
/// the exact numbers for real plotting.
pub fn ascii_chart(title: &str, series: &[(&str, Vec<(usize, f64)>)], height: usize) -> String {
    let height = height.max(4);
    let mut max_x = 1usize;
    for (_, pts) in series {
        for &(x, _) in pts {
            max_x = max_x.max(x);
        }
    }
    let width = 72usize;
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['*', '+', 'o', 'x', '#', '@'];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in pts {
            if !y.is_finite() {
                continue;
            }
            let col = ((x as f64 / max_x as f64) * (width - 1) as f64).round() as usize;
            let row = ((1.0 - y.clamp(0.0, 1.0)) * (height - 1) as f64).round() as usize;
            grid[row][col.min(width - 1)] = mark;
        }
    }
    let mut out = format!("{title}\n");
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            "1.0 |"
        } else if i == height - 1 {
            "0.0 |"
        } else {
            "    |"
        };
        out += label;
        out.extend(row.iter());
        out.push('\n');
    }
    out += &format!("    +{}\n     rounds 1..{max_x}   ", "-".repeat(width));
    for (si, (name, _)) in series.iter().enumerate() {
        out += &format!("[{}] {}  ", marks[si % marks.len()], name);
    }
    out.push('\n');
    out
}

/// Fig. 3: dataset distribution tables for a set of experiments.
pub fn fig3(tables: &[(String, DistributionTable)]) -> String {
    let mut out = String::from("Fig. 3 — Dataset distribution of clients\n\n");
    for (name, t) in tables {
        out += &t.to_text(&format!("experiment {name}"));
        out.push('\n');
    }
    out
}

/// Fig. 4: global accuracy per algorithm within one experiment.
pub fn fig4(experiment: &str, runs: &[RunMetrics]) -> String {
    let series: Vec<(&str, Vec<(usize, f64)>)> = runs
        .iter()
        .map(|m| (m.algorithm.as_str(), m.acc_curve()))
        .collect();
    ascii_chart(
        &format!("Fig. 4({experiment}) — Acc of each algorithm, experiment {experiment}"),
        &series,
        16,
    )
}

/// Fig. 5: per-client accuracy under VAFL for one experiment.
pub fn fig5(experiment: &str, vafl_run: &RunMetrics) -> String {
    let curves = vafl_run.client_acc_curves();
    let names: Vec<String> =
        (0..curves.len()).map(|c| format!("client{}", c + 1)).collect();
    let series: Vec<(&str, Vec<(usize, f64)>)> = names
        .iter()
        .map(|n| n.as_str())
        .zip(curves.into_iter())
        .collect();
    ascii_chart(
        &format!("Fig. 5({experiment}) — Acc of each client under VAFL, experiment {experiment}"),
        &series,
        16,
    )
}

/// Fig. 6: VAFL global accuracy across experiments.
pub fn fig6(vafl_runs: &[RunMetrics]) -> String {
    let series: Vec<(&str, Vec<(usize, f64)>)> = vafl_runs
        .iter()
        .map(|m| (m.experiment.as_str(), m.acc_curve()))
        .collect();
    ascii_chart("Fig. 6 — VAFL Acc across experiments", &series, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RoundRecord;

    fn run_with_curve(exp: &str, algo: &str, accs: &[f64]) -> RunMetrics {
        let mut m = RunMetrics::new(exp, algo, 0.94);
        for (i, &a) in accs.iter().enumerate() {
            m.push(RoundRecord {
                round: i + 1,
                vtime: i as f64,
                global_acc: a,
                global_loss: 1.0,
                train_loss: 1.0,
                uploads: 1,
                cum_uploads: i + 1,
                bytes_up: 0,
                bytes_down: 0,
                bytes_up_ctrl: 0,
                bytes_down_ctrl: 0,
                threshold: 0.0,
                values: vec![],
                selected: vec![true],
                client_accs: vec![a, a / 2.0],
                idle_seconds: 0.0,
                reports: 1,
                in_flight: 0,
                upload_staleness: vec![0],
                shard: 0,
                spec_committed: 0,
                spec_replayed: 0,
                quarantined: 0,
                trust_mean: f64::NAN,
                faults: Default::default(),
            });
        }
        m
    }

    #[test]
    fn chart_contains_marks_and_legend() {
        let m = run_with_curve("a", "vafl", &[0.2, 0.5, 0.9]);
        let s = fig4("a", &[m]);
        assert!(s.contains("[*] vafl"));
        assert!(s.contains('*'));
        assert!(s.lines().count() > 10);
    }

    #[test]
    fn fig5_one_series_per_client() {
        let m = run_with_curve("b", "vafl", &[0.3, 0.6]);
        let s = fig5("b", &m);
        assert!(s.contains("client1"));
        assert!(s.contains("client2"));
    }

    #[test]
    fn fig6_one_series_per_experiment() {
        let runs = vec![
            run_with_curve("a", "vafl", &[0.5]),
            run_with_curve("b", "vafl", &[0.6]),
        ];
        let s = fig6(&runs);
        assert!(s.contains("[*] a"));
        assert!(s.contains("[+] b"));
    }

    #[test]
    fn chart_handles_nan_and_clamps() {
        let m = run_with_curve("a", "afl", &[f64::NAN, 1.5, -0.2]);
        let s = fig4("a", &[m]);
        assert!(s.contains("Fig. 4"));
    }
}
