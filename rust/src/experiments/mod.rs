//! Experiment presets (the paper's a–d), the end-to-end runner, and the
//! emitters that regenerate every table and figure of §V.

pub mod figures;
pub mod straggler;
pub mod table3;

use anyhow::{bail, Context, Result};

use crate::config::{Algorithm, Backend, EngineMode, ExperimentConfig};
use crate::coordinator::policy::make_policy;
use crate::coordinator::server::{build_server, Server};
use crate::data::synth::SynthConfig;
use crate::data::{partition, PartitionScheme};
use crate::metrics::RunMetrics;
use crate::model::ParamSpec;
use crate::runtime::{Executor, ExecutorPool, ExecutorService, MockExecutor, PjrtRuntime};
use crate::util::rng::Rng;

/// The paper's four experiments (§V-B), scaled per EXPERIMENTS.md
/// §Scaling: shard sizes 20k/10k -> 2000/1000 and a full local epoch ->
/// r x `batches_per_pass` batches, keeping the paper's r=5, E=1, B=32,
/// eta=0.1, R=200.
pub fn preset(which: char) -> Result<ExperimentConfig> {
    let mut cfg = ExperimentConfig::default();
    match which {
        'a' => {
            cfg.name = "a".into();
            cfg.num_clients = 3;
            cfg.partition = PartitionScheme::Iid;
            cfg.samples_per_client = 2000;
        }
        'b' => {
            cfg.name = "b".into();
            cfg.num_clients = 7;
            cfg.partition = PartitionScheme::Iid;
            cfg.samples_per_client = 1000;
        }
        'c' => {
            cfg.name = "c".into();
            cfg.num_clients = 3;
            cfg.partition = PartitionScheme::PaperSkew;
            cfg.samples_per_client = 2000;
        }
        'd' => {
            cfg.name = "d".into();
            cfg.num_clients = 7;
            cfg.partition = PartitionScheme::PaperSkew;
            cfg.samples_per_client = 1000;
        }
        other => bail!("unknown experiment preset {other:?} (a|b|c|d)"),
    }
    Ok(cfg)
}

/// Outcome of one experiment run.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub metrics: RunMetrics,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub comm_times_to_target: Option<usize>,
    pub total_uploads: usize,
    pub total_vtime: f64,
}

impl Outcome {
    fn from_metrics(metrics: RunMetrics) -> Self {
        Outcome {
            final_accuracy: metrics.final_accuracy(),
            best_accuracy: metrics.best_accuracy(),
            comm_times_to_target: metrics.comm_times_to_target(),
            total_uploads: metrics.total_uploads(),
            total_vtime: metrics.total_vtime(),
            metrics,
        }
    }
}

/// Materialize the server (data, fleet, policy) for a config, returning the
/// executor alongside. The caller drives rounds (the CLI, examples and
/// benches all go through this).
pub fn build(cfg: &ExperimentConfig) -> Result<(Server, Box<dyn Executor>)> {
    cfg.validate()?;
    // Unconditional: threads = 0 *clears* the process-wide override back
    // to auto, so a later experiment never inherits a stale cap from an
    // earlier config in the same process.
    crate::util::par::set_max_threads(cfg.threads);
    let synth_cfg = SynthConfig { pixel_noise: cfg.pixel_noise, ..Default::default() };
    let root_rng = Rng::new(cfg.seed);
    let (shards, test) = partition(
        cfg.partition,
        cfg.num_clients,
        cfg.samples_per_client,
        cfg.test_samples,
        &synth_cfg,
        &root_rng,
    );
    let policy = make_policy(cfg.algorithm, cfg.value_fn, cfg.eaflm);

    let (exec, init_params, flops, payload, layer_sizes): (
        Box<dyn Executor>,
        Vec<f32>,
        (u64, u64),
        u64,
        Vec<usize>,
    ) = match &cfg.backend {
        Backend::Pjrt { artifact_dir } => {
            let spec = ParamSpec::load(artifact_dir)
                .context("loading artifacts (run `make artifacts`)")?;
            anyhow::ensure!(
                spec.input_dim == test.input_dim(),
                "artifact input_dim {} != dataset {}",
                spec.input_dim,
                test.input_dim()
            );
            let init = spec.load_init_params()?;
            let flops = (spec.train_step_flops, spec.eval_step_flops);
            let payload = cfg.upload_precision.payload_bytes(spec.param_count);
            let layer_sizes: Vec<usize> = spec.layers.iter().map(|l| l.size).collect();
            let rt = PjrtRuntime::from_spec(spec)?;
            (Box::new(rt), init, flops, payload, layer_sizes)
        }
        Backend::Mock => {
            let exec = MockExecutor::standard();
            let p = exec.param_count();
            // Mock "model" cost stands in for the real one. The mock net
            // is a single dense layer as far as the wire is concerned.
            let flops = (2_000_000u64, 600_000u64);
            let payload = cfg.upload_precision.payload_bytes(p);
            (Box::new(exec), vec![0.0; p], flops, payload, vec![p])
        }
    };

    let batch = exec.batch_size();
    let mut server = build_server(cfg, shards, test, init_params, policy, batch, flops, payload);
    server.set_layer_sizes(layer_sizes);
    Ok((server, exec))
}

/// Spawn the executor pool of the threaded barrier-free engine: `workers`
/// executors, each constructed on its own worker thread from the config's
/// backend (PJRT clients must be created where they are used).
pub fn make_executor_pool(cfg: &ExperimentConfig, workers: usize) -> Result<ExecutorPool> {
    match &cfg.backend {
        Backend::Mock => ExecutorPool::spawn(workers, || {
            Ok(Box::new(MockExecutor::standard()) as Box<dyn Executor>)
        }),
        Backend::Pjrt { artifact_dir } => {
            let dir = artifact_dir.clone();
            ExecutorPool::spawn(workers, move || {
                let spec =
                    ParamSpec::load(&dir).context("loading artifacts for a pool worker")?;
                Ok(Box::new(PjrtRuntime::from_spec(spec)?) as Box<dyn Executor>)
            })
        }
    }
}

/// Resolve `engine.workers`: explicit count, else the `util::par` chain
/// (config `threads` key, `VAFL_THREADS`, available parallelism).
pub fn engine_workers(cfg: &ExperimentConfig) -> usize {
    if cfg.engine_opts.workers > 0 {
        cfg.engine_opts.workers
    } else {
        crate::util::par::max_threads()
    }
}

/// Build and run the **barrier-free** engine (threaded per
/// `cfg.engine_opts`), timing only the engine itself: data generation,
/// server build, and pool construction/shutdown are excluded. Returns
/// the run metrics and the wall seconds. The engine bench and
/// `straggler::compare_execution` both go through here so the timing
/// convention stays uniform.
pub fn run_barrier_free_timed(cfg: &ExperimentConfig) -> Result<(RunMetrics, f64)> {
    let (mut server, mut exec) = build(cfg)?;
    if cfg.engine_opts.threaded {
        let pool = make_executor_pool(cfg, engine_workers(cfg))?;
        let t0 = std::time::Instant::now();
        server.run_event_driven_threaded(exec.as_mut(), &pool)?;
        let wall = t0.elapsed().as_secs_f64();
        pool.shutdown();
        Ok((server.metrics.clone(), wall))
    } else {
        let t0 = std::time::Instant::now();
        server.run_event_driven(exec.as_mut())?;
        Ok((server.metrics.clone(), t0.elapsed().as_secs_f64()))
    }
}

/// Run a full experiment to completion on the configured engine
/// (barriered round loop, or the barrier-free event-driven engine when
/// `cfg.engine = barrier_free`), threaded when `engine.threaded` is set.
pub fn run(cfg: &ExperimentConfig) -> Result<Outcome> {
    crate::util::logging::init();
    let (mut server, mut exec) = build(cfg)?;
    match (cfg.engine, cfg.engine_opts.threaded) {
        (EngineMode::Barriered, false) => server.run(exec.as_mut())?,
        (EngineMode::Barriered, true) => {
            // One shared service thread (PJRT executors are not Send),
            // one OS thread per active client per round — bit-identical
            // to the sequential loop. This path computes exclusively
            // through the service; release the built executor first so
            // the PJRT backend never holds two runtimes at once.
            drop(exec);
            match &cfg.backend {
                Backend::Mock => {
                    let svc = ExecutorService::spawn(|| Ok(MockExecutor::standard()))?;
                    for _ in 0..cfg.rounds {
                        server.run_round_threaded(&svc)?;
                    }
                    svc.shutdown();
                }
                Backend::Pjrt { artifact_dir } => {
                    let dir = artifact_dir.clone();
                    let svc = ExecutorService::spawn(move || PjrtRuntime::load(&dir))?;
                    for _ in 0..cfg.rounds {
                        server.run_round_threaded(&svc)?;
                    }
                    svc.shutdown();
                }
            }
        }
        (EngineMode::BarrierFree, false) => server.run_event_driven(exec.as_mut())?,
        (EngineMode::BarrierFree, true) => {
            let pool = make_executor_pool(cfg, engine_workers(cfg))?;
            server.run_event_driven_threaded(exec.as_mut(), &pool)?;
            pool.shutdown();
        }
    }
    // The threaded barriered arm drives `run_round_threaded` from out
    // here and never reaches `Server::run`'s own finalize; idempotent
    // (and a no-op with `obs.enabled = false`) for the other arms.
    server.finalize_obs();
    Ok(Outcome::from_metrics(server.metrics.clone()))
}

/// Run one experiment for each algorithm (paper comparison grid), reusing
/// the same data/seed so curves are directly comparable.
pub fn run_all_algorithms(base: &ExperimentConfig) -> Result<Vec<Outcome>> {
    Algorithm::ALL
        .iter()
        .map(|&algorithm| {
            let cfg = ExperimentConfig { algorithm, ..base.clone() };
            run(&cfg)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(mut cfg: ExperimentConfig) -> ExperimentConfig {
        cfg.backend = Backend::Mock;
        cfg.rounds = 3;
        cfg.samples_per_client = 80;
        cfg.test_samples = 64;
        cfg.probe_samples = 32;
        cfg.local_passes = 1;
        cfg.batches_per_pass = 2;
        cfg
    }

    #[test]
    fn presets_match_paper_grid() {
        let a = preset('a').unwrap();
        assert_eq!((a.num_clients, a.partition), (3, PartitionScheme::Iid));
        let d = preset('d').unwrap();
        assert_eq!((d.num_clients, d.partition), (7, PartitionScheme::PaperSkew));
        assert_eq!(d.rounds, 200);
        assert_eq!(d.local_passes, 5);
        assert_eq!(d.lr, 0.1);
        assert!(preset('z').is_err());
    }

    #[test]
    fn run_produces_outcome() {
        let cfg = quick(preset('a').unwrap());
        let out = run(&cfg).unwrap();
        assert_eq!(out.metrics.records.len(), 3);
        assert!(out.total_uploads > 0);
        assert!(out.final_accuracy.is_finite());
    }

    #[test]
    fn run_all_algorithms_yields_three() {
        let cfg = quick(preset('c').unwrap());
        let outs = run_all_algorithms(&cfg).unwrap();
        assert_eq!(outs.len(), 3);
        assert_eq!(outs[0].metrics.algorithm, "afl");
        assert_eq!(outs[2].metrics.algorithm, "vafl");
        // AFL must have the most uploads (it never gates).
        assert!(outs[0].total_uploads >= outs[2].total_uploads);
    }
}
