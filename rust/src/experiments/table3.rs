//! Table III: "CCR and communication times of different experiments" —
//! communication times (model uploads) to reach the target accuracy and
//! the communication-compression rate vs the AFL baseline, for each
//! algorithm x experiment.

use crate::metrics::{ccr, ccr_bytes, RunMetrics};
use crate::util::json::{obj, Value};

/// One Table III row.
#[derive(Debug, Clone)]
pub struct Row {
    pub experiment: String,
    pub algorithm: String,
    /// Communication times to reach the target Acc (None = not reached;
    /// rendered as the total uploads with a `>` marker).
    pub comm_times: Option<usize>,
    pub total_uploads: usize,
    pub ccr: f64,
    /// Uplink bytes to reach the target Acc (total bytes when the target
    /// was never reached) — separates the gating axis (fewer
    /// communications) from the sparse-compression axis (cheaper ones).
    pub bytes_up: u64,
    /// Eq. 4 over `bytes_up` against the AFL baseline of the same
    /// experiment.
    pub ccr_bytes: f64,
    /// Round-trip *payload* bytes across the run: model uploads plus
    /// model broadcasts, with the fixed-size control frames (V reports,
    /// upload requests) excluded from both links — bidirectional
    /// compression is graded only on the bytes it can actually move.
    pub bytes_rt_payload: u64,
    /// Eq. 4 over `bytes_rt_payload` against the AFL baseline of the
    /// same experiment: the full round-trip compression rate.
    pub ccr_bytes_rt: f64,
    pub best_acc: f64,
}

/// Build Table III rows from one experiment's three runs. The CCR baseline
/// is AFL's communication count within the same experiment (Eq. 4); the
/// byte-level CCR baselines on AFL's uplink bytes the same way.
pub fn rows_for_experiment(runs: &[RunMetrics]) -> Vec<Row> {
    let afl = runs.iter().find(|r| r.algorithm == "afl");
    let baseline = afl
        .and_then(|r| r.comm_times_to_target())
        .unwrap_or_else(|| afl.map_or(0, |r| r.total_uploads()));
    let baseline_bytes = afl
        .and_then(|r| r.bytes_up_to_target())
        .unwrap_or_else(|| afl.map_or(0, |r| r.total_bytes_up()));
    let rt_payload =
        |r: &RunMetrics| r.total_bytes_up_payload() + r.total_bytes_down_payload();
    let baseline_rt = afl.map_or(0, rt_payload);
    runs.iter()
        .map(|m| {
            let mine = m.comm_times_to_target().unwrap_or(m.total_uploads());
            let mine_bytes = m.bytes_up_to_target().unwrap_or(m.total_bytes_up());
            let mine_rt = rt_payload(m);
            let is_afl = m.algorithm == "afl";
            Row {
                experiment: m.experiment.clone(),
                algorithm: m.algorithm.clone(),
                comm_times: m.comm_times_to_target(),
                total_uploads: m.total_uploads(),
                ccr: if is_afl { 0.0 } else { ccr(baseline, mine) },
                bytes_up: mine_bytes,
                ccr_bytes: if is_afl { 0.0 } else { ccr_bytes(baseline_bytes, mine_bytes) },
                bytes_rt_payload: mine_rt,
                ccr_bytes_rt: if is_afl { 0.0 } else { ccr_bytes(baseline_rt, mine_rt) },
                best_acc: m.best_accuracy(),
            }
        })
        .collect()
}

/// Render rows in the paper's Table III layout.
pub fn render(rows: &[Row]) -> String {
    let mut s = String::from(
        "experiment  algorithm  comm_times  CCR      bytes_up      CCR_bytes  CCR_rt     best_acc\n\
         ---------------------------------------------------------------------------------------\n",
    );
    for r in rows {
        let comm = match r.comm_times {
            Some(c) => format!("{c}"),
            None => format!(">{}", r.total_uploads),
        };
        s += &format!(
            "{:<11} {:<10} {:<11} {:<8.4} {:<13} {:<10.4} {:<10.4} {:.4}\n",
            r.experiment,
            r.algorithm,
            comm,
            r.ccr,
            r.bytes_up,
            r.ccr_bytes,
            r.ccr_bytes_rt,
            r.best_acc
        );
    }
    s
}

/// Summary across experiments: mean comm reduction vs AFL and mean CCR for
/// one algorithm (the paper's headline "51.02 % fewer communications,
/// 48.26 % average CCR").
pub fn headline(all_rows: &[Row], algorithm: &str) -> (f64, f64) {
    let mut reductions = Vec::new();
    let mut ccrs = Vec::new();
    // Group rows by experiment.
    let mut experiments: Vec<&str> = all_rows.iter().map(|r| r.experiment.as_str()).collect();
    experiments.sort_unstable();
    experiments.dedup();
    for exp in experiments {
        let afl = all_rows
            .iter()
            .find(|r| r.experiment == exp && r.algorithm == "afl");
        let alg = all_rows
            .iter()
            .find(|r| r.experiment == exp && r.algorithm == algorithm);
        if let (Some(afl), Some(alg)) = (afl, alg) {
            let c0 = afl.comm_times.unwrap_or(afl.total_uploads) as f64;
            let c1 = alg.comm_times.unwrap_or(alg.total_uploads) as f64;
            if c0 > 0.0 {
                reductions.push((c0 - c1) / c0);
            }
            ccrs.push(alg.ccr);
        }
    }
    let mean = |v: &[f64]| {
        if v.is_empty() {
            0.0
        } else {
            v.iter().sum::<f64>() / v.len() as f64
        }
    };
    (mean(&reductions), mean(&ccrs))
}

/// JSON export for the report pipeline.
pub fn to_json(rows: &[Row]) -> Value {
    Value::Arr(
        rows.iter()
            .map(|r| {
                obj(vec![
                    ("experiment", Value::from(r.experiment.as_str())),
                    ("algorithm", Value::from(r.algorithm.as_str())),
                    (
                        "comm_times",
                        r.comm_times.map(Value::from).unwrap_or(Value::Null),
                    ),
                    ("total_uploads", Value::from(r.total_uploads)),
                    ("ccr", Value::from(r.ccr)),
                    ("bytes_up", Value::from(r.bytes_up as usize)),
                    ("ccr_bytes", Value::from(r.ccr_bytes)),
                    ("bytes_rt_payload", Value::from(r.bytes_rt_payload as usize)),
                    ("ccr_bytes_rt", Value::from(r.ccr_bytes_rt)),
                    ("best_acc", Value::from(r.best_acc)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{RoundRecord, RunMetrics};

    fn fake_run(exp: &str, algo: &str, comms_at_target: usize) -> RunMetrics {
        let mut m = RunMetrics::new(exp, algo, 0.94);
        m.push(RoundRecord {
            round: 1,
            vtime: 1.0,
            global_acc: 0.95,
            global_loss: 0.2,
            train_loss: 0.2,
            uploads: comms_at_target,
            cum_uploads: comms_at_target,
            bytes_up: 0,
            bytes_down: 0,
            bytes_up_ctrl: 0,
            bytes_down_ctrl: 0,
            threshold: 0.0,
            values: vec![],
            selected: vec![],
            client_accs: vec![],
            idle_seconds: 0.0,
            reports: 0,
            in_flight: 0,
            upload_staleness: vec![],
            shard: 0,
            spec_committed: 0,
            spec_replayed: 0,
            quarantined: 0,
            trust_mean: f64::NAN,
            faults: Default::default(),
        });
        m
    }

    #[test]
    fn table_matches_paper_arithmetic() {
        // Paper experiment b: AFL 84, EAFLM 45 (0.4643), VAFL 43 (0.4881).
        let runs = vec![
            fake_run("b", "afl", 84),
            fake_run("b", "eaflm", 45),
            fake_run("b", "vafl", 43),
        ];
        let rows = rows_for_experiment(&runs);
        assert_eq!(rows[0].ccr, 0.0);
        assert!((rows[1].ccr - 0.4643).abs() < 1e-4);
        assert!((rows[2].ccr - 0.4881).abs() < 1e-4);
    }

    #[test]
    fn byte_ccr_baselines_on_afl_bytes() {
        // Same upload counts, but the "compressed" run ships half the
        // bytes per record: count-CCR 0, byte-CCR 0.5.
        let mut afl = fake_run("a", "afl", 10);
        afl.records[0].bytes_up = 4000;
        let mut topk = fake_run("a", "vafl", 10);
        topk.records[0].bytes_up = 2000;
        let rows = rows_for_experiment(&[afl, topk]);
        assert_eq!(rows[0].bytes_up, 4000);
        assert_eq!(rows[0].ccr_bytes, 0.0);
        assert_eq!(rows[1].bytes_up, 2000);
        assert!((rows[1].ccr_bytes - 0.5).abs() < 1e-12);
        assert_eq!(rows[1].ccr, 0.0, "count CCR must not see byte compression");
    }

    #[test]
    fn round_trip_ccr_is_payload_only_both_links() {
        // AFL ships 4000 up + 4000 down, 500 of each being control
        // frames. The compressed run halves only the payloads; control
        // frames are identical. Payload round trip: 7000 -> 3500.
        let mut afl = fake_run("a", "afl", 10);
        afl.records[0].bytes_up = 4000;
        afl.records[0].bytes_down = 4000;
        afl.records[0].bytes_up_ctrl = 500;
        afl.records[0].bytes_down_ctrl = 500;
        let mut bidir = fake_run("a", "vafl", 10);
        bidir.records[0].bytes_up = 2250; // 1750 payload + 500 ctrl
        bidir.records[0].bytes_down = 2250;
        bidir.records[0].bytes_up_ctrl = 500;
        bidir.records[0].bytes_down_ctrl = 500;
        let rows = rows_for_experiment(&[afl, bidir]);
        assert_eq!(rows[0].bytes_rt_payload, 7000);
        assert_eq!(rows[0].ccr_bytes_rt, 0.0);
        assert_eq!(rows[1].bytes_rt_payload, 3500);
        assert!((rows[1].ccr_bytes_rt - 0.5).abs() < 1e-12, "ctrl frames must not dilute CCR");
        let text = render(&rows);
        assert!(text.contains("CCR_rt"), "{text}");
    }

    #[test]
    fn headline_averages_over_experiments() {
        // Two experiments with VAFL halving comms -> 50 % reduction, CCR 0.5.
        let mut rows = rows_for_experiment(&[fake_run("a", "afl", 40), fake_run("a", "vafl", 20)]);
        rows.extend(rows_for_experiment(&[
            fake_run("b", "afl", 80),
            fake_run("b", "vafl", 40),
        ]));
        let (red, mccr) = headline(&rows, "vafl");
        assert!((red - 0.5).abs() < 1e-12);
        assert!((mccr - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unreached_target_renders_total() {
        let mut m = RunMetrics::new("a", "vafl", 0.99);
        m.push(RoundRecord {
            round: 1,
            vtime: 1.0,
            global_acc: 0.5,
            global_loss: 1.0,
            train_loss: 1.0,
            uploads: 3,
            cum_uploads: 3,
            bytes_up: 0,
            bytes_down: 0,
            bytes_up_ctrl: 0,
            bytes_down_ctrl: 0,
            threshold: 0.0,
            values: vec![],
            selected: vec![],
            client_accs: vec![],
            idle_seconds: 0.0,
            reports: 0,
            in_flight: 0,
            upload_staleness: vec![],
            shard: 0,
            spec_committed: 0,
            spec_replayed: 0,
            quarantined: 0,
            trust_mean: f64::NAN,
            faults: Default::default(),
        });
        let rows = rows_for_experiment(&[fake_run("a", "afl", 10), m]);
        let text = render(&rows);
        assert!(text.contains(">3"), "{text}");
    }
}
