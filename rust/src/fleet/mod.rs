//! The simulated edge fleet: one [`Client`] per paper device, owning its
//! local shard, local model, batcher, device profile, and the client half
//! of Algorithm 1 (lines 18–26): local SGD passes, the communication value
//! V (Eq. 1), and the probe-set accuracy Acc_i.
//!
//! # Virtualized fleet: active set + parked records
//!
//! A dense [`Client`] carries three O(dim) buffers (params, delta base,
//! EF residual) plus its materialized data shard — fine for the paper's
//! 3/7-client testbeds, fatal for the ROADMAP's "millions of users". The
//! [`Fleet`] therefore keeps only the clients with work in flight (the
//! **active set**) materialized; everyone else is a compact
//! [`ParkedClient`] record — batcher replay position, jitter-RNG state,
//! versions-behind, sample count, a 1-byte device-profile index, and a
//! sparse top-|budget| summary of the error-feedback residual. Resident
//! memory scales with the concurrency window, not the fleet size.
//!
//! ## Hydration semantics
//!
//! Parking and hydration are **deterministic and lossless for every
//! random stream**:
//!
//! * **Batcher.** The shuffle RNG is a named fork (`batcher-{id}`) of the
//!   experiment seed; the parked record stores `(reshuffles, cursor)` and
//!   [`Batcher::restore`] replays exactly that many shuffles from a fresh
//!   fork — the hydrated client's future batch stream is bit-identical to
//!   a never-parked client's (proptested over park/hydrate cycles).
//! * **Jitter RNG.** Parked verbatim (the state is four words; the
//!   log-normal jitter draws a variable number of uniforms, so replaying
//!   a draw *count* is infeasible). The stream continues exactly where it
//!   stopped.
//! * **Data shard.** Never stored: `FleetData::Lazy` re-renders it on
//!   hydration from the same named generator fork (`client-{id}`) the
//!   eager partitioner uses — bit-identical pixels, whenever and however
//!   often the client is hydrated.
//! * **Model state.** A client is parked only when it holds no novel
//!   model state: the engines park at flush time, immediately after the
//!   client's upload was folded into the aggregate (the point where the
//!   legacy path would overwrite the local model with the broadcast
//!   anyway). Hydration takes the then-current model as its sync, so
//!   `params == base == model` and staleness restarts at 0, exactly like
//!   [`Client::sync`].
//! * **EF residual.** Summarized as the top-|`residual_budget`| owed
//!   coordinates (magnitude order, index tie-break); debt below the
//!   budget is forgotten at park time. With the budget ≥ the count of
//!   nonzero coordinates the residual round-trips exactly.
//! * **`prev_grad`.** Deliberately dropped: a parked client's previous
//!   gradient was measured against a long-gone model, so a re-hydrated
//!   client reports a fresh-gradient value on its first round — the same
//!   high initial V as a newly joined client (paper §III-A), which is
//!   what re-entering the fleet *is*.
//!
//! With `fleet.active_set = 0` (the default) every client is hydrated at
//! construction and nothing ever parks: that mode is bitwise identical to
//! the pre-virtualization engines and is pinned by the golden snapshots.

use std::sync::Arc;

use anyhow::Result;

use crate::config::ValueFnConfig;
use crate::data::{Batcher, ClientShard, LazyPartition};
use crate::device::DeviceProfile;
use crate::model::quant::{Precision, QuantBuf};
use crate::model::sparse::SparseDelta;
use crate::model::{sq_distance, ParamVec};
use crate::runtime::{evaluate_with_params, Executor};
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;

/// Per-client malicious behavior of the attack simulator (ISSUE 8 /
/// `[attack]` config). Profiles are applied **at gradient-encode time**
/// (or, for [`AttackProfile::LabelFlip`], at shard hydration), so the
/// poisoned update flows through sparsification, error-feedback residuals,
/// and speculation exactly like an honest one — the robust aggregator
/// must catch it on the wire, not via a side channel. The table lives on
/// the [`Fleet`], so a profile survives park/hydrate cycles.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum AttackProfile {
    /// Honest client.
    #[default]
    Benign,
    /// Data poisoning: every shard label `l` becomes `9 − l` (the synth
    /// datasets are 10-class), applied when the shard materializes.
    LabelFlip,
    /// Model poisoning: the upload is the local update reflected around
    /// the sync base (`2·base − params`), i.e. an exact sign flip of the
    /// update direction.
    SignFlip,
    /// Model poisoning: the update is amplified by `gain`
    /// (`base + gain·(params − base)`).
    Scale { gain: f32 },
    /// Backdoor: `coords` evenly strided coordinates of the upload are
    /// overwritten with the fixed trigger value `boost`.
    Backdoor { coords: usize, boost: f32 },
}

/// What a client sends to the server at the end of a local round
/// (Algorithm 1 line 6: "upload the V_i to server").
#[derive(Debug, Clone)]
pub struct ClientReport {
    pub client_id: usize,
    pub round: usize,
    /// Communication value V_i (Eq. 1).
    pub value: f64,
    /// Probe-set accuracy of the local model (Acc_i in Eq. 1).
    pub acc: f64,
    /// ||grad||^2 of the final local gradient (EAFLM's left-hand side).
    pub grad_norm_sq: f64,
    /// Mean training loss over this round's batches.
    pub train_loss: f64,
    /// Local sample count n_i (FedAvg weight).
    pub num_samples: usize,
    /// Virtual seconds of local compute this round.
    pub compute_seconds: f64,
}

/// A simulated edge client.
///
/// The heavy read-only state (data shard, probe set) is `Arc`-shared, so a
/// `Clone` copies only the mutable training state (model, batcher order,
/// RNG streams). That makes [`Client::speculate`] cheap enough to fork on
/// every dispatched local round of the threaded barrier-free engine.
#[derive(Clone)]
pub struct Client {
    pub id: usize,
    pub device: DeviceProfile,
    shard: Arc<ClientShard>,
    batcher: Batcher,
    /// Local model theta_i (diverges from global when uploads are skipped).
    pub params: ParamVec,
    /// The global model this client last synced to — the delta base of
    /// the sparse top-k upload path (`local − base` drives coordinate
    /// selection; see `model::sparse`).
    base: ParamVec,
    /// Error-feedback residual of the sparse upload path: delta mass that
    /// lost the top-k race at encode time, folded into the next
    /// selection. A coordinate's debt clears only when it is transmitted
    /// — the residual deliberately **survives model downloads**: in these
    /// engines every upload is immediately followed by a broadcast sync,
    /// so a reset-on-download residual could never carry to the next
    /// encode and error feedback would be inert. Zero (and inert) in
    /// dense mode. Like `staleness`, it never feeds `local_round`, so it
    /// is excluded from the speculation `epoch`.
    residual: Vec<f32>,
    /// Gradient of the previous round (nabla^{k-1}); None before round 1.
    prev_grad: Option<Vec<f32>>,
    /// Rounds since this client last synced with the global model.
    pub staleness: usize,
    /// RNG stream for device jitter.
    jitter_rng: Rng,
    /// Probe set (slice of the server test set) for Acc_i.
    probe_images: Arc<Vec<f32>>,
    probe_labels: Arc<Vec<i32>>,
    /// Monotonic training-state version: bumped whenever the inputs of a
    /// future `local_round` change (`local_round` itself, [`Client::sync`],
    /// [`Client::commit_speculation`]). A speculative fork is valid only
    /// while the origin's epoch still matches the fork's (compare
    /// [`Client::epoch`]); `staleness` bookkeeping is deliberately
    /// excluded — it never feeds the local round.
    epoch: u64,
    /// Malicious behavior applied when this client's upload is encoded
    /// (`Benign` trains and encodes exactly the pre-attack code paths).
    attack: AttackProfile,
    /// Scratch for the attacked parameter view at encode time — reused
    /// across rounds; empty (and never touched) for benign clients and
    /// on speculative forks, which never encode.
    attack_buf: Vec<f32>,
}

impl Client {
    /// Build a fully hydrated client. The probe set and shard are
    /// `Arc`-shared across the fleet — construction copies no read-only
    /// data (at a million clients, per-client probe clones were the
    /// second-largest memory term after the shards themselves).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        shard: Arc<ClientShard>,
        device: DeviceProfile,
        init_params: ParamVec,
        batch_size: usize,
        probe_images: Arc<Vec<f32>>,
        probe_labels: Arc<Vec<i32>>,
        root_rng: &Rng,
    ) -> Self {
        let n = shard.num_samples();
        Client {
            batcher: Batcher::new(n, batch_size, root_rng.fork(&format!("batcher-{id}"))),
            jitter_rng: root_rng.fork(&format!("jitter-{id}")),
            id,
            device,
            shard,
            base: init_params.clone(),
            residual: vec![0.0; init_params.len()],
            params: init_params,
            prev_grad: None,
            staleness: 0,
            probe_images,
            probe_labels,
            epoch: 0,
            attack: AttackProfile::Benign,
            attack_buf: Vec::new(),
        }
    }

    pub fn num_samples(&self) -> usize {
        self.shard.num_samples()
    }

    /// Receive the aggregated global model (end of Algorithm 1 round).
    /// Resets the sparse-upload delta base to the downloaded model; the
    /// error-feedback residual persists (see the `residual` field docs —
    /// the download wipes the local params, including never-transmitted
    /// progress, and the residual is exactly the memory of that loss).
    pub fn sync(&mut self, global: &[f32]) {
        self.params.clear();
        self.params.extend_from_slice(global);
        self.base.clear();
        self.base.extend_from_slice(global);
        self.staleness = 0;
        self.epoch += 1;
    }

    /// Receive a sparse downlink frame (bidirectional compression): the
    /// new global is the last-acked `base` with the frame's transmitted
    /// coordinates overwritten by their decoded absolute values. The
    /// reconstruction becomes both the working params and the next
    /// upload/download base — exactly what [`Client::sync`] does with a
    /// dense frame, and bitwise the same computation the server replays
    /// against its `coordinator::downlink` slot. The caller must
    /// guarantee this client acked the base the delta was encoded
    /// against (the engine force-feeds a dense frame otherwise). The
    /// upload error-feedback residual persists, as in a dense sync.
    pub fn sync_sparse(&mut self, delta: &SparseDelta) {
        self.params.clear();
        self.params.extend_from_slice(&self.base);
        delta.scatter_into(&mut self.params);
        self.base.clear();
        self.base.extend_from_slice(&self.params);
        self.staleness = 0;
        self.epoch += 1;
    }

    /// The sparse-delta base model this client last acked
    /// (tests/diagnostics — the downlink compressor's per-client slot
    /// must stay bitwise equal to this).
    pub fn sync_base(&self) -> &[f32] {
        &self.base
    }

    /// Current training-state version (see the `epoch` field docs).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Fork a speculative copy for an off-thread local round. The fork
    /// shares the immutable shard/probe data and snapshots only the state
    /// a local round actually reads: the sparse-upload `base`/`residual`
    /// pair stays behind (empty on the ghost) — ghosts never encode an
    /// upload, and copying two model-sized vectors per dispatch would
    /// double the fork cost for state that is dead weight. Pair with
    /// [`Client::commit_speculation`] once the engine reaches the round's
    /// commit point in virtual-event order.
    pub fn speculate(&self) -> Client {
        Client {
            id: self.id,
            device: self.device.clone(),
            shard: Arc::clone(&self.shard),
            batcher: self.batcher.clone(),
            params: self.params.clone(),
            base: Vec::new(),
            residual: Vec::new(),
            prev_grad: self.prev_grad.clone(),
            staleness: self.staleness,
            jitter_rng: self.jitter_rng.clone(),
            probe_images: Arc::clone(&self.probe_images),
            probe_labels: Arc::clone(&self.probe_labels),
            epoch: self.epoch,
            attack: self.attack,
            attack_buf: Vec::new(),
        }
    }

    /// Absorb the training state a speculative fork produced. Only valid
    /// while `self.epoch() == fork_epoch` recorded at [`Client::speculate`]
    /// time (the engine replays the round serially otherwise). Staleness is
    /// *not* taken from the ghost: offline retries may have marked the
    /// origin stale while the speculation was in flight, and that counter
    /// never feeds the local round. The sparse-upload `base`/`residual`
    /// pair likewise stays on the origin — the ghost carries none (see
    /// [`Client::speculate`]) and a local round never touches it.
    pub fn commit_speculation(&mut self, ghost: Client) {
        debug_assert_eq!(self.id, ghost.id, "speculation committed to the wrong client");
        self.params = ghost.params;
        self.prev_grad = ghost.prev_grad;
        self.batcher = ghost.batcher;
        self.jitter_rng = ghost.jitter_rng;
        self.epoch += 1;
    }

    /// Mark a round where this client kept its local model.
    pub fn mark_stale(&mut self) {
        self.staleness += 1;
    }

    /// This client's attack profile (Benign unless the fleet's attack
    /// table marked it malicious).
    pub fn attack(&self) -> AttackProfile {
        self.attack
    }

    /// Encode this client's local model into the reusable wire buffer
    /// `buf` at `precision` — the upload payload the server consumes via
    /// the fused dequantize-accumulate path (no dense staging vector).
    /// Malicious profiles transform the transmitted view here, after
    /// training and before quantization.
    pub fn encode_upload(&mut self, precision: Precision, buf: &mut QuantBuf) {
        let view = attacked_params(self.attack, &self.params, &self.base, &mut self.attack_buf);
        buf.encode(precision, view);
    }

    /// Encode the sparse top-k upload: the `k` coordinates of
    /// `params − base (+ residual)` with the largest magnitude, as
    /// absolute values at `precision` (see `model::sparse`). With
    /// `error_feedback` the unsent delta mass accumulates into this
    /// client's residual (cleared per coordinate when transmitted, kept
    /// across syncs); without it, selection uses the raw delta and the
    /// residual stays untouched.
    pub fn encode_sparse_upload(
        &mut self,
        precision: Precision,
        k: usize,
        error_feedback: bool,
        buf: &mut SparseDelta,
    ) {
        let view = attacked_params(self.attack, &self.params, &self.base, &mut self.attack_buf);
        let residual = error_feedback.then_some(&mut self.residual[..]);
        buf.encode_topk(precision, view, &self.base, residual, k);
    }

    /// Per-layer variant of [`Client::encode_sparse_upload`]: the top-k
    /// race runs inside each layer's parameter range (`layer_sizes` from
    /// `ParamSpec::layers`, `ks` from `compression.layer_k_fractions`), so
    /// a quiet layer keeps its own budget. Error-feedback semantics are
    /// identical, applied per range.
    pub fn encode_sparse_upload_layers(
        &mut self,
        precision: Precision,
        layer_sizes: &[usize],
        ks: &[usize],
        error_feedback: bool,
        buf: &mut SparseDelta,
    ) {
        let view = attacked_params(self.attack, &self.params, &self.base, &mut self.attack_buf);
        let residual = error_feedback.then_some(&mut self.residual[..]);
        buf.encode_topk_layers(precision, view, &self.base, residual, layer_sizes, ks);
    }

    /// Current error-feedback residual (tests/diagnostics).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Run one local round (Algorithm 1 lines 19–26): `passes x batches`
    /// SGD steps, then V from the gradient change, then Acc_i on the probe
    /// set. Returns the report the server receives.
    pub fn local_round(
        &mut self,
        exec: &mut dyn Executor,
        round: usize,
        passes: usize,
        batches_per_pass: usize,
        lr: f32,
        train_flops: u64,
        eval_flops: u64,
    ) -> Result<ClientReport> {
        self.epoch += 1;
        let d = exec.input_dim();
        let b = exec.batch_size();
        let mut x = vec![0.0f32; b * d];
        let mut y = vec![0i32; b];
        let mut loss_sum = 0.0f64;
        let mut steps = 0usize;
        let mut last_grad: Option<Vec<f32>> = None;

        for _ in 0..passes {
            for _ in 0..batches_per_pass {
                self.batcher.next_batch(&self.shard.data, &mut x, &mut y);
                let out = exec.train_step(&self.params, &x, &y, lr)?;
                self.params = out.new_params;
                loss_sum += out.loss as f64;
                steps += 1;
                last_grad = Some(out.grad);
            }
        }
        let grad = last_grad.expect("at least one step");

        // Probe accuracy (Acc_i on the test set, paper §III-A).
        let (acc, _probe_loss) = evaluate_with_params(
            exec,
            &self.params,
            &self.probe_images[..],
            &self.probe_labels[..],
        )?;

        // V_i (Eq. 1). Before the first round there is no nabla^{k-1}: the
        // gradient difference degenerates to ||nabla^1||^2 (nabla^0 = 0),
        // giving every client a high initial value — everyone communicates
        // early, matching the paper's fast initial convergence.
        // Clients report the raw ||∇^{k-1}-∇^k||²; the server applies the
        // (1 + N/10^3)^Acc amplification (it knows N authoritatively —
        // paper: the server "can only be informed about the model of each
        // client and the total number of clients").
        let diff_sq = match &self.prev_grad {
            Some(prev) => sq_distance(prev, &grad),
            None => crate::model::l2_norm_sq(&grad),
        };
        let grad_norm_sq = crate::model::l2_norm_sq(&grad);
        self.prev_grad = Some(grad);

        // Virtual compute time: training steps + one probe evaluation.
        let probe_chunks = self.probe_labels.len().div_ceil(exec.eval_batch());
        let flops = train_flops * steps as u64 + eval_flops * probe_chunks as u64;
        let compute_seconds = self.device.compute_seconds(flops, &mut self.jitter_rng);

        Ok(ClientReport {
            client_id: self.id,
            round,
            value: diff_sq, // raw ||∇^{k-1}-∇^k||²; server applies Eq. 1 amplification
            acc,
            grad_norm_sq,
            train_loss: loss_sum / steps as f64,
            num_samples: self.shard.num_samples(),
            compute_seconds,
        })
    }
}

/// The parameter view an upload encode actually transmits: the honest
/// local params for [`AttackProfile::Benign`] / [`AttackProfile::LabelFlip`]
/// (the latter poisons data, not the wire), or the attacked view built
/// into `scratch`. Model-poisoning profiles need the sync `base` (the
/// update is defined relative to it) — speculative ghosts carry an empty
/// base and never encode, which the debug assert keeps loud.
fn attacked_params<'a>(
    attack: AttackProfile,
    params: &'a [f32],
    base: &[f32],
    scratch: &'a mut Vec<f32>,
) -> &'a [f32] {
    match attack {
        AttackProfile::Benign | AttackProfile::LabelFlip => params,
        AttackProfile::SignFlip => {
            debug_assert_eq!(base.len(), params.len(), "sign-flip encode without a sync base");
            scratch.clear();
            scratch.extend(params.iter().zip(base).map(|(&p, &b)| 2.0 * b - p));
            scratch
        }
        AttackProfile::Scale { gain } => {
            debug_assert_eq!(base.len(), params.len(), "scale encode without a sync base");
            scratch.clear();
            scratch.extend(params.iter().zip(base).map(|(&p, &b)| b + gain * (p - b)));
            scratch
        }
        AttackProfile::Backdoor { coords, boost } => {
            scratch.clear();
            scratch.extend_from_slice(params);
            let n = params.len();
            let coords = coords.clamp(1, n);
            let stride = (n / coords).max(1);
            for h in 0..coords {
                scratch[h * stride] = boost;
            }
            scratch
        }
    }
}

/// Label-flip data poisoning: every label `l` of the shard becomes
/// `9 − l` (the synth datasets are 10-class; see `data::synth`). Applied
/// when a [`AttackProfile::LabelFlip`] client's shard materializes, so
/// the poison survives park/hydrate and lazy re-rendering alike.
fn flip_labels(shard: &ClientShard) -> ClientShard {
    let mut data = shard.data.clone();
    for l in data.labels.iter_mut() {
        *l = 9 - *l;
    }
    ClientShard { client_id: shard.client_id, data }
}

/// Compact record of a client with no work in flight (see the module
/// docs). Everything a future hydration needs, in O(1) + O(budget) space:
/// no model buffers, no pixels, no heap strings.
#[derive(Debug, Clone)]
pub struct ParkedClient {
    /// Batcher replay position (see [`Batcher::restore`]).
    reshuffles: u64,
    cursor: u32,
    /// Device-jitter RNG, parked verbatim (four words of state).
    jitter_rng: Rng,
    /// Staleness at park time (informational: hydration syncs to the
    /// current model, which restarts staleness at 0 — like any sync).
    pub staleness: u32,
    /// Local sample count n_i — the FedAvg weight and the shard/batcher
    /// length, readable without hydrating (the rebalancer migrates parked
    /// clients by this weight alone).
    pub num_samples: u32,
    /// Index into [`DeviceProfile::table`].
    device: u8,
    /// Training-state epoch at park time; hydration resumes past it.
    epoch: u64,
    /// Sparse top-|budget| summary of the EF residual, `(index, value)`
    /// in ascending index order. Empty in dense mode.
    residual: Vec<(u32, f32)>,
}

/// Where the fleet's data shards come from.
pub enum FleetData {
    /// Deferred partition: shards render on hydration and drop on park —
    /// the million-client mode.
    Lazy(LazyPartition),
    /// Pre-materialized shards (`Arc`-held, so parking a client does not
    /// drop its pixels). The small-fleet / direct-test mode.
    Eager(Vec<Arc<ClientShard>>),
}

impl FleetData {
    pub fn num_clients(&self) -> usize {
        match self {
            FleetData::Lazy(p) => p.num_clients(),
            FleetData::Eager(shards) => shards.len(),
        }
    }

    fn num_samples(&self, id: usize) -> usize {
        match self {
            FleetData::Lazy(p) => p.num_samples(id),
            FleetData::Eager(shards) => shards[id].num_samples(),
        }
    }

    fn shard(&self, id: usize) -> Arc<ClientShard> {
        match self {
            FleetData::Lazy(p) => Arc::new(p.materialize(id)),
            FleetData::Eager(shards) => Arc::clone(&shards[id]),
        }
    }
}

/// One fleet slot: a hydrated client (boxed — the dense struct is large
/// and most slots are parked) or a compact parked record.
enum Slot {
    Active(Box<Client>),
    Parked(ParkedClient),
}

/// The virtualized fleet (see the module docs): full [`Client`]s for the
/// active set, [`ParkedClient`] records for everyone else, with
/// deterministic park/hydrate transitions.
pub struct Fleet {
    slots: Vec<Slot>,
    source: FleetData,
    batch_size: usize,
    probe_images: Arc<Vec<f32>>,
    probe_labels: Arc<Vec<i32>>,
    /// Root of the per-client named forks (`batcher-{id}`, `jitter-{id}`)
    /// — forking never advances this state, so hydration at any time
    /// reproduces the same streams.
    root_rng: Rng,
    profiles: [DeviceProfile; 5],
    /// Top-|budget| EF-residual coordinates kept across a park.
    residual_budget: usize,
    /// Per-client attack profile (all Benign by default). Lives here, not
    /// on the parked record, so it survives park/hydrate for free.
    attacks: Vec<AttackProfile>,
    active: usize,
    peak_active: usize,
    hydrations: u64,
    parks: u64,
}

impl Fleet {
    /// Build a fleet with every client parked (fresh records: batcher at
    /// `(1, 0)`, pristine jitter fork, zero residual). Call
    /// [`Fleet::hydrate`] / [`Fleet::hydrate_all`] to materialize.
    pub fn new(
        source: FleetData,
        batch_size: usize,
        probe_images: Arc<Vec<f32>>,
        probe_labels: Arc<Vec<i32>>,
        residual_budget: usize,
        root_rng: Rng,
    ) -> Self {
        let n = source.num_clients();
        let slots = (0..n)
            .map(|id| {
                let num_samples = source.num_samples(id);
                assert!(num_samples > 0, "client {id} has an empty shard");
                assert!(num_samples <= u32::MAX as usize, "shard too large for a parked record");
                Slot::Parked(ParkedClient {
                    reshuffles: 1,
                    cursor: 0,
                    jitter_rng: root_rng.fork(&format!("jitter-{id}")),
                    staleness: 0,
                    num_samples: num_samples as u32,
                    device: DeviceProfile::paper_fleet_index(n, id),
                    epoch: 0,
                    residual: Vec::new(),
                })
            })
            .collect();
        Fleet {
            slots,
            source,
            batch_size,
            probe_images,
            probe_labels,
            root_rng,
            profiles: DeviceProfile::table(),
            residual_budget,
            attacks: vec![AttackProfile::Benign; n],
            active: 0,
            peak_active: 0,
            hydrations: 0,
            parks: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn is_active(&self, id: usize) -> bool {
        matches!(self.slots[id], Slot::Active(_))
    }

    /// The hydrated client at `id`. Panics if parked — engines must
    /// hydrate before touching a client, which keeps accidental
    /// fleet-wide materialization loud instead of silent.
    pub fn client(&self, id: usize) -> &Client {
        match &self.slots[id] {
            Slot::Active(c) => c,
            Slot::Parked(_) => panic!("client {id} is parked"),
        }
    }

    pub fn client_mut(&mut self, id: usize) -> &mut Client {
        match &mut self.slots[id] {
            Slot::Active(c) => c,
            Slot::Parked(_) => panic!("client {id} is parked"),
        }
    }

    /// Install the per-client attack table (one profile per client, in id
    /// order). Must be called before any client hydrates — label-flip
    /// poisoning is applied when the shard materializes, so a profile set
    /// after hydration would silently miss the data transform.
    pub fn set_attacks(&mut self, attacks: Vec<AttackProfile>) {
        assert_eq!(attacks.len(), self.slots.len(), "attack table / fleet size mismatch");
        assert_eq!(self.active, 0, "set_attacks after hydration would miss label flips");
        self.attacks = attacks;
    }

    /// The attack profile of client `id` (active or parked).
    pub fn attack_of(&self, id: usize) -> AttackProfile {
        self.attacks[id]
    }

    /// Sample count n_i without hydrating (active or parked).
    pub fn num_samples(&self, id: usize) -> usize {
        match &self.slots[id] {
            Slot::Active(c) => c.num_samples(),
            Slot::Parked(p) => p.num_samples as usize,
        }
    }

    /// Materialize client `id`, syncing it to `model` (see the module
    /// docs for exactly what a hydration restores). No-op if already
    /// active — the engines only hydrate parked clients, but
    /// `hydrate_all` leans on the idempotence.
    pub fn hydrate(&mut self, id: usize, model: &[f32]) {
        let parked = match &mut self.slots[id] {
            Slot::Active(_) => return,
            Slot::Parked(p) => std::mem::replace(
                p,
                // Placeholder; overwritten by the Active slot below.
                ParkedClient {
                    reshuffles: 0,
                    cursor: 0,
                    jitter_rng: Rng::new(0),
                    staleness: 0,
                    num_samples: 0,
                    device: 0,
                    epoch: 0,
                    residual: Vec::new(),
                },
            ),
        };
        let attack = self.attacks[id];
        let shard = match attack {
            AttackProfile::LabelFlip => Arc::new(flip_labels(&self.source.shard(id))),
            _ => self.source.shard(id),
        };
        let n = shard.num_samples();
        debug_assert_eq!(n, parked.num_samples as usize);
        let mut residual = vec![0.0f32; model.len()];
        for &(i, v) in &parked.residual {
            residual[i as usize] = v;
        }
        let client = Client {
            batcher: Batcher::restore(
                n,
                self.batch_size,
                self.root_rng.fork(&format!("batcher-{id}")),
                parked.reshuffles,
                parked.cursor as usize,
            ),
            jitter_rng: parked.jitter_rng,
            id,
            device: self.profiles[parked.device as usize].clone(),
            shard,
            params: model.to_vec(),
            base: model.to_vec(),
            residual,
            prev_grad: None,
            staleness: 0,
            probe_images: Arc::clone(&self.probe_images),
            probe_labels: Arc::clone(&self.probe_labels),
            epoch: parked.epoch + 1,
            attack,
            attack_buf: Vec::new(),
        };
        self.slots[id] = Slot::Active(Box::new(client));
        self.active += 1;
        self.peak_active = self.peak_active.max(self.active);
        self.hydrations += 1;
    }

    /// Demote client `id` to a compact record (see the module docs for
    /// what survives a park). Panics if already parked or if the client
    /// still has novel model state the engines would need — callers park
    /// only at the post-flush point where a sync would have overwritten
    /// the local model anyway.
    pub fn park(&mut self, id: usize) {
        let client = match std::mem::replace(
            &mut self.slots[id],
            Slot::Parked(ParkedClient {
                reshuffles: 0,
                cursor: 0,
                jitter_rng: Rng::new(0),
                staleness: 0,
                num_samples: 0,
                device: 0,
                epoch: 0,
                residual: Vec::new(),
            }),
        ) {
            Slot::Active(c) => c,
            Slot::Parked(_) => panic!("client {id} is already parked"),
        };
        let residual = summarize_residual(&client.residual, self.residual_budget);
        self.slots[id] = Slot::Parked(ParkedClient {
            reshuffles: client.batcher.reshuffles(),
            cursor: client.batcher.cursor() as u32,
            jitter_rng: client.jitter_rng,
            staleness: client.staleness.min(u32::MAX as usize) as u32,
            num_samples: client.num_samples() as u32,
            device: DeviceProfile::paper_fleet_index(self.slots.len(), id),
            epoch: client.epoch,
            residual,
        });
        self.active -= 1;
        self.parks += 1;
    }

    /// Hydrate every parked client to `model` — the legacy
    /// (pre-virtualization) fleet shape, bitwise identical to eager
    /// construction when the records are fresh.
    pub fn hydrate_all(&mut self, model: &[f32]) {
        for id in 0..self.slots.len() {
            self.hydrate(id, model);
        }
    }

    /// The parked record at `id` (tests/diagnostics). None if active.
    pub fn parked(&self, id: usize) -> Option<&ParkedClient> {
        match &self.slots[id] {
            Slot::Parked(p) => Some(p),
            Slot::Active(_) => None,
        }
    }

    /// Iterate the hydrated clients, in id order.
    pub fn iter_hydrated_mut(&mut self) -> impl Iterator<Item = (usize, &mut Client)> {
        self.slots.iter_mut().enumerate().filter_map(|(i, s)| match s {
            Slot::Active(c) => Some((i, &mut **c)),
            Slot::Parked(_) => None,
        })
    }

    /// Hydrated-client count right now.
    pub fn active_count(&self) -> usize {
        self.active
    }

    /// High-water mark of simultaneously hydrated clients.
    pub fn peak_active(&self) -> usize {
        self.peak_active
    }

    /// Total hydrations (initial materializations included).
    pub fn hydrations(&self) -> u64 {
        self.hydrations
    }

    /// Total parks.
    pub fn parks(&self) -> u64 {
        self.parks
    }

    /// Approximate resident bytes of the *parked* representation: slot
    /// array + residual summaries + the lazy source's count matrix. The
    /// fleet-scale bench reports this next to process RSS so the
    /// O(n · parked_record) term is measured, not assumed.
    pub fn approx_parked_bytes(&self) -> usize {
        let residual_heap: usize = self
            .slots
            .iter()
            .map(|s| match s {
                Slot::Parked(p) => p.residual.capacity() * std::mem::size_of::<(u32, f32)>(),
                Slot::Active(_) => 0,
            })
            .sum();
        let source = match &self.source {
            FleetData::Lazy(p) => p.approx_bytes(),
            FleetData::Eager(_) => 0,
        };
        self.slots.len() * std::mem::size_of::<Slot>() + residual_heap + source
    }

    /// Serialize the fleet's mutable state for a checkpoint: every slot
    /// (active clients in full — params, delta base, EF residual,
    /// previous gradient, staleness, epoch, jitter-RNG stream, batcher
    /// replay position; parked records verbatim) plus the window
    /// counters. Config-derived state (shards, probe set, device
    /// profiles, attack table, root RNG) is **not** written — a restore
    /// rebuilds it through normal construction, exactly like hydration
    /// does, so a checkpoint stays O(active·dim + n·budget), not O(data).
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.slots.len());
        for slot in &self.slots {
            match slot {
                Slot::Active(c) => {
                    enc.bool(true);
                    enc.f32s(&c.params);
                    enc.f32s(&c.base);
                    enc.f32s(&c.residual);
                    match &c.prev_grad {
                        Some(g) => {
                            enc.bool(true);
                            enc.f32s(g);
                        }
                        None => enc.bool(false),
                    }
                    enc.usize(c.staleness);
                    enc.u64(c.epoch);
                    let (s, spare) = c.jitter_rng.state();
                    enc.u64s(&s);
                    enc.opt_f64(spare);
                    enc.u64(c.batcher.reshuffles());
                    enc.usize(c.batcher.cursor());
                }
                Slot::Parked(p) => {
                    enc.bool(false);
                    enc.u64(p.reshuffles);
                    enc.u32(p.cursor);
                    let (s, spare) = p.jitter_rng.state();
                    enc.u64s(&s);
                    enc.opt_f64(spare);
                    enc.u32(p.staleness);
                    enc.u32(p.num_samples);
                    enc.u8(p.device);
                    enc.u64(p.epoch);
                    enc.usize(p.residual.len());
                    for &(i, v) in &p.residual {
                        enc.u32(i);
                        enc.f32(v);
                    }
                }
            }
        }
        enc.usize(self.active);
        enc.usize(self.peak_active);
        enc.u64(self.hydrations);
        enc.u64(self.parks);
    }

    /// Restore the state saved by [`Fleet::save`] into a freshly built
    /// fleet (same config, same data source, attack table already
    /// installed via [`Fleet::set_attacks`] — label-flip shards rebuild
    /// from the table here, as in hydration). Active clients come back
    /// with their exact training state — **not** through
    /// [`Fleet::hydrate`], which deliberately resets
    /// params/base/staleness/`prev_grad` to fresh-joiner values.
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        let n = dec.usize()?;
        anyhow::ensure!(
            n == self.slots.len(),
            "fleet checkpoint holds {n} clients, this fleet has {}",
            self.slots.len()
        );
        for id in 0..n {
            if dec.bool()? {
                let params = dec.f32s()?;
                let base = dec.f32s()?;
                let residual = dec.f32s()?;
                let prev_grad = if dec.bool()? { Some(dec.f32s()?) } else { None };
                let staleness = dec.usize()?;
                let epoch = dec.u64()?;
                let jitter_rng = rng_from(dec)?;
                let reshuffles = dec.u64()?;
                let cursor = dec.usize()?;
                let attack = self.attacks[id];
                let shard = match attack {
                    AttackProfile::LabelFlip => Arc::new(flip_labels(&self.source.shard(id))),
                    _ => self.source.shard(id),
                };
                let samples = shard.num_samples();
                let client = Client {
                    batcher: Batcher::restore(
                        samples,
                        self.batch_size,
                        self.root_rng.fork(&format!("batcher-{id}")),
                        reshuffles,
                        cursor,
                    ),
                    jitter_rng,
                    id,
                    device: self.profiles
                        [DeviceProfile::paper_fleet_index(n, id) as usize]
                        .clone(),
                    shard,
                    params,
                    base,
                    residual,
                    prev_grad,
                    staleness,
                    probe_images: Arc::clone(&self.probe_images),
                    probe_labels: Arc::clone(&self.probe_labels),
                    epoch,
                    attack,
                    attack_buf: Vec::new(),
                };
                self.slots[id] = Slot::Active(Box::new(client));
            } else {
                let reshuffles = dec.u64()?;
                let cursor = dec.u32()?;
                let jitter_rng = rng_from(dec)?;
                let staleness = dec.u32()?;
                let num_samples = dec.u32()?;
                let device = dec.u8()?;
                let epoch = dec.u64()?;
                let pairs = dec.usize()?;
                let mut residual = Vec::with_capacity(pairs);
                for _ in 0..pairs {
                    let i = dec.u32()?;
                    let v = dec.f32()?;
                    residual.push((i, v));
                }
                self.slots[id] = Slot::Parked(ParkedClient {
                    reshuffles,
                    cursor,
                    jitter_rng,
                    staleness,
                    num_samples,
                    device,
                    epoch,
                    residual,
                });
            }
        }
        self.active = dec.usize()?;
        self.peak_active = dec.usize()?;
        self.hydrations = dec.u64()?;
        self.parks = dec.u64()?;
        Ok(())
    }
}

/// Decode a four-word xoshiro state (+ spare Gaussian) written by
/// [`Rng::state`].
fn rng_from(dec: &mut Dec) -> Result<Rng> {
    let s = dec.u64s()?;
    anyhow::ensure!(s.len() == 4, "rng state must hold 4 words, got {}", s.len());
    Ok(Rng::from_state([s[0], s[1], s[2], s[3]], dec.opt_f64()?))
}

/// Top-|budget| nonzero residual coordinates by magnitude (index
/// tie-break), returned in ascending index order — the deterministic
/// park-time EF summary.
fn summarize_residual(residual: &[f32], budget: usize) -> Vec<(u32, f32)> {
    if budget == 0 {
        return Vec::new();
    }
    let mut owed: Vec<(u32, f32)> = residual
        .iter()
        .enumerate()
        .filter(|&(_, &v)| v != 0.0)
        .map(|(i, &v)| (i as u32, v))
        .collect();
    if owed.len() > budget {
        owed.select_nth_unstable_by(budget - 1, |a, b| {
            b.1.abs().total_cmp(&a.1.abs()).then_with(|| a.0.cmp(&b.0))
        });
        owed.truncate(budget);
    }
    owed.sort_unstable_by_key(|&(i, _)| i);
    owed
}

/// Apply the Eq. 1 amplification server-side:
/// `V_i = raw * (1 + N/10^3)^{Acc_i}` (identity when the ablation disables
/// the accuracy term).
pub fn amplify_value(raw: f64, acc: f64, n_clients: usize, cfg: ValueFnConfig) -> f64 {
    if cfg.use_acc_term {
        raw * (1.0 + n_clients as f64 / 1000.0).powf(acc)
    } else {
        raw
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    fn shard(id: usize, n: usize, dim: usize) -> ClientShard {
        let mut rng = Rng::new(90 + id as u64);
        let images = (0..n * dim).map(|_| rng.f64() as f32).collect();
        let labels = (0..n).map(|i| (i % 10) as i32).collect();
        ClientShard { client_id: id, data: Dataset { images, labels, dim } }
    }

    fn build() -> Fleet {
        let shards: Vec<_> = (0..3).map(|id| Arc::new(shard(id, 12, 4))).collect();
        let mut fleet = Fleet::new(
            FleetData::Eager(shards),
            4,
            Arc::new(vec![0.0f32; 8]),
            Arc::new(vec![0i32; 2]),
            8,
            Rng::new(7),
        );
        fleet.set_attacks(vec![
            AttackProfile::Benign,
            AttackProfile::LabelFlip,
            AttackProfile::Benign,
        ]);
        fleet
    }

    #[test]
    fn save_load_round_trips_active_and_parked_state() {
        let model = vec![0.25f32; 6];
        let mut a = build();
        a.hydrate_all(&model);
        // Dirty every kind of mutable state a checkpoint must carry.
        {
            let c = a.client_mut(0);
            c.params[1] = 1.5;
            c.residual[3] = -0.75;
            c.prev_grad = Some(vec![0.1f32; 6]);
            c.staleness = 2;
            c.epoch = 5;
            c.jitter_rng.f64();
        }
        // Advance client 1's batcher into mid-epoch.
        let data1 = Arc::clone(&a.client(1).shard);
        let mut x = vec![0.0f32; 4 * 4];
        let mut y = vec![0i32; 4];
        a.client_mut(1).batcher.next_batch(&data1.data, &mut x, &mut y);
        // Park client 2 so a parked record rides the checkpoint too.
        a.client_mut(2).residual[5] = 0.5;
        a.park(2);

        let mut enc = Enc::new();
        a.save(&mut enc);
        let bytes = enc.into_bytes();

        let mut b = build();
        let mut dec = Dec::new(&bytes);
        b.load(&mut dec).unwrap();
        dec.finish().unwrap();

        assert_eq!(b.active_count(), a.active_count());
        assert_eq!(b.peak_active(), a.peak_active());
        assert_eq!(b.hydrations(), a.hydrations());
        assert_eq!(b.parks(), a.parks());

        let fb = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        for id in 0..2 {
            assert!(b.is_active(id));
            let (ca, cb) = (a.client(id), b.client(id));
            assert_eq!(fb(&cb.params), fb(&ca.params), "client {id} params");
            assert_eq!(fb(&cb.base), fb(&ca.base), "client {id} base");
            assert_eq!(fb(&cb.residual), fb(&ca.residual), "client {id} residual");
            assert_eq!(
                cb.prev_grad.as_deref().map(fb),
                ca.prev_grad.as_deref().map(fb),
                "client {id} prev_grad"
            );
            assert_eq!(cb.staleness, ca.staleness);
            assert_eq!(cb.epoch, ca.epoch);
            assert_eq!(cb.batcher.reshuffles(), ca.batcher.reshuffles());
            assert_eq!(cb.batcher.cursor(), ca.batcher.cursor());
            assert_eq!(cb.attack, ca.attack);
        }
        // The label-flip shard was rebuilt poisoned, not honest.
        assert_eq!(b.client(1).shard.data.labels, a.client(1).shard.data.labels);
        assert_ne!(b.client(1).shard.data.labels, shard(1, 12, 4).data.labels);

        let (pa, pb) = (a.parked(2).unwrap(), b.parked(2).unwrap());
        assert_eq!(pb.reshuffles, pa.reshuffles);
        assert_eq!(pb.cursor, pa.cursor);
        assert_eq!(pb.staleness, pa.staleness);
        assert_eq!(pb.num_samples, pa.num_samples);
        assert_eq!(pb.device, pa.device);
        assert_eq!(pb.epoch, pa.epoch);
        assert_eq!(pb.residual, pa.residual);

        // The restored fleet *continues* bitwise: jitter streams, batch
        // order, and a hydration of the parked client all line up.
        for _ in 0..5 {
            assert_eq!(
                a.client_mut(0).jitter_rng.f64().to_bits(),
                b.client_mut(0).jitter_rng.f64().to_bits()
            );
        }
        let (mut xa, mut ya) = (vec![0.0f32; 4 * 4], vec![0i32; 4]);
        let (mut xb, mut yb) = (vec![0.0f32; 4 * 4], vec![0i32; 4]);
        for _ in 0..7 {
            let da = Arc::clone(&a.client(1).shard);
            let db = Arc::clone(&b.client(1).shard);
            a.client_mut(1).batcher.next_batch(&da.data, &mut xa, &mut ya);
            b.client_mut(1).batcher.next_batch(&db.data, &mut xb, &mut yb);
            assert_eq!(fb(&xa), fb(&xb));
            assert_eq!(ya, yb);
        }
        let fresh = vec![0.5f32; 6];
        a.hydrate(2, &fresh);
        b.hydrate(2, &fresh);
        let (ca, cb) = (a.client(2), b.client(2));
        assert_eq!(fb(&cb.params), fb(&ca.params));
        assert_eq!(fb(&cb.residual), fb(&ca.residual), "EF summary re-expanded identically");
        assert_eq!(cb.epoch, ca.epoch);
        assert_eq!(cb.batcher.reshuffles(), ca.batcher.reshuffles());
    }

    #[test]
    fn load_rejects_fleet_size_mismatch() {
        let mut a = build();
        let mut enc = Enc::new();
        a.hydrate_all(&[0.0f32; 6]);
        a.save(&mut enc);
        let bytes = enc.into_bytes();
        let shards: Vec<_> = (0..2).map(|id| Arc::new(shard(id, 12, 4))).collect();
        let mut small = Fleet::new(
            FleetData::Eager(shards),
            4,
            Arc::new(vec![0.0f32; 8]),
            Arc::new(vec![0i32; 2]),
            8,
            Rng::new(7),
        );
        assert!(small.load(&mut Dec::new(&bytes)).is_err());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthConfig};
    use crate::data::ClientShard;
    use crate::runtime::MockExecutor;

    fn mk_client(seed: u64) -> (Client, MockExecutor) {
        let exec = MockExecutor::standard();
        let mut rng = Rng::new(seed);
        let data = generate(100, &SynthConfig::default(), &mut rng);
        let probe = generate(32, &SynthConfig::default(), &mut rng);
        let shard = ClientShard { client_id: 0, data };
        let client = Client::new(
            0,
            Arc::new(shard),
            DeviceProfile::rpi4_8gb(),
            vec![0.0; exec.param_count()],
            exec.batch_size(),
            Arc::new(probe.images.clone()),
            Arc::new(probe.labels.clone()),
            &Rng::new(seed),
        );
        (client, exec)
    }

    #[test]
    fn local_round_produces_report_and_updates_model() {
        let (mut c, mut exec) = mk_client(1);
        let before = c.params.clone();
        let r = c
            .local_round(&mut exec, 1, 2, 3, 0.2, 1_000_000, 300_000)
            .unwrap();
        assert_ne!(c.params, before, "params must move");
        assert!(r.value > 0.0);
        assert!(r.compute_seconds > 0.0);
        assert!((0.0..=1.0).contains(&r.acc));
        assert_eq!(r.num_samples, 100);
        assert!(r.train_loss.is_finite());
    }

    #[test]
    fn value_shrinks_as_training_converges() {
        // As the local model converges, successive gradients become similar
        // and the raw value (grad-change norm) must trend down — the
        // paper's "old model" detection.
        let (mut c, mut exec) = mk_client(2);
        let mut first = None;
        let mut last = 0.0;
        for round in 1..=12 {
            let r = c
                .local_round(&mut exec, round, 2, 4, 0.5, 1, 1)
                .unwrap();
            if round == 2 {
                first = Some(r.value); // skip round 1 (prev_grad = None)
            }
            last = r.value;
        }
        assert!(last < first.unwrap(), "{last} !< {first:?}");
    }

    #[test]
    fn sync_resets_staleness() {
        let (mut c, _) = mk_client(3);
        c.mark_stale();
        c.mark_stale();
        assert_eq!(c.staleness, 2);
        let g = vec![1.0f32; c.params.len()];
        c.sync(&g);
        assert_eq!(c.staleness, 0);
        assert_eq!(c.params, g);
    }

    #[test]
    fn encode_upload_round_trips_wire_payload() {
        let (mut c, mut exec) = mk_client(4);
        c.local_round(&mut exec, 1, 1, 2, 0.2, 1, 1).unwrap();
        let mut buf = QuantBuf::new();
        for precision in [Precision::F32, Precision::F16, Precision::Int8] {
            c.encode_upload(precision, &mut buf);
            assert_eq!(buf.len(), c.params.len());
            let want = precision.round_trip(&c.params);
            let mut got = vec![0.0f32; c.params.len()];
            buf.decode_into(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", precision.name());
            }
        }
    }

    #[test]
    fn sparse_upload_residual_survives_sync_and_drives_selection() {
        let (mut c, mut exec) = mk_client(5);
        c.local_round(&mut exec, 1, 1, 2, 0.5, 1, 1).unwrap();
        let mut buf = SparseDelta::new();
        let k = 8;
        c.encode_sparse_upload(Precision::F32, k, true, &mut buf);
        assert_eq!(buf.len(), k);
        assert_eq!(buf.dim(), c.params.len());
        // Transmitted values are the absolute local params.
        for (j, &idx) in buf.indices().iter().enumerate() {
            assert_eq!(buf.value(j).to_bits(), c.params[idx as usize].to_bits());
        }
        // Error feedback: some delta mass was left behind (params moved in
        // more than k coordinates under SGD)...
        assert!(c.residual().iter().any(|&r| r != 0.0), "no residual after partial upload");
        let residual_before: Vec<f32> = c.residual().to_vec();
        let top_owed: Vec<u32> = {
            let mut order: Vec<u32> = (0..residual_before.len() as u32).collect();
            order.sort_by(|&a, &b| {
                residual_before[b as usize]
                    .abs()
                    .total_cmp(&residual_before[a as usize].abs())
                    .then_with(|| a.cmp(&b))
            });
            let mut top: Vec<u32> = order[..k].to_vec();
            top.sort_unstable();
            top
        };
        // ...and it survives the model download (a reset here would make
        // error feedback inert: every upload is followed by a sync).
        let g = vec![0.25f32; c.params.len()];
        c.sync(&g);
        assert_eq!(c.residual(), &residual_before[..], "sync must keep the residual");
        // After sync the delta base is the downloaded model, so the raw
        // delta is zero everywhere and the residual alone decides the
        // next selection: the most-owed coordinates win, and transmitting
        // them clears exactly their debt.
        c.encode_sparse_upload(Precision::F32, k, true, &mut buf);
        assert_eq!(buf.indices(), &top_owed[..]);
        for &i in buf.indices() {
            assert_eq!(c.residual()[i as usize], 0.0, "transmitted coord keeps its debt");
        }
        // Without error feedback the same encode ignores the residual and
        // leaves it untouched.
        let before: Vec<f32> = c.residual().to_vec();
        c.encode_sparse_upload(Precision::F32, k, false, &mut buf);
        assert_eq!(c.residual(), &before[..]);
        for j in 0..buf.len() {
            assert_eq!(buf.value(j), 0.25);
        }
    }

    #[test]
    fn sparse_upload_without_error_feedback_keeps_residual_zero() {
        let (mut c, mut exec) = mk_client(6);
        c.local_round(&mut exec, 1, 1, 2, 0.5, 1, 1).unwrap();
        let mut buf = SparseDelta::new();
        c.encode_sparse_upload(Precision::F32, 4, false, &mut buf);
        assert!(c.residual().iter().all(|&r| r == 0.0));
    }

    #[test]
    fn speculation_commit_matches_serial_local_round() {
        // Forking, training the ghost, and committing must be bitwise
        // indistinguishable from training the client in place.
        let (mut a, mut exec) = mk_client(10);
        let (mut b, mut exec2) = mk_client(10);
        for round in 1..=3 {
            let ra = a.local_round(&mut exec, round, 1, 2, 0.2, 1, 1).unwrap();
            let fork_epoch = b.epoch();
            let mut ghost = b.speculate();
            let rb = ghost.local_round(&mut exec2, round, 1, 2, 0.2, 1, 1).unwrap();
            assert_eq!(b.epoch(), fork_epoch, "origin untouched while fork runs");
            b.commit_speculation(ghost);
            assert_eq!(ra.value.to_bits(), rb.value.to_bits());
            assert_eq!(ra.train_loss.to_bits(), rb.train_loss.to_bits());
            assert_eq!(ra.compute_seconds.to_bits(), rb.compute_seconds.to_bits());
            for (x, y) in a.params.iter().zip(&b.params) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn speculation_epoch_detects_superseded_state() {
        let (mut c, mut exec) = mk_client(11);
        let fork_epoch = c.epoch();
        let ghost = c.speculate();
        // A sync (new global landed) supersedes the fork...
        let g = vec![0.5f32; c.params.len()];
        c.sync(&g);
        assert_ne!(c.epoch(), fork_epoch, "sync must invalidate the fork");
        drop(ghost);
        // ...while mark_stale (offline retry path) does not.
        let e = c.epoch();
        let _ghost = c.speculate();
        c.mark_stale();
        assert_eq!(c.epoch(), e, "staleness bookkeeping must not invalidate");
        // A serial local round on the origin also supersedes.
        c.local_round(&mut exec, 1, 1, 1, 0.1, 1, 1).unwrap();
        assert_ne!(c.epoch(), e);
    }

    #[test]
    fn speculation_commit_preserves_origin_staleness() {
        let (mut c, mut exec) = mk_client(12);
        let mut ghost = c.speculate();
        ghost.local_round(&mut exec, 1, 1, 1, 0.1, 1, 1).unwrap();
        c.mark_stale();
        c.mark_stale();
        c.commit_speculation(ghost);
        assert_eq!(c.staleness, 2, "ghost's staleness=0 must not leak back");
    }

    fn mk_fleet(seed: u64, n: usize, budget: usize) -> (Fleet, MockExecutor) {
        use crate::data::{LazyPartition, PartitionScheme};
        let exec = MockExecutor::standard();
        let root = Rng::new(seed);
        let lazy = LazyPartition::new(
            PartitionScheme::Iid,
            n,
            64,
            &SynthConfig::default(),
            &root.fork("data"),
        );
        let probe = generate(32, &SynthConfig::default(), &mut root.fork("probe"));
        let fleet = Fleet::new(
            FleetData::Lazy(lazy),
            exec.batch_size(),
            Arc::new(probe.images),
            Arc::new(probe.labels),
            budget,
            root,
        );
        (fleet, exec)
    }

    #[test]
    fn fleet_hydrate_all_matches_eager_construction() {
        // A freshly hydrated fleet must be bitwise the eager Client::new
        // fleet: same batcher forks, same jitter forks, same shard data.
        let (mut fleet, mut exec) = mk_fleet(21, 3, 32);
        let init = vec![0.0f32; exec.param_count()];
        fleet.hydrate_all(&init);
        assert_eq!(fleet.active_count(), 3);

        use crate::data::{LazyPartition, PartitionScheme};
        let root = Rng::new(21);
        let lazy = LazyPartition::new(
            PartitionScheme::Iid,
            3,
            64,
            &SynthConfig::default(),
            &root.fork("data"),
        );
        let probe = generate(32, &SynthConfig::default(), &mut root.fork("probe"));
        let probe_images = Arc::new(probe.images);
        let probe_labels = Arc::new(probe.labels);
        let mut exec2 = MockExecutor::standard();
        for id in 0..3 {
            let mut eager = Client::new(
                id,
                Arc::new(lazy.materialize(id)),
                DeviceProfile::table()[DeviceProfile::paper_fleet_index(3, id) as usize].clone(),
                init.clone(),
                exec.batch_size(),
                Arc::clone(&probe_images),
                Arc::clone(&probe_labels),
                &root,
            );
            let ra = eager.local_round(&mut exec2, 1, 1, 2, 0.2, 1_000, 300).unwrap();
            let rb = fleet
                .client_mut(id)
                .local_round(&mut exec, 1, 1, 2, 0.2, 1_000, 300)
                .unwrap();
            assert_eq!(ra.value.to_bits(), rb.value.to_bits(), "client {id}");
            assert_eq!(ra.compute_seconds.to_bits(), rb.compute_seconds.to_bits());
            for (a, b) in eager.params.iter().zip(&fleet.client(id).params) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn park_hydrate_preserves_batcher_and_jitter_streams() {
        // A park/hydrate cycle at a sync point must continue the batcher
        // order and jitter stream exactly where a never-parked client
        // (synced at the same point) would.
        let (mut parked_fleet, mut exec) = mk_fleet(22, 2, 32);
        let (mut straight, mut exec2) = mk_fleet(22, 2, 32);
        let init = vec![0.0f32; exec.param_count()];
        parked_fleet.hydrate_all(&init);
        straight.hydrate_all(&init);
        let g = vec![0.125f32; init.len()];
        for cycle in 0..3 {
            for round in 1..=2 {
                let r = cycle * 2 + round;
                let ra = parked_fleet
                    .client_mut(0)
                    .local_round(&mut exec, r, 1, 2, 0.3, 1_000, 300)
                    .unwrap();
                let rb = straight
                    .client_mut(0)
                    .local_round(&mut exec2, r, 1, 2, 0.3, 1_000, 300)
                    .unwrap();
                // compute_seconds is pure jitter-stream: bitwise equality
                // means the RNG stream survived the park.
                assert_eq!(
                    ra.compute_seconds.to_bits(),
                    rb.compute_seconds.to_bits(),
                    "cycle {cycle} round {round}"
                );
            }
            // Park at a sync point vs. a plain sync.
            parked_fleet.park(0);
            assert!(parked_fleet.parked(0).is_some());
            parked_fleet.hydrate(0, &g);
            straight.client_mut(0).sync(&g);
            for (a, b) in parked_fleet.client(0).params.iter().zip(&straight.client(0).params) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
        assert_eq!(parked_fleet.parks(), 3);
        assert_eq!(parked_fleet.hydrations(), 2 + 3);
        assert_eq!(parked_fleet.peak_active(), 2);
    }

    #[test]
    fn park_summarizes_residual_top_budget() {
        let (mut fleet, mut exec) = mk_fleet(23, 1, 4);
        let init = vec![0.0f32; exec.param_count()];
        fleet.hydrate_all(&init);
        fleet.client_mut(0).local_round(&mut exec, 1, 1, 2, 0.5, 1, 1).unwrap();
        let mut buf = SparseDelta::new();
        fleet.client_mut(0).encode_sparse_upload(Precision::F32, 8, true, &mut buf);
        let full: Vec<f32> = fleet.client(0).residual().to_vec();
        assert!(full.iter().filter(|&&v| v != 0.0).count() > 4, "test needs residual pressure");
        // Expected top-4 by |v|, index tie-break.
        let mut owed: Vec<(u32, f32)> = full
            .iter()
            .enumerate()
            .filter(|&(_, &v)| v != 0.0)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        owed.sort_by(|a, b| b.1.abs().total_cmp(&a.1.abs()).then_with(|| a.0.cmp(&b.0)));
        owed.truncate(4);
        owed.sort_unstable_by_key(|&(i, _)| i);
        fleet.park(0);
        assert_eq!(fleet.parked(0).unwrap().residual, owed);
        // Hydration expands the summary back into a dense residual.
        fleet.hydrate(0, &init);
        for (i, &v) in fleet.client(0).residual().iter().enumerate() {
            let want = owed.iter().find(|&&(j, _)| j as usize == i).map_or(0.0, |&(_, w)| w);
            assert_eq!(v.to_bits(), want.to_bits(), "coord {i}");
        }
    }

    #[test]
    fn fleet_reads_samples_without_hydrating() {
        let (fleet, _) = mk_fleet(24, 5, 0);
        for id in 0..5 {
            assert!(!fleet.is_active(id));
            assert_eq!(fleet.num_samples(id), 64);
        }
        assert!(fleet.approx_parked_bytes() > 0);
    }

    #[test]
    fn sign_flip_encodes_reflected_update() {
        let (mut c, mut exec) = mk_client(30);
        c.local_round(&mut exec, 1, 1, 2, 0.5, 1, 1).unwrap();
        c.attack = AttackProfile::SignFlip;
        let want: Vec<f32> =
            c.params.iter().zip(c.sync_base()).map(|(&p, &b)| 2.0 * b - p).collect();
        let mut buf = QuantBuf::new();
        c.encode_upload(Precision::F32, &mut buf);
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(buf.get(i).to_bits(), w.to_bits());
        }
        // The local model itself is untouched — only the wire view lies.
        c.attack = AttackProfile::Benign;
        c.encode_upload(Precision::F32, &mut buf);
        for (i, &p) in c.params.iter().enumerate() {
            assert_eq!(buf.get(i).to_bits(), p.to_bits());
        }
    }

    #[test]
    fn scale_attack_amplifies_update_around_base() {
        let (mut c, _) = mk_client(31);
        let g = vec![0.5f32; c.params.len()];
        c.sync(&g);
        for (i, p) in c.params.iter_mut().enumerate() {
            *p += (i % 3) as f32 * 0.125;
        }
        c.attack = AttackProfile::Scale { gain: 4.0 };
        let want: Vec<f32> =
            c.params.iter().zip(c.sync_base()).map(|(&p, &b)| b + 4.0 * (p - b)).collect();
        let mut buf = QuantBuf::new();
        c.encode_upload(Precision::F32, &mut buf);
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(buf.get(i).to_bits(), w.to_bits());
        }
    }

    #[test]
    fn backdoor_spikes_trigger_coords_through_sparse_path() {
        let (mut c, mut exec) = mk_client(32);
        c.local_round(&mut exec, 1, 1, 2, 0.5, 1, 1).unwrap();
        c.attack = AttackProfile::Backdoor { coords: 4, boost: 9.5 };
        let n = c.params.len();
        let stride = (n / 4).max(1);
        let mut buf = SparseDelta::new();
        c.encode_sparse_upload(Precision::F32, n, false, &mut buf);
        for h in 0..4 {
            let idx = (h * stride) as u32;
            assert_eq!(buf.value_at(idx), Some(9.5), "trigger coord {idx} not spiked");
        }
        // Untouched coordinates still carry the honest params.
        let clean = (1..n).find(|j| j % stride != 0).unwrap();
        assert_eq!(buf.value_at(clean as u32).unwrap().to_bits(), c.params[clean].to_bits());
    }

    #[test]
    fn label_flip_applies_at_hydration_and_survives_park() {
        let (mut fleet, exec) = mk_fleet(33, 2, 8);
        fleet.set_attacks(vec![AttackProfile::LabelFlip, AttackProfile::Benign]);
        let init = vec![0.0f32; exec.param_count()];
        fleet.hydrate_all(&init);
        assert_eq!(fleet.attack_of(0), AttackProfile::LabelFlip);
        assert_eq!(fleet.client(0).attack(), AttackProfile::LabelFlip);
        assert_eq!(fleet.client(1).attack(), AttackProfile::Benign);
        // Reference: the honest shard from an identically seeded source.
        use crate::data::{LazyPartition, PartitionScheme};
        let root = Rng::new(33);
        let lazy = LazyPartition::new(
            PartitionScheme::Iid,
            2,
            64,
            &SynthConfig::default(),
            &root.fork("data"),
        );
        let honest = lazy.materialize(0);
        let flipped: Vec<i32> = fleet.client(0).shard.data.labels.clone();
        assert_eq!(flipped.len(), honest.data.labels.len());
        for (f, h) in flipped.iter().zip(&honest.data.labels) {
            assert_eq!(*f, 9 - *h);
        }
        assert!(flipped != honest.data.labels, "flip must change at least one label");
        // The poison is re-applied on every hydration after a park.
        fleet.park(0);
        fleet.hydrate(0, &init);
        assert_eq!(fleet.client(0).attack(), AttackProfile::LabelFlip);
        assert_eq!(fleet.client(0).shard.data.labels, flipped);
    }

    #[test]
    fn ghost_of_attacker_keeps_profile() {
        let (mut c, _) = mk_client(34);
        c.attack = AttackProfile::SignFlip;
        let ghost = c.speculate();
        assert_eq!(ghost.attack, AttackProfile::SignFlip);
        assert!(ghost.attack_buf.is_empty() && ghost.sync_base().is_empty());
    }

    #[test]
    fn amplify_value_matches_eq1() {
        let v = amplify_value(2.0, 0.5, 7, ValueFnConfig::default());
        assert!((v - 2.0 * (1.007f64).powf(0.5)).abs() < 1e-12);
        let off = amplify_value(2.0, 0.5, 7, ValueFnConfig { use_acc_term: false });
        assert_eq!(off, 2.0);
    }
}
