//! # VAFL — Value-based Asynchronous Federated Learning
//!
//! A production-grade reproduction of *"A Novel Optimized Asynchronous
//! Federated Learning Framework"* (Zhou et al., 2021) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the asynchronous federated-learning coordinator:
//!   two round engines (the paper's barriered loop and a barrier-free
//!   event-driven engine with staleness-weighted on-arrival aggregation —
//!   see EXPERIMENTS.md §Engines), communication-value client selection
//!   (VAFL, Eq. 1–2),
//!   the paper's comparators (plain async FedAvg "AFL" and the EAFLM
//!   gradient gate, Eq. 3), a simulated heterogeneous edge fleet
//!   (Raspberry-Pi-class device models + LAN network simulator), metrics,
//!   config, and CLI.
//! * **L2/L1 (build-time Python)** — the client model (ResNet-lite fwd/bwd +
//!   SGD over a flat parameter vector) with Pallas compute kernels, lowered
//!   once to HLO text in `artifacts/` and executed from Rust through the
//!   PJRT C API ([`runtime`]).
//!
//! Python never runs on the training path: after `make artifacts`, the
//! `vafl` binary is self-contained.
//!
//! ## Quick start
//!
//! ```no_run
//! use vafl::config::ExperimentConfig;
//! use vafl::experiments;
//!
//! // Paper experiment b: 7 clients, IID data, VAFL policy.
//! let mut cfg = experiments::preset('b').expect("preset");
//! cfg.rounds = 20;
//! let outcome = experiments::run(&cfg).expect("run");
//! println!("final acc = {:.4}", outcome.final_accuracy);
//! ```
//!
//! See `examples/` for full drivers and `rust/benches/` for the harnesses
//! that regenerate every table and figure of the paper.

pub mod config;
pub mod control;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod experiments;
pub mod fleet;
pub mod metrics;
pub mod model;
pub mod netsim;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod util;

pub use config::{Algorithm, ExperimentConfig};
pub use experiments::{run, Outcome};

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
