//! `vafl` — launcher CLI for the VAFL asynchronous federated learning
//! framework.
//!
//! ```text
//! vafl run [--config FILE] [--algorithm afl|vafl|eaflm] [--preset a|b|c|d]
//!          [--engine barriered|barrier_free] [--engine-threads N]
//!          [--shards S] [--reconcile-every N] [--rounds N] [--seed N]
//!          [--compression dense|topk] [--k-fraction F]
//!          [--error-feedback true|false]
//!          [--down-mode dense|topk] [--down-k-fraction F]
//!          [--down-precision f32|f16|int8]
//!          [--robust-mode none|trimmed_mean|median] [--trim-fraction F]
//!          [--trust on|off] [--attack none|label_flip|sign_flip|scale|backdoor]
//!          [--attack-fraction F]
//!          [--control on|off|staleness,compression,rebalance]
//!          [--control-interval N] [--control-window N]
//!          [--trace-out FILE] [--metrics-out FILE]
//!          [--mock] [--out DIR] [--realtime SCALE]
//! vafl experiment --preset a|b|c|d [--rounds N] [--out DIR] [--mock]
//!     # one preset, all three algorithms, Table III rows + Fig. 4
//! vafl sweep [--rounds N] [--out DIR] [--mock]
//!     # all four presets x three algorithms: full Table III + Figs. 4-6
//! vafl fig3 [--out DIR]
//!     # dataset distribution tables (Fig. 3)
//! vafl info
//!     # artifact + environment report
//! ```
//!
//! Hand-rolled argument parsing (the offline crate set has no clap).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use vafl::config::{Algorithm, Backend, ExperimentConfig};
use vafl::data::stats::DistributionTable;
use vafl::data::synth::SynthConfig;
use vafl::data::partition;
use vafl::experiments::{self, figures, table3};
use vafl::metrics::csv::{write_client_acc_csv, write_control_csv, write_rounds_csv};
use vafl::model::ParamSpec;
use vafl::util::rng::Rng;

fn main() {
    vafl::util::logging::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal flag parser: `--key value` pairs plus boolean flags.
struct Flags {
    map: BTreeMap<String, String>,
}

impl Flags {
    const BOOL_FLAGS: [&'static str; 2] = ["mock", "quiet"];

    fn parse(args: &[String]) -> Result<Self> {
        let mut map = BTreeMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .with_context(|| format!("expected --flag, got {a:?}"))?;
            if Self::BOOL_FLAGS.contains(&key) {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let val = args
                    .get(i + 1)
                    .with_context(|| format!("--{key} needs a value"))?;
                map.insert(key.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(Flags { map })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.map.get(key).map(|s| s.as_str())
    }

    fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} {v:?}")))
            .transpose()
    }

    fn has(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = Flags::parse(&args[1..])?;
    if flags.has("quiet") {
        vafl::util::logging::set_level(vafl::util::logging::Level::Warn);
    }
    match cmd.as_str() {
        "run" => cmd_run(&flags),
        "experiment" => cmd_experiment(&flags),
        "sweep" => cmd_sweep(&flags),
        "fig3" => cmd_fig3(&flags),
        "info" => cmd_info(&flags),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (run|experiment|sweep|fig3|info|help)"),
    }
}

fn print_usage() {
    println!(
        "vafl — Value-based Asynchronous Federated Learning (paper reproduction)\n\n\
         USAGE:\n  vafl run        [--preset a|b|c|d] [--config FILE] [--algorithm afl|vafl|eaflm]\n\
         \x20                 [--engine barriered|barrier_free] [--engine-threads N] [--shards S]\n\
         \x20                 [--reconcile-every N] [--rounds N] [--seed N] [--mock]\n\
         \x20                 [--compression dense|topk] [--k-fraction F] [--error-feedback true|false]\n\
         \x20                 [--down-mode dense|topk] [--down-k-fraction F] [--down-precision f32|f16|int8]\n\
         \x20                 [--robust-mode none|trimmed_mean|median] [--trim-fraction F] [--trust on|off]\n\
         \x20                 [--attack none|label_flip|sign_flip|scale|backdoor] [--attack-fraction F]\n\
         \x20                 [--layer-k-fractions F1,F2,..] [--active-set N] [--edge-fanout N]\n\
         \x20                 [--compact-records] [--alpha-step F]\n\
         \x20                 [--control on|off|staleness,compression,rebalance]\n\
         \x20                 [--control-interval N] [--control-window N]\n\
         \x20                 [--trace-out FILE] [--metrics-out FILE]\n\
         \x20                 [--out DIR] [--realtime SCALE] [--quiet]\n\
         \x20 vafl experiment --preset a|b|c|d [--rounds N] [--out DIR] [--mock]\n\
         \x20 vafl sweep      [--rounds N] [--out DIR] [--mock]\n\
         \x20 vafl fig3       [--out DIR]\n\
         \x20 vafl info       [--artifacts DIR]\n"
    );
}

/// Assemble a config from --config / --preset / overrides.
fn config_from_flags(flags: &Flags) -> Result<ExperimentConfig> {
    let mut cfg = if let Some(path) = flags.get("config") {
        ExperimentConfig::from_toml_file(path)?
    } else if let Some(p) = flags.get("preset") {
        let c = p.chars().next().context("--preset needs a letter a-d")?;
        experiments::preset(c)?
    } else {
        experiments::preset('a')?
    };
    if let Some(a) = flags.get("algorithm") {
        cfg.algorithm = Algorithm::from_name(a)?;
    }
    if let Some(e) = flags.get("engine") {
        cfg.engine = vafl::config::EngineMode::from_name(e)?;
    }
    if let Some(t) = flags.get_usize("engine-threads")? {
        // --engine-threads N: threaded execution with N pool workers
        // (0 = auto-resolve from threads config / VAFL_THREADS / cores).
        cfg.engine_opts.threaded = true;
        cfg.engine_opts.workers = t;
    }
    if let Some(s) = flags.get_usize("shards")? {
        cfg.engine_opts.shards = s;
    }
    if let Some(r) = flags.get_usize("reconcile-every")? {
        cfg.engine_opts.reconcile_every = r;
    }
    if let Some(c) = flags.get("compression") {
        cfg.compression.mode = vafl::config::CompressionMode::from_name(c)?;
    }
    if let Some(f) = flags.get("k-fraction") {
        cfg.compression.k_fraction =
            f.parse::<f64>().with_context(|| format!("--k-fraction {f:?}"))?;
    }
    if let Some(l) = flags.get("layer-k-fractions") {
        cfg.compression.layer_k_fractions = vafl::config::parse_fraction_list(l)
            .with_context(|| format!("--layer-k-fractions {l:?}"))?;
    }
    if let Some(c) = flags.get("down-mode") {
        cfg.compression.down_mode = vafl::config::CompressionMode::from_name(c)?;
    }
    if let Some(f) = flags.get("down-k-fraction") {
        cfg.compression.down_k_fraction =
            f.parse::<f64>().with_context(|| format!("--down-k-fraction {f:?}"))?;
    }
    if let Some(p) = flags.get("down-precision") {
        cfg.compression.down_precision = Some(
            vafl::model::quant::Precision::from_name(p)
                .with_context(|| format!("--down-precision {p:?} (f32|f16|int8)"))?,
        );
    }
    if let Some(m) = flags.get("robust-mode") {
        cfg.robust.mode = vafl::config::RobustMode::from_name(m)?;
    }
    if let Some(f) = flags.get("trim-fraction") {
        cfg.robust.trim_fraction =
            f.parse::<f64>().with_context(|| format!("--trim-fraction {f:?}"))?;
    }
    if let Some(t) = flags.get("trust") {
        cfg.robust.trust = match t {
            "true" | "on" | "1" => true,
            "false" | "off" | "0" => false,
            other => bail!("--trust {other:?} (on|off)"),
        };
    }
    if let Some(a) = flags.get("attack") {
        cfg.attack.mode = vafl::config::AttackMode::from_name(a)?;
    }
    if let Some(f) = flags.get("attack-fraction") {
        cfg.attack.fraction =
            f.parse::<f64>().with_context(|| format!("--attack-fraction {f:?}"))?;
    }
    if let Some(a) = flags.get("active-set") {
        cfg.fleet.active_set =
            a.parse::<usize>().with_context(|| format!("--active-set {a:?}"))?;
    }
    if let Some(e) = flags.get_usize("edge-fanout")? {
        cfg.engine_opts.edge_fanout = e;
    }
    if flags.has("compact-records") {
        cfg.fleet.compact_records = true;
    }
    if let Some(s) = flags.get("alpha-step") {
        cfg.control.alpha_step =
            s.parse::<f64>().with_context(|| format!("--alpha-step {s:?}"))?;
    }
    if let Some(e) = flags.get("error-feedback") {
        cfg.compression.error_feedback = match e {
            "true" | "on" | "1" => true,
            "false" | "off" | "0" => false,
            other => bail!("--error-feedback {other:?} (true|false)"),
        };
    }
    if let Some(c) = flags.get("control") {
        // --control on|off enables/disables the whole plane; a comma
        // list enables exactly that controller subset.
        match c {
            "on" | "all" | "true" => cfg.control.enabled = true,
            "off" | "false" => cfg.control.enabled = false,
            list => {
                cfg.control.enabled = true;
                cfg.control.staleness = false;
                cfg.control.compression = false;
                cfg.control.rebalance = false;
                for part in list.split(',') {
                    match part.trim() {
                        "staleness" => cfg.control.staleness = true,
                        "compression" => cfg.control.compression = true,
                        "rebalance" => cfg.control.rebalance = true,
                        other => bail!(
                            "--control {other:?} (on|off|staleness,compression,rebalance)"
                        ),
                    }
                }
            }
        }
    }
    if let Some(i) = flags.get_usize("control-interval")? {
        cfg.control.interval = i;
    }
    if let Some(w) = flags.get_usize("control-window")? {
        cfg.control.window = w;
    }
    if let Some(r) = flags.get_usize("rounds")? {
        cfg.rounds = r;
    }
    if let Some(s) = flags.get_usize("seed")? {
        cfg.seed = s as u64;
    }
    if flags.has("mock") {
        cfg.backend = Backend::Mock;
    } else if let Some(dir) = flags.get("artifacts") {
        cfg.backend = Backend::Pjrt { artifact_dir: dir.to_string() };
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(flags: &Flags) -> Result<()> {
    let mut cfg = config_from_flags(flags)?;
    // `--realtime` replays the committed engine-event stream when one is
    // available; ask the engine to record it.
    if flags.get("realtime").is_some() {
        cfg.trace_events = true;
    }
    // Asking for either observability export arms the plane.
    let trace_out = flags.get("trace-out").map(str::to_string);
    let metrics_out = flags.get("metrics-out").map(str::to_string);
    if trace_out.is_some() || metrics_out.is_some() {
        cfg.obs.enabled = true;
    }
    println!(
        "running experiment {} / {} ({} clients, {:?}, {} rounds)",
        cfg.name,
        cfg.algorithm.name(),
        cfg.num_clients,
        cfg.partition,
        cfg.rounds
    );
    let out = experiments::run(&cfg)?;
    println!(
        "\nfinal acc = {:.4}  best acc = {:.4}  uploads = {}  bytes_up = {}  vtime = {:.1}s  comm->{:.0}% = {:?}",
        out.final_accuracy,
        out.best_accuracy,
        out.total_uploads,
        out.metrics.total_bytes_up(),
        out.total_vtime,
        cfg.target_acc * 100.0,
        out.comm_times_to_target
    );
    if cfg.control.enabled {
        println!("control decisions = {}", out.metrics.control_records.len());
    }
    if trace_out.is_some() || metrics_out.is_some() {
        let report = out
            .metrics
            .obs
            .as_ref()
            .context("observability was armed but the run produced no report")?;
        if let Some(path) = &trace_out {
            // Chrome trace-event JSON: load in Perfetto / chrome://tracing.
            std::fs::write(path, vafl::obs::chrome_trace_json(report).to_string_compact())?;
            println!(
                "wrote {path} ({} spans, {} dropped)",
                report.spans.len(),
                report.dropped
            );
        }
        if let Some(path) = &metrics_out {
            // Prometheus text exposition snapshot.
            std::fs::write(path, vafl::obs::prometheus_text(report))?;
            println!("wrote {path}");
        }
    }
    if let Some(dir) = flags.get("out") {
        let base = format!("{dir}/{}_{}", cfg.name, cfg.algorithm.name());
        write_rounds_csv(&out.metrics, format!("{base}_rounds.csv"))?;
        write_client_acc_csv(&out.metrics, format!("{base}_clients.csv"))?;
        std::fs::write(format!("{base}.json"), out.metrics.to_json().to_string_pretty())?;
        println!("wrote {base}_rounds.csv, {base}_clients.csv, {base}.json");
        if !out.metrics.control_records.is_empty() {
            write_control_csv(&out.metrics, format!("{base}_control.csv"))?;
            println!("wrote {base}_control.csv");
        }
    }
    if let Some(scale) = flags.get("realtime") {
        let scale: f64 = scale.parse().context("--realtime SCALE")?;
        replay_realtime(&out.metrics, scale);
    }
    Ok(())
}

/// Replay the recorded virtual-time trace with wall-clock pacing: the
/// committed engine-event stream when one was recorded (barrier-free
/// engine under `trace_events` — in-flight uploads, buffer occupancy,
/// live controller decisions), else the per-round record stream.
fn replay_realtime(metrics: &vafl::metrics::RunMetrics, scale: f64) {
    println!("\nrealtime replay (x{scale} wall seconds per virtual second):");
    if !metrics.event_trace.is_empty() {
        println!("({} committed engine events)", metrics.event_trace.len());
        vafl::sim::Trace::replay_points(&metrics.event_trace, scale, |t, label| {
            println!("[vt {t:>8.2}s] {label}")
        });
        return;
    }
    let mut trace = vafl::sim::Trace::default();
    for r in &metrics.records {
        trace.record(
            r.vtime,
            format!(
                "round {:>3}  acc={}  uploads={}",
                r.round,
                if r.global_acc.is_finite() {
                    format!("{:.4}", r.global_acc)
                } else {
                    "  -  ".into()
                },
                r.uploads
            ),
        );
    }
    trace.replay(scale, |t, label| println!("[vt {t:>8.1}s] {label}"));
}

fn cmd_experiment(flags: &Flags) -> Result<()> {
    let base = config_from_flags(flags)?;
    let outs = experiments::run_all_algorithms(&base)?;
    let runs: Vec<_> = outs.iter().map(|o| o.metrics.clone()).collect();
    println!("\n{}", figures::fig4(&base.name, &runs));
    let rows = table3::rows_for_experiment(&runs);
    println!("{}", table3::render(&rows));
    if let Some(dir) = flags.get("out") {
        persist_runs(dir, &runs)?;
        std::fs::write(
            format!("{dir}/table3_{}.json", base.name),
            table3::to_json(&rows).to_string_pretty(),
        )?;
    }
    Ok(())
}

fn cmd_sweep(flags: &Flags) -> Result<()> {
    let mut all_rows = Vec::new();
    let mut vafl_runs = Vec::new();
    for which in ['a', 'b', 'c', 'd'] {
        let mut base = experiments::preset(which)?;
        if let Some(r) = flags.get_usize("rounds")? {
            base.rounds = r;
        }
        if let Some(s) = flags.get_usize("seed")? {
            base.seed = s as u64;
        }
        if flags.has("mock") {
            base.backend = Backend::Mock;
        }
        let outs = experiments::run_all_algorithms(&base)?;
        let runs: Vec<_> = outs.iter().map(|o| o.metrics.clone()).collect();
        println!("\n{}", figures::fig4(&base.name, &runs));
        if let Some(v) = runs.iter().find(|m| m.algorithm == "vafl") {
            println!("{}", figures::fig5(&base.name, v));
            vafl_runs.push(v.clone());
        }
        all_rows.extend(table3::rows_for_experiment(&runs));
        if let Some(dir) = flags.get("out") {
            persist_runs(dir, &runs)?;
        }
    }
    println!("{}", figures::fig6(&vafl_runs));
    println!("Table III\n{}", table3::render(&all_rows));
    let (red, mccr) = table3::headline(&all_rows, "vafl");
    println!(
        "headline: VAFL reduces communications by {:.2}% vs AFL, mean CCR {:.2}%",
        red * 100.0,
        mccr * 100.0
    );
    if let Some(dir) = flags.get("out") {
        std::fs::write(
            format!("{dir}/table3.json"),
            table3::to_json(&all_rows).to_string_pretty(),
        )?;
        println!("wrote {dir}/table3.json");
    }
    Ok(())
}

fn persist_runs(dir: &str, runs: &[vafl::metrics::RunMetrics]) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    for m in runs {
        let base = format!("{dir}/{}_{}", m.experiment, m.algorithm);
        write_rounds_csv(m, format!("{base}_rounds.csv"))?;
        write_client_acc_csv(m, format!("{base}_clients.csv"))?;
        std::fs::write(format!("{base}.json"), m.to_json().to_string_pretty())?;
    }
    Ok(())
}

fn cmd_fig3(flags: &Flags) -> Result<()> {
    let mut tables = Vec::new();
    for which in ['a', 'b', 'c', 'd'] {
        let cfg = experiments::preset(which)?;
        let synth = SynthConfig { pixel_noise: cfg.pixel_noise, ..Default::default() };
        let (shards, _) = partition(
            cfg.partition,
            cfg.num_clients,
            cfg.samples_per_client,
            cfg.test_samples,
            &synth,
            &Rng::new(cfg.seed),
        );
        tables.push((cfg.name.clone(), DistributionTable::from_shards(&shards)));
    }
    let text = figures::fig3(&tables);
    println!("{text}");
    if let Some(dir) = flags.get("out") {
        std::fs::create_dir_all(dir)?;
        std::fs::write(format!("{dir}/fig3.txt"), &text)?;
        for (name, t) in &tables {
            std::fs::write(
                format!("{dir}/fig3_{name}.json"),
                t.to_json().to_string_pretty(),
            )?;
        }
    }
    Ok(())
}

fn cmd_info(flags: &Flags) -> Result<()> {
    let dir = flags.get("artifacts").unwrap_or("artifacts");
    println!("vafl {} — three-layer rust+jax+pallas build", env!("CARGO_PKG_VERSION"));
    match ParamSpec::load(dir) {
        Ok(spec) => {
            println!("artifacts: {}", spec.dir.display());
            println!("  model         : resnet_lite ({} params)", spec.param_count);
            println!("  pallas mode   : {}", spec.pallas_mode);
            println!("  batch/eval    : {}/{}", spec.batch_size, spec.eval_batch);
            println!("  train flops   : {}", spec.train_step_flops);
            println!("  layers        : {}", spec.layers.len());
            for l in &spec.layers {
                println!("    {:<10} {:?} @ {}", l.name, l.shape, l.offset);
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    Ok(())
}
