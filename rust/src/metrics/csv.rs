//! CSV writers for run metrics (round curves) and summary tables, so the
//! figures can be re-plotted with any external tool.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use super::RunMetrics;

/// Write the per-round curve: one row per round.
pub fn write_rounds_csv(m: &RunMetrics, path: impl AsRef<Path>) -> Result<()> {
    let mut out = String::new();
    out.push_str("round,vtime,acc,loss,train_loss,uploads,cum_uploads,threshold,idle_seconds,bytes_up,bytes_down,reports,in_flight,stale_mean,stale_max,shard,spec_committed,spec_replayed,bytes_up_ctrl,bytes_down_ctrl,quarantined,trust_mean,retransmits,frames_lost,frames_corrupt,dup_suppressed,resyncs,recoveries\n");
    for r in &m.records {
        out.push_str(&format!(
            "{},{:.6},{},{},{},{},{},{},{:.6},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{},{}\n",
            r.round,
            r.vtime,
            fmt(r.global_acc),
            fmt(r.global_loss),
            fmt(r.train_loss),
            r.uploads,
            r.cum_uploads,
            fmt(r.threshold),
            r.idle_seconds,
            r.bytes_up,
            r.bytes_down,
            r.reports,
            r.in_flight,
            fmt(r.staleness_mean()),
            r.staleness_max(),
            r.shard,
            r.spec_committed,
            r.spec_replayed,
            // Later columns appended after the originals so existing
            // column indices (external plotting scripts) stay stable.
            r.bytes_up_ctrl,
            r.bytes_down_ctrl,
            r.quarantined,
            fmt(r.trust_mean),
            r.faults.retransmits,
            r.faults.frames_lost,
            r.faults.frames_corrupt,
            r.faults.dup_suppressed,
            r.faults.resyncs,
            r.faults.recoveries,
        ));
    }
    write_atomic(path.as_ref(), out.as_bytes())
}

/// Write per-client accuracy curves (Fig. 5): round, then one column per
/// client.
pub fn write_client_acc_csv(m: &RunMetrics, path: impl AsRef<Path>) -> Result<()> {
    let n = m.records.first().map_or(0, |r| r.client_accs.len());
    let mut out = String::from("round");
    for c in 0..n {
        out.push_str(&format!(",client{}", c + 1));
    }
    out.push('\n');
    for r in &m.records {
        out.push_str(&r.round.to_string());
        for &a in &r.client_accs {
            out.push(',');
            out.push_str(&fmt(a));
        }
        out.push('\n');
    }
    write_atomic(path.as_ref(), out.as_bytes())
}

/// Write the adaptive control plane's decision log: one row per applied
/// decision, in commit order (`tools/check.sh` diffs this stream for
/// drift via the adaptive golden snapshot).
pub fn write_control_csv(m: &RunMetrics, path: impl AsRef<Path>) -> Result<()> {
    let mut out = String::from("round,vtime,controller,knob,old,new,signal,client\n");
    for c in &m.control_records {
        out.push_str(&format!(
            "{},{:.6},{},{},{},{},{},{}\n",
            c.round,
            c.vtime,
            c.controller,
            c.knob,
            fmt(c.old),
            fmt(c.new),
            fmt(c.signal),
            c.client.map(|i| i.to_string()).unwrap_or_default(),
        ));
    }
    write_atomic(path.as_ref(), out.as_bytes())
}

fn fmt(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        String::new() // empty cell for skipped evals
    }
}

fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming to {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{FaultCounters, RoundRecord, RunMetrics};

    fn sample() -> RunMetrics {
        let mut m = RunMetrics::new("a", "vafl", 0.94);
        m.push(RoundRecord {
            round: 1,
            vtime: 1.25,
            global_acc: 0.5,
            global_loss: 2.0,
            train_loss: 2.2,
            uploads: 2,
            cum_uploads: 2,
            bytes_up: 77000,
            bytes_down: 78000,
            bytes_up_ctrl: 136,
            bytes_down_ctrl: 128,
            threshold: 0.1,
            values: vec![0.2, 0.05],
            selected: vec![true, false],
            client_accs: vec![0.5, 0.4],
            idle_seconds: 0.3,
            reports: 2,
            in_flight: 1,
            upload_staleness: vec![0, 3],
            shard: 1,
            spec_committed: 4,
            spec_replayed: 1,
            quarantined: 2,
            trust_mean: f64::NAN,
            faults: FaultCounters {
                retransmits: 7,
                frames_lost: 1,
                frames_corrupt: 0,
                dup_suppressed: 2,
                resyncs: 3,
                recoveries: 1,
            },
        });
        m
    }

    #[test]
    fn rounds_csv_round_trips_fields() {
        let dir = std::env::temp_dir().join(format!("vafl-csv-{}", std::process::id()));
        let path = dir.join("rounds.csv");
        write_rounds_csv(&sample(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        // The full header is a compatibility contract (append-only): the
        // registry migration must never rename or reorder a column.
        assert_eq!(
            lines[0],
            "round,vtime,acc,loss,train_loss,uploads,cum_uploads,threshold,idle_seconds,\
             bytes_up,bytes_down,reports,in_flight,stale_mean,stale_max,shard,\
             spec_committed,spec_replayed,bytes_up_ctrl,bytes_down_ctrl,quarantined,\
             trust_mean,retransmits,frames_lost,frames_corrupt,dup_suppressed,resyncs,\
             recoveries"
        );
        assert!(lines[1].starts_with("1,1.250000,0.500000"));
        // NaN trust_mean formats as an empty cell; the fault counters
        // follow it.
        assert!(lines[1].ends_with("2,1,1.500000,3,1,4,1,136,128,2,,7,1,0,2,3,1"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn control_csv_rows_match_decisions() {
        let mut m = sample();
        m.control_records.push(crate::metrics::ControlRecord {
            round: 4,
            vtime: 4.25,
            controller: "staleness".into(),
            knob: "buffer_k".into(),
            old: 2.0,
            new: 3.0,
            signal: 3.5,
            client: None,
        });
        m.control_records.push(crate::metrics::ControlRecord {
            round: 6,
            vtime: 7.5,
            controller: "rebalance".into(),
            knob: "client_shard".into(),
            old: 1.0,
            new: 0.0,
            signal: 2.0,
            client: Some(3),
        });
        let dir = std::env::temp_dir().join(format!("vafl-csv3-{}", std::process::id()));
        let path = dir.join("control.csv");
        write_control_csv(&m, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0], "round,vtime,controller,knob,old,new,signal,client");
        assert!(lines[1].starts_with("4,4.250000,staleness,buffer_k,2.000000,3.000000,3.500000,"));
        assert!(lines[1].ends_with(','), "no-client rows end with an empty cell");
        assert!(lines[2].ends_with(",3"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn client_csv_has_one_column_per_client() {
        let dir = std::env::temp_dir().join(format!("vafl-csv2-{}", std::process::id()));
        let path = dir.join("clients.csv");
        write_client_acc_csv(&sample(), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,client1,client2\n"));
        assert!(text.contains("1,0.500000,0.400000"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
