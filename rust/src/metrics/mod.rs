//! Metrics: everything the paper's evaluation section reports — accuracy
//! curves (Figs. 4–6), communication counts and compression rate (Eq. 4,
//! Table III) — plus operational telemetry (bytes on the wire, straggler
//! idle time, virtual wall-clock).

pub mod csv;

use crate::util::codec::{Dec, Enc};
use crate::util::json::{obj, Value};
use anyhow::Result;

/// Per-record fault/recovery telemetry of the deterministic
/// fault-injection layer (`netsim::FaultPlan`). All zero — and absent
/// from every code path — while `faults.enabled = false`, which keeps
/// fault-free runs bitwise identical to pre-fault seeds.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Upload frames rescheduled after a loss/corruption verdict
    /// (each retransmission re-charges wire bytes).
    pub retransmits: u64,
    /// Upload/broadcast frames the fault plan dropped outright.
    pub frames_lost: u64,
    /// Frames delivered with a failed integrity check (length/checksum/
    /// sequence header mismatch) — handled exactly like a loss, but
    /// counted separately so corruption grids read directly.
    pub frames_corrupt: u64,
    /// Duplicate deliveries suppressed by the per-client monotone
    /// sequence number (bytes charged, effects skipped).
    pub dup_suppressed: u64,
    /// Downlink resyncs: a lost/corrupt sparse broadcast (or a base-
    /// version mismatch) NACKed into a forced dense re-sync.
    pub resyncs: u64,
    /// Client crash/restart recoveries (park-on-crash + rehydrate).
    pub recoveries: u64,
}

impl FaultCounters {
    /// True if any counter fired (CSV/JSON writers and tests).
    pub fn any(&self) -> bool {
        *self != FaultCounters::default()
    }

    /// Fold another window's counters into this one.
    pub fn add(&mut self, other: &FaultCounters) {
        self.retransmits += other.retransmits;
        self.frames_lost += other.frames_lost;
        self.frames_corrupt += other.frames_corrupt;
        self.dup_suppressed += other.dup_suppressed;
        self.resyncs += other.resyncs;
        self.recoveries += other.recoveries;
    }

    pub fn save(&self, enc: &mut Enc) {
        enc.u64(self.retransmits);
        enc.u64(self.frames_lost);
        enc.u64(self.frames_corrupt);
        enc.u64(self.dup_suppressed);
        enc.u64(self.resyncs);
        enc.u64(self.recoveries);
    }

    pub fn load(dec: &mut Dec) -> Result<Self> {
        Ok(FaultCounters {
            retransmits: dec.u64()?,
            frames_lost: dec.u64()?,
            frames_corrupt: dec.u64()?,
            dup_suppressed: dec.u64()?,
            resyncs: dec.u64()?,
            recoveries: dec.u64()?,
        })
    }
}

/// One communication round's record.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    pub round: usize,
    /// Virtual time when the round's aggregation completed.
    pub vtime: f64,
    /// Global-model accuracy on the server test set (NaN on skipped evals).
    pub global_acc: f64,
    pub global_loss: f64,
    /// Mean of client training losses this round.
    pub train_loss: f64,
    /// Model uploads this round (the gated, counted quantity).
    pub uploads: usize,
    /// Cumulative model uploads.
    pub cum_uploads: usize,
    /// Uplink wire bytes of this round / window. Barrier-free engine:
    /// model-upload bytes count when the upload *arrives* (rides on the
    /// `Upload` event), so uploads still in flight when the run ends are
    /// excluded — see `coordinator::server::EngineEvent::Upload`.
    pub bytes_up: u64,
    pub bytes_down: u64,
    /// Control-frame share of `bytes_up`: the fixed-size V reports
    /// (`Message::ValueReport`), which no compression mode shrinks. The
    /// payload share is `bytes_up - bytes_up_ctrl`. Kept separate so
    /// compression ratios compare payloads, not payloads diluted by
    /// protocol overhead (`bytes_up` stays the total for CSV/JSON/golden
    /// compatibility).
    pub bytes_up_ctrl: u64,
    /// Control-frame share of `bytes_down`: the fixed-size upload
    /// requests (`Message::UploadRequest`). The broadcast payload share
    /// is `bytes_down - bytes_down_ctrl`.
    pub bytes_down_ctrl: u64,
    /// Policy threshold (mean-V for VAFL, Eq. 3 RHS for EAFLM).
    pub threshold: f64,
    /// Per-client effective values the policy used.
    pub values: Vec<f64>,
    /// Per-client upload decision.
    pub selected: Vec<bool>,
    /// Per-client probe accuracies (Fig. 5).
    pub client_accs: Vec<f64>,
    /// Straggler idle time. Barriered: sum over clients of
    /// (round end - own report arrival). Barrier-free: sum over the
    /// flushed buffer of (flush time - upload arrival) — time an upload
    /// sat waiting for the buffer to fill.
    pub idle_seconds: f64,
    /// V reports processed this round / aggregation window (the gated
    /// upload set is always a subset of these).
    pub reports: usize,
    /// Model uploads still in flight when this record was cut (always 0
    /// for the barriered engine — the barrier drains them).
    pub in_flight: usize,
    /// Staleness (global versions behind) of each aggregated upload, in
    /// aggregation order. Barriered: rounds since each selected client
    /// last synced.
    pub upload_staleness: Vec<usize>,
    /// Aggregator shard that flushed this record (always 0 for the
    /// barriered and unsharded barrier-free engines).
    pub shard: usize,
    /// Speculative local rounds committed as-is in this record's window
    /// (threaded barrier-free engine; 0 on serial runs).
    pub spec_committed: usize,
    /// Speculative local rounds whose forked state was superseded and
    /// were replayed serially at the commit point (threaded engine; 0 on
    /// serial runs).
    pub spec_replayed: usize,
    /// Aggregated uploads in this record whose trust multiplier was below
    /// 1.0 when the weights were built (soft-quarantined clients). Always
    /// 0 while trust scoring is off.
    pub quarantined: usize,
    /// Mean per-client trust score at flush time. NaN while trust scoring
    /// is off — no signal, not perfect trust.
    pub trust_mean: f64,
    /// Fault/recovery events of this record's window (all zero while
    /// `faults.enabled = false`).
    pub faults: FaultCounters,
}

impl RoundRecord {
    /// Model-payload share of the uplink bytes (total minus the fixed
    /// V-report control frames) — the quantity sparse uploads shrink.
    pub fn bytes_up_payload(&self) -> u64 {
        self.bytes_up.saturating_sub(self.bytes_up_ctrl)
    }

    /// Broadcast-payload share of the downlink bytes (total minus the
    /// fixed upload-request control frames) — the quantity sparse
    /// broadcasts shrink.
    pub fn bytes_down_payload(&self) -> u64 {
        self.bytes_down.saturating_sub(self.bytes_down_ctrl)
    }

    /// Mean staleness of this record's aggregated uploads (NaN if none).
    pub fn staleness_mean(&self) -> f64 {
        if self.upload_staleness.is_empty() {
            return f64::NAN;
        }
        self.upload_staleness.iter().sum::<usize>() as f64 / self.upload_staleness.len() as f64
    }

    /// Max staleness of this record's aggregated uploads (0 if none).
    pub fn staleness_max(&self) -> usize {
        self.upload_staleness.iter().copied().max().unwrap_or(0)
    }

    /// Serialize for a checkpoint (every field, floats by bits — a
    /// restored record stream must stay bitwise identical).
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.round);
        enc.f64(self.vtime);
        enc.f64(self.global_acc);
        enc.f64(self.global_loss);
        enc.f64(self.train_loss);
        enc.usize(self.uploads);
        enc.usize(self.cum_uploads);
        enc.u64(self.bytes_up);
        enc.u64(self.bytes_down);
        enc.u64(self.bytes_up_ctrl);
        enc.u64(self.bytes_down_ctrl);
        enc.f64(self.threshold);
        enc.f64s(&self.values);
        enc.bools(&self.selected);
        enc.f64s(&self.client_accs);
        enc.f64(self.idle_seconds);
        enc.usize(self.reports);
        enc.usize(self.in_flight);
        enc.usizes(&self.upload_staleness);
        enc.usize(self.shard);
        enc.usize(self.spec_committed);
        enc.usize(self.spec_replayed);
        enc.usize(self.quarantined);
        enc.f64(self.trust_mean);
        self.faults.save(enc);
    }

    /// Decode a record written by [`RoundRecord::save`].
    pub fn load(dec: &mut Dec) -> Result<Self> {
        Ok(RoundRecord {
            round: dec.usize()?,
            vtime: dec.f64()?,
            global_acc: dec.f64()?,
            global_loss: dec.f64()?,
            train_loss: dec.f64()?,
            uploads: dec.usize()?,
            cum_uploads: dec.usize()?,
            bytes_up: dec.u64()?,
            bytes_down: dec.u64()?,
            bytes_up_ctrl: dec.u64()?,
            bytes_down_ctrl: dec.u64()?,
            threshold: dec.f64()?,
            values: dec.f64s()?,
            selected: dec.bools()?,
            client_accs: dec.f64s()?,
            idle_seconds: dec.f64()?,
            reports: dec.usize()?,
            in_flight: dec.usize()?,
            upload_staleness: dec.usizes()?,
            shard: dec.usize()?,
            spec_committed: dec.usize()?,
            spec_replayed: dec.usize()?,
            quarantined: dec.usize()?,
            trust_mean: dec.f64()?,
            faults: FaultCounters::load(dec)?,
        })
    }
}

/// One applied decision of the adaptive control plane (`control`
/// module): which controller moved which knob, from what to what, and
/// the window statistic that triggered it. Streamed alongside the round
/// records (CSV via [`csv::write_control_csv`], JSON under `"control"`).
#[derive(Debug, Clone)]
pub struct ControlRecord {
    /// Flush / round index after which the decision took effect.
    pub round: usize,
    /// Virtual time of the decision.
    pub vtime: f64,
    /// Controller that fired: "staleness" | "compression" | "rebalance".
    pub controller: String,
    /// Knob moved: "buffer_k" | "alpha0" | "k_fraction" |
    /// "down_k_fraction" | "client_shard".
    pub knob: String,
    /// Old and new knob values (shard ids for migrations).
    pub old: f64,
    pub new: f64,
    /// The triggering window statistic (mean staleness, residual ratio,
    /// or flush-rate skew).
    pub signal: f64,
    /// Migrated client (rebalance decisions only).
    pub client: Option<usize>,
}

impl ControlRecord {
    /// Serialize for a checkpoint.
    pub fn save(&self, enc: &mut Enc) {
        enc.usize(self.round);
        enc.f64(self.vtime);
        enc.str(&self.controller);
        enc.str(&self.knob);
        enc.f64(self.old);
        enc.f64(self.new);
        enc.f64(self.signal);
        match self.client {
            Some(c) => {
                enc.bool(true);
                enc.usize(c);
            }
            None => enc.bool(false),
        }
    }

    /// Decode a record written by [`ControlRecord::save`].
    pub fn load(dec: &mut Dec) -> Result<Self> {
        Ok(ControlRecord {
            round: dec.usize()?,
            vtime: dec.f64()?,
            controller: dec.str()?,
            knob: dec.str()?,
            old: dec.f64()?,
            new: dec.f64()?,
            signal: dec.f64()?,
            client: if dec.bool()? { Some(dec.usize()?) } else { None },
        })
    }
}

/// A full run's metrics.
#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub experiment: String,
    pub algorithm: String,
    pub target_acc: f64,
    pub records: Vec<RoundRecord>,
    /// Simulation events the engine committed (barrier-free runs; the
    /// denominator-free throughput measure — events/sec in the bench).
    /// Identical between serial and threaded execution.
    pub engine_events: usize,
    /// Per-decision log of the adaptive control plane, in commit order
    /// (empty while `control.enabled = false`). Identical between serial
    /// and threaded execution.
    pub control_records: Vec<ControlRecord>,
    /// Committed engine-event trace `(vtime, label)` for the realtime
    /// driver — recorded only under `trace_events` (barrier-free engine).
    pub event_trace: Vec<(f64, String)>,
    /// Fleet lifecycle counters (lifetime totals over the server's fleet;
    /// see `crate::fleet`): parked-record hydrations, active→parked
    /// demotions, and the high-water mark of simultaneously hydrated
    /// clients — the resident-memory driver at fleet scale.
    pub fleet_hydrations: u64,
    pub fleet_parks: u64,
    pub peak_active: usize,
    /// Link transfers that hit the retry cap and were force-delivered by
    /// the legacy lossy-link model (`LinkProfile::max_attempts`) — the
    /// previously silent 5th-attempt success, now counted. Distinct from
    /// `FaultCounters::retransmits`, which belongs to the fault plan.
    pub link_capped: u64,
    /// Observability report (spans + unified metric registry) — `Some`
    /// only when `obs.enabled` armed the tracer; exported through
    /// `obs::chrome_trace_json` / `obs::prometheus_text` and the `"obs"`
    /// entry of `to_json`.
    pub obs: Option<crate::obs::ObsReport>,
}

impl RunMetrics {
    pub fn new(experiment: &str, algorithm: &str, target_acc: f64) -> Self {
        RunMetrics {
            experiment: experiment.to_string(),
            algorithm: algorithm.to_string(),
            target_acc,
            records: Vec::new(),
            engine_events: 0,
            control_records: Vec::new(),
            event_trace: Vec::new(),
            fleet_hydrations: 0,
            fleet_parks: 0,
            peak_active: 0,
            link_capped: 0,
            obs: None,
        }
    }

    /// Whole-run fault totals (all zero for fault-free runs).
    pub fn fault_totals(&self) -> FaultCounters {
        let mut total = FaultCounters::default();
        for r in &self.records {
            total.add(&r.faults);
        }
        total
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    /// Cumulative model uploads when the global accuracy first reached the
    /// target — the paper's "communication times ... to achieve 94 % Acc"
    /// (Table III). `None` if the target was never reached.
    pub fn comm_times_to_target(&self) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.global_acc >= self.target_acc)
            .map(|r| r.cum_uploads)
    }

    /// Round index where the target accuracy was first reached.
    pub fn rounds_to_target(&self) -> Option<usize> {
        self.records
            .iter()
            .find(|r| r.global_acc >= self.target_acc)
            .map(|r| r.round)
    }

    /// Virtual time at which the target accuracy was first reached — the
    /// wall-clock-to-accuracy metric the engine comparison reports.
    pub fn vtime_to_target(&self) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.global_acc >= self.target_acc)
            .map(|r| r.vtime)
    }

    /// Histogram of upload staleness across the whole run:
    /// `map[tau] = number of aggregated uploads that were tau versions
    /// stale`. Empty for runs that recorded no staleness (e.g. seeds
    /// predating the field).
    pub fn staleness_histogram(&self) -> std::collections::BTreeMap<usize, usize> {
        let mut hist = std::collections::BTreeMap::new();
        for r in &self.records {
            for &tau in &r.upload_staleness {
                *hist.entry(tau).or_insert(0) += 1;
            }
        }
        hist
    }

    /// Total reports processed across the run.
    pub fn total_reports(&self) -> usize {
        self.records.iter().map(|r| r.reports).sum()
    }

    /// Total uplink wire bytes (reports + model uploads) across the run
    /// — the quantity the sparse top-k compression mode shrinks.
    pub fn total_bytes_up(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_up).sum()
    }

    /// Total downlink wire bytes (requests + broadcasts) across the run.
    pub fn total_bytes_down(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_down).sum()
    }

    /// Total uplink *payload* bytes (model uploads only, V-report control
    /// frames excluded) — the numerator/denominator Eq. 4 byte ratios
    /// should use, so a compression mode is not graded on protocol
    /// overhead it cannot touch.
    pub fn total_bytes_up_payload(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_up_payload()).sum()
    }

    /// Total downlink *payload* bytes (model broadcasts only,
    /// upload-request control frames excluded).
    pub fn total_bytes_down_payload(&self) -> u64 {
        self.records.iter().map(|r| r.bytes_down_payload()).sum()
    }

    /// Cumulative uplink bytes when the target accuracy was first
    /// reached — the byte-level companion of
    /// [`RunMetrics::comm_times_to_target`] for Table III–style
    /// comparisons across compression modes. `None` if never reached.
    pub fn bytes_up_to_target(&self) -> Option<u64> {
        let mut cum = 0u64;
        for r in &self.records {
            cum += r.bytes_up;
            if r.global_acc >= self.target_acc {
                return Some(cum);
            }
        }
        None
    }

    /// Flush counts per aggregator shard: `map[shard] = flushes`. A
    /// single zero entry for unsharded / barriered runs.
    pub fn per_shard_flushes(&self) -> std::collections::BTreeMap<usize, usize> {
        let mut map = std::collections::BTreeMap::new();
        for r in &self.records {
            *map.entry(r.shard).or_insert(0) += 1;
        }
        map
    }

    /// Total speculative local rounds `(committed, replayed)` across the
    /// run. `(0, 0)` on serial runs.
    pub fn speculation_totals(&self) -> (usize, usize) {
        self.records.iter().fold((0, 0), |(c, p), r| {
            (c + r.spec_committed, p + r.spec_replayed)
        })
    }

    /// Fraction of speculative local rounds committed without a replay
    /// (NaN when the run had no speculation, i.e. the serial engine).
    pub fn speculation_hit_rate(&self) -> f64 {
        let (committed, replayed) = self.speculation_totals();
        let total = committed + replayed;
        if total == 0 {
            return f64::NAN;
        }
        committed as f64 / total as f64
    }

    /// Highest accuracy seen (paper: "Acc is the highest Acc rate").
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.global_acc)
            .filter(|a| a.is_finite())
            .fold(0.0, f64::max)
    }

    /// Final-round accuracy (last finite eval).
    pub fn final_accuracy(&self) -> f64 {
        self.records
            .iter()
            .rev()
            .map(|r| r.global_acc)
            .find(|a| a.is_finite())
            .unwrap_or(f64::NAN)
    }

    pub fn total_uploads(&self) -> usize {
        self.records.last().map_or(0, |r| r.cum_uploads)
    }

    pub fn total_vtime(&self) -> f64 {
        self.records.last().map_or(0.0, |r| r.vtime)
    }

    pub fn total_idle(&self) -> f64 {
        self.records.iter().map(|r| r.idle_seconds).sum()
    }

    /// Accuracy curve as (round, acc) pairs, skipping non-eval rounds.
    pub fn acc_curve(&self) -> Vec<(usize, f64)> {
        self.records
            .iter()
            .filter(|r| r.global_acc.is_finite())
            .map(|r| (r.round, r.global_acc))
            .collect()
    }

    /// Per-client accuracy curves (Fig. 5): `curves[c]` = Vec<(round, acc)>.
    pub fn client_acc_curves(&self) -> Vec<Vec<(usize, f64)>> {
        let n = self.records.first().map_or(0, |r| r.client_accs.len());
        let mut out = vec![Vec::new(); n];
        for r in &self.records {
            for (c, &a) in r.client_accs.iter().enumerate() {
                out[c].push((r.round, a));
            }
        }
        out
    }

    /// JSON export of the whole run.
    pub fn to_json(&self) -> Value {
        let (spec_committed, spec_replayed) = self.speculation_totals();
        let totals = self.fault_totals();
        obj(vec![
            ("experiment", Value::from(self.experiment.as_str())),
            ("algorithm", Value::from(self.algorithm.as_str())),
            ("target_acc", Value::from(self.target_acc)),
            (
                "comm_times_to_target",
                self.comm_times_to_target()
                    .map(Value::from)
                    .unwrap_or(Value::Null),
            ),
            ("best_accuracy", Value::from(self.best_accuracy())),
            ("total_uploads", Value::from(self.total_uploads())),
            ("total_bytes_up", Value::from(self.total_bytes_up() as usize)),
            ("total_bytes_down", Value::from(self.total_bytes_down() as usize)),
            (
                "total_bytes_up_payload",
                Value::from(self.total_bytes_up_payload() as usize),
            ),
            (
                "total_bytes_down_payload",
                Value::from(self.total_bytes_down_payload() as usize),
            ),
            (
                "bytes_up_to_target",
                self.bytes_up_to_target()
                    .map(|b| Value::from(b as usize))
                    .unwrap_or(Value::Null),
            ),
            ("total_vtime", Value::from(self.total_vtime())),
            ("engine_events", Value::from(self.engine_events)),
            ("spec_committed", Value::from(spec_committed)),
            ("spec_replayed", Value::from(spec_replayed)),
            ("fleet_hydrations", Value::from(self.fleet_hydrations as usize)),
            ("fleet_parks", Value::from(self.fleet_parks as usize)),
            ("peak_active", Value::from(self.peak_active)),
            ("link_capped", Value::from(self.link_capped as usize)),
            (
                "obs",
                self.obs
                    .as_ref()
                    .map(crate::obs::report_json)
                    .unwrap_or(Value::Null),
            ),
            ("retransmits", Value::from(totals.retransmits as usize)),
            ("frames_lost", Value::from(totals.frames_lost as usize)),
            ("frames_corrupt", Value::from(totals.frames_corrupt as usize)),
            ("dup_suppressed", Value::from(totals.dup_suppressed as usize)),
            ("resyncs", Value::from(totals.resyncs as usize)),
            ("recoveries", Value::from(totals.recoveries as usize)),
            (
                "control",
                Value::Arr(
                    self.control_records
                        .iter()
                        .map(|c| {
                            obj(vec![
                                ("round", Value::from(c.round)),
                                ("vtime", Value::from(c.vtime)),
                                ("controller", Value::from(c.controller.as_str())),
                                ("knob", Value::from(c.knob.as_str())),
                                ("old", Value::from(c.old)),
                                ("new", Value::from(c.new)),
                                ("signal", finite_or_null(c.signal)),
                                (
                                    "client",
                                    c.client.map(Value::from).unwrap_or(Value::Null),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "rounds",
                Value::Arr(
                    self.records
                        .iter()
                        .map(|r| {
                            obj(vec![
                                ("round", Value::from(r.round)),
                                ("vtime", Value::from(r.vtime)),
                                ("acc", finite_or_null(r.global_acc)),
                                ("loss", finite_or_null(r.global_loss)),
                                ("train_loss", finite_or_null(r.train_loss)),
                                ("uploads", Value::from(r.uploads)),
                                ("cum_uploads", Value::from(r.cum_uploads)),
                                ("reports", Value::from(r.reports)),
                                ("in_flight", Value::from(r.in_flight)),
                                ("stale_max", Value::from(r.staleness_max())),
                                ("shard", Value::from(r.shard)),
                                ("spec_committed", Value::from(r.spec_committed)),
                                ("spec_replayed", Value::from(r.spec_replayed)),
                                ("quarantined", Value::from(r.quarantined)),
                                ("trust_mean", finite_or_null(r.trust_mean)),
                                ("retransmits", Value::from(r.faults.retransmits as usize)),
                                ("frames_lost", Value::from(r.faults.frames_lost as usize)),
                                (
                                    "frames_corrupt",
                                    Value::from(r.faults.frames_corrupt as usize),
                                ),
                                (
                                    "dup_suppressed",
                                    Value::from(r.faults.dup_suppressed as usize),
                                ),
                                ("resyncs", Value::from(r.faults.resyncs as usize)),
                                ("recoveries", Value::from(r.faults.recoveries as usize)),
                                ("threshold", finite_or_null(r.threshold)),
                                (
                                    "selected",
                                    Value::Arr(
                                        r.selected.iter().map(|&s| Value::Bool(s)).collect(),
                                    ),
                                ),
                                (
                                    "client_accs",
                                    Value::Arr(
                                        r.client_accs.iter().map(|&a| Value::from(a)).collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn finite_or_null(x: f64) -> Value {
    if x.is_finite() {
        Value::from(x)
    } else {
        Value::Null
    }
}

/// Communication-compression rate, paper Eq. 4:
/// `CCR = (C_t0 - C_t1) / C_t0` (reported as a fraction, like Table III).
pub fn ccr(baseline_comms: usize, compressed_comms: usize) -> f64 {
    if baseline_comms == 0 {
        return 0.0;
    }
    (baseline_comms as f64 - compressed_comms as f64) / baseline_comms as f64
}

/// Eq. 4 over wire bytes instead of communication counts — the axis the
/// sparse top-k upload mode moves (gating cuts *how often* clients
/// communicate; top-k cuts *how much* each communication carries).
pub fn ccr_bytes(baseline_bytes: u64, compressed_bytes: u64) -> f64 {
    if baseline_bytes == 0 {
        return 0.0;
    }
    (baseline_bytes as f64 - compressed_bytes as f64) / baseline_bytes as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(round: usize, acc: f64, uploads: usize, cum: usize) -> RoundRecord {
        RoundRecord {
            round,
            vtime: round as f64,
            global_acc: acc,
            global_loss: 1.0,
            train_loss: 1.0,
            uploads,
            cum_uploads: cum,
            bytes_up: 100,
            bytes_down: 100,
            bytes_up_ctrl: 30,
            bytes_down_ctrl: 20,
            threshold: 0.5,
            values: vec![1.0, 2.0],
            selected: vec![true, false],
            client_accs: vec![acc, acc / 2.0],
            idle_seconds: 0.1,
            reports: 2,
            in_flight: 0,
            upload_staleness: vec![0, uploads],
            shard: round % 2,
            spec_committed: uploads,
            spec_replayed: round % 2,
            quarantined: round % 2,
            trust_mean: f64::NAN,
            faults: FaultCounters { retransmits: round as u64, ..FaultCounters::default() },
        }
    }

    fn run() -> RunMetrics {
        let mut m = RunMetrics::new("a", "vafl", 0.9);
        m.push(record(1, 0.5, 2, 2));
        m.push(record(2, 0.92, 1, 3));
        m.push(record(3, 0.88, 1, 4));
        m
    }

    #[test]
    fn comm_times_to_target_first_crossing() {
        let m = run();
        assert_eq!(m.comm_times_to_target(), Some(3));
        assert_eq!(m.rounds_to_target(), Some(2));
    }

    #[test]
    fn target_never_reached() {
        let mut m = RunMetrics::new("a", "afl", 0.99);
        m.push(record(1, 0.5, 2, 2));
        assert_eq!(m.comm_times_to_target(), None);
    }

    #[test]
    fn best_and_final_accuracy() {
        let m = run();
        assert_eq!(m.best_accuracy(), 0.92);
        assert_eq!(m.final_accuracy(), 0.88);
    }

    #[test]
    fn skipped_evals_are_ignored() {
        let mut m = RunMetrics::new("a", "afl", 0.9);
        m.push(record(1, f64::NAN, 1, 1));
        m.push(record(2, 0.95, 1, 2));
        assert_eq!(m.comm_times_to_target(), Some(2));
        assert_eq!(m.acc_curve(), vec![(2, 0.95)]);
        assert_eq!(m.final_accuracy(), 0.95);
    }

    #[test]
    fn ccr_matches_eq4() {
        // Paper exp b: AFL 84, VAFL 43 -> 0.4881.
        assert!((ccr(84, 43) - 0.4881).abs() < 1e-4);
        assert_eq!(ccr(0, 5), 0.0);
        assert_eq!(ccr(10, 10), 0.0);
    }

    #[test]
    fn ccr_bytes_matches_eq4_over_bytes() {
        assert!((ccr_bytes(1000, 500) - 0.5).abs() < 1e-12);
        assert_eq!(ccr_bytes(0, 5), 0.0);
        assert_eq!(ccr_bytes(10, 10), 0.0);
        assert!(ccr_bytes(10, 20) < 0.0, "expansion must report negative CCR");
    }

    #[test]
    fn byte_rollups_and_bytes_to_target() {
        let m = run(); // 3 records x 100 bytes each way; target hit at #2
        assert_eq!(m.total_bytes_up(), 300);
        assert_eq!(m.total_bytes_down(), 300);
        // Payload = total - control frames (30 up / 20 down per record).
        assert_eq!(m.records[0].bytes_up_payload(), 70);
        assert_eq!(m.records[0].bytes_down_payload(), 80);
        assert_eq!(m.total_bytes_up_payload(), 210);
        assert_eq!(m.total_bytes_down_payload(), 240);
        // A ctrl count exceeding the total (malformed seed) saturates.
        let odd = RoundRecord { bytes_up_ctrl: 500, ..m.records[0].clone() };
        assert_eq!(odd.bytes_up_payload(), 0);
        assert_eq!(m.bytes_up_to_target(), Some(200));
        let mut never = RunMetrics::new("a", "afl", 0.99);
        never.push(record(1, 0.5, 1, 1));
        assert_eq!(never.bytes_up_to_target(), None);
        assert_eq!(never.total_bytes_up(), 100);
    }

    #[test]
    fn client_curves_transpose() {
        let m = run();
        let curves = m.client_acc_curves();
        assert_eq!(curves.len(), 2);
        assert_eq!(curves[0].len(), 3);
        assert_eq!(curves[1][0], (1, 0.25));
    }

    #[test]
    fn vtime_to_target_first_crossing() {
        let m = run();
        // Target 0.9 first crossed at round 2 (vtime = round as f64).
        assert_eq!(m.vtime_to_target(), Some(2.0));
        let mut never = RunMetrics::new("a", "afl", 0.99);
        never.push(record(1, 0.5, 1, 1));
        assert_eq!(never.vtime_to_target(), None);
    }

    #[test]
    fn staleness_stats_and_histogram() {
        let m = run(); // staleness vecs: [0,2], [0,1], [0,1]
        assert_eq!(m.records[0].staleness_max(), 2);
        assert!((m.records[1].staleness_mean() - 0.5).abs() < 1e-12);
        let h = m.staleness_histogram();
        assert_eq!(h.get(&0), Some(&3));
        assert_eq!(h.get(&1), Some(&2));
        assert_eq!(h.get(&2), Some(&1));
        assert_eq!(m.total_reports(), 6);
        let empty = RoundRecord { upload_staleness: vec![], ..m.records[0].clone() };
        assert!(empty.staleness_mean().is_nan());
        assert_eq!(empty.staleness_max(), 0);
    }

    #[test]
    fn json_export_has_rounds() {
        let v = run().to_json();
        assert_eq!(v.get("rounds").unwrap().as_arr().unwrap().len(), 3);
        let r0 = &v.get("rounds").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("quarantined").unwrap().as_usize(), Some(1));
        assert_eq!(r0.get("trust_mean").unwrap(), &Value::Null);
        assert_eq!(v.get("comm_times_to_target").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("spec_committed").unwrap().as_usize(), Some(4));
        assert_eq!(v.get("total_bytes_up").unwrap().as_usize(), Some(300));
        assert_eq!(v.get("total_bytes_up_payload").unwrap().as_usize(), Some(210));
        assert_eq!(v.get("total_bytes_down_payload").unwrap().as_usize(), Some(240));
        assert_eq!(v.get("bytes_up_to_target").unwrap().as_usize(), Some(200));
    }

    #[test]
    fn control_records_export_to_json() {
        let mut m = run();
        assert!(m.to_json().get("control").unwrap().as_arr().unwrap().is_empty());
        m.control_records.push(ControlRecord {
            round: 4,
            vtime: 4.5,
            controller: "compression".into(),
            knob: "k_fraction".into(),
            old: 0.25,
            new: 0.5,
            signal: 0.8,
            client: None,
        });
        m.control_records.push(ControlRecord {
            round: 8,
            vtime: 9.0,
            controller: "rebalance".into(),
            knob: "client_shard".into(),
            old: 0.0,
            new: 1.0,
            signal: 3.0,
            client: Some(5),
        });
        let v = m.to_json();
        let ctl = v.get("control").unwrap().as_arr().unwrap();
        assert_eq!(ctl.len(), 2);
        assert_eq!(ctl[0].get("knob").unwrap().as_str(), Some("k_fraction"));
        assert_eq!(ctl[0].get("client").unwrap(), &Value::Null);
        assert_eq!(ctl[1].get("client").unwrap().as_usize(), Some(5));
        assert_eq!(ctl[1].get("controller").unwrap().as_str(), Some("rebalance"));
    }

    #[test]
    fn fault_totals_roll_up_and_export() {
        let m = run(); // retransmits = round (1, 2, 3), everything else 0
        let totals = m.fault_totals();
        assert_eq!(totals.retransmits, 6);
        assert_eq!(totals.frames_lost, 0);
        assert!(totals.any());
        assert!(!FaultCounters::default().any());
        let v = m.to_json();
        assert_eq!(v.get("retransmits").unwrap().as_usize(), Some(6));
        assert_eq!(v.get("resyncs").unwrap().as_usize(), Some(0));
        assert_eq!(v.get("link_capped").unwrap().as_usize(), Some(0));
        let r2 = &v.get("rounds").unwrap().as_arr().unwrap()[1];
        assert_eq!(r2.get("retransmits").unwrap().as_usize(), Some(2));
        assert_eq!(r2.get("frames_lost").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn record_codecs_round_trip_bitwise() {
        let mut original = record(3, f64::NAN, 2, 7);
        original.faults = FaultCounters {
            retransmits: 1,
            frames_lost: 2,
            frames_corrupt: 3,
            dup_suppressed: 4,
            resyncs: 5,
            recoveries: 6,
        };
        let ctl = ControlRecord {
            round: 4,
            vtime: 4.5,
            controller: "trim".into(),
            knob: "trim_fraction".into(),
            old: 0.1,
            new: 0.15,
            signal: f64::NAN,
            client: Some(9),
        };
        let mut enc = Enc::new();
        original.save(&mut enc);
        ctl.save(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = Dec::new(&bytes);
        let r = RoundRecord::load(&mut dec).unwrap();
        let c = ControlRecord::load(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(r.round, original.round);
        assert_eq!(r.vtime.to_bits(), original.vtime.to_bits());
        assert_eq!(r.global_acc.to_bits(), original.global_acc.to_bits(), "NaN by bits");
        assert_eq!(r.trust_mean.to_bits(), original.trust_mean.to_bits());
        assert_eq!(r.cum_uploads, original.cum_uploads);
        assert_eq!(r.bytes_up, original.bytes_up);
        assert_eq!(r.bytes_down_ctrl, original.bytes_down_ctrl);
        let vb = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(vb(&r.values), vb(&original.values));
        assert_eq!(r.selected, original.selected);
        assert_eq!(vb(&r.client_accs), vb(&original.client_accs));
        assert_eq!(r.upload_staleness, original.upload_staleness);
        assert_eq!(r.shard, original.shard);
        assert_eq!(r.spec_committed, original.spec_committed);
        assert_eq!(r.quarantined, original.quarantined);
        assert_eq!(r.faults, original.faults);
        assert_eq!(c.round, ctl.round);
        assert_eq!(c.controller, ctl.controller);
        assert_eq!(c.knob, ctl.knob);
        assert_eq!(c.old.to_bits(), ctl.old.to_bits());
        assert_eq!(c.new.to_bits(), ctl.new.to_bits());
        assert_eq!(c.signal.to_bits(), ctl.signal.to_bits());
        assert_eq!(c.client, ctl.client);
    }

    #[test]
    fn shard_and_speculation_rollups() {
        // Records at rounds 1..3 carry shard = round % 2 and
        // spec_committed = uploads (2, 1, 1), spec_replayed = round % 2.
        let m = run();
        let shards = m.per_shard_flushes();
        assert_eq!(shards.get(&0), Some(&1));
        assert_eq!(shards.get(&1), Some(&2));
        assert_eq!(m.speculation_totals(), (4, 2));
        assert!((m.speculation_hit_rate() - 4.0 / 6.0).abs() < 1e-12);
        // A serial run (no speculation) has an undefined hit rate.
        let serial = RunMetrics::new("a", "afl", 0.9);
        assert!(serial.speculation_hit_rate().is_nan());
        assert_eq!(serial.engine_events, 0);
    }
}
