//! Model-side plumbing on the Rust side: the flat-parameter layout
//! (`params_spec.json` from the AOT bundle), initial parameters, and the
//! dense vector math the coordinator hot path uses (aggregation, norms).
//!
//! The Rust coordinator never knows the network architecture — parameters
//! are an opaque `f32[P]` vector plus a named layout for diagnostics.

pub mod quant;
pub mod sparse;
pub mod spec;
pub mod vector;

pub use quant::{Precision, QuantBuf};
pub use sparse::{sparse_payload_bytes, sparse_payload_bytes_layers, SparseDelta};
pub use spec::{LayerSpec, ParamSpec};
pub use vector::{
    axpy, l2_norm_sq, sq_distance, weighted_average, weighted_average_into,
    weighted_average_into_t, ParamVec,
};
