//! Payload quantization for model uploads/broadcasts.
//!
//! The paper's future-work section points at further communication
//! compression; this module implements the standard lossy payload codecs —
//! IEEE half precision (f16) and symmetric per-tensor int8 — so the
//! framework can trade accuracy for wire bytes (`upload_precision` in the
//! config, `ablation` benches). Codec error bounds are tested; the server
//! dequantizes before aggregation so the coordinator math stays in f32.

/// Wire precision of a model payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F16,
    Int8,
}

impl Precision {
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Precision::F32),
            "f16" => Some(Precision::F16),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Payload bytes for `n` parameters (plus the 64-byte frame header;
    /// int8 carries an extra f32 scale).
    pub fn payload_bytes(&self, n: usize) -> u64 {
        let body = match self {
            Precision::F32 => 4 * n,
            Precision::F16 => 2 * n,
            Precision::Int8 => n + 4,
        };
        (body + 64) as u64
    }

    /// Quantize-dequantize round trip (what the receiver reconstructs).
    pub fn round_trip(&self, params: &[f32]) -> Vec<f32> {
        match self {
            Precision::F32 => params.to_vec(),
            Precision::F16 => params.iter().map(|&v| f16_to_f32(f32_to_f16(v))).collect(),
            Precision::Int8 => {
                let (q, scale) = quantize_int8(params);
                dequantize_int8(&q, scale)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// IEEE 754 binary16 (hand-rolled: no `half` crate offline)
// ---------------------------------------------------------------------------

/// f32 -> f16 bits with round-to-nearest-even.
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN.
        let f16_frac = if frac != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | f16_frac;
    }
    // Re-bias: f32 exp-127 -> f16 exp-15.
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal or underflow to zero.
        if e < -10 {
            return sign;
        }
        let frac = frac | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rem = frac & ((1u32 << shift) - 1);
        let mut out = (frac >> shift) as u16;
        // Round to nearest, ties to even.
        if rem > half || (rem == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out;
    }
    // Normal: round mantissa 23 -> 10 bits, nearest even. The integer add
    // carries mantissa overflow into the exponent, which is exactly the
    // right behaviour (1.111..·2^e rounds up to 1.0·2^{e+1}).
    let mut out = sign | ((e as u16) << 10) | (frac >> 13) as u16;
    let rem = frac & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out = out.wrapping_add(1);
    }
    out
}

/// f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign // +-0
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x0400 == 0 {
                f <<= 1;
                e += 1;
            }
            let f = (f & 0x03ff) << 13;
            let e = (127 - 15 - e) as u32;
            sign | (e << 23) | f
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13) // inf/nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// Symmetric per-tensor int8
// ---------------------------------------------------------------------------

/// Quantize to int8 with a single symmetric scale (max-abs / 127).
pub fn quantize_int8(params: &[f32]) -> (Vec<i8>, f32) {
    let max_abs = params.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
    let q = params
        .iter()
        .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

pub fn dequantize_int8(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trips_exactly_representable() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, -0.25, 65504.0] {
            let rt = f16_to_f32(f32_to_f16(v));
            assert_eq!(rt, v, "{v}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        // Normal range: relative error <= 2^-11.
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..10_000 {
            let v = (rng.gauss() as f32) * 10.0;
            if v == 0.0 {
                continue;
            }
            let rt = f16_to_f32(f32_to_f16(v));
            let rel = ((rt - v) / v).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "{v} -> {rt} rel {rel}");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Overflow saturates to inf.
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        // Tiny values underflow to (signed) zero or subnormals.
        let tiny = f16_to_f32(f32_to_f16(1e-8));
        assert!(tiny.abs() < 1e-4);
    }

    #[test]
    fn f16_subnormal_range() {
        let v = 3.0e-5f32; // subnormal in f16
        let rt = f16_to_f32(f32_to_f16(v));
        assert!((rt - v).abs() / v < 0.05, "{v} -> {rt}");
    }

    #[test]
    fn int8_round_trip_error_bounded() {
        let mut rng = crate::util::rng::Rng::new(2);
        let params: Vec<f32> = (0..1000).map(|_| rng.gauss() as f32).collect();
        let (q, scale) = quantize_int8(&params);
        let rt = dequantize_int8(&q, scale);
        let max_abs = params.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (a, b) in params.iter().zip(&rt) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-6, "{a} vs {b} (bound {})", max_abs / 254.0);
        }
    }

    #[test]
    fn int8_zero_vector() {
        let (q, scale) = quantize_int8(&[0.0; 8]);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(dequantize_int8(&q, scale), vec![0.0; 8]);
    }

    #[test]
    fn payload_bytes_ordering() {
        let n = 17290;
        assert!(Precision::Int8.payload_bytes(n) < Precision::F16.payload_bytes(n));
        assert!(Precision::F16.payload_bytes(n) < Precision::F32.payload_bytes(n));
        assert_eq!(Precision::F32.payload_bytes(n), (4 * n + 64) as u64);
    }

    #[test]
    fn precision_round_trip_dispatch() {
        let params = vec![0.1f32, -0.5, 2.0];
        assert_eq!(Precision::F32.round_trip(&params), params);
        let h = Precision::F16.round_trip(&params);
        for (a, b) in params.iter().zip(&h) {
            assert!((a - b).abs() < 1e-3);
        }
        let q = Precision::Int8.round_trip(&params);
        for (a, b) in params.iter().zip(&q) {
            assert!((a - b).abs() < 0.02);
        }
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            assert_eq!(Precision::from_name(p.name()), Some(p));
        }
        assert_eq!(Precision::from_name("bf16"), None);
    }
}
