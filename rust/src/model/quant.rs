//! Payload quantization for model uploads/broadcasts.
//!
//! The paper's future-work section points at further communication
//! compression; this module implements the standard lossy payload codecs —
//! IEEE half precision (f16) and symmetric per-tensor int8 — so the
//! framework can trade accuracy for wire bytes (`upload_precision` in the
//! config, `ablation` benches).
//!
//! Two consumption paths exist:
//!
//! * [`Precision::round_trip`] — the naive reference: decode every payload
//!   to a dense `Vec<f32>` before aggregation. Allocates one full vector
//!   per upload per round; kept as the semantic oracle for the fused path.
//! * [`QuantBuf`] — the hot path: clients encode into reusable wire-format
//!   byte buffers, and the server *dequantizes-and-accumulates in one
//!   fused pass* ([`QuantBuf::accumulate_dequant`]) straight out of the
//!   payload bytes into the aggregator's f64 accumulator. No staging
//!   vector ever exists, and steady-state rounds perform zero heap
//!   allocation (see EXPERIMENTS.md §Perf). The fused pass is bit-identical
//!   to the reference path by construction: each lane computes exactly
//!   `weight * (reconstructed_f32 as f64)` in index order.

/// Wire precision of a model payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F32,
    F16,
    Int8,
}

impl Precision {
    pub fn name(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Int8 => "int8",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "f32" => Some(Precision::F32),
            "f16" => Some(Precision::F16),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Value-body bytes for `n` parameters, without the frame header
    /// (int8 carries an extra f32 scale). The sparse wire format
    /// (`model::sparse`) composes this with its own index block.
    pub fn body_bytes(&self, n: usize) -> u64 {
        match self {
            Precision::F32 => 4 * n as u64,
            Precision::F16 => 2 * n as u64,
            Precision::Int8 => n as u64 + 4,
        }
    }

    /// Payload bytes for `n` parameters (plus the 64-byte frame header).
    pub fn payload_bytes(&self, n: usize) -> u64 {
        self.body_bytes(n) + 64
    }

    /// Quantize-dequantize round trip (what the receiver reconstructs).
    pub fn round_trip(&self, params: &[f32]) -> Vec<f32> {
        match self {
            Precision::F32 => params.to_vec(),
            Precision::F16 => params.iter().map(|&v| f16_to_f32(f32_to_f16(v))).collect(),
            Precision::Int8 => {
                let (q, scale) = quantize_int8(params);
                dequantize_int8(&q, scale)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Streaming wire buffers (the fused hot path)
// ---------------------------------------------------------------------------

/// A reusable wire-format payload buffer.
///
/// [`QuantBuf::encode`] quantizes a parameter vector into the internal byte
/// buffer, reusing its capacity across rounds, and the `accumulate_*` /
/// [`QuantBuf::decode_into`] methods consume the payload without ever
/// materializing an intermediate dense `Vec<f32>`. Layout: f32/f16 payloads
/// are little-endian words; int8 payloads are raw bytes plus the symmetric
/// [`QuantBuf::scale`].
#[derive(Debug, Clone)]
pub struct QuantBuf {
    precision: Precision,
    data: Vec<u8>,
    scale: f32,
    n: usize,
}

impl Default for QuantBuf {
    fn default() -> Self {
        QuantBuf { precision: Precision::F32, data: Vec::new(), scale: 1.0, n: 0 }
    }
}

impl QuantBuf {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wire precision of the currently encoded payload.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Number of encoded parameters.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Symmetric int8 scale (1.0 for f32/f16 payloads).
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Wire size of this payload (body + the 64-byte frame header).
    pub fn payload_bytes(&self) -> u64 {
        self.precision.payload_bytes(self.n)
    }

    /// Encode `params` at `precision` into the reusable byte buffer.
    /// Allocation-free once the buffer has grown to its steady-state size.
    pub fn encode(&mut self, precision: Precision, params: &[f32]) {
        self.precision = precision;
        self.n = params.len();
        self.scale = 1.0;
        self.data.clear();
        match precision {
            Precision::F32 => {
                self.data.reserve(4 * params.len());
                for &v in params {
                    self.data.extend_from_slice(&v.to_le_bytes());
                }
            }
            Precision::F16 => {
                self.data.reserve(2 * params.len());
                for &v in params {
                    self.data.extend_from_slice(&f32_to_f16(v).to_le_bytes());
                }
            }
            Precision::Int8 => {
                self.scale = int8_scale(params);
                self.data.reserve(params.len());
                for &v in params {
                    self.data.push(int8_quantize_one(v, self.scale) as u8);
                }
            }
        }
    }

    /// Fused dequantize-accumulate over the whole payload:
    /// `acc[i] += weight * dequant(i)` in one pass, no staging vector.
    ///
    /// Bit-identical to `round_trip` + f64 weighted accumulation: each lane
    /// performs exactly `weight * (reconstructed_f32 as f64)` in index
    /// order.
    pub fn accumulate_dequant(&self, weight: f64, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.n, "accumulator length mismatch");
        self.accumulate_dequant_range(0, weight, acc);
    }

    /// Fused dequantize-accumulate over params `start .. start + acc.len()`
    /// (the per-worker span of a parallel aggregation; see
    /// `coordinator::aggregate`).
    pub fn accumulate_dequant_range(&self, start: usize, weight: f64, acc: &mut [f64]) {
        let end = start + acc.len();
        assert!(end <= self.n, "range {start}..{end} out of payload len {}", self.n);
        match self.precision {
            Precision::F32 => {
                let bytes = &self.data[4 * start..4 * end];
                for (a, w) in acc.iter_mut().zip(bytes.chunks_exact(4)) {
                    let v = f32::from_le_bytes([w[0], w[1], w[2], w[3]]);
                    *a += weight * v as f64;
                }
            }
            Precision::F16 => {
                let bytes = &self.data[2 * start..2 * end];
                for (a, w) in acc.iter_mut().zip(bytes.chunks_exact(2)) {
                    let v = f16_to_f32(u16::from_le_bytes([w[0], w[1]]));
                    *a += weight * v as f64;
                }
            }
            Precision::Int8 => {
                let scale = self.scale;
                let bytes = &self.data[start..end];
                for (a, &b) in acc.iter_mut().zip(bytes) {
                    let v = (b as i8) as f32 * scale;
                    *a += weight * v as f64;
                }
            }
        }
    }

    /// Decode the single value at position `i` — the sparse
    /// scatter-aggregation path reads one transmitted coordinate at a
    /// time. Reconstruction is bit-identical to [`QuantBuf::decode_into`]
    /// at the same position.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        debug_assert!(i < self.n, "value index {i} out of payload len {}", self.n);
        match self.precision {
            Precision::F32 => {
                let w = &self.data[4 * i..4 * i + 4];
                f32::from_le_bytes([w[0], w[1], w[2], w[3]])
            }
            Precision::F16 => {
                let w = &self.data[2 * i..2 * i + 2];
                f16_to_f32(u16::from_le_bytes([w[0], w[1]]))
            }
            Precision::Int8 => (self.data[i] as i8) as f32 * self.scale,
        }
    }

    /// FNV-1a checksum over the payload's wire content (precision tag,
    /// length, int8 scale bits, body bytes) — the integrity field of the
    /// fault-injection layer's frame header. Deterministic, and any
    /// single-byte payload change flips it.
    pub fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        eat(match self.precision {
            Precision::F32 => 0,
            Precision::F16 => 1,
            Precision::Int8 => 2,
        });
        for b in (self.n as u64).to_le_bytes() {
            eat(b);
        }
        for b in self.scale.to_bits().to_le_bytes() {
            eat(b);
        }
        for &b in &self.data {
            eat(b);
        }
        h
    }

    /// Decode the whole payload into `out` (the broadcast receive path;
    /// reuses the caller's buffer instead of allocating).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n, "decode buffer length mismatch");
        match self.precision {
            Precision::F32 => {
                for (o, w) in out.iter_mut().zip(self.data.chunks_exact(4)) {
                    *o = f32::from_le_bytes([w[0], w[1], w[2], w[3]]);
                }
            }
            Precision::F16 => {
                for (o, w) in out.iter_mut().zip(self.data.chunks_exact(2)) {
                    *o = f16_to_f32(u16::from_le_bytes([w[0], w[1]]));
                }
            }
            Precision::Int8 => {
                let scale = self.scale;
                for (o, &b) in out.iter_mut().zip(&self.data) {
                    *o = (b as i8) as f32 * scale;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// IEEE 754 binary16 (hand-rolled: no `half` crate offline)
// ---------------------------------------------------------------------------

/// f32 -> f16 bits with round-to-nearest-even.
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x007f_ffff;

    if exp == 0xff {
        // Inf / NaN.
        let f16_frac = if frac != 0 { 0x0200 } else { 0 };
        return sign | 0x7c00 | f16_frac;
    }
    // Re-bias: f32 exp-127 -> f16 exp-15.
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        // Subnormal or underflow to zero.
        if e < -10 {
            return sign;
        }
        let frac = frac | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rem = frac & ((1u32 << shift) - 1);
        let mut out = (frac >> shift) as u16;
        // Round to nearest, ties to even.
        if rem > half || (rem == half && (out & 1) == 1) {
            out += 1;
        }
        return sign | out;
    }
    // Normal: round mantissa 23 -> 10 bits, nearest even. The integer add
    // carries mantissa overflow into the exponent, which is exactly the
    // right behaviour (1.111..·2^e rounds up to 1.0·2^{e+1}).
    let mut out = sign | ((e as u16) << 10) | (frac >> 13) as u16;
    let rem = frac & 0x1fff;
    if rem > 0x1000 || (rem == 0x1000 && (out & 1) == 1) {
        out = out.wrapping_add(1);
    }
    out
}

/// f16 bits -> f32.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x03ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign // +-0
        } else {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut f = frac;
            while f & 0x0400 == 0 {
                f <<= 1;
                e += 1;
            }
            let f = (f & 0x03ff) << 13;
            let e = (127 - 15 - e) as u32;
            sign | (e << 23) | f
        }
    } else if exp == 0x1f {
        sign | 0x7f80_0000 | (frac << 13) // inf/nan
    } else {
        sign | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// Symmetric per-tensor int8
// ---------------------------------------------------------------------------

/// Symmetric per-tensor scale (max-abs / 127) over the *finite* entries of
/// `params`. `f32::max` silently ignores a NaN operand and an infinity
/// would poison the scale (everything else dequantizes to 0), so
/// non-finite values are excluded here and handled per-element in
/// [`int8_quantize_one`].
pub fn int8_scale(params: &[f32]) -> f32 {
    let mut max_abs = 0.0f32;
    for &v in params {
        if v.is_finite() {
            max_abs = max_abs.max(v.abs());
        }
    }
    if max_abs > 0.0 {
        max_abs / 127.0
    } else {
        1.0
    }
}

/// Quantize one value at `scale`: NaN maps to 0, +/-infinity (and any
/// finite overflow) saturates to +/-127.
#[inline]
pub fn int8_quantize_one(v: f32, scale: f32) -> i8 {
    if v.is_nan() {
        return 0;
    }
    // `clamp` handles +/-inf; the float->int cast cannot hit NaN here.
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Quantize to int8 with a single symmetric scale (max-abs / 127).
///
/// Non-finite inputs have documented, tested behavior: the scale is
/// computed over finite values only, NaN quantizes to 0, and +/-infinity
/// saturate to +/-127 (see `int8_scale` / `int8_quantize_one`).
pub fn quantize_int8(params: &[f32]) -> (Vec<i8>, f32) {
    let scale = int8_scale(params);
    let q = params.iter().map(|&v| int8_quantize_one(v, scale)).collect();
    (q, scale)
}

pub fn dequantize_int8(q: &[i8], scale: f32) -> Vec<f32> {
    q.iter().map(|&v| v as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trips_exactly_representable() {
        for &v in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, -0.25, 65504.0] {
            let rt = f16_to_f32(f32_to_f16(v));
            assert_eq!(rt, v, "{v}");
        }
    }

    #[test]
    fn f16_relative_error_bounded() {
        // Normal range: relative error <= 2^-11.
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..10_000 {
            let v = (rng.gauss() as f32) * 10.0;
            if v == 0.0 {
                continue;
            }
            let rt = f16_to_f32(f32_to_f16(v));
            let rel = ((rt - v) / v).abs();
            assert!(rel <= 1.0 / 2048.0 + 1e-7, "{v} -> {rt} rel {rel}");
        }
    }

    #[test]
    fn f16_specials() {
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_to_f32(f32_to_f16(f32::NEG_INFINITY)), f32::NEG_INFINITY);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // Overflow saturates to inf.
        assert_eq!(f16_to_f32(f32_to_f16(1e6)), f32::INFINITY);
        // Tiny values underflow to (signed) zero or subnormals.
        let tiny = f16_to_f32(f32_to_f16(1e-8));
        assert!(tiny.abs() < 1e-4);
    }

    #[test]
    fn f16_subnormal_range() {
        let v = 3.0e-5f32; // subnormal in f16
        let rt = f16_to_f32(f32_to_f16(v));
        assert!((rt - v).abs() / v < 0.05, "{v} -> {rt}");
    }

    #[test]
    fn int8_round_trip_error_bounded() {
        let mut rng = crate::util::rng::Rng::new(2);
        let params: Vec<f32> = (0..1000).map(|_| rng.gauss() as f32).collect();
        let (q, scale) = quantize_int8(&params);
        let rt = dequantize_int8(&q, scale);
        let max_abs = params.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        for (a, b) in params.iter().zip(&rt) {
            assert!((a - b).abs() <= scale / 2.0 + 1e-6, "{a} vs {b} (bound {})", max_abs / 254.0);
        }
    }

    #[test]
    fn int8_zero_vector() {
        let (q, scale) = quantize_int8(&[0.0; 8]);
        assert!(q.iter().all(|&v| v == 0));
        assert_eq!(dequantize_int8(&q, scale), vec![0.0; 8]);
    }

    #[test]
    fn payload_bytes_ordering() {
        let n = 17290;
        assert!(Precision::Int8.payload_bytes(n) < Precision::F16.payload_bytes(n));
        assert!(Precision::F16.payload_bytes(n) < Precision::F32.payload_bytes(n));
        assert_eq!(Precision::F32.payload_bytes(n), (4 * n + 64) as u64);
    }

    #[test]
    fn precision_round_trip_dispatch() {
        let params = vec![0.1f32, -0.5, 2.0];
        assert_eq!(Precision::F32.round_trip(&params), params);
        let h = Precision::F16.round_trip(&params);
        for (a, b) in params.iter().zip(&h) {
            assert!((a - b).abs() < 1e-3);
        }
        let q = Precision::Int8.round_trip(&params);
        for (a, b) in params.iter().zip(&q) {
            assert!((a - b).abs() < 0.02);
        }
    }

    #[test]
    fn int8_nonfinite_inputs() {
        let v = [1.0f32, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, -2.0];
        let (q, scale) = quantize_int8(&v);
        // Scale comes from the finite entries only (max abs 2.0).
        assert_eq!(scale, 2.0 / 127.0);
        assert_eq!(q[1], 0, "NaN must quantize to 0");
        assert_eq!(q[2], 127, "+inf must saturate");
        assert_eq!(q[3], -127, "-inf must saturate");
        // All-non-finite input: scale falls back to 1.0, output is defined.
        let (q2, scale2) = quantize_int8(&[f32::NAN, f32::INFINITY]);
        assert_eq!(scale2, 1.0);
        assert_eq!(q2, vec![0, 127]);
    }

    #[test]
    fn quantbuf_decode_matches_round_trip() {
        let mut rng = crate::util::rng::Rng::new(9);
        let params: Vec<f32> = (0..257).map(|_| rng.gauss() as f32).collect();
        let mut buf = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            buf.encode(p, &params);
            assert_eq!(buf.len(), params.len());
            assert_eq!(buf.precision(), p);
            assert_eq!(buf.payload_bytes(), p.payload_bytes(params.len()));
            let want = p.round_trip(&params);
            let mut got = vec![0.0f32; params.len()];
            buf.decode_into(&mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn quantbuf_fused_accumulate_is_bit_identical_to_staged() {
        let mut rng = crate::util::rng::Rng::new(10);
        let params: Vec<f32> = (0..100).map(|_| rng.gauss() as f32 * 3.0).collect();
        let mut buf = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            buf.encode(p, &params);
            let w = 0.3728_f64;
            // Staged reference: decode to dense, then accumulate.
            let staged = p.round_trip(&params);
            let mut want = vec![0.25f64; params.len()];
            for (a, &v) in want.iter_mut().zip(&staged) {
                *a += w * v as f64;
            }
            // Fused: straight out of the payload bytes.
            let mut got = vec![0.25f64; params.len()];
            buf.accumulate_dequant(w, &mut got);
            for (a, b) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}", p.name());
            }
            // Range variant covers split accumulation (parallel spans).
            let mut split = vec![0.25f64; params.len()];
            let (lo, hi) = split.split_at_mut(37);
            buf.accumulate_dequant_range(0, w, lo);
            buf.accumulate_dequant_range(37, w, hi);
            for (a, b) in split.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits(), "{} (split)", p.name());
            }
        }
    }

    #[test]
    fn quantbuf_get_matches_decode_into() {
        let mut rng = crate::util::rng::Rng::new(11);
        let params: Vec<f32> = (0..63).map(|_| rng.gauss() as f32).collect();
        let mut buf = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            buf.encode(p, &params);
            let mut dense = vec![0.0f32; params.len()];
            buf.decode_into(&mut dense);
            for (i, &d) in dense.iter().enumerate() {
                assert_eq!(buf.get(i).to_bits(), d.to_bits(), "{} idx {i}", p.name());
            }
        }
    }

    #[test]
    fn quantbuf_reuse_shrinks_and_regrows() {
        let mut buf = QuantBuf::new();
        buf.encode(Precision::F32, &[1.0, 2.0, 3.0]);
        assert_eq!(buf.len(), 3);
        buf.encode(Precision::Int8, &[0.5]);
        assert_eq!(buf.len(), 1);
        let mut out = vec![0.0f32; 1];
        buf.decode_into(&mut out);
        assert!((out[0] - 0.5).abs() < 0.01);
        assert!(!buf.is_empty());
    }

    #[test]
    fn checksum_is_deterministic_and_content_sensitive() {
        let params = vec![0.1f32, -0.5, 2.0, 7.25];
        let mut a = QuantBuf::new();
        let mut b = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            a.encode(p, &params);
            b.encode(p, &params);
            assert_eq!(a.checksum(), b.checksum(), "{}", p.name());
        }
        // Any value change flips the sum.
        a.encode(Precision::F32, &params);
        b.encode(Precision::F32, &[0.1f32, -0.5, 2.0, 7.26]);
        assert_ne!(a.checksum(), b.checksum());
        // Precision is part of the sum even for similar bodies.
        b.encode(Precision::F16, &params);
        assert_ne!(a.checksum(), b.checksum());
    }

    #[test]
    fn precision_names_round_trip() {
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            assert_eq!(Precision::from_name(p.name()), Some(p));
        }
        assert_eq!(Precision::from_name("bf16"), None);
    }
}
