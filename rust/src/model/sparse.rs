//! Sparse top-k delta wire format for model uploads.
//!
//! The paper's headline is communication compression (Eq. 4); beyond
//! gating *whether* a client uploads, this module compresses *what* an
//! upload carries: only the `k` coordinates whose local model moved the
//! most since the last sync — the top-k by magnitude of
//! `local − base (+ residual)` — cross the wire.
//!
//! Wire layout of a [`SparseDelta`] payload:
//!
//! * 64-byte frame header (dimension, count, precision tag — modeled, not
//!   materialized, exactly like [`QuantBuf`]'s header),
//! * `4·k` bytes of sorted `u32` coordinate indices — **elided when
//!   `k == dim`** (a full payload needs no index block; this makes the
//!   `k_fraction = 1.0` configuration byte- and bit-identical to the
//!   dense path),
//! * the value body at the configured [`Precision`] (reusing the
//!   f32/f16/int8 codecs of [`crate::model::quant`]; int8 carries its
//!   per-payload scale, computed over the *transmitted* values only).
//!
//! The transmitted values are the client's **absolute** parameters at the
//! selected coordinates, not the deltas: the delta (plus the
//! error-feedback residual) drives *selection* only. Shipping absolute
//! values keeps the server stateless per client (no base tracking), makes
//! uploads idempotent, and — decisive for testing — makes the
//! `k == dim` payload literally the dense payload, so the sparse path
//! degenerates to the dense one bit-for-bit (asserted in
//! `rust/tests/sparse.rs`).
//!
//! The untransmitted remainder of the delta is the caller's
//! **error-feedback residual**: [`SparseDelta::encode_topk`] folds the
//! residual into the selection key and writes back the unsent mass, so a
//! coordinate that keeps losing the top-k race accumulates pressure until
//! it is transmitted — transmitting clears exactly that coordinate's
//! debt ([`crate::fleet::Client`] owns the per-client residual and keeps
//! it across model downloads; see its field docs for why resetting there
//! would make error feedback inert).
//!
//! All scratch (selection keys, index permutation, gathered values) lives
//! inside the buffer and is reused across rounds: steady-state encodes
//! perform zero heap allocation (`rust/tests/alloc_sparse.rs`).

use crate::model::quant::{Precision, QuantBuf};

/// Exact wire size of a sparse payload of `k` of `dim` values at
/// `precision`: 64-byte frame header + `4·k` index bytes (elided at
/// `k == dim`) + the precision's value body.
pub fn sparse_payload_bytes(precision: Precision, k: usize, dim: usize) -> u64 {
    let index_bytes = if k == dim { 0 } else { 4 * k as u64 };
    64 + index_bytes + precision.body_bytes(k)
}

/// Exact wire size of a per-layer sparse payload transmitting `ks[l]` of
/// `sizes[l]` values in each layer: one 64-byte frame header (the
/// per-layer counts ride in it, like the dimension/precision tags), each
/// layer's index block elided when that layer is full, and one value
/// body over all transmitted coordinates. With every layer full this is
/// exactly the dense payload.
pub fn sparse_payload_bytes_layers(precision: Precision, ks: &[usize], sizes: &[usize]) -> u64 {
    assert_eq!(ks.len(), sizes.len(), "per-layer k/size length mismatch");
    let total_k: usize = ks.iter().sum();
    let index_bytes: u64 =
        ks.iter().zip(sizes).map(|(&k, &s)| if k == s { 0 } else { 4 * k as u64 }).sum();
    64 + index_bytes + precision.body_bytes(total_k)
}

/// A reusable sparse top-k wire payload: sorted `u32` indices plus the
/// quantized values at those coordinates (see the module docs for the
/// exact layout and the selection semantics).
#[derive(Debug, Clone, Default)]
pub struct SparseDelta {
    /// Transmitted coordinate indices, sorted strictly ascending.
    indices: Vec<u32>,
    /// Quantized values at `indices`, in index order.
    values: QuantBuf,
    /// Full parameter dimension the indices address.
    dim: usize,
    /// Wire bytes of the index block(s) of the last encode (flat: `4·k`,
    /// elided at `k == dim`; layered: per-layer sum with full layers
    /// elided).
    index_bytes: u64,
    /// Scratch: per-coordinate selection key (delta + residual).
    key_scratch: Vec<f32>,
    /// Scratch: candidate index permutation for the top-k select.
    order_scratch: Vec<u32>,
    /// Scratch: gathered parameter values before quantization.
    val_scratch: Vec<f32>,
}

impl SparseDelta {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sorted transmitted coordinate indices.
    pub fn indices(&self) -> &[u32] {
        &self.indices
    }

    /// Number of transmitted coordinates (k).
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Full parameter dimension this payload addresses.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Wire precision of the value body.
    pub fn precision(&self) -> Precision {
        self.values.precision()
    }

    /// Dequantize the `i`-th transmitted value (position in the sorted
    /// index order, not a coordinate). Bit-identical to the dense codec's
    /// reconstruction of the same value.
    #[inline]
    pub fn value(&self, i: usize) -> f32 {
        self.values.get(i)
    }

    /// L1 mass of the last encode's full selection key
    /// (`delta + residual`, all coordinates) — O(n) over the retained
    /// scratch; only meaningful after [`SparseDelta::encode_topk`].
    /// Together with [`SparseDelta::sent_key_l1`] this is the control
    /// plane's residual-ratio signal: `(key_l1 - sent_key_l1) / key_l1`
    /// is the fraction of delta mass the budget left behind (exactly the
    /// residual written back when error feedback is on). Non-finite
    /// coordinates contribute nothing.
    pub fn key_l1(&self) -> f64 {
        let mut sum = 0.0f64;
        for &v in &self.key_scratch {
            let a = v.abs() as f64;
            if a.is_finite() {
                sum += a;
            }
        }
        sum
    }

    /// L1 mass of the transmitted subset of the last encode's selection
    /// key — O(k) (see [`SparseDelta::key_l1`]).
    pub fn sent_key_l1(&self) -> f64 {
        let mut sum = 0.0f64;
        for &i in &self.indices {
            let a = self.key_scratch[i as usize].abs() as f64;
            if a.is_finite() {
                sum += a;
            }
        }
        sum
    }

    /// Exact wire size of this payload (see [`sparse_payload_bytes`] /
    /// [`sparse_payload_bytes_layers`]).
    pub fn payload_bytes(&self) -> u64 {
        64 + self.index_bytes + self.values.precision().body_bytes(self.indices.len())
    }

    /// Encode the top-`k`-by-magnitude coordinates of
    /// `params − base (+ residual)` at `precision` into the reusable
    /// buffers.
    ///
    /// Selection is fully deterministic: candidates are ordered by
    /// `|delta|` descending under `total_cmp` (NaN deltas rank first —
    /// a diverged coordinate is "maximally changed") with the coordinate
    /// index as the tie-break, so the selected *set* is unique for any
    /// input and identical across platforms and worker counts.
    ///
    /// When `residual` is `Some`, the error-feedback state is updated in
    /// place: transmitted coordinates reset to 0, untransmitted
    /// coordinates accumulate the unsent delta (which already folds the
    /// previous residual in, since the residual participated in the key).
    ///
    /// `k` is clamped to `[1, params.len()]`; at `k == params.len()` the
    /// index block is elided on the wire and the value body is exactly
    /// the dense payload (same bytes, same int8 scale).
    pub fn encode_topk(
        &mut self,
        precision: Precision,
        params: &[f32],
        base: &[f32],
        residual: Option<&mut [f32]>,
        k: usize,
    ) {
        let n = params.len();
        assert_eq!(base.len(), n, "base/params length mismatch");
        assert!(n > 0, "cannot encode an empty parameter vector");
        let k = k.clamp(1, n);
        self.dim = n;
        self.build_key(params, base, residual.as_deref());
        self.indices.clear();
        self.select_range(0, n, k);
        self.index_bytes = if k == n { 0 } else { 4 * k as u64 };
        self.gather_and_feedback(precision, params, residual);
    }

    /// Per-layer variant of [`SparseDelta::encode_topk`]: the top
    /// `ks[l]`-by-magnitude coordinates are selected *within each layer's
    /// parameter range* (`layer_sizes` partitions the flat vector in
    /// offset order, as validated by `ParamSpec`), so a quiet layer
    /// cannot be starved by a loud one. Selection semantics, transmitted
    /// absolute values, and error feedback are exactly the flat encode's,
    /// applied per range; the concatenated index list stays strictly
    /// ascending because layers are contiguous. Each `ks[l]` is clamped
    /// to `[1, layer_sizes[l]]`; with every layer at full k the payload
    /// is bitwise the dense path (all index blocks elided, value body =
    /// the dense body).
    pub fn encode_topk_layers(
        &mut self,
        precision: Precision,
        params: &[f32],
        base: &[f32],
        residual: Option<&mut [f32]>,
        layer_sizes: &[usize],
        ks: &[usize],
    ) {
        let n = params.len();
        assert_eq!(base.len(), n, "base/params length mismatch");
        assert!(n > 0, "cannot encode an empty parameter vector");
        assert_eq!(layer_sizes.len(), ks.len(), "per-layer k/size length mismatch");
        assert_eq!(
            layer_sizes.iter().sum::<usize>(),
            n,
            "layer sizes must partition the parameter vector"
        );
        self.dim = n;
        self.build_key(params, base, residual.as_deref());
        self.indices.clear();
        self.index_bytes = 0;
        let mut off = 0usize;
        for (&size, &k) in layer_sizes.iter().zip(ks) {
            assert!(size > 0, "empty layer in layer_sizes");
            let k = k.clamp(1, size);
            self.select_range(off, size, k);
            self.index_bytes += if k == size { 0 } else { 4 * k as u64 };
            off += size;
        }
        self.gather_and_feedback(precision, params, residual);
    }

    /// Selection key: how far each coordinate has moved since the last
    /// sync, plus any error-feedback debt.
    fn build_key(&mut self, params: &[f32], base: &[f32], residual: Option<&[f32]>) {
        self.key_scratch.clear();
        match residual {
            Some(r) => {
                assert_eq!(r.len(), params.len(), "residual/params length mismatch");
                self.key_scratch
                    .extend(params.iter().zip(base).zip(r.iter()).map(|((&p, &b), &e)| p - b + e));
            }
            None => self.key_scratch.extend(params.iter().zip(base).map(|(&p, &b)| p - b)),
        }
    }

    /// Append the top-`k`-by-key coordinates of `[off, off + size)` to
    /// `indices`, sorted ascending (the whole range, selection elided,
    /// when `k == size`).
    fn select_range(&mut self, off: usize, size: usize, k: usize) {
        self.order_scratch.clear();
        self.order_scratch.extend(off as u32..(off + size) as u32);
        if k < size {
            let keys = &self.key_scratch;
            let by_magnitude_desc = |&a: &u32, &b: &u32| {
                keys[b as usize]
                    .abs()
                    .total_cmp(&keys[a as usize].abs())
                    .then_with(|| a.cmp(&b))
            };
            let _ = self.order_scratch.select_nth_unstable_by(k - 1, by_magnitude_desc);
            self.order_scratch[..k].sort_unstable();
        }
        self.indices.extend_from_slice(&self.order_scratch[..k]);
        debug_assert!(self.indices.windows(2).all(|w| w[0] < w[1]), "indices not strictly sorted");
    }

    /// Gather the absolute values at the selected coordinates through the
    /// dense codec (at full k this is byte-identical to encoding
    /// `params`), then write back the error-feedback residual: unsent
    /// delta mass carries to the next round, transmitted coordinates
    /// clear their debt.
    fn gather_and_feedback(
        &mut self,
        precision: Precision,
        params: &[f32],
        residual: Option<&mut [f32]>,
    ) {
        self.val_scratch.clear();
        self.val_scratch.extend(self.indices.iter().map(|&i| params[i as usize]));
        self.values.encode(precision, &self.val_scratch);
        if let Some(r) = residual {
            r.copy_from_slice(&self.key_scratch);
            for &i in &self.indices {
                r[i as usize] = 0.0;
            }
        }
    }

    /// FNV-1a checksum over the payload's wire content (dimension, sorted
    /// index block, value-body checksum) — the integrity field of the
    /// fault-injection layer's frame header. A payload with every index
    /// block elided (`k == dim`) hashes its (empty) index list the same
    /// way, so the sum stays well-defined across both layouts.
    pub fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        for b in (self.dim as u64).to_le_bytes() {
            eat(b);
        }
        for b in (self.indices.len() as u64).to_le_bytes() {
            eat(b);
        }
        for &i in &self.indices {
            for b in i.to_le_bytes() {
                eat(b);
            }
        }
        for b in self.values.checksum().to_le_bytes() {
            eat(b);
        }
        h
    }

    /// Dequantized value at coordinate `idx`, or `None` when `idx` was not
    /// transmitted — binary search over the sorted index block (attack /
    /// robustness diagnostics; the hot paths walk cursors instead).
    pub fn value_at(&self, idx: u32) -> Option<f32> {
        self.indices.binary_search(&idx).ok().map(|pos| self.values.get(pos))
    }

    /// Scatter-decode into a dense vector: transmitted coordinates are
    /// overwritten with their reconstructed values, every other
    /// coordinate is left untouched. `out.len()` must equal
    /// [`SparseDelta::dim`].
    pub fn scatter_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim, "scatter buffer length mismatch");
        for (j, &idx) in self.indices.iter().enumerate() {
            out[idx as usize] = self.values.get(j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecs(seed: u64, n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let params: Vec<f32> = (0..n).map(|_| rng.gauss() as f32).collect();
        let base: Vec<f32> = (0..n).map(|_| rng.gauss() as f32 * 0.1).collect();
        (params, base)
    }

    #[test]
    fn topk_selects_largest_deltas_sorted() {
        let params = vec![0.0f32, 5.0, -0.1, -7.0, 0.2, 3.0];
        let base = vec![0.0f32; 6];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 3);
        // |delta| = [0, 5, .1, 7, .2, 3] -> top-3 are coords 3, 1, 5.
        assert_eq!(sd.indices(), &[1, 3, 5]);
        assert_eq!(sd.len(), 3);
        assert_eq!(sd.dim(), 6);
        assert_eq!(sd.value(0), 5.0);
        assert_eq!(sd.value(1), -7.0);
        assert_eq!(sd.value(2), 3.0);
    }

    #[test]
    fn ties_break_by_lowest_index() {
        let params = vec![1.0f32, -1.0, 1.0, 1.0];
        let base = vec![0.0f32; 4];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 2);
        assert_eq!(sd.indices(), &[0, 1]);
    }

    #[test]
    fn full_k_matches_dense_payload_exactly() {
        let (params, base) = vecs(3, 97);
        let mut sd = SparseDelta::new();
        let mut dense = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            sd.encode_topk(p, &params, &base, None, params.len());
            dense.encode(p, &params);
            assert_eq!(sd.len(), params.len());
            assert_eq!(sd.indices().len(), params.len());
            // Index block elided: payload bytes equal the dense payload.
            assert_eq!(sd.payload_bytes(), dense.payload_bytes(), "{}", p.name());
            for i in 0..params.len() {
                assert_eq!(sd.value(i).to_bits(), dense.get(i).to_bits(), "{}", p.name());
            }
        }
    }

    #[test]
    fn payload_bytes_accounting_is_exact() {
        assert_eq!(sparse_payload_bytes(Precision::F32, 10, 100), 64 + 40 + 40);
        assert_eq!(sparse_payload_bytes(Precision::F16, 10, 100), 64 + 40 + 20);
        assert_eq!(sparse_payload_bytes(Precision::Int8, 10, 100), 64 + 40 + 14);
        // Full payloads elide the index block entirely.
        assert_eq!(
            sparse_payload_bytes(Precision::F32, 100, 100),
            Precision::F32.payload_bytes(100)
        );
        // Partial sparse payloads are smaller than dense ones.
        assert!(
            sparse_payload_bytes(Precision::F32, 100, 17290) < Precision::F32.payload_bytes(17290)
        );
    }

    #[test]
    fn scatter_into_touches_only_transmitted_coords() {
        let (params, base) = vecs(4, 40);
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 7);
        let mut out = vec![f32::MIN; 40];
        sd.scatter_into(&mut out);
        let sent: std::collections::HashSet<u32> = sd.indices().iter().copied().collect();
        for (i, &v) in out.iter().enumerate() {
            if sent.contains(&(i as u32)) {
                assert_eq!(v.to_bits(), params[i].to_bits());
            } else {
                assert_eq!(v, f32::MIN, "untransmitted coord {i} was written");
            }
        }
    }

    #[test]
    fn residual_accumulates_unsent_and_resets_sent() {
        let params = vec![10.0f32, 0.5, 0.4, 0.0];
        let base = vec![0.0f32; 4];
        let mut r = vec![0.0f32; 4];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, Some(&mut r), 1);
        assert_eq!(sd.indices(), &[0]);
        assert_eq!(r, vec![0.0, 0.5, 0.4, 0.0]);
        // Second round, same params: the residual doubles the pressure on
        // the unsent coordinates (the key folds the residual in), and
        // coordinate 1 still wins the race behind 0.
        sd.encode_topk(Precision::F32, &params, &base, Some(&mut r), 2);
        assert_eq!(sd.indices(), &[0, 1]);
        assert_eq!(r, vec![0.0, 0.0, 0.8, 0.0]);
    }

    #[test]
    fn residual_boosts_selection() {
        // Coordinate 2 has a tiny fresh delta but a large residual debt:
        // error feedback must put it in the transmitted set.
        let params = vec![1.0f32, 0.9, 0.1];
        let base = vec![0.0f32; 3];
        let mut r = vec![0.0f32, 0.0, 5.0];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, Some(&mut r), 1);
        assert_eq!(sd.indices(), &[2]);
        // Without the residual the same inputs pick coordinate 0.
        sd.encode_topk(Precision::F32, &params, &base, None, 1);
        assert_eq!(sd.indices(), &[0]);
    }

    #[test]
    fn nan_and_inf_deltas_are_selected_first() {
        let params = vec![0.1f32, f32::NAN, f32::INFINITY, 100.0];
        let base = vec![0.0f32; 4];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 2);
        // total_cmp ranks |NaN| above +inf above any finite magnitude.
        assert_eq!(sd.indices(), &[1, 2]);
        assert!(sd.value(0).is_nan());
        assert_eq!(sd.value(1), f32::INFINITY);
        // int8 follows the documented dense codec semantics (NaN -> 0,
        // inf saturates).
        sd.encode_topk(Precision::Int8, &params, &base, None, 2);
        assert_eq!(sd.value(0), 0.0);
    }

    #[test]
    fn key_mass_splits_into_sent_and_unsent() {
        // Deltas |3|, |4|, |0.5|, |0| -> top-2 sends coords 0 and 1:
        // sent key mass 7, total 7.5.
        let params = vec![3.0f32, -4.0, 0.5, 0.0];
        let base = vec![0.0f32; 4];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 2);
        assert!((sd.key_l1() - 7.5).abs() < 1e-9);
        assert!((sd.sent_key_l1() - 7.0).abs() < 1e-9);
        // With error feedback, the unsent key mass is exactly the
        // residual written back.
        let mut r = vec![0.0f32; 4];
        sd.encode_topk(Precision::F32, &params, &base, Some(&mut r), 2);
        let unsent = sd.key_l1() - sd.sent_key_l1();
        let residual_l1: f64 = r.iter().map(|&x| x.abs() as f64).sum();
        assert!((unsent - residual_l1).abs() < 1e-9);
        // Full-k: nothing is left behind.
        sd.encode_topk(Precision::F32, &params, &base, None, 4);
        assert!((sd.key_l1() - sd.sent_key_l1()).abs() < 1e-12);
        // Non-finite keys are skipped in both sums.
        let nan_params = vec![f32::NAN, -4.0, 0.5, 0.0];
        sd.encode_topk(Precision::F32, &nan_params, &base, None, 1);
        assert!((sd.key_l1() - 4.5).abs() < 1e-9);
        assert_eq!(sd.sent_key_l1(), 0.0, "the NaN coord is selected but adds no mass");
    }

    #[test]
    fn layered_topk_selects_within_each_layer() {
        // One loud layer and one quiet layer: a flat top-3 would spend the
        // whole budget on layer 0; per-layer budgets guarantee layer 1
        // representation.
        let params = vec![10.0f32, -9.0, 8.0, 7.0, 0.2, 0.1, -0.3, 0.05];
        let base = vec![0.0f32; 8];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 3);
        assert_eq!(sd.indices(), &[0, 1, 2], "flat top-3 starves layer 1");
        sd.encode_topk_layers(Precision::F32, &params, &base, None, &[4, 4], &[2, 1]);
        assert_eq!(sd.indices(), &[0, 1, 6]);
        assert_eq!(sd.value(0), 10.0);
        assert_eq!(sd.value(1), -9.0);
        assert_eq!(sd.value(2), -0.3);
        // Index blocks: both layers partial -> 4 bytes per index.
        assert_eq!(sd.payload_bytes(), 64 + 12 + Precision::F32.body_bytes(3));
        assert_eq!(
            sd.payload_bytes(),
            sparse_payload_bytes_layers(Precision::F32, &[2, 1], &[4, 4])
        );
    }

    #[test]
    fn layered_full_k_matches_dense_payload_exactly() {
        let (params, base) = vecs(8, 96);
        let mut sd = SparseDelta::new();
        let mut dense = QuantBuf::new();
        for p in [Precision::F32, Precision::F16, Precision::Int8] {
            sd.encode_topk_layers(p, &params, &base, None, &[64, 32], &[64, 32]);
            dense.encode(p, &params);
            assert_eq!(sd.len(), 96);
            assert_eq!(sd.payload_bytes(), dense.payload_bytes(), "{}", p.name());
            for i in 0..96 {
                assert_eq!(sd.value(i).to_bits(), dense.get(i).to_bits(), "{}", p.name());
            }
        }
        // A full layer next to a partial one elides only its own index
        // block.
        sd.encode_topk_layers(Precision::F32, &params, &base, None, &[64, 32], &[64, 8]);
        assert_eq!(sd.len(), 72);
        assert_eq!(sd.payload_bytes(), 64 + 4 * 8 + Precision::F32.body_bytes(72));
    }

    #[test]
    fn layered_error_feedback_matches_flat_semantics_per_range() {
        let params = vec![3.0f32, 1.0, 0.5, 2.0, 0.25, 0.125];
        let base = vec![0.0f32; 6];
        let mut r = vec![0.0f32; 6];
        let mut sd = SparseDelta::new();
        sd.encode_topk_layers(Precision::F32, &params, &base, Some(&mut r), &[3, 3], &[1, 1]);
        assert_eq!(sd.indices(), &[0, 3]);
        assert_eq!(r, vec![0.0, 1.0, 0.5, 0.0, 0.25, 0.125]);
        // The residual participates in the next selection within its
        // layer, exactly like the flat path.
        sd.encode_topk_layers(Precision::F32, &params, &base, Some(&mut r), &[3, 3], &[1, 1]);
        assert_eq!(sd.indices(), &[1, 4]);
    }

    #[test]
    fn layered_ks_are_clamped_per_layer() {
        let (params, base) = vecs(9, 10);
        let mut sd = SparseDelta::new();
        sd.encode_topk_layers(Precision::F32, &params, &base, None, &[6, 4], &[0, 100]);
        // k=0 clamps to 1 in layer 0; k=100 clamps to 4 (full layer 1).
        assert_eq!(sd.len(), 5);
        assert!(sd.indices()[0] < 6);
        assert_eq!(&sd.indices()[1..], &[6, 7, 8, 9]);
    }

    #[test]
    fn value_at_finds_transmitted_coords_only() {
        let params = vec![0.0f32, 5.0, -0.1, -7.0, 0.2, 3.0];
        let base = vec![0.0f32; 6];
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 3);
        assert_eq!(sd.value_at(1), Some(5.0));
        assert_eq!(sd.value_at(3), Some(-7.0));
        assert_eq!(sd.value_at(0), None);
        assert_eq!(sd.value_at(4), None);
    }

    #[test]
    fn checksum_covers_indices_and_values() {
        let (params, base) = vecs(12, 50);
        let mut a = SparseDelta::new();
        let mut b = SparseDelta::new();
        a.encode_topk(Precision::F32, &params, &base, None, 10);
        b.encode_topk(Precision::F32, &params, &base, None, 10);
        assert_eq!(a.checksum(), b.checksum(), "same encode, same sum");
        // A different selection budget changes the index block.
        b.encode_topk(Precision::F32, &params, &base, None, 11);
        assert_ne!(a.checksum(), b.checksum());
        // Same k, different values.
        let mut bumped = params.clone();
        bumped[0] += 100.0;
        b.encode_topk(Precision::F32, &bumped, &base, None, 10);
        assert_ne!(a.checksum(), b.checksum());
    }

    #[test]
    fn k_is_clamped_to_valid_range() {
        let (params, base) = vecs(5, 9);
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::F32, &params, &base, None, 0);
        assert_eq!(sd.len(), 1);
        sd.encode_topk(Precision::F32, &params, &base, None, 1000);
        assert_eq!(sd.len(), 9);
    }

    #[test]
    fn buffer_reuse_across_shapes() {
        let (p1, b1) = vecs(6, 64);
        let (p2, b2) = vecs(7, 16);
        let mut sd = SparseDelta::new();
        sd.encode_topk(Precision::Int8, &p1, &b1, None, 10);
        assert_eq!((sd.len(), sd.dim()), (10, 64));
        sd.encode_topk(Precision::F16, &p2, &b2, None, 4);
        assert_eq!((sd.len(), sd.dim()), (4, 16));
        assert!(!sd.is_empty());
        let mut out = vec![0.0f32; 16];
        sd.scatter_into(&mut out);
    }
}
