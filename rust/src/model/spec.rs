//! `params_spec.json` — the contract between the AOT bundle and the
//! coordinator: flat-vector layout, batch shapes, and the analytic cost
//! model that drives the device-latency simulator.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Value};

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub param_count: usize,
    pub input_dim: usize,
    pub image_dim: usize,
    pub num_classes: usize,
    pub batch_size: usize,
    pub eval_batch: usize,
    pub seed: u64,
    pub pallas_mode: String,
    /// Analytic FLOPs of one train step (feeds the device model).
    pub train_step_flops: u64,
    pub eval_step_flops: u64,
    pub layers: Vec<LayerSpec>,
    /// Directory the spec was loaded from (artifact root).
    pub dir: PathBuf,
}

impl ParamSpec {
    /// Load and validate `params_spec.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("params_spec.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = json::parse(&text).context("parsing params_spec.json")?;
        Self::from_json(&v, dir)
    }

    fn from_json(v: &Value, dir: PathBuf) -> Result<Self> {
        let get_usize = |k: &str| -> Result<usize> {
            v.req(k)?
                .as_usize()
                .with_context(|| format!("spec field {k} must be a non-negative integer"))
        };
        let layers_v = v.req("layers")?.as_arr().context("layers must be an array")?;
        let mut layers = Vec::with_capacity(layers_v.len());
        for lv in layers_v {
            layers.push(LayerSpec {
                name: lv.req("name")?.as_str().context("layer name")?.to_string(),
                shape: lv
                    .req("shape")?
                    .as_arr()
                    .context("layer shape")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
                offset: lv.req("offset")?.as_usize().context("layer offset")?,
                size: lv.req("size")?.as_usize().context("layer size")?,
            });
        }
        let spec = ParamSpec {
            param_count: get_usize("param_count")?,
            input_dim: get_usize("input_dim")?,
            image_dim: get_usize("image_dim")?,
            num_classes: get_usize("num_classes")?,
            batch_size: get_usize("batch_size")?,
            eval_batch: get_usize("eval_batch")?,
            seed: get_usize("seed")? as u64,
            pallas_mode: v.req("pallas_mode")?.as_str().context("pallas_mode")?.to_string(),
            train_step_flops: get_usize("train_step_flops")? as u64,
            eval_step_flops: get_usize("eval_step_flops")? as u64,
            layers,
            dir,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Internal consistency: layers are contiguous, sizes match shapes, and
    /// the total equals `param_count`.
    pub fn validate(&self) -> Result<()> {
        let mut off = 0usize;
        for l in &self.layers {
            if l.offset != off {
                bail!("layer {} offset {} != expected {off}", l.name, l.offset);
            }
            let prod: usize = l.shape.iter().product();
            if prod != l.size {
                bail!("layer {} size {} != shape product {prod}", l.name, l.size);
            }
            off += l.size;
        }
        if off != self.param_count {
            bail!("layers sum to {off} != param_count {}", self.param_count);
        }
        if self.input_dim != self.image_dim * self.image_dim {
            bail!("input_dim != image_dim^2");
        }
        Ok(())
    }

    /// Load the server's initial parameters (theta_0, Algorithm 1 line 2).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let path = self.dir.join("init_params.f32");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != self.param_count * 4 {
            bail!(
                "init_params.f32 is {} bytes, expected {}",
                bytes.len(),
                self.param_count * 4
            );
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Path of a named HLO artifact.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Byte size of a serialized model payload (f32 params + 64B header) —
    /// used by the network simulator for transfer times.
    pub fn model_payload_bytes(&self) -> u64 {
        (self.param_count * 4 + 64) as u64
    }

    /// Find a layer by name.
    pub fn layer(&self, name: &str) -> Option<&LayerSpec> {
        self.layers.iter().find(|l| l.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec_json(param_count: usize) -> String {
        format!(
            r#"{{
              "param_count": {param_count},
              "input_dim": 784, "image_dim": 28, "num_classes": 10,
              "batch_size": 32, "eval_batch": 256, "seed": 0,
              "pallas_mode": "head",
              "train_step_flops": 1000000, "eval_step_flops": 300000,
              "layers": [
                {{"name": "a/w", "shape": [2, 3], "offset": 0, "size": 6}},
                {{"name": "a/b", "shape": [4], "offset": 6, "size": 4}}
              ]
            }}"#
        )
    }

    #[test]
    fn parses_valid_spec() {
        let v = json::parse(&spec_json(10)).unwrap();
        let s = ParamSpec::from_json(&v, PathBuf::from("/tmp")).unwrap();
        assert_eq!(s.param_count, 10);
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.layer("a/b").unwrap().offset, 6);
        assert_eq!(s.model_payload_bytes(), 10 * 4 + 64);
    }

    #[test]
    fn rejects_bad_total() {
        let v = json::parse(&spec_json(11)).unwrap();
        assert!(ParamSpec::from_json(&v, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_gap_in_offsets() {
        let text = spec_json(10).replace("\"offset\": 6", "\"offset\": 7");
        let v = json::parse(&text).unwrap();
        assert!(ParamSpec::from_json(&v, PathBuf::from("/tmp")).is_err());
    }
}
