//! Dense `f32` vector math on the coordinator hot path.
//!
//! Aggregation (`weighted_average`) and the EAFLM/VAFL norms run every
//! round over every participating model, so these are written to
//! auto-vectorize: flat slices, no bounds checks in the inner loops
//! (chunked iterators), f64 accumulation for numerical stability.

/// A model parameter vector (opaque to the coordinator).
pub type ParamVec = Vec<f32>;

/// Squared L2 norm, accumulated in f64.
pub fn l2_norm_sq(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// Squared L2 distance `||a - b||^2`, accumulated in f64.
///
/// This is the `||grad_prev - grad||^2` factor of the paper's Eq. 1.
pub fn sq_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "sq_distance length mismatch");
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum()
}

/// `y += alpha * x` (SGD-style update, mixing, etc.).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// FedAvg aggregation (Algorithm 1 line 16): `theta = sum_i (n_i/n) theta_i`.
///
/// `models` and `weights` must be non-empty and same-length; weights are
/// normalized internally so callers can pass raw sample counts `n_i`.
pub fn weighted_average(models: &[&[f32]], weights: &[f64]) -> ParamVec {
    assert!(!models.is_empty(), "weighted_average of zero models");
    assert_eq!(models.len(), weights.len(), "models/weights length mismatch");
    let dim = models[0].len();
    for m in models {
        assert_eq!(m.len(), dim, "model dimension mismatch");
    }
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");

    let mut acc = vec![0.0f64; dim];
    for (m, &w) in models.iter().zip(weights) {
        let wn = w / total;
        for (a, &v) in acc.iter_mut().zip(m.iter()) {
            *a += wn * v as f64;
        }
    }
    acc.into_iter().map(|v| v as f32).collect()
}

/// In-place weighted average into a reusable buffer (hot-path variant used
/// by the coordinator to avoid per-round allocation; see EXPERIMENTS.md
/// §Perf).
pub fn weighted_average_into(models: &[&[f32]], weights: &[f64], out: &mut [f32], scratch: &mut Vec<f64>) {
    assert!(!models.is_empty());
    assert_eq!(models.len(), weights.len());
    let dim = models[0].len();
    assert_eq!(out.len(), dim);
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0);
    scratch.clear();
    scratch.resize(dim, 0.0);
    for (m, &w) in models.iter().zip(weights) {
        let wn = w / total;
        for (a, &v) in scratch.iter_mut().zip(m.iter()) {
            *a += wn * v as f64;
        }
    }
    for (o, &a) in out.iter_mut().zip(scratch.iter()) {
        *o = a as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(sq_distance(&[1.0, 2.0], [0.0, 0.0].as_slice()), 5.0);
        assert_eq!(sq_distance(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn sq_distance_checks_len() {
        sq_distance(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn axpy_updates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
    }

    #[test]
    fn weighted_average_normalizes_sample_counts() {
        let a = vec![0.0f32, 0.0];
        let b = vec![1.0f32, 2.0];
        // n_a = 3000, n_b = 1000 -> 0.75*a + 0.25*b
        let avg = weighted_average(&[&a, &b], &[3000.0, 1000.0]);
        assert_eq!(avg, vec![0.25, 0.5]);
    }

    #[test]
    fn weighted_average_single_model_is_identity() {
        let a = vec![1.5f32, -2.0, 3.0];
        assert_eq!(weighted_average(&[&a], &[7.0]), a);
    }

    #[test]
    fn weighted_average_into_matches_alloc_version() {
        let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..100).map(|i| (i * 2) as f32).collect();
        let want = weighted_average(&[&a, &b], &[1.0, 2.0]);
        let mut out = vec![0.0f32; 100];
        let mut scratch = Vec::new();
        weighted_average_into(&[&a, &b], &[1.0, 2.0], &mut out, &mut scratch);
        assert_eq!(out, want);
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn weighted_average_rejects_empty() {
        weighted_average(&[], &[]);
    }
}
