//! Dense `f32` vector math on the coordinator hot path.
//!
//! Aggregation (`weighted_average`) and the EAFLM/VAFL norms run every
//! round over every participating model, so the inner loops are written as
//! `chunks_exact(8)` + explicit remainder: eight independent accumulator
//! lanes, no bounds checks, f64 accumulation for numerical stability — a
//! shape LLVM reliably auto-vectorizes. The averaging kernels additionally
//! fan out across parameter chunks on scoped threads (`util::par`);
//! because every output index sees exactly the same operations in the same
//! order regardless of the split, results are bit-identical for every
//! worker count (asserted in `tests/proptests.rs`).

/// A model parameter vector (opaque to the coordinator).
pub type ParamVec = Vec<f32>;

/// Minimum parameter count per worker before the averaging kernels fan out
/// (below this, spawn cost dominates and the call stays serial and
/// allocation-free).
const PAR_MIN_DIM: usize = 8192;

/// Squared L2 norm, accumulated in f64 over eight lanes.
///
/// Lane order is fixed, so the result is deterministic (though the
/// reduction tree differs from a strictly sequential sum).
pub fn l2_norm_sq(x: &[f32]) -> f64 {
    let mut lanes = [0.0f64; 8];
    let mut chunks = x.chunks_exact(8);
    for c in chunks.by_ref() {
        for (l, &v) in lanes.iter_mut().zip(c) {
            *l += v as f64 * v as f64;
        }
    }
    let mut tail = 0.0f64;
    for &v in chunks.remainder() {
        tail += v as f64 * v as f64;
    }
    lanes.iter().sum::<f64>() + tail
}

/// Squared L2 distance `||a - b||^2`, accumulated in f64 over eight lanes.
///
/// This is the `||grad_prev - grad||^2` factor of the paper's Eq. 1.
pub fn sq_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len(), "sq_distance length mismatch");
    let mut lanes = [0.0f64; 8];
    let mut ca = a.chunks_exact(8);
    let mut cb = b.chunks_exact(8);
    for (x8, y8) in ca.by_ref().zip(cb.by_ref()) {
        for (l, (&x, &y)) in lanes.iter_mut().zip(x8.iter().zip(y8)) {
            let d = x as f64 - y as f64;
            *l += d * d;
        }
    }
    let mut tail = 0.0f64;
    for (&x, &y) in ca.remainder().iter().zip(cb.remainder()) {
        let d = x as f64 - y as f64;
        tail += d * d;
    }
    lanes.iter().sum::<f64>() + tail
}

/// `y += alpha * x` (SGD-style update, mixing, etc.).
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len(), "axpy length mismatch");
    let mut cy = y.chunks_exact_mut(8);
    let mut cx = x.chunks_exact(8);
    for (y8, x8) in cy.by_ref().zip(cx.by_ref()) {
        for (yi, &xi) in y8.iter_mut().zip(x8) {
            *yi += alpha * xi;
        }
    }
    for (yi, &xi) in cy.into_remainder().iter_mut().zip(cx.remainder()) {
        *yi += alpha * xi;
    }
}

/// `acc[i] += w * x[i]` — the 8-lane accumulation kernel shared by the
/// averaging paths. Elementwise (no cross-index reduction), so chunking
/// never changes any output bit.
#[inline]
fn accumulate_scaled(x: &[f32], w: f64, acc: &mut [f64]) {
    debug_assert_eq!(x.len(), acc.len());
    let mut ca = acc.chunks_exact_mut(8);
    let mut cx = x.chunks_exact(8);
    for (a8, x8) in ca.by_ref().zip(cx.by_ref()) {
        for (a, &v) in a8.iter_mut().zip(x8) {
            *a += w * v as f64;
        }
    }
    for (a, &v) in ca.into_remainder().iter_mut().zip(cx.remainder()) {
        *a += w * v as f64;
    }
}

/// FedAvg aggregation (Algorithm 1 line 16): `theta = sum_i (n_i/n) theta_i`.
///
/// `models` and `weights` must be non-empty and same-length; weights are
/// normalized internally so callers can pass raw sample counts `n_i`.
/// Allocating reference version — the coordinator uses
/// [`weighted_average_into`]; this stays as the semantic oracle for tests.
pub fn weighted_average(models: &[&[f32]], weights: &[f64]) -> ParamVec {
    let dim = models.first().map_or(0, |m| m.len());
    let mut out = vec![0.0f32; dim];
    let mut scratch = Vec::new();
    weighted_average_into_t(models, weights, &mut out, &mut scratch, 1);
    out
}

/// In-place weighted average into a reusable buffer (hot-path variant used
/// by the coordinator to avoid per-round allocation; see EXPERIMENTS.md
/// §Perf). Fans out across parameter chunks for large models.
pub fn weighted_average_into(
    models: &[&[f32]],
    weights: &[f64],
    out: &mut [f32],
    scratch: &mut Vec<f64>,
) {
    let dim = models.first().map_or(0, |m| m.len());
    let threads = crate::util::par::threads_for(dim, PAR_MIN_DIM);
    weighted_average_into_t(models, weights, out, scratch, threads);
}

/// Explicit-worker-count variant of [`weighted_average_into`] (benches and
/// the thread-count equivalence property tests). Bit-identical for every
/// `threads` value; `threads == 1` is serial and allocation-free.
pub fn weighted_average_into_t(
    models: &[&[f32]],
    weights: &[f64],
    out: &mut [f32],
    scratch: &mut Vec<f64>,
    threads: usize,
) {
    assert!(!models.is_empty(), "weighted_average of zero models");
    assert_eq!(models.len(), weights.len(), "models/weights length mismatch");
    let dim = models[0].len();
    for m in models {
        assert_eq!(m.len(), dim, "model dimension mismatch");
    }
    assert_eq!(out.len(), dim, "output dimension mismatch");
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum to a positive value");
    scratch.clear();
    scratch.resize(dim, 0.0);
    crate::util::par::par_chunks_mut(scratch.as_mut_slice(), threads, 8, |start, acc| {
        for (m, &w) in models.iter().zip(weights) {
            accumulate_scaled(&m[start..start + acc.len()], w / total, acc);
        }
    });
    for (o, &a) in out.iter_mut().zip(scratch.iter()) {
        *o = a as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norms() {
        assert_eq!(l2_norm_sq(&[3.0, 4.0]), 25.0);
        assert_eq!(sq_distance(&[1.0, 2.0], [0.0, 0.0].as_slice()), 5.0);
        assert_eq!(sq_distance(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn norms_cover_lanes_and_remainder() {
        // 19 = 2 full 8-lane chunks + 3-element remainder.
        let x: Vec<f32> = (1..=19).map(|i| i as f32).collect();
        let want: f64 = (1..=19).map(|i| (i * i) as f64).sum();
        assert_eq!(l2_norm_sq(&x), want);
        let zero = vec![0.0f32; 19];
        assert_eq!(sq_distance(&x, &zero), want);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn sq_distance_checks_len() {
        sq_distance(&[1.0], &[1.0, 2.0]);
    }

    #[test]
    fn axpy_updates() {
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
        // Lanes + remainder.
        let mut y2 = vec![0.0f32; 11];
        let x2: Vec<f32> = (0..11).map(|i| i as f32).collect();
        axpy(0.5, &x2, &mut y2);
        for (i, &v) in y2.iter().enumerate() {
            assert_eq!(v, i as f32 * 0.5);
        }
    }

    #[test]
    fn weighted_average_normalizes_sample_counts() {
        let a = vec![0.0f32, 0.0];
        let b = vec![1.0f32, 2.0];
        // n_a = 3000, n_b = 1000 -> 0.75*a + 0.25*b
        let avg = weighted_average(&[&a, &b], &[3000.0, 1000.0]);
        assert_eq!(avg, vec![0.25, 0.5]);
    }

    #[test]
    fn weighted_average_single_model_is_identity() {
        let a = vec![1.5f32, -2.0, 3.0];
        assert_eq!(weighted_average(&[&a], &[7.0]), a);
    }

    #[test]
    fn weighted_average_into_matches_alloc_version() {
        let a: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..100).map(|i| (i * 2) as f32).collect();
        let want = weighted_average(&[&a, &b], &[1.0, 2.0]);
        let mut out = vec![0.0f32; 100];
        let mut scratch = Vec::new();
        weighted_average_into(&[&a, &b], &[1.0, 2.0], &mut out, &mut scratch);
        assert_eq!(out, want);
    }

    #[test]
    fn weighted_average_into_t_bit_identical_across_threads() {
        let a: Vec<f32> = (0..531).map(|i| (i as f32).sin()).collect();
        let b: Vec<f32> = (0..531).map(|i| (i as f32).cos()).collect();
        let mut base = vec![0.0f32; 531];
        let mut scratch = Vec::new();
        weighted_average_into_t(&[&a, &b], &[3.0, 2.0], &mut base, &mut scratch, 1);
        for threads in 2..=8 {
            let mut out = vec![0.0f32; 531];
            weighted_average_into_t(&[&a, &b], &[3.0, 2.0], &mut out, &mut scratch, threads);
            for (x, y) in out.iter().zip(&base) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "zero models")]
    fn weighted_average_rejects_empty() {
        weighted_average(&[], &[]);
    }
}
