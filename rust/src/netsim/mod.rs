//! Network simulator: the paper's LAN (§IV-A — one 2.4 GHz WLAN, measured
//! 216 Mbps down / 120 Mbps up) as a deterministic latency + bandwidth +
//! jitter + loss model, with byte-accurate message sizing.
//!
//! Communication *counts* (the paper's headline metric, Table III) are
//! tracked by the metrics stack; this module supplies the *time* a message
//! occupies the virtual clock, and simulates transient drops (retries) that
//! make asynchrony matter.

use crate::util::rng::Rng;

/// Direction of a transfer relative to the client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Client -> server (paper: 120 Mbps).
    Up,
    /// Server -> client (paper: 216 Mbps).
    Down,
}

/// Wire messages of the VAFL protocol (Algorithm 1), with sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Message {
    /// Scalar communication value V_i + header (Algorithm 1 line 6).
    ValueReport,
    /// Full model upload theta_i (line 11) — the gated, counted quantity.
    ModelUpload { payload_bytes: u64 },
    /// Global model broadcast theta^{t+1} (end of round).
    ModelBroadcast { payload_bytes: u64 },
    /// Server -> client upload request (line 11 "request").
    UploadRequest,
}

impl Message {
    /// Serialized size in bytes (f32 payload + 64-byte framing header).
    pub fn bytes(&self) -> u64 {
        match self {
            Message::ValueReport => 64 + 4,
            Message::UploadRequest => 64,
            Message::ModelUpload { payload_bytes }
            | Message::ModelBroadcast { payload_bytes } => *payload_bytes,
        }
    }

    pub fn direction(&self) -> Direction {
        match self {
            Message::ValueReport | Message::ModelUpload { .. } => Direction::Up,
            Message::UploadRequest | Message::ModelBroadcast { .. } => Direction::Down,
        }
    }
}

/// Link model parameters.
#[derive(Debug, Clone)]
pub struct LinkProfile {
    pub up_mbps: f64,
    pub down_mbps: f64,
    /// One-way base latency, seconds.
    pub latency_s: f64,
    /// Sigma of multiplicative log-normal latency jitter.
    pub jitter_sigma: f64,
    /// Probability a transfer must be retried once (transient WLAN loss).
    pub drop_prob: f64,
}

impl LinkProfile {
    /// The paper's measured WLAN.
    pub fn paper_lan() -> Self {
        LinkProfile {
            up_mbps: 120.0,
            down_mbps: 216.0,
            latency_s: 0.004,
            jitter_sigma: 0.25,
            drop_prob: 0.02,
        }
    }

    /// An ideal link (ablations: isolate compute heterogeneity).
    pub fn ideal() -> Self {
        LinkProfile {
            up_mbps: f64::INFINITY,
            down_mbps: f64::INFINITY,
            latency_s: 0.0,
            jitter_sigma: 0.0,
            drop_prob: 0.0,
        }
    }

    /// A straggler-heavy WAN: thin asymmetric uplink, high latency, heavy
    /// jitter, frequent transient loss — the regime where the barriered
    /// engine stalls on its slowest transfer every round and the
    /// barrier-free engine pulls ahead (see `experiments::straggler` and
    /// the `async_engine` bench).
    pub fn straggler_wan() -> Self {
        LinkProfile {
            up_mbps: 8.0,
            down_mbps: 40.0,
            latency_s: 0.08,
            jitter_sigma: 0.8,
            drop_prob: 0.15,
        }
    }

    /// Delivery attempts for one transfer: 1 plus one re-delivery per
    /// transient drop, capped at 5 attempts. Each drop consumes exactly
    /// one uniform draw from `rng`, so the retry count is reproducible
    /// from the stream.
    pub fn sample_attempts(&self, rng: &mut Rng) -> u32 {
        let mut attempts = 1u32;
        while self.drop_prob > 0.0 && rng.f64() < self.drop_prob && attempts < 5 {
            attempts += 1;
        }
        attempts
    }

    /// Virtual seconds to deliver `msg`, including retries.
    pub fn transfer_seconds(&self, msg: &Message, rng: &mut Rng) -> f64 {
        let mbps = match msg.direction() {
            Direction::Up => self.up_mbps,
            Direction::Down => self.down_mbps,
        };
        let wire = if mbps.is_finite() {
            (msg.bytes() * 8) as f64 / (mbps * 1e6)
        } else {
            0.0
        };
        let attempts = self.sample_attempts(rng);
        (wire + self.latency_s) * attempts as f64 * rng.lognormal_jitter(self.jitter_sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_jitter(mut l: LinkProfile) -> LinkProfile {
        l.jitter_sigma = 0.0;
        l.drop_prob = 0.0;
        l
    }

    #[test]
    fn message_sizes() {
        assert_eq!(Message::ValueReport.bytes(), 68);
        assert_eq!(Message::UploadRequest.bytes(), 64);
        assert_eq!(Message::ModelUpload { payload_bytes: 1000 }.bytes(), 1000);
    }

    #[test]
    fn directions() {
        assert_eq!(Message::ValueReport.direction(), Direction::Up);
        assert_eq!(
            Message::ModelBroadcast { payload_bytes: 1 }.direction(),
            Direction::Down
        );
    }

    #[test]
    fn upload_slower_than_download() {
        // Paper asymmetry: 120 up vs 216 down.
        let l = no_jitter(LinkProfile::paper_lan());
        let mut rng = Rng::new(1);
        let up = l.transfer_seconds(&Message::ModelUpload { payload_bytes: 1_000_000 }, &mut rng);
        let down =
            l.transfer_seconds(&Message::ModelBroadcast { payload_bytes: 1_000_000 }, &mut rng);
        assert!(up > 1.5 * down, "up {up} down {down}");
        // 1 MB at 120 Mbps ~ 66.7 ms + 4 ms latency.
        assert!((up - (8e6 / 120e6 + 0.004)).abs() < 1e-9);
    }

    #[test]
    fn ideal_link_is_free() {
        let mut rng = Rng::new(2);
        let l = LinkProfile::ideal();
        assert_eq!(
            l.transfer_seconds(&Message::ModelUpload { payload_bytes: 1 << 30 }, &mut rng),
            0.0
        );
    }

    #[test]
    fn drops_add_integer_retries() {
        let mut l = no_jitter(LinkProfile::paper_lan());
        l.drop_prob = 0.9999; // force retries up to the cap
        let mut rng = Rng::new(3);
        let base = no_jitter(LinkProfile::paper_lan())
            .transfer_seconds(&Message::UploadRequest, &mut Rng::new(4));
        let t = l.transfer_seconds(&Message::UploadRequest, &mut rng);
        let ratio = t / base;
        assert!((ratio - ratio.round()).abs() < 1e-9, "ratio {ratio}");
        assert!(ratio >= 2.0 && ratio <= 5.0);
    }

    #[test]
    fn lossy_link_redelivers_exactly_once_per_drop() {
        // Replay the same seeded stream by hand: the number of delivery
        // attempts must be exactly 1 + (number of drop draws below
        // drop_prob before the first success), capped at 5 attempts.
        let mut l = no_jitter(LinkProfile::paper_lan());
        for &p in &[0.05, 0.3, 0.7, 0.9999] {
            l.drop_prob = p;
            for seed in 0..200u64 {
                let mut rng = Rng::new(0xD0_0000 + seed);
                let attempts = l.sample_attempts(&mut rng);
                let mut oracle = Rng::new(0xD0_0000 + seed);
                let mut drops = 0u32;
                while drops < 4 && oracle.f64() < p {
                    drops += 1;
                }
                assert_eq!(attempts, 1 + drops, "p={p} seed={seed}");
                assert!(attempts <= 5);
            }
        }
        // A lossless link never retries and consumes no randomness.
        l.drop_prob = 0.0;
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(l.sample_attempts(&mut rng), 1);
        assert_eq!(rng.next_u64(), before, "lossless path consumed rng");
    }

    #[test]
    fn lossy_transfer_time_monotone_in_payload_bytes() {
        // With the rng stream replayed from the same seed per call, total
        // simulated transfer time (retries included) is monotone
        // non-decreasing in payload bytes — a drop multiplies the per-
        // attempt time, it never reorders sizes.
        let mut l = LinkProfile::straggler_wan();
        l.jitter_sigma = 0.4; // keep jitter, pin the stream per call
        for seed in 0..50u64 {
            let mut last = 0.0f64;
            for bytes in [100u64, 1_000, 50_000, 1_000_000, 5_000_000] {
                let t = l.transfer_seconds(
                    &Message::ModelUpload { payload_bytes: bytes },
                    &mut Rng::new(7000 + seed),
                );
                assert!(
                    t >= last,
                    "seed {seed}: {bytes} B took {t} < smaller payload's {last}"
                );
                last = t;
            }
        }
    }

    #[test]
    fn straggler_wan_is_much_slower_than_paper_lan() {
        let msg = Message::ModelUpload { payload_bytes: 1_000_000 };
        let lan = no_jitter(LinkProfile::paper_lan())
            .transfer_seconds(&msg, &mut Rng::new(1));
        let wan = no_jitter(LinkProfile::straggler_wan())
            .transfer_seconds(&msg, &mut Rng::new(1));
        assert!(wan > 10.0 * lan, "wan {wan} lan {lan}");
    }

    #[test]
    fn deterministic_per_stream() {
        let l = LinkProfile::paper_lan();
        let msg = Message::ModelUpload { payload_bytes: 40_000 };
        let a: Vec<f64> =
            (0..5).map(|_| l.transfer_seconds(&msg, &mut Rng::new(5))).collect();
        // same fresh seed each call -> identical
        assert!(a.windows(2).all(|w| w[0] == w[1]));
    }
}
