//! Network simulator: the paper's LAN (§IV-A — one 2.4 GHz WLAN, measured
//! 216 Mbps down / 120 Mbps up) as a deterministic latency + bandwidth +
//! jitter + loss model, with byte-accurate message sizing.
//!
//! Communication *counts* (the paper's headline metric, Table III) are
//! tracked by the metrics stack; this module supplies the *time* a message
//! occupies the virtual clock, and simulates transient drops (retries) that
//! make asynchrony matter.

use crate::config::FaultConfig;
use crate::util::codec::{Dec, Enc};
use crate::util::rng::Rng;
use anyhow::Result;

/// Bytes the integrity header adds to every payload frame when fault
/// injection is armed: 4-byte length + 8-byte checksum + 4-byte per-client
/// monotone sequence number. Charged on uploads and sparse broadcasts; with
/// faults disabled no header is sent and byte accounting is unchanged.
pub const INTEGRITY_HEADER_BYTES: u64 = 16;

/// Direction of a transfer relative to the client.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Client -> server (paper: 120 Mbps).
    Up,
    /// Server -> client (paper: 216 Mbps).
    Down,
}

/// Wire messages of the VAFL protocol (Algorithm 1), with sizes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Message {
    /// Scalar communication value V_i + header (Algorithm 1 line 6).
    ValueReport,
    /// Full model upload theta_i (line 11) — the gated, counted quantity.
    ModelUpload { payload_bytes: u64 },
    /// Global model broadcast theta^{t+1} (end of round).
    ModelBroadcast { payload_bytes: u64 },
    /// Server -> client upload request (line 11 "request").
    UploadRequest,
}

impl Message {
    /// Serialized size in bytes (f32 payload + 64-byte framing header).
    pub fn bytes(&self) -> u64 {
        match self {
            Message::ValueReport => 64 + 4,
            Message::UploadRequest => 64,
            Message::ModelUpload { payload_bytes }
            | Message::ModelBroadcast { payload_bytes } => *payload_bytes,
        }
    }

    pub fn direction(&self) -> Direction {
        match self {
            Message::ValueReport | Message::ModelUpload { .. } => Direction::Up,
            Message::UploadRequest | Message::ModelBroadcast { .. } => Direction::Down,
        }
    }
}

/// Link model parameters.
#[derive(Debug, Clone)]
pub struct LinkProfile {
    pub up_mbps: f64,
    pub down_mbps: f64,
    /// One-way base latency, seconds.
    pub latency_s: f64,
    /// Sigma of multiplicative log-normal latency jitter.
    pub jitter_sigma: f64,
    /// Probability a transfer must be retried once (transient WLAN loss).
    pub drop_prob: f64,
    /// Cap on delivery attempts per transfer (>= 1). When the retry loop
    /// hits this cap the link-layer model stops retrying; callers that care
    /// use [`LinkProfile::sample_attempts_counted`] to learn how often the
    /// cap bound the loop instead of an observed success.
    pub max_attempts: u32,
}

impl LinkProfile {
    /// The paper's measured WLAN.
    pub fn paper_lan() -> Self {
        LinkProfile {
            up_mbps: 120.0,
            down_mbps: 216.0,
            latency_s: 0.004,
            jitter_sigma: 0.25,
            drop_prob: 0.02,
            max_attempts: 5,
        }
    }

    /// An ideal link (ablations: isolate compute heterogeneity).
    pub fn ideal() -> Self {
        LinkProfile {
            up_mbps: f64::INFINITY,
            down_mbps: f64::INFINITY,
            latency_s: 0.0,
            jitter_sigma: 0.0,
            drop_prob: 0.0,
            max_attempts: 5,
        }
    }

    /// A straggler-heavy WAN: thin asymmetric uplink, high latency, heavy
    /// jitter, frequent transient loss — the regime where the barriered
    /// engine stalls on its slowest transfer every round and the
    /// barrier-free engine pulls ahead (see `experiments::straggler` and
    /// the `async_engine` bench).
    pub fn straggler_wan() -> Self {
        LinkProfile {
            up_mbps: 8.0,
            down_mbps: 40.0,
            latency_s: 0.08,
            jitter_sigma: 0.8,
            drop_prob: 0.15,
            max_attempts: 5,
        }
    }

    /// Delivery attempts for one transfer: 1 plus one re-delivery per
    /// transient drop, capped at `max_attempts`. Each drop consumes
    /// exactly one uniform draw from `rng`, so the retry count is
    /// reproducible from the stream.
    pub fn sample_attempts(&self, rng: &mut Rng) -> u32 {
        let mut capped = 0u64;
        self.sample_attempts_counted(rng, &mut capped)
    }

    /// [`LinkProfile::sample_attempts`], but counting the transfers whose
    /// retry loop was stopped by the attempt cap rather than by a success
    /// draw. The old model pretended the capped-out attempt succeeded;
    /// the count makes that optimism visible in telemetry instead of
    /// silent. Draw-stream identical to `sample_attempts`.
    pub fn sample_attempts_counted(&self, rng: &mut Rng, capped: &mut u64) -> u32 {
        let cap = self.max_attempts.max(1);
        let mut attempts = 1u32;
        while self.drop_prob > 0.0 {
            let dropped = rng.f64() < self.drop_prob;
            if !dropped {
                break; // observed success
            }
            if attempts >= cap {
                // The draw said "dropped again" but the cap forces the
                // loop to stop and assume delivery it never sampled.
                *capped += 1;
                break;
            }
            attempts += 1;
        }
        attempts
    }

    /// Virtual seconds to deliver `msg`, including retries.
    pub fn transfer_seconds(&self, msg: &Message, rng: &mut Rng) -> f64 {
        let mut capped = 0u64;
        self.transfer_seconds_counted(msg, rng, &mut capped)
    }

    /// [`LinkProfile::transfer_seconds`] with capped-out retry accounting
    /// (see [`LinkProfile::sample_attempts_counted`]).
    pub fn transfer_seconds_counted(&self, msg: &Message, rng: &mut Rng, capped: &mut u64) -> f64 {
        let mbps = match msg.direction() {
            Direction::Up => self.up_mbps,
            Direction::Down => self.down_mbps,
        };
        let wire = if mbps.is_finite() {
            (msg.bytes() * 8) as f64 / (mbps * 1e6)
        } else {
            0.0
        };
        let attempts = self.sample_attempts_counted(rng, capped);
        (wire + self.latency_s) * attempts as f64 * rng.lognormal_jitter(self.jitter_sigma)
    }
}

/// What happened to one injected frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFate {
    /// Arrived intact.
    Delivered,
    /// Terminally lost (no bytes arrive; sender times out and retransmits).
    Lost,
    /// Arrived but fails its integrity checksum (receiver treats it as
    /// lost and NACKs / waits for retransmit); counted separately.
    Corrupt,
    /// Arrived intact and a stale duplicate arrives later (suppressed at
    /// the receiver via the monotone per-client sequence number).
    Duplicated,
}

/// Deterministic fault-injection plan: terminal loss, corruption,
/// duplication, reordering, client crashes, and server outage windows, all
/// drawn from RNG streams forked off the experiment root. Every draw
/// happens at an event-queue pop point in the (single-threaded) engine
/// loop, so fault schedules are seed-reproducible and thread-count
/// invariant by construction.
///
/// With `[faults] enabled = false` no plan is built and no stream is ever
/// consumed — fault-free runs stay bitwise identical to pre-fault builds.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    cfg: FaultConfig,
    /// Uplink frame fates + reorder delays.
    up_rng: Rng,
    /// Downlink (broadcast) frame fates.
    down_rng: Rng,
    /// Client crash schedule.
    crash_rng: Rng,
}

impl FaultPlan {
    /// Fork the fault streams off the experiment root RNG.
    pub fn new(cfg: &FaultConfig, root: &Rng) -> Self {
        FaultPlan {
            cfg: *cfg,
            up_rng: root.fork("faults/up"),
            down_rng: root.fork("faults/down"),
            crash_rng: root.fork("faults/crash"),
        }
    }

    pub fn cfg(&self) -> &FaultConfig {
        &self.cfg
    }

    /// Classify one fate draw `u` against stacked probability bands.
    fn fate(u: f64, loss: f64, corrupt: f64, dup: f64) -> FrameFate {
        if u < loss {
            FrameFate::Lost
        } else if u < loss + corrupt {
            FrameFate::Corrupt
        } else if u < loss + corrupt + dup {
            FrameFate::Duplicated
        } else {
            FrameFate::Delivered
        }
    }

    /// Fate of one uplink payload frame arriving at virtual time `now`.
    /// During a server outage window every frame is lost without consuming
    /// a draw (the outage schedule is purely arithmetic); otherwise exactly
    /// one uniform is drawn per call.
    pub fn up_fate(&mut self, now: f64) -> FrameFate {
        if self.in_outage(now) {
            return FrameFate::Lost;
        }
        let u = self.up_rng.f64();
        Self::fate(u, self.cfg.loss_prob, self.cfg.corrupt_prob, self.cfg.dup_prob)
    }

    /// Fate of one downlink (broadcast) frame; one uniform per call.
    /// Duplication is not modeled downstream — a duplicate broadcast is
    /// harmlessly idempotent on the client.
    pub fn down_fate(&mut self) -> FrameFate {
        let u = self.down_rng.f64();
        Self::fate(u, self.cfg.down_loss_prob, self.cfg.down_corrupt_prob, 0.0)
    }

    /// True while the server sits inside a scheduled outage window.
    /// Windows open at `outage_every, 2*outage_every, ...` (never at t=0,
    /// which would kill the boot uploads) and last `outage_len` seconds.
    pub fn in_outage(&self, now: f64) -> bool {
        self.cfg.outage_every > 0.0
            && now >= self.cfg.outage_every
            && (now % self.cfg.outage_every) < self.cfg.outage_len
    }

    /// Crash draw for a client reaching a scheduling point. Consumes one
    /// uniform per call only when crashes are armed.
    pub fn crash(&mut self) -> bool {
        self.cfg.crash_prob > 0.0 && self.crash_rng.f64() < self.cfg.crash_prob
    }

    /// Extra delivery delay modeling reordering: with `reorder_prob`, a
    /// delivered frame is held for up to `reorder_window` extra seconds,
    /// letting later frames overtake it (the sequence number makes the
    /// overtaken frame a suppressible stale duplicate when it mattered).
    pub fn reorder_delay(&mut self) -> f64 {
        if self.cfg.reorder_prob > 0.0 && self.up_rng.f64() < self.cfg.reorder_prob {
            self.up_rng.f64() * self.cfg.reorder_window
        } else {
            0.0
        }
    }

    /// Sender backoff before retransmit number `attempt` (1-based):
    /// `backoff_base * 2^(attempt-1)`, capped at `backoff_cap`.
    pub fn backoff(&self, attempt: u32) -> f64 {
        let exp = attempt.saturating_sub(1).min(30);
        (self.cfg.backoff_base * f64::powi(2.0, exp as i32)).min(self.cfg.backoff_cap)
    }

    pub fn max_retransmits(&self) -> u32 {
        self.cfg.max_retransmits
    }

    pub fn crash_downtime(&self) -> f64 {
        self.cfg.crash_downtime
    }

    pub fn checkpoint_every(&self) -> usize {
        self.cfg.checkpoint_every
    }

    /// Serialize the three stream positions (the config half is rebuilt
    /// from the experiment config on restore).
    pub fn save(&self, enc: &mut Enc) {
        for rng in [&self.up_rng, &self.down_rng, &self.crash_rng] {
            let (s, spare) = rng.state();
            enc.u64s(&s);
            enc.opt_f64(spare);
        }
    }

    /// Restore stream positions into a freshly built plan.
    pub fn load(&mut self, dec: &mut Dec) -> Result<()> {
        for rng in [&mut self.up_rng, &mut self.down_rng, &mut self.crash_rng] {
            let s = dec.u64s()?;
            anyhow::ensure!(s.len() == 4, "bad rng state length {}", s.len());
            let spare = dec.opt_f64()?;
            *rng = Rng::from_state([s[0], s[1], s[2], s[3]], spare);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn no_jitter(mut l: LinkProfile) -> LinkProfile {
        l.jitter_sigma = 0.0;
        l.drop_prob = 0.0;
        l
    }

    #[test]
    fn message_sizes() {
        assert_eq!(Message::ValueReport.bytes(), 68);
        assert_eq!(Message::UploadRequest.bytes(), 64);
        assert_eq!(Message::ModelUpload { payload_bytes: 1000 }.bytes(), 1000);
    }

    #[test]
    fn directions() {
        assert_eq!(Message::ValueReport.direction(), Direction::Up);
        assert_eq!(
            Message::ModelBroadcast { payload_bytes: 1 }.direction(),
            Direction::Down
        );
    }

    #[test]
    fn upload_slower_than_download() {
        // Paper asymmetry: 120 up vs 216 down.
        let l = no_jitter(LinkProfile::paper_lan());
        let mut rng = Rng::new(1);
        let up = l.transfer_seconds(&Message::ModelUpload { payload_bytes: 1_000_000 }, &mut rng);
        let down =
            l.transfer_seconds(&Message::ModelBroadcast { payload_bytes: 1_000_000 }, &mut rng);
        assert!(up > 1.5 * down, "up {up} down {down}");
        // 1 MB at 120 Mbps ~ 66.7 ms + 4 ms latency.
        assert!((up - (8e6 / 120e6 + 0.004)).abs() < 1e-9);
    }

    #[test]
    fn ideal_link_is_free() {
        let mut rng = Rng::new(2);
        let l = LinkProfile::ideal();
        assert_eq!(
            l.transfer_seconds(&Message::ModelUpload { payload_bytes: 1 << 30 }, &mut rng),
            0.0
        );
    }

    #[test]
    fn drops_add_integer_retries() {
        let mut l = no_jitter(LinkProfile::paper_lan());
        l.drop_prob = 0.9999; // force retries up to the cap
        let mut rng = Rng::new(3);
        let base = no_jitter(LinkProfile::paper_lan())
            .transfer_seconds(&Message::UploadRequest, &mut Rng::new(4));
        let t = l.transfer_seconds(&Message::UploadRequest, &mut rng);
        let ratio = t / base;
        assert!((ratio - ratio.round()).abs() < 1e-9, "ratio {ratio}");
        assert!(ratio >= 2.0 && ratio <= 5.0);
    }

    #[test]
    fn lossy_link_redelivers_exactly_once_per_drop() {
        // Replay the same seeded stream by hand: the number of delivery
        // attempts must be exactly 1 + (number of drop draws below
        // drop_prob before the first success), capped at 5 attempts.
        let mut l = no_jitter(LinkProfile::paper_lan());
        for &p in &[0.05, 0.3, 0.7, 0.9999] {
            l.drop_prob = p;
            for seed in 0..200u64 {
                let mut rng = Rng::new(0xD0_0000 + seed);
                let attempts = l.sample_attempts(&mut rng);
                let mut oracle = Rng::new(0xD0_0000 + seed);
                let mut drops = 0u32;
                while drops < 4 && oracle.f64() < p {
                    drops += 1;
                }
                assert_eq!(attempts, 1 + drops, "p={p} seed={seed}");
                assert!(attempts <= 5);
            }
        }
        // A lossless link never retries and consumes no randomness.
        l.drop_prob = 0.0;
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(l.sample_attempts(&mut rng), 1);
        assert_eq!(rng.next_u64(), before, "lossless path consumed rng");
    }

    #[test]
    fn lossy_transfer_time_monotone_in_payload_bytes() {
        // With the rng stream replayed from the same seed per call, total
        // simulated transfer time (retries included) is monotone
        // non-decreasing in payload bytes — a drop multiplies the per-
        // attempt time, it never reorders sizes.
        let mut l = LinkProfile::straggler_wan();
        l.jitter_sigma = 0.4; // keep jitter, pin the stream per call
        for seed in 0..50u64 {
            let mut last = 0.0f64;
            for bytes in [100u64, 1_000, 50_000, 1_000_000, 5_000_000] {
                let t = l.transfer_seconds(
                    &Message::ModelUpload { payload_bytes: bytes },
                    &mut Rng::new(7000 + seed),
                );
                assert!(
                    t >= last,
                    "seed {seed}: {bytes} B took {t} < smaller payload's {last}"
                );
                last = t;
            }
        }
    }

    #[test]
    fn straggler_wan_is_much_slower_than_paper_lan() {
        let msg = Message::ModelUpload { payload_bytes: 1_000_000 };
        let lan = no_jitter(LinkProfile::paper_lan())
            .transfer_seconds(&msg, &mut Rng::new(1));
        let wan = no_jitter(LinkProfile::straggler_wan())
            .transfer_seconds(&msg, &mut Rng::new(1));
        assert!(wan > 10.0 * lan, "wan {wan} lan {lan}");
    }

    #[test]
    fn deterministic_per_stream() {
        let l = LinkProfile::paper_lan();
        let msg = Message::ModelUpload { payload_bytes: 40_000 };
        let a: Vec<f64> =
            (0..5).map(|_| l.transfer_seconds(&msg, &mut Rng::new(5))).collect();
        // same fresh seed each call -> identical
        assert!(a.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn attempt_cap_is_configurable_and_counted() {
        let mut l = no_jitter(LinkProfile::paper_lan());
        l.drop_prob = 0.9999;
        // With a near-certain drop, every transfer caps out at max_attempts
        // and each cap-out is counted instead of silently "succeeding".
        for cap in [1u32, 2, 3, 8] {
            l.max_attempts = cap;
            let mut capped = 0u64;
            let mut rng = Rng::new(77);
            for _ in 0..50 {
                let a = l.sample_attempts_counted(&mut rng, &mut capped);
                assert_eq!(a, cap, "cap {cap}");
            }
            assert_eq!(capped, 50, "cap {cap}");
        }
        // A reliable link never caps out.
        l.drop_prob = 0.0;
        l.max_attempts = 3;
        let mut capped = 0u64;
        let mut rng = Rng::new(78);
        assert_eq!(l.sample_attempts_counted(&mut rng, &mut capped), 1);
        assert_eq!(capped, 0);
    }

    #[test]
    fn counted_variant_matches_legacy_draw_stream() {
        // sample_attempts (cap 5) must consume the exact same uniforms as
        // the pre-cap-fix loop so all golden streams stay bitwise. Oracle:
        // one draw per iteration; success draw exits; a drop draw at the
        // cap exits (that draw is still consumed).
        let mut l = no_jitter(LinkProfile::paper_lan());
        for &p in &[0.05, 0.5, 0.9999] {
            l.drop_prob = p;
            for seed in 0..100u64 {
                let mut rng = Rng::new(0xCAFE + seed);
                let _ = l.sample_attempts(&mut rng);
                let mut oracle = Rng::new(0xCAFE + seed);
                let mut attempts = 1u32;
                while oracle.f64() < p {
                    if attempts >= 5 {
                        break;
                    }
                    attempts += 1;
                }
                // Both streams must now be at the same position.
                assert_eq!(rng.next_u64(), oracle.next_u64(), "p={p} seed={seed}");
            }
        }
    }

    fn chaos_cfg() -> FaultConfig {
        FaultConfig {
            enabled: true,
            loss_prob: 0.2,
            corrupt_prob: 0.1,
            dup_prob: 0.1,
            down_loss_prob: 0.15,
            down_corrupt_prob: 0.05,
            reorder_prob: 0.25,
            reorder_window: 0.5,
            max_retransmits: 4,
            backoff_base: 0.05,
            backoff_cap: 1.0,
            crash_prob: 0.01,
            crash_downtime: 5.0,
            outage_every: 40.0,
            outage_len: 2.0,
            checkpoint_every: 0,
        }
    }

    #[test]
    fn fault_plan_is_seed_reproducible() {
        let cfg = chaos_cfg();
        let root = Rng::new(2021);
        let mut a = FaultPlan::new(&cfg, &root);
        let mut b = FaultPlan::new(&cfg, &root);
        for i in 0..500 {
            let t = i as f64 * 0.37;
            assert_eq!(a.up_fate(t), b.up_fate(t));
            assert_eq!(a.down_fate(), b.down_fate());
            assert_eq!(a.crash(), b.crash());
            assert_eq!(a.reorder_delay().to_bits(), b.reorder_delay().to_bits());
        }
    }

    #[test]
    fn fault_plan_streams_are_independent() {
        // Consuming only the downlink stream must not move the uplink
        // stream (forked labels), so adding down-faults never perturbs
        // up-fault schedules.
        let cfg = chaos_cfg();
        let root = Rng::new(99);
        let mut a = FaultPlan::new(&cfg, &root);
        let mut b = FaultPlan::new(&cfg, &root);
        for _ in 0..100 {
            let _ = b.down_fate();
        }
        for _ in 0..100 {
            assert_eq!(a.up_fate(1.0), b.up_fate(1.0));
        }
    }

    #[test]
    fn disabled_faults_consume_no_randomness() {
        let mut cfg = chaos_cfg();
        cfg.crash_prob = 0.0;
        cfg.reorder_prob = 0.0;
        let root = Rng::new(5);
        let mut plan = FaultPlan::new(&cfg, &root);
        // crash and reorder draws are gated on their probabilities.
        let before = plan.crash_rng.clone().next_u64();
        assert!(!plan.crash());
        assert_eq!(plan.crash_rng.next_u64(), before);
        let before = plan.up_rng.clone().next_u64();
        assert_eq!(plan.reorder_delay(), 0.0);
        assert_eq!(plan.up_rng.next_u64(), before);
    }

    #[test]
    fn outage_windows_are_arithmetic_and_never_at_boot() {
        let cfg = chaos_cfg(); // every 40 s, 2 s long
        let root = Rng::new(1);
        let plan = FaultPlan::new(&cfg, &root);
        assert!(!plan.in_outage(0.0), "no outage at boot");
        assert!(!plan.in_outage(1.9));
        assert!(plan.in_outage(40.5));
        assert!(!plan.in_outage(42.5));
        assert!(plan.in_outage(81.0));
        // Disabled outages.
        let mut cfg2 = cfg;
        cfg2.outage_every = 0.0;
        let plan2 = FaultPlan::new(&cfg2, &root);
        assert!(!plan2.in_outage(40.5));
    }

    #[test]
    fn backoff_is_capped_exponential() {
        let cfg = chaos_cfg(); // base 0.05, cap 1.0
        let plan = FaultPlan::new(&cfg, &Rng::new(1));
        assert!((plan.backoff(1) - 0.05).abs() < 1e-12);
        assert!((plan.backoff(2) - 0.10).abs() < 1e-12);
        assert!((plan.backoff(3) - 0.20).abs() < 1e-12);
        assert_eq!(plan.backoff(30), 1.0, "cap binds");
        assert_eq!(plan.backoff(200), 1.0, "huge attempts saturate safely");
    }

    #[test]
    fn fault_plan_save_load_resumes_streams_bitwise() {
        let cfg = chaos_cfg();
        let root = Rng::new(7);
        let mut a = FaultPlan::new(&cfg, &root);
        for i in 0..57 {
            let _ = a.up_fate(i as f64);
            let _ = a.down_fate();
            let _ = a.crash();
        }
        let mut enc = Enc::new();
        a.save(&mut enc);
        let bytes = enc.into_bytes();
        let mut b = FaultPlan::new(&cfg, &root);
        let mut dec = Dec::new(&bytes);
        b.load(&mut dec).unwrap();
        dec.finish().unwrap();
        for i in 0..200 {
            let t = 100.0 + i as f64;
            assert_eq!(a.up_fate(t), b.up_fate(t));
            assert_eq!(a.down_fate(), b.down_fate());
            assert_eq!(a.crash(), b.crash());
        }
    }
}
