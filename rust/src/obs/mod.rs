//! Deterministic observability plane: dual-timestamp span tracing, a
//! unified metric registry, and Perfetto / Prometheus exporters.
//!
//! Design rules (the control plane's bitwise-inertness bar, applied to
//! telemetry):
//!
//! * **Zero-cost when disabled.** `obs.enabled = false` (the default)
//!   reduces every span hook to one branch on a plain bool: no RNG is
//!   drawn, no bytes are charged, no steady-state allocation happens,
//!   and the committed record stream stays byte-identical to pre-obs
//!   builds (pinned by goldens 1–8).
//! * **Read-only when armed.** Hooks observe engine state, never mutate
//!   it — armed runs commit the same `RoundRecord` stream as disarmed
//!   runs (pinned by the ninth golden, `barrier_free_traced`).
//! * **Thread-count-invariant virtual stream.** [`SpanKind::Virtual`]
//!   spans are emitted only on the engine thread at deterministic commit
//!   points, so the virtual-time span stream is identical across
//!   `VAFL_THREADS=1/4` and serial vs speculative execution (pinned by
//!   `tests/obs.rs`). Wall-time spans from pool workers ride bounded
//!   lock-free SPSC rings ([`SpanRing`]) and are drained at commit
//!   points; they carry real, non-deterministic wall timings and are
//!   excluded from invariance checks.
//!
//! The [`MetricRegistry`] half is *always* live (plain integer adds at
//! the same commit points that build round records), so counter totals
//! are auditable in every run; it only becomes externally visible via
//! the exporters when obs is armed.

use std::cell::{RefCell, UnsafeCell};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ObsConfig;
use crate::util::codec::{Dec, Enc};
use crate::util::json::{obj, Value};

/// Sentinel for spans not attributed to a single client.
pub const NO_CLIENT: u32 = u32::MAX;

// ---------------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------------

/// Instrumented engine phase. Names are static so metric/trace rows never
/// allocate per event.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpanPhase {
    /// A client's local training rounds (schedule → report).
    ClientExecute,
    /// A speculative local round running on a pool worker (wall only).
    SpecExecute,
    /// A speculation committed as-is at its commit point.
    SpecCommit,
    /// A superseded speculation replayed serially at its commit point.
    SpecReplay,
    /// An upload landing in a shard buffer.
    BufferFill,
    /// A shard buffer flush: aggregation + weights + trust + broadcast.
    Flush,
    /// Encoding per-client downlink frames inside a flush.
    DownlinkEncode,
    /// A lost/corrupt frame rescheduled onto the backoff ladder.
    Retransmit,
    /// Writing an engine checkpoint.
    CheckpointSave,
    /// Restoring an engine checkpoint.
    CheckpointRestore,
    /// An adaptive-control tick.
    ControlTick,
    /// A global-model evaluation.
    Eval,
}

impl SpanPhase {
    pub const ALL: [SpanPhase; 12] = [
        SpanPhase::ClientExecute,
        SpanPhase::SpecExecute,
        SpanPhase::SpecCommit,
        SpanPhase::SpecReplay,
        SpanPhase::BufferFill,
        SpanPhase::Flush,
        SpanPhase::DownlinkEncode,
        SpanPhase::Retransmit,
        SpanPhase::CheckpointSave,
        SpanPhase::CheckpointRestore,
        SpanPhase::ControlTick,
        SpanPhase::Eval,
    ];

    pub fn name(self) -> &'static str {
        match self {
            SpanPhase::ClientExecute => "client_execute",
            SpanPhase::SpecExecute => "spec_execute",
            SpanPhase::SpecCommit => "spec_commit",
            SpanPhase::SpecReplay => "spec_replay",
            SpanPhase::BufferFill => "buffer_fill",
            SpanPhase::Flush => "flush",
            SpanPhase::DownlinkEncode => "downlink_encode",
            SpanPhase::Retransmit => "retransmit",
            SpanPhase::CheckpointSave => "checkpoint_save",
            SpanPhase::CheckpointRestore => "checkpoint_restore",
            SpanPhase::ControlTick => "control_tick",
            SpanPhase::Eval => "eval",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&p| p == self).unwrap()
    }
}

/// Which timeline a span's duration is meaningful on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Deterministic simulation-time span, emitted on the engine thread
    /// at a commit point. Identical across thread counts.
    Virtual,
    /// Real monotonic wall-time span (engine thread or pool worker).
    Wall,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Virtual => "virtual",
            SpanKind::Wall => "wall",
        }
    }
}

/// One traced interval carrying **dual timestamps**: virtual simulation
/// seconds (`vstart`/`vend`) and monotonic wall microseconds since the
/// plane's epoch (`wstart_us`/`wend_us`). Point events set start == end
/// on the timeline they don't measure.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Span {
    pub phase: SpanPhase,
    pub kind: SpanKind,
    /// Client the span is attributed to, or [`NO_CLIENT`].
    pub client: u32,
    /// Trace lane: 0 = engine thread, `1 + ring` for pool workers.
    pub tid: u32,
    pub vstart: f64,
    pub vend: f64,
    pub wstart_us: f64,
    pub wend_us: f64,
}

impl Span {
    const EMPTY: Span = Span {
        phase: SpanPhase::ClientExecute,
        kind: SpanKind::Wall,
        client: NO_CLIENT,
        tid: 0,
        vstart: 0.0,
        vend: 0.0,
        wstart_us: 0.0,
        wend_us: 0.0,
    };
}

// ---------------------------------------------------------------------------
// Lock-free SPSC span ring (one producer worker, one consumer: the engine)
// ---------------------------------------------------------------------------

/// Bounded single-producer/single-consumer ring of [`Span`]s. Pushes are
/// wait-free and allocation-free; a full ring drops the span and counts
/// it instead of blocking a worker. The engine thread is the only
/// consumer ([`ObsShared::drain_each`]).
pub struct SpanRing {
    slots: Box<[UnsafeCell<Span>]>,
    mask: usize,
    head: AtomicUsize,
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: the head/tail indices partition the slots between exactly one
// producer (writes at `tail`, then releases) and one consumer (acquires
// `tail`, reads up to it, then releases `head`); no slot is ever read
// and written concurrently. Producer exclusivity is enforced by
// `ObsShared::sink`, which assigns each worker thread its own ring.
unsafe impl Sync for SpanRing {}
unsafe impl Send for SpanRing {}

impl SpanRing {
    fn new(capacity: usize) -> Self {
        let cap = capacity.next_power_of_two().max(2);
        let slots: Vec<UnsafeCell<Span>> =
            (0..cap).map(|_| UnsafeCell::new(Span::EMPTY)).collect();
        SpanRing {
            slots: slots.into_boxed_slice(),
            mask: cap - 1,
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Producer side: push one span, dropping (and counting) on overflow.
    pub fn push(&self, span: Span) -> bool {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.mask {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return false;
        }
        // SAFETY: this slot is past `head`, so the consumer will not read
        // it until the tail store below publishes the write.
        unsafe {
            *self.slots[tail & self.mask].get() = span;
        }
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        true
    }

    /// Consumer side: pop everything currently published.
    fn drain(&self, mut f: impl FnMut(Span)) {
        let tail = self.tail.load(Ordering::Acquire);
        let mut head = self.head.load(Ordering::Relaxed);
        while head != tail {
            // SAFETY: slots in [head, tail) were published by the
            // producer's release store and are not rewritten until the
            // head store below frees them.
            let span = unsafe { *self.slots[head & self.mask].get() };
            f(span);
            head = head.wrapping_add(1);
        }
        self.head.store(head, Ordering::Release);
    }

    fn take_dropped(&self) -> u64 {
        self.dropped.swap(0, Ordering::Relaxed)
    }
}

// Each (shared-plane id → ring index) binding a thread has claimed.
// Thread-locals keep sink lookup allocation- and lock-free on the hot
// path; entries are a few bytes per plane a thread ever touched.
thread_local! {
    static SINK_IDS: RefCell<Vec<(u64, usize)>> = const { RefCell::new(Vec::new()) };
}

static NEXT_SHARED_ID: AtomicU64 = AtomicU64::new(0);

/// The cross-thread half of the plane: per-worker span rings plus the
/// shared wall-clock epoch. Pool-worker closures capture an
/// `Arc<ObsShared>` only when obs is armed, so disarmed runs ship no
/// extra captures at all.
pub struct ObsShared {
    id: u64,
    epoch: Instant,
    rings: Vec<SpanRing>,
    next_sink: AtomicUsize,
    /// Spans from threads that arrived after every ring was claimed.
    missed: AtomicU64,
}

impl ObsShared {
    fn new(epoch: Instant, rings: usize, ring_capacity: usize) -> Self {
        ObsShared {
            id: NEXT_SHARED_ID.fetch_add(1, Ordering::Relaxed),
            epoch,
            rings: (0..rings.max(1)).map(|_| SpanRing::new(ring_capacity)).collect(),
            next_sink: AtomicUsize::new(0),
            missed: AtomicU64::new(0),
        }
    }

    /// Monotonic wall microseconds since the plane's epoch.
    pub fn now_us(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e6
    }

    /// The calling thread's private ring (first call claims one). `None`
    /// once more threads than rings have claimed sinks — those threads'
    /// spans are counted as missed rather than corrupting a ring.
    fn sink(&self) -> Option<&SpanRing> {
        let idx = SINK_IDS.with(|ids| {
            let mut ids = ids.borrow_mut();
            if let Some(&(_, i)) = ids.iter().find(|(id, _)| *id == self.id) {
                i
            } else {
                let i = self.next_sink.fetch_add(1, Ordering::Relaxed);
                ids.push((self.id, i));
                i
            }
        });
        self.rings.get(idx)
    }

    /// Producer entry point for worker threads.
    pub fn push(&self, span: Span) {
        match self.sink() {
            Some(ring) => {
                ring.push(span);
            }
            None => {
                self.missed.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Convenience for workers: record a wall span for `phase` that
    /// started at `wstart_us` (from [`ObsShared::now_us`]) and ends now.
    pub fn wall_span(&self, phase: SpanPhase, client: u32, vtime: f64, wstart_us: f64) {
        let wend_us = self.now_us();
        self.push(Span {
            phase,
            kind: SpanKind::Wall,
            client,
            tid: 0, // rewritten to the ring lane at drain time
            vstart: vtime,
            vend: vtime,
            wstart_us,
            wend_us,
        });
    }

    /// Consumer side (engine thread only): pop all published spans,
    /// tagging each with its ring lane.
    fn drain_each(&self, mut f: impl FnMut(Span)) {
        for (lane, ring) in self.rings.iter().enumerate() {
            ring.drain(|mut span| {
                span.tid = 1 + lane as u32;
                f(span);
            });
        }
    }

    fn take_dropped(&self) -> u64 {
        let mut n = self.missed.swap(0, Ordering::Relaxed);
        for ring in &self.rings {
            n += ring.take_dropped();
        }
        n
    }
}

// ---------------------------------------------------------------------------
// Metric registry
// ---------------------------------------------------------------------------

/// Monotone counters with static names. The first nine mirror existing
/// RoundRecord/CSV columns one-to-one (same names, cumulated over the
/// run) so the registry is the auditable ledger behind them — pinned by
/// `tests/obs.rs::registry_totals_match_record_columns`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    Retransmits,
    FramesLost,
    FramesCorrupt,
    DupSuppressed,
    Resyncs,
    Recoveries,
    SpecCommitted,
    SpecReplayed,
    Quarantined,
    LinkCapped,
    Uploads,
    Flushes,
    Checkpoints,
}

impl Counter {
    pub const ALL: [Counter; 13] = [
        Counter::Retransmits,
        Counter::FramesLost,
        Counter::FramesCorrupt,
        Counter::DupSuppressed,
        Counter::Resyncs,
        Counter::Recoveries,
        Counter::SpecCommitted,
        Counter::SpecReplayed,
        Counter::Quarantined,
        Counter::LinkCapped,
        Counter::Uploads,
        Counter::Flushes,
        Counter::Checkpoints,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::Retransmits => "retransmits",
            Counter::FramesLost => "frames_lost",
            Counter::FramesCorrupt => "frames_corrupt",
            Counter::DupSuppressed => "dup_suppressed",
            Counter::Resyncs => "resyncs",
            Counter::Recoveries => "recoveries",
            Counter::SpecCommitted => "spec_committed",
            Counter::SpecReplayed => "spec_replayed",
            Counter::Quarantined => "quarantined",
            Counter::LinkCapped => "link_capped",
            Counter::Uploads => "uploads",
            Counter::Flushes => "flushes",
            Counter::Checkpoints => "checkpoints",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&c| c == self).unwrap()
    }
}

/// Last-write-wins gauges with static names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Gauge {
    /// Mean per-client trust score at the latest flush (NaN = trust off).
    TrustMean,
    /// Model uploads in flight at the latest record cut.
    InFlight,
    /// Simulation event-queue depth at the latest flush.
    QueueDepth,
}

impl Gauge {
    pub const ALL: [Gauge; 3] = [Gauge::TrustMean, Gauge::InFlight, Gauge::QueueDepth];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::TrustMean => "trust_mean",
            Gauge::InFlight => "in_flight",
            Gauge::QueueDepth => "queue_depth",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&g| g == self).unwrap()
    }
}

/// Histogram bucket upper bounds, in seconds (shared by the wall and
/// virtual per-phase histograms; one overflow bucket is appended).
pub const HIST_BOUNDS: [f64; 11] =
    [1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 100.0, 1e3, 1e4];

/// Fixed-bucket duration histogram (bounds: [`HIST_BOUNDS`] + overflow).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Per-bucket (non-cumulative) observation counts.
    pub buckets: [u64; HIST_BOUNDS.len() + 1],
    pub count: u64,
    pub sum: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram { buckets: [0; HIST_BOUNDS.len() + 1], count: 0, sum: 0.0 }
    }
}

impl Histogram {
    pub fn observe(&mut self, seconds: f64) {
        if !seconds.is_finite() || seconds < 0.0 {
            return;
        }
        let mut idx = HIST_BOUNDS.len();
        for (i, &bound) in HIST_BOUNDS.iter().enumerate() {
            if seconds <= bound {
                idx = i;
                break;
            }
        }
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum += seconds;
    }

    fn save(&self, enc: &mut Enc) {
        enc.u64s(&self.buckets);
        enc.u64(self.count);
        enc.f64(self.sum);
    }

    fn load(dec: &mut Dec) -> Result<Self> {
        let raw = dec.u64s()?;
        if raw.len() != HIST_BOUNDS.len() + 1 {
            bail!("obs histogram bucket count {} != {}", raw.len(), HIST_BOUNDS.len() + 1);
        }
        let mut buckets = [0u64; HIST_BOUNDS.len() + 1];
        buckets.copy_from_slice(&raw);
        Ok(Histogram { buckets, count: dec.u64()?, sum: dec.f64()? })
    }
}

/// The unified registry: counters, gauges, and per-phase wall/virtual
/// duration histograms, all with static names and fixed slots (no maps,
/// no per-event allocation).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricRegistry {
    counters: [u64; Counter::ALL.len()],
    gauges: [f64; Gauge::ALL.len()],
    virt_hist: Vec<Histogram>,
    wall_hist: Vec<Histogram>,
}

impl Default for MetricRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl MetricRegistry {
    pub fn new() -> Self {
        MetricRegistry {
            counters: [0; Counter::ALL.len()],
            gauges: [f64::NAN; Gauge::ALL.len()],
            virt_hist: vec![Histogram::default(); SpanPhase::ALL.len()],
            wall_hist: vec![Histogram::default(); SpanPhase::ALL.len()],
        }
    }

    pub fn inc(&mut self, c: Counter) {
        self.counters[c.index()] += 1;
    }

    pub fn add(&mut self, c: Counter, n: u64) {
        self.counters[c.index()] += n;
    }

    pub fn counter(&self, c: Counter) -> u64 {
        self.counters[c.index()]
    }

    pub fn set_gauge(&mut self, g: Gauge, v: f64) {
        self.gauges[g.index()] = v;
    }

    pub fn gauge(&self, g: Gauge) -> f64 {
        self.gauges[g.index()]
    }

    pub fn observe_virtual(&mut self, phase: SpanPhase, seconds: f64) {
        self.virt_hist[phase.index()].observe(seconds);
    }

    pub fn observe_wall(&mut self, phase: SpanPhase, seconds: f64) {
        self.wall_hist[phase.index()].observe(seconds);
    }

    pub fn virt_hist(&self, phase: SpanPhase) -> &Histogram {
        &self.virt_hist[phase.index()]
    }

    pub fn wall_hist(&self, phase: SpanPhase) -> &Histogram {
        &self.wall_hist[phase.index()]
    }

    /// Checkpoint the deterministic half (counters, gauges, virtual
    /// histograms). Wall histograms measure real machine time and are
    /// deliberately reset by a restore.
    pub fn save(&self, enc: &mut Enc) {
        enc.u64s(&self.counters);
        enc.f64s(&self.gauges);
        for h in &self.virt_hist {
            h.save(enc);
        }
    }

    /// Decode a registry written by [`MetricRegistry::save`].
    pub fn load(dec: &mut Dec) -> Result<Self> {
        let raw = dec.u64s()?;
        if raw.len() != Counter::ALL.len() {
            bail!("obs registry counter count {} != {}", raw.len(), Counter::ALL.len());
        }
        let mut counters = [0u64; Counter::ALL.len()];
        counters.copy_from_slice(&raw);
        let raw_g = dec.f64s()?;
        if raw_g.len() != Gauge::ALL.len() {
            bail!("obs registry gauge count {} != {}", raw_g.len(), Gauge::ALL.len());
        }
        let mut gauges = [f64::NAN; Gauge::ALL.len()];
        gauges.copy_from_slice(&raw_g);
        let mut virt_hist = Vec::with_capacity(SpanPhase::ALL.len());
        for _ in SpanPhase::ALL {
            virt_hist.push(Histogram::load(dec)?);
        }
        Ok(MetricRegistry {
            counters,
            gauges,
            virt_hist,
            wall_hist: vec![Histogram::default(); SpanPhase::ALL.len()],
        })
    }
}

// ---------------------------------------------------------------------------
// The plane
// ---------------------------------------------------------------------------

/// Engine-side observability state, owned by the server. All span hooks
/// branch on [`ObsPlane::armed`] first; the registry is always live.
pub struct ObsPlane {
    enabled: bool,
    max_spans: usize,
    /// The unified metric registry (always updated; exported when armed).
    pub registry: MetricRegistry,
    spans: Vec<Span>,
    dropped: u64,
    shared: Option<Arc<ObsShared>>,
    epoch: Instant,
}

impl ObsPlane {
    /// Build the plane. `rings` bounds how many worker threads can trace
    /// concurrently (extra threads drop spans into a counter instead).
    pub fn new(cfg: &ObsConfig, rings: usize) -> Self {
        let epoch = Instant::now();
        let shared = if cfg.enabled {
            Some(Arc::new(ObsShared::new(epoch, rings, cfg.ring_capacity)))
        } else {
            None
        };
        ObsPlane {
            enabled: cfg.enabled,
            max_spans: cfg.max_spans,
            registry: MetricRegistry::new(),
            spans: Vec::new(),
            dropped: 0,
            shared,
            epoch,
        }
    }

    /// Whether span tracing is armed (one branch — the whole cost of a
    /// disarmed hook).
    pub fn armed(&self) -> bool {
        self.enabled
    }

    /// Handle for pool-worker closures (None while disarmed, so disarmed
    /// dispatches capture nothing).
    pub fn shared(&self) -> Option<Arc<ObsShared>> {
        self.shared.clone()
    }

    /// Monotonic wall microseconds since the plane's epoch (0 disarmed).
    pub fn now_us(&self) -> f64 {
        if self.enabled {
            self.epoch.elapsed().as_secs_f64() * 1e6
        } else {
            0.0
        }
    }

    /// Start timestamp for an engine-thread wall span.
    pub fn wall_start(&self) -> f64 {
        self.now_us()
    }

    fn push(&mut self, span: Span) {
        if self.spans.len() >= self.max_spans {
            self.dropped += 1;
        } else {
            self.spans.push(span);
        }
    }

    /// Record a deterministic virtual-time span at an engine-thread
    /// commit point (`vstart`/`vend` in simulation seconds).
    pub fn virt_span(&mut self, phase: SpanPhase, client: u32, vstart: f64, vend: f64) {
        if !self.enabled {
            return;
        }
        self.registry.observe_virtual(phase, (vend - vstart).max(0.0));
        let w = self.now_us();
        self.push(Span {
            phase,
            kind: SpanKind::Virtual,
            client,
            tid: 0,
            vstart,
            vend,
            wstart_us: w,
            wend_us: w,
        });
    }

    /// Record an engine-thread wall span started at `wstart_us` (from
    /// [`ObsPlane::wall_start`]) and ending now, pinned to virtual time
    /// `vtime`.
    pub fn wall_span(&mut self, phase: SpanPhase, client: u32, vtime: f64, wstart_us: f64) {
        if !self.enabled {
            return;
        }
        let wend_us = self.now_us();
        self.registry.observe_wall(phase, ((wend_us - wstart_us) / 1e6).max(0.0));
        self.push(Span {
            phase,
            kind: SpanKind::Wall,
            client,
            tid: 0,
            vstart: vtime,
            vend: vtime,
            wstart_us,
            wend_us,
        });
    }

    /// Drain worker rings into the engine-side span store (commit points
    /// and finalization; engine thread only).
    pub fn drain(&mut self) {
        if !self.enabled {
            return;
        }
        if let Some(shared) = self.shared.clone() {
            shared.drain_each(|span| {
                self.registry
                    .observe_wall(span.phase, ((span.wend_us - span.wstart_us) / 1e6).max(0.0));
                if self.spans.len() >= self.max_spans {
                    self.dropped += 1;
                } else {
                    self.spans.push(span);
                }
            });
            self.dropped += shared.take_dropped();
        }
    }

    /// Spans recorded so far (drained worker spans included).
    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    /// Final drain + snapshot for `RunMetrics::obs`. `None` disarmed, so
    /// disarmed JSON output is byte-identical to pre-obs builds.
    pub fn finalize_report(&mut self) -> Option<ObsReport> {
        if !self.enabled {
            return None;
        }
        self.drain();
        Some(ObsReport {
            spans: self.spans.clone(),
            dropped: self.dropped,
            registry: self.registry.clone(),
        })
    }
}

/// The exported snapshot of an armed run: every retained span plus the
/// final registry state. Carried on `RunMetrics::obs` and consumed by
/// [`chrome_trace_json`] / [`prometheus_text`] / the RunMetrics JSON.
#[derive(Debug, Clone, PartialEq)]
pub struct ObsReport {
    pub spans: Vec<Span>,
    /// Spans lost to ring overflow, the `max_spans` cap, or sink
    /// exhaustion.
    pub dropped: u64,
    pub registry: MetricRegistry,
}

impl ObsReport {
    /// The deterministic virtual-time sub-stream (the thread-count
    /// invariant the obs tests pin).
    pub fn virtual_spans(&self) -> impl Iterator<Item = &Span> {
        self.spans.iter().filter(|s| s.kind == SpanKind::Virtual)
    }
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

/// Chrome trace-event JSON (the object form), loadable in Perfetto /
/// `chrome://tracing`. Virtual spans land on pid 0 with 1 simulated
/// second = 1 trace second; wall spans land on pid 1 at real
/// microseconds since the plane epoch, one tid lane per worker ring.
pub fn chrome_trace_json(report: &ObsReport) -> Value {
    let mut events: Vec<Value> = Vec::with_capacity(report.spans.len() + 4);
    for (pid, name) in [(0u64, "virtual time (sim)"), (1u64, "wall time")] {
        events.push(obj(vec![
            ("name", Value::from("process_name")),
            ("ph", Value::from("M")),
            ("pid", Value::from(pid)),
            ("tid", Value::from(0u64)),
            ("args", obj(vec![("name", Value::from(name))])),
        ]));
    }
    for span in &report.spans {
        let (pid, ts, dur) = match span.kind {
            SpanKind::Virtual => {
                (0u64, span.vstart * 1e6, (span.vend - span.vstart).max(0.0) * 1e6)
            }
            SpanKind::Wall => (1u64, span.wstart_us, (span.wend_us - span.wstart_us).max(0.0)),
        };
        let mut args = vec![
            ("kind", Value::from(span.kind.name())),
            ("vstart", Value::from(span.vstart)),
            ("vend", Value::from(span.vend)),
        ];
        if span.client != NO_CLIENT {
            args.push(("client", Value::from(span.client as u64)));
        }
        events.push(obj(vec![
            ("name", Value::from(span.phase.name())),
            ("cat", Value::from(span.kind.name())),
            ("ph", Value::from("X")),
            ("ts", Value::from(ts)),
            ("dur", Value::from(dur)),
            ("pid", Value::from(pid)),
            ("tid", Value::from(span.tid as u64)),
            ("args", obj(args)),
        ]));
    }
    obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", Value::from("ms")),
        ("otherData", obj(vec![("dropped_spans", Value::from(report.dropped))])),
    ])
}

fn prom_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "NaN".to_string()
    } else if v > 0.0 {
        "+Inf".to_string()
    } else {
        "-Inf".to_string()
    }
}

fn prom_histogram(out: &mut String, metric: &str, phase: &str, h: &Histogram) {
    let mut cum = 0u64;
    for (i, &n) in h.buckets.iter().enumerate() {
        cum += n;
        let le = if i < HIST_BOUNDS.len() {
            prom_f64(HIST_BOUNDS[i])
        } else {
            "+Inf".to_string()
        };
        out.push_str(&format!("{metric}_bucket{{phase=\"{phase}\",le=\"{le}\"}} {cum}\n"));
    }
    out.push_str(&format!("{metric}_sum{{phase=\"{phase}\"}} {}\n", prom_f64(h.sum)));
    out.push_str(&format!("{metric}_count{{phase=\"{phase}\"}} {}\n", h.count));
}

/// Prometheus text exposition format: every counter/gauge plus the
/// non-empty per-phase wall/virtual histograms. This file is the twin of
/// the `/metrics` endpoint the service-mode transport will serve.
pub fn prometheus_text(report: &ObsReport) -> String {
    let mut out = String::new();
    let reg = &report.registry;
    for c in Counter::ALL {
        out.push_str(&format!(
            "# TYPE vafl_{0}_total counter\nvafl_{0}_total {1}\n",
            c.name(),
            reg.counter(c)
        ));
    }
    out.push_str(&format!(
        "# TYPE vafl_dropped_spans_total counter\nvafl_dropped_spans_total {}\n",
        report.dropped
    ));
    for g in Gauge::ALL {
        out.push_str(&format!(
            "# TYPE vafl_{0} gauge\nvafl_{0} {1}\n",
            g.name(),
            prom_f64(reg.gauge(g))
        ));
    }
    for (metric, pick_wall) in
        [("vafl_phase_wall_seconds", true), ("vafl_phase_virtual_seconds", false)]
    {
        let any = SpanPhase::ALL.iter().any(|&p| {
            let h = if pick_wall { reg.wall_hist(p) } else { reg.virt_hist(p) };
            h.count > 0
        });
        if !any {
            continue;
        }
        out.push_str(&format!("# TYPE {metric} histogram\n"));
        for p in SpanPhase::ALL {
            let h = if pick_wall { reg.wall_hist(p) } else { reg.virt_hist(p) };
            if h.count > 0 {
                prom_histogram(&mut out, metric, p.name(), h);
            }
        }
    }
    out
}

fn hist_json(h: &Histogram) -> Value {
    obj(vec![
        ("count", Value::from(h.count)),
        ("sum", Value::from(h.sum)),
        ("buckets", Value::Arr(h.buckets.iter().map(|&n| Value::from(n)).collect())),
    ])
}

/// The `"obs"` block of the RunMetrics JSON: counters, gauges, and the
/// per-phase wall/virtual histograms (phases with observations only).
pub fn report_json(report: &ObsReport) -> Value {
    let reg = &report.registry;
    let counters = obj(
        Counter::ALL.iter().map(|&c| (c.name(), Value::from(reg.counter(c)))).collect(),
    );
    let gauges = obj(
        Gauge::ALL
            .iter()
            .map(|&g| {
                let v = reg.gauge(g);
                (g.name(), if v.is_finite() { Value::from(v) } else { Value::Null })
            })
            .collect(),
    );
    let mut phases: Vec<(&str, Value)> = Vec::new();
    for p in SpanPhase::ALL {
        let (wall, virt) = (reg.wall_hist(p), reg.virt_hist(p));
        if wall.count == 0 && virt.count == 0 {
            continue;
        }
        let mut entry: Vec<(&str, Value)> = Vec::new();
        if wall.count > 0 {
            entry.push(("wall", hist_json(wall)));
        }
        if virt.count > 0 {
            entry.push(("virtual", hist_json(virt)));
        }
        phases.push((p.name(), obj(entry)));
    }
    obj(vec![
        ("spans", Value::from(report.spans.len())),
        ("dropped_spans", Value::from(report.dropped)),
        ("hist_bounds", Value::Arr(HIST_BOUNDS.iter().map(|&b| Value::from(b)).collect())),
        ("counters", counters),
        ("gauges", gauges),
        ("phases", obj(phases)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_cfg() -> ObsConfig {
        ObsConfig { enabled: true, ..Default::default() }
    }

    fn span(phase: SpanPhase, v: f64) -> Span {
        Span {
            phase,
            kind: SpanKind::Wall,
            client: 1,
            tid: 0,
            vstart: v,
            vend: v,
            wstart_us: v,
            wend_us: v + 1.0,
        }
    }

    #[test]
    fn ring_push_drain_fifo_and_overflow() {
        let ring = SpanRing::new(4);
        for i in 0..4 {
            assert!(ring.push(span(SpanPhase::Flush, i as f64)));
        }
        // Full: the 5th push drops and counts.
        assert!(!ring.push(span(SpanPhase::Flush, 99.0)));
        assert_eq!(ring.take_dropped(), 1);
        let mut got = Vec::new();
        ring.drain(|s| got.push(s.vstart));
        assert_eq!(got, vec![0.0, 1.0, 2.0, 3.0]);
        // Space freed: pushes work again.
        assert!(ring.push(span(SpanPhase::Flush, 5.0)));
        let mut got = Vec::new();
        ring.drain(|s| got.push(s.vstart));
        assert_eq!(got, vec![5.0]);
    }

    #[test]
    fn shared_rings_survive_concurrent_producers() {
        let shared = Arc::new(ObsShared::new(Instant::now(), 4, 64));
        std::thread::scope(|scope| {
            for t in 0..4 {
                let shared = Arc::clone(&shared);
                scope.spawn(move || {
                    for i in 0..50 {
                        shared.push(span(SpanPhase::SpecExecute, (t * 100 + i) as f64));
                    }
                });
            }
        });
        let mut n = 0;
        shared.drain_each(|s| {
            assert!(s.tid >= 1 && s.tid <= 4);
            n += 1;
        });
        assert_eq!(n, 200);
        assert_eq!(shared.take_dropped(), 0);
    }

    #[test]
    fn histogram_buckets_and_bounds() {
        let mut h = Histogram::default();
        h.observe(5e-7); // bucket 0 (<= 1e-6)
        h.observe(0.5); // <= 1.0
        h.observe(1e9); // overflow
        h.observe(f64::NAN); // ignored
        h.observe(-1.0); // ignored
        assert_eq!(h.count, 3);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[6], 1);
        assert_eq!(h.buckets[HIST_BOUNDS.len()], 1);
        assert!((h.sum - (5e-7 + 0.5 + 1e9)).abs() < 1.0);
    }

    #[test]
    fn registry_counters_gauges_round_trip() {
        let mut reg = MetricRegistry::new();
        reg.inc(Counter::Retransmits);
        reg.add(Counter::Uploads, 41);
        reg.inc(Counter::Uploads);
        reg.set_gauge(Gauge::TrustMean, 0.75);
        reg.observe_virtual(SpanPhase::Flush, 2.5);
        reg.observe_wall(SpanPhase::Flush, 0.001);
        assert_eq!(reg.counter(Counter::Retransmits), 1);
        assert_eq!(reg.counter(Counter::Uploads), 42);
        assert_eq!(reg.counter(Counter::Resyncs), 0);
        assert_eq!(reg.gauge(Gauge::TrustMean), 0.75);
        assert!(reg.gauge(Gauge::InFlight).is_nan());

        let mut enc = Enc::new();
        reg.save(&mut enc);
        let bytes = enc.into_bytes();
        let mut dec = Dec::new(&bytes);
        let back = MetricRegistry::load(&mut dec).unwrap();
        dec.finish().unwrap();
        assert_eq!(back.counter(Counter::Uploads), 42);
        assert_eq!(back.virt_hist(SpanPhase::Flush).count, 1);
        // Wall histograms are machine time: deliberately reset on load.
        assert_eq!(back.wall_hist(SpanPhase::Flush).count, 0);
    }

    #[test]
    fn disarmed_plane_records_nothing() {
        let mut plane = ObsPlane::new(&ObsConfig::default(), 2);
        assert!(!plane.armed());
        assert!(plane.shared().is_none());
        plane.virt_span(SpanPhase::Flush, NO_CLIENT, 0.0, 1.0);
        let t0 = plane.wall_start();
        plane.wall_span(SpanPhase::Flush, NO_CLIENT, 0.0, t0);
        plane.drain();
        assert!(plane.spans().is_empty());
        assert!(plane.finalize_report().is_none());
        // The registry stays live regardless.
        plane.registry.inc(Counter::Flushes);
        assert_eq!(plane.registry.counter(Counter::Flushes), 1);
    }

    #[test]
    fn armed_plane_caps_spans_and_reports() {
        let cfg = ObsConfig { enabled: true, max_spans: 3, ..Default::default() };
        let mut plane = ObsPlane::new(&cfg, 2);
        for i in 0..5 {
            plane.virt_span(SpanPhase::BufferFill, i, i as f64, i as f64 + 1.0);
        }
        let report = plane.finalize_report().unwrap();
        assert_eq!(report.spans.len(), 3);
        assert_eq!(report.dropped, 2);
        assert_eq!(report.virtual_spans().count(), 3);
        assert_eq!(report.registry.virt_hist(SpanPhase::BufferFill).count, 5);
    }

    #[test]
    fn worker_spans_drain_through_the_plane() {
        let mut plane = ObsPlane::new(&armed_cfg(), 2);
        let shared = plane.shared().unwrap();
        let t0 = shared.now_us();
        shared.wall_span(SpanPhase::SpecExecute, 7, 3.0, t0);
        plane.drain();
        assert_eq!(plane.spans().len(), 1);
        let s = plane.spans()[0];
        assert_eq!(s.phase, SpanPhase::SpecExecute);
        assert_eq!(s.kind, SpanKind::Wall);
        assert_eq!(s.client, 7);
        assert!(s.tid >= 1);
        assert_eq!(plane.registry.wall_hist(SpanPhase::SpecExecute).count, 1);
    }

    #[test]
    fn chrome_trace_is_valid_and_parseable() {
        let mut plane = ObsPlane::new(&armed_cfg(), 2);
        plane.virt_span(SpanPhase::ClientExecute, 2, 1.0, 2.5);
        let t0 = plane.wall_start();
        plane.wall_span(SpanPhase::Flush, NO_CLIENT, 2.5, t0);
        let report = plane.finalize_report().unwrap();
        let trace = chrome_trace_json(&report);
        let parsed = crate::util::json::parse(&trace.to_string_compact()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 metadata + 2 spans.
        assert_eq!(events.len(), 4);
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            assert!(ph == "X" || ph == "M");
            assert!(e.get("name").unwrap().as_str().is_some());
            assert!(e.get("pid").unwrap().as_f64().is_some());
        }
        let x = events.iter().find(|e| e.get("ph").unwrap().as_str() == Some("X")).unwrap();
        assert_eq!(x.get("name").unwrap().as_str(), Some("client_execute"));
        assert_eq!(x.get("ts").unwrap().as_f64(), Some(1.0e6));
        assert_eq!(x.get("dur").unwrap().as_f64(), Some(1.5e6));
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let mut plane = ObsPlane::new(&armed_cfg(), 2);
        plane.registry.add(Counter::Retransmits, 3);
        plane.registry.set_gauge(Gauge::TrustMean, 0.5);
        plane.virt_span(SpanPhase::Flush, NO_CLIENT, 0.0, 2.0);
        let report = plane.finalize_report().unwrap();
        let text = prometheus_text(&report);
        assert!(text.contains("# TYPE vafl_retransmits_total counter\n"));
        assert!(text.contains("vafl_retransmits_total 3\n"));
        assert!(text.contains("vafl_trust_mean 0.5\n"));
        assert!(text.contains("# TYPE vafl_phase_virtual_seconds histogram\n"));
        assert!(text.contains("vafl_phase_virtual_seconds_count{phase=\"flush\"} 1\n"));
        assert!(text.contains("le=\"+Inf\"} 1\n"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let (name, value) = line.rsplit_once(' ').expect("name value");
            assert!(!name.is_empty());
            assert!(
                value.parse::<f64>().is_ok() || value == "NaN" || value.ends_with("Inf"),
                "bad sample value {value:?} in {line:?}"
            );
        }
    }

    #[test]
    fn report_json_has_counters_and_phases() {
        let mut plane = ObsPlane::new(&armed_cfg(), 2);
        plane.registry.add(Counter::Uploads, 9);
        plane.virt_span(SpanPhase::Eval, NO_CLIENT, 1.0, 1.5);
        let report = plane.finalize_report().unwrap();
        let v = report_json(&report);
        assert_eq!(v.get("spans").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("counters").unwrap().get("uploads").unwrap().as_usize(), Some(9));
        let eval = v.get("phases").unwrap().get("eval").unwrap();
        assert_eq!(eval.get("virtual").unwrap().get("count").unwrap().as_usize(), Some(1));
        assert!(eval.get("wall").is_none());
    }
}
